"""Batched serving engine: prefill + decode with a slot-based batch.

A minimal production shape (vLLM-lite): fixed decode batch of ``slots``;
requests occupy slots; each decode step advances every live slot one
token; finished slots are refilled from a queue via prefill.  The decode
step is a single jitted function over the slot batch, so throughput is
MXU-bound and independent of request interleave (continuous batching).

This container is single-device — the engine exercises the same
prefill/decode code paths the dry-run lowers at (16,16)/(2,16,16), so
examples/serve_lm.py demonstrates real batched generation end-to-end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm as lm_lib


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 256
    slots: int = 4
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 32
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, api: lm_lib.ModelAPI, values, scfg: ServeConfig):
        self.api = api
        self.values = values
        self.scfg = scfg
        cfg = api.cfg
        self._decode = jax.jit(api.decode_fn)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        )

    def generate(self, requests: List[Request]) -> List[Request]:
        """Slot-batched generation: prefill each request at its own length,
        then advance all slots together (per-slot position bookkeeping)."""
        scfg = self.scfg
        done: List[Request] = []
        queue = list(requests)
        # process in waves of `slots` equal-prompt-length requests (prefill
        # batches need uniform length; production would bucket — we bucket
        # by exact length here)
        by_len: Dict[int, List[Request]] = {}
        for r in queue:
            by_len.setdefault(len(r.prompt), []).append(r)

        for plen, reqs in sorted(by_len.items()):
            for s in range(0, len(reqs), scfg.slots):
                wave = reqs[s : s + scfg.slots]
                done.extend(self._run_wave(wave, plen))
        return done

    def _run_wave(self, wave: List[Request], plen: int) -> List[Request]:
        scfg = self.scfg
        B = len(wave)
        t0 = time.time()
        prompts = np.stack([r.prompt for r in wave]).astype(np.int32)
        batch = {"tokens": jnp.asarray(prompts)}
        logits, caches = self.api.prefill_fn(
            self.values, batch, max_seq=scfg.max_seq
        )
        key = jax.random.PRNGKey(scfg.seed)
        tok = self._sample(logits[:, -1], key)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        max_new = max(r.max_new for r in wave)
        pos = plen
        for step in range(max_new - 1):
            key, skey = jax.random.split(key)
            logits, caches = self._decode(
                self.values, caches, tok, jnp.asarray(pos, jnp.int32)
            )
            tok = self._sample(logits[:, 0], skey)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
            pos += 1
        gen = np.concatenate(outs, axis=1)
        dt = time.time() - t0
        for i, r in enumerate(wave):
            r.out = gen[i, : r.max_new]
            r.latency_s = dt
        return wave
