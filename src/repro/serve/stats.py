"""Counters and aggregate reporting — the serving pipeline's ledger layer.

Every number the engine exposes lives in one of two places:

  * ``EngineCounters`` — plain integers plus BOUNDED timing ledgers
    accumulated across ``render()`` calls.  Mutated ONLY on the engine
    thread (admission commits and batch collection), so they need no
    lock and stay deterministic at every prefetch depth and worker
    count — the executor determinism tests gate on them.
    ``misprepares`` is the single deliberate exception to cross-config
    determinism: it counts speculation that aged out between Stage A
    and commit, which depends on speculation TIMING (prefetch depth,
    worker scheduling) by design.
  * per-cache ledgers (probe/radiance/scenecache) — owned by the caches
    themselves; ``engine_stats`` only reads them.

Timing ledgers (march_ms, latency_ms, admit_stall_ms) are
``obs.metrics.Series`` ring buffers — a long-running engine holds at
most ``SERIES_CAPACITY`` samples per series instead of an unbounded
list (the pre-obs leak), while p50/p99 keep their semantics over the
recent window.  ``batches_per_round`` is a Counter keyed by batch count
(bounded by the distinct counts seen, i.e. by ``inflight_batches``).

This module owns the invariant arithmetic: probe hits + misses + skips
== admissions, reused fractions, pad fractions, the samples split.
``engine_stats`` publishes every key into an ``obs.metrics.Registry``
when one is passed (the engine's), and the returned dict is then a READ
of that registry — same keys, same values, but also available as
Prometheus exposition and periodic JSONL snapshots.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional

from ..obs import metrics as obs_metrics
from ..obs.metrics import percentile as _percentile  # noqa: F401 (compat)

# ring capacity of the per-engine timing series: enough to hold every
# round of any bench/test run exactly, O(1) for a long-running engine
SERIES_CAPACITY = 4096


def _series():
    return obs_metrics.Series(SERIES_CAPACITY)


@dataclasses.dataclass
class ClassLedger:
    """Per-RequestClass slice of the finalize ledger (scheduler tier):
    frame count, shed/deadline accounting, and a bounded latency series
    so ``engine_stats`` reports p50/p99 PER CLASS — the number the SLO
    bench gates (a deadline class's tail must not hide in the global
    percentile next to bulk traffic)."""
    frames: int = 0
    shed: int = 0                 # frames served at a degraded tier
    deadline_misses: int = 0
    latency_ms: obs_metrics.Series = dataclasses.field(
        default_factory=_series)

    def stats(self) -> Dict:
        return {"frames": self.frames, "shed": self.shed,
                "deadline_misses": self.deadline_misses,
                "latency_ms_p50": self.latency_ms.percentile(50.0),
                "latency_ms_p99": self.latency_ms.percentile(99.0)}


@dataclasses.dataclass
class EngineCounters:
    """Engine-thread-only counters, accumulated across render() calls."""
    frames: int = 0
    batches: int = 0
    blocks_marched: int = 0
    pad_blocks: int = 0
    rays_marched: int = 0
    rays_total: int = 0
    scene_blocks_hit: int = 0
    admissions: int = 0
    full_radiance_hits: int = 0   # admissions that skipped Phase I
    misprepares: int = 0          # speculated Stage-A work discarded
    # request-lifecycle scheduler accounting (serve/scheduler.py).  Like
    # misprepares, all four depend on admission-stall TIMING under a
    # shedding policy and are deliberately NOT in DETERMINISTIC_COUNTERS
    # (FIFO keeps them at zero).  Invariant the property tests gate:
    # requests_shed + requests_full == frames — shedding degrades, it
    # never drops.
    shed_degrades: int = 0        # tier steps the scheduler applied
    shed_reprepares: int = 0      # speculation redone after a degrade
    requests_shed: int = 0        # frames served at a degraded tier
    requests_full: int = 0        # frames served at their class tier
    deadline_misses: int = 0
    samples_processed: int = 0
    samples_reused: int = 0
    # sample work the fused march's per-RAY early exit skipped (pool
    # collect, gated on ASDRConfig.per_ray_early_exit): rays whose
    # transmittance saturated before their block's exit chunk stop
    # running the field, chunk-granular.  Stays 0 with the flag off, so
    # it is deliberately NOT in DETERMINISTIC_COUNTERS — it prices an
    # opt-in approximation tier, like the shed counters.
    ray_exit_samples_skipped: int = 0
    # per-round streaming-dispatch observability (engine thread only):
    # wall time of each dispatch_round->collect window and how many
    # batches it launched.  Wall times are TIMING, not scheduling — they
    # are reported as percentiles, never gated for determinism.  Bounded:
    # a Series ring (recent window) and a Counter histogram.
    march_ms: obs_metrics.Series = dataclasses.field(default_factory=_series)
    batches_per_round: Counter = dataclasses.field(default_factory=Counter)
    # per-request end-to-end ledgers, fed at finalize: first-class
    # latency stats instead of every bench re-aggregating RenderRequest
    # fields by hand
    latency_ms: obs_metrics.Series = dataclasses.field(
        default_factory=_series)
    admit_stall_ms: obs_metrics.Series = dataclasses.field(
        default_factory=_series)
    # per-RequestClass slices of the same ledger, keyed by class name
    by_class: Dict[str, ClassLedger] = dataclasses.field(
        default_factory=dict)

    def note_finalized(self, req_stats: Dict, latency_s: float = 0.0):
        """Fold one finalized request's per-frame stats into the ledger."""
        self.frames += 1
        self.rays_marched += req_stats["rays_marched"]
        self.rays_total += req_stats["rays_total"]
        self.samples_processed += req_stats["samples_processed"]
        self.samples_reused += req_stats["samples_reused"]
        self.latency_ms.observe(latency_s * 1e3)
        self.admit_stall_ms.observe(req_stats["admit_stall_s"] * 1e3)
        # scheduler accounting: every frame is either full-tier or shed
        shed = req_stats.get("degrades", 0) > 0
        missed = not req_stats.get("deadline_met", True)
        self.requests_shed += shed
        self.requests_full += not shed
        self.deadline_misses += missed
        led = self.by_class.setdefault(req_stats.get("class", "default"),
                                       ClassLedger())
        led.frames += 1
        led.shed += shed
        led.deadline_misses += missed
        led.latency_ms.observe(latency_s * 1e3)

    def note_round(self, wall_s: float, n_batches: int):
        """Record one dispatch_round->collect window."""
        self.march_ms.observe(wall_s * 1e3)
        self.batches_per_round[n_batches] += 1


COUNTER_FIELDS = frozenset(f.name for f in
                           dataclasses.fields(EngineCounters))

# engine_stats() keys that must be identical across executors at any
# worker count / prefetch depth: everything decided at commit time
# (engine thread, admission order).  ``misprepares`` is deliberately
# absent — it counts speculation that aged out between Stage A and
# commit, which depends on speculation timing by design.  The executor
# determinism tests and the --workers benchmark gate both consume this.
# Tracing on/off must never change any of these either
# (tests/test_obs.py).
DETERMINISTIC_COUNTERS = (
    "frames", "admissions", "probe_hits", "probe_misses", "probe_skips",
    "probe_refreshes", "full_radiance_hits", "radiance_hits",
    "radiance_misses", "rays_marched", "rays_total", "samples_processed",
    "samples_reused", "blocks_marched")


def engine_stats(counters: EngineCounters, probe_caches: Dict,
                 radiance_caches: Dict, scenecache,
                 registry: Optional[obs_metrics.Registry] = None) -> Dict:
    """The engine's aggregate stats dict (the public ``engine_stats()``).

    With a registry, every key is published as a gauge and the returned
    dict is a read-back of those gauges — ``engine_stats()`` IS a
    registry view, and the same numbers flow to the Prometheus text
    exposition and the periodic JSONL snapshots.
    """
    c = counters
    out = {
        "frames": c.frames,
        "batches": c.batches,
        "blocks_marched": c.blocks_marched,
        "pad_block_fraction": (
            c.pad_blocks / max(c.blocks_marched + c.pad_blocks, 1)),
        "rays_marched": c.rays_marched,
        "rays_total": c.rays_total,
        "rays_marched_fraction": c.rays_marched / max(c.rays_total, 1),
        "admissions": c.admissions,
        "full_radiance_hits": c.full_radiance_hits,
        "misprepares": c.misprepares,
        # scheduler tier (serve/scheduler.py): shed/degrade accounting —
        # shed + full == frames (degrade, never drop) — plus per-class
        # frame/latency slices so a deadline class's p99 is gateable
        # next to bulk traffic
        "shed_degrades": c.shed_degrades,
        "shed_reprepares": c.shed_reprepares,
        "requests_shed": c.requests_shed,
        "requests_full": c.requests_full,
        "deadline_misses": c.deadline_misses,
        "class_stats": {name: led.stats()
                        for name, led in sorted(c.by_class.items())},
        "samples_processed": c.samples_processed,
        "samples_reused": c.samples_reused,
        "ray_exit_samples_skipped": c.ray_exit_samples_skipped,
        # streaming-dispatch round observability: march wall-time
        # percentiles + how many batches each round launched (a
        # histogram {n_batches: rounds}); batches_per_round > 1 is the
        # signal that multi-batch rounds actually fill idle launches
        "march_ms_p50": c.march_ms.percentile(50.0),
        "march_ms_p99": c.march_ms.percentile(99.0),
        "march_rounds": c.march_ms.count,
        "batches_per_round": dict(sorted(c.batches_per_round.items())),
        # first-class per-request latency: end-to-end (queue wait +
        # admission + march) and the blocking admission stall, both in
        # ms from the bounded series the finalize path feeds
        "latency_ms_p50": c.latency_ms.percentile(50.0),
        "latency_ms_p99": c.latency_ms.percentile(99.0),
        "admit_stall_ms_p50": c.admit_stall_ms.percentile(50.0),
        "admit_stall_ms_p99": c.admit_stall_ms.percentile(99.0),
    }
    hits = sum(pc.hits for pc in probe_caches.values())
    misses = sum(pc.misses for pc in probe_caches.values())
    skips = sum(pc.skips for pc in probe_caches.values())
    out["probe_hits"] = hits
    out["probe_misses"] = misses
    # skips are admissions that never needed Phase I (full radiance
    # hit) — they paid zero probe samples, so the reuse fraction
    # counts them with the hits; with probe reuse ENABLED,
    # probes + skips == admissions holds as misses + hits + skips ==
    # admissions (every admission either probed [miss/refresh],
    # reused maps [hit], or skipped).  The ledger is the probe
    # caches' own: with reuse=None nothing is booked and the
    # fraction reads 0.0, not a fake 1.0 (full_radiance_hits still
    # counts engine-wide skips in that config).
    out["probe_skips"] = skips
    out["reused_probe_fraction"] = (
        (hits + skips) / max(hits + misses + skips, 1))
    out["probe_refreshes"] = sum(
        pc.refreshes for pc in probe_caches.values())
    r_hits = sum(rc.hits for rc in radiance_caches.values())
    r_miss = sum(rc.misses for rc in radiance_caches.values())
    out["radiance_hits"] = r_hits
    out["radiance_misses"] = r_miss
    out["reused_radiance_fraction"] = r_hits / max(r_hits + r_miss, 1)
    # scene-space block tier: hit rate over blocks that needed output
    # (delivered from the shared store vs actually marched; pad blocks
    # excluded from both sides)
    out["scene_block_hits"] = c.scene_blocks_hit
    out["scene_block_hit_rate"] = c.scene_blocks_hit / max(
        c.scene_blocks_hit + c.blocks_marched, 1)
    if scenecache is not None:
        out["scenecache"] = scenecache.stats()
    # weight-pack memoization ledger (kernels.ops.packed_weights): a
    # process-wide LRU shared by every engine — hits here are re-laid-out
    # weight stacks AVOIDED on engine restarts / multi-scene hot-swap.
    # Lazy import: serve/ stays importable without the kernels package
    # loaded (pure-field engines never touch it).
    try:
        from ..kernels import ops as _kops
        pstats = _kops.pack_cache_stats()
    except ImportError:  # pragma: no cover — kernels always ship here
        pstats = {"hits": 0, "misses": 0, "size": 0}
    out["pack_cache_hits"] = pstats["hits"]
    out["pack_cache_misses"] = pstats["misses"]
    out["pack_cache_size"] = pstats["size"]
    if registry is not None:
        for k, v in out.items():
            registry.set_value(k, v)
        return {k: registry.get(k).read() for k in out}
    return out
