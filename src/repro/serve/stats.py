"""Counters and aggregate reporting — the serving pipeline's ledger layer.

Every number the engine exposes lives in one of two places:

  * ``EngineCounters`` — plain integers accumulated across ``render()``
    calls.  Mutated ONLY on the engine thread (admission commits and
    batch collection), so they need no lock and stay deterministic at
    every prefetch depth and worker count — the executor determinism
    tests gate on them.  ``misprepares`` is the single deliberate
    exception to cross-config determinism: it counts speculation that
    aged out between Stage A and commit, which depends on speculation
    TIMING (prefetch depth, worker scheduling) by design.
  * per-cache ledgers (probe/radiance/scenecache) — owned by the caches
    themselves; ``engine_stats`` only reads them.

This module owns the invariant arithmetic: probe hits + misses + skips
== admissions, reused fractions, pad fractions, the samples split.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List


@dataclasses.dataclass
class EngineCounters:
    """Engine-thread-only counters, accumulated across render() calls."""
    frames: int = 0
    batches: int = 0
    blocks_marched: int = 0
    pad_blocks: int = 0
    rays_marched: int = 0
    rays_total: int = 0
    scene_blocks_hit: int = 0
    admissions: int = 0
    full_radiance_hits: int = 0   # admissions that skipped Phase I
    misprepares: int = 0          # speculated Stage-A work discarded
    samples_processed: int = 0
    samples_reused: int = 0
    # per-round streaming-dispatch observability (engine thread only):
    # wall time of each dispatch_round->collect window and how many
    # batches it launched.  Wall times are TIMING, not scheduling — they
    # are reported as percentiles, never gated for determinism.
    march_ms: List[float] = dataclasses.field(default_factory=list)
    batches_per_round: List[int] = dataclasses.field(default_factory=list)

    def note_finalized(self, req_stats: Dict):
        """Fold one finalized request's per-frame stats into the ledger."""
        self.frames += 1
        self.rays_marched += req_stats["rays_marched"]
        self.rays_total += req_stats["rays_total"]
        self.samples_processed += req_stats["samples_processed"]
        self.samples_reused += req_stats["samples_reused"]


COUNTER_FIELDS = frozenset(f.name for f in
                           dataclasses.fields(EngineCounters))

# engine_stats() keys that must be identical across executors at any
# worker count / prefetch depth: everything decided at commit time
# (engine thread, admission order).  ``misprepares`` is deliberately
# absent — it counts speculation that aged out between Stage A and
# commit, which depends on speculation timing by design.  The executor
# determinism tests and the --workers benchmark gate both consume this.
DETERMINISTIC_COUNTERS = (
    "frames", "admissions", "probe_hits", "probe_misses", "probe_skips",
    "probe_refreshes", "full_radiance_hits", "radiance_hits",
    "radiance_misses", "rays_marched", "rays_total", "samples_processed",
    "samples_reused", "blocks_marched")


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (matches the benches' convention); 0.0 on
    an empty series so stats stay JSON-clean before any round ran."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return float(s[min(int(len(s) * q / 100.0), len(s) - 1)])


def engine_stats(counters: EngineCounters, probe_caches: Dict,
                 radiance_caches: Dict, scenecache) -> Dict:
    """The engine's aggregate stats dict (the public ``engine_stats()``)."""
    c = counters
    out = {
        "frames": c.frames,
        "batches": c.batches,
        "blocks_marched": c.blocks_marched,
        "pad_block_fraction": (
            c.pad_blocks / max(c.blocks_marched + c.pad_blocks, 1)),
        "rays_marched": c.rays_marched,
        "rays_total": c.rays_total,
        "rays_marched_fraction": c.rays_marched / max(c.rays_total, 1),
        "admissions": c.admissions,
        "full_radiance_hits": c.full_radiance_hits,
        "misprepares": c.misprepares,
        "samples_processed": c.samples_processed,
        "samples_reused": c.samples_reused,
        # streaming-dispatch round observability: march wall-time
        # percentiles + how many batches each round launched (a
        # histogram {n_batches: rounds}); batches_per_round > 1 is the
        # signal that multi-batch rounds actually fill idle launches
        "march_ms_p50": _percentile(c.march_ms, 50.0),
        "march_ms_p99": _percentile(c.march_ms, 99.0),
        "march_rounds": len(c.march_ms),
        "batches_per_round": dict(sorted(
            Counter(c.batches_per_round).items())),
    }
    hits = sum(pc.hits for pc in probe_caches.values())
    misses = sum(pc.misses for pc in probe_caches.values())
    skips = sum(pc.skips for pc in probe_caches.values())
    out["probe_hits"] = hits
    out["probe_misses"] = misses
    # skips are admissions that never needed Phase I (full radiance
    # hit) — they paid zero probe samples, so the reuse fraction
    # counts them with the hits; with probe reuse ENABLED,
    # probes + skips == admissions holds as misses + hits + skips ==
    # admissions (every admission either probed [miss/refresh],
    # reused maps [hit], or skipped).  The ledger is the probe
    # caches' own: with reuse=None nothing is booked and the
    # fraction reads 0.0, not a fake 1.0 (full_radiance_hits still
    # counts engine-wide skips in that config).
    out["probe_skips"] = skips
    out["reused_probe_fraction"] = (
        (hits + skips) / max(hits + misses + skips, 1))
    out["probe_refreshes"] = sum(
        pc.refreshes for pc in probe_caches.values())
    r_hits = sum(rc.hits for rc in radiance_caches.values())
    r_miss = sum(rc.misses for rc in radiance_caches.values())
    out["radiance_hits"] = r_hits
    out["radiance_misses"] = r_miss
    out["reused_radiance_fraction"] = r_hits / max(r_hits + r_miss, 1)
    # scene-space block tier: hit rate over blocks that needed output
    # (delivered from the shared store vs actually marched; pad blocks
    # excluded from both sides)
    out["scene_block_hits"] = c.scene_blocks_hit
    out["scene_block_hit_rate"] = c.scene_blocks_hit / max(
        c.scene_blocks_hit + c.blocks_marched, 1)
    if scenecache is not None:
        out["scenecache"] = scenecache.stats()
    return out
