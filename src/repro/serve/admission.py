"""Admission layer: Stage-A speculation and the Stage-B commit.

Admission is a two-stage, radiance-first pipeline:

  Stage A (``prepare``) — PURE speculation, runnable ahead of need on
    ANY thread (see executor.py) while the dispatched march is in
    flight: radiance plan first (warp included), probe plan + its device
    execution only on a non-full hit, and the slot's padded/budget-sorted
    block layout (``pool.build_layout``) — the pad/sort that used to run
    inside the commit.  No cache mutates.
  Stage B (``admit``) — the scheduling round consumes a slot, engine
    thread only: every plan is revalidated against the CURRENT cache
    state, stale speculation is re-executed (counted in ``misprepares``,
    still pre-commit), and then the commit section applies ALL cache
    bookkeeping — so admission decisions, rendered frames, and the
    deterministic counters are bit-identical at every prefetch depth and
    worker count.

The commit section performs NO device-shape work (no pad/sort, no warp,
no probe): everything it consumes was produced by Stage-A code paths.
``commit_active()`` exposes that window for test instrumentation.

Ordering is radiance-FIRST: the radiance lookup runs before Phase I, so
a full warp hit (zero disoccluded rays) never pays the probe it would
immediately discard — the skip is booked explicitly via
``ProbeCache.note_skip`` so reuse fractions and staleness bounds stay
coherent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import numpy as np

from ..core import scene
from ..core.pipeline import ASDRConfig
from ..framecache import probe as fc_probe
from ..framecache import radiance as fc_radiance
from ..framecache.probe import ProbeMaps, ProbeReuseConfig
from ..framecache.radiance import RadianceReuseConfig
from ..obs import trace as trace_lib
from ..obs.trace import TraceConfig
from ..scenecache import SceneCacheConfig
from . import executor as executor_lib
from . import pool as pool_lib
from . import scheduler as scheduler_lib
from .scheduler import DEFAULT_CLASS, RequestClass  # noqa: F401 (surface)


@dataclasses.dataclass(frozen=True)
class RenderServeConfig:
    slots: int = 4
    blocks_per_batch: int = 16
    reuse: Optional[ProbeReuseConfig] = ProbeReuseConfig()
    # warped-radiance reuse is opt-in: None keeps the engine bit-identical
    # to the single-image pipeline (the identity tests rely on this)
    radiance: Optional[RadianceReuseConfig] = None
    # scene-space block reuse (repro.scenecache) is likewise opt-in: None
    # leaves the pooled-march path untouched.  An explicit SceneBlockCache
    # instance passed to the engine constructor overrides this config —
    # that is how several engines over one scene share a single store.
    scenecache: Optional[SceneCacheConfig] = None
    probe_seed: Optional[int] = None   # None = deterministic midpoint probe
    # Stage-A lookahead: up to this many QUEUED requests have their
    # radiance lookup + probe + layout speculated each round while the
    # dispatched march is still in flight (0 = fully synchronous
    # admission).  All cache bookkeeping commits at admission regardless,
    # so rendered frames and counters are bit-identical at every prefetch
    # depth — speculation only moves the device work earlier.
    prefetch: int = 2
    # Stage-A executor worker threads: 0 = synchronous executor (inline
    # speculation on the engine thread, the bit-identical default); n > 0
    # runs prepare() on n worker threads so probe/warp DEVICE time
    # overlaps march device time.  Commits stay on the engine thread in
    # admission order at any worker count.
    workers: int = 0
    # Multi-device Stage-A placement (the fleet tier): n > 0 places
    # speculation on up to n SECONDARY jax devices (jax.devices()[1:],
    # round-robin per slot) while the pooled march owns device 0.
    # Takes precedence over ``workers``; degrades to the synchronous
    # executor on a single-device host (executor.make_executor).  Frames
    # and deterministic counters stay bit-identical at any device count
    # (tests/test_fleet.py).
    devices: int = 0
    # Streaming dispatch: up to this many batches launched per scheduling
    # round (pool.dispatch_round) — when the largest-budget scene group
    # runs dry, the next group's blocks fill the remaining launches, and
    # all launches are in flight before any is collected (the double
    # buffer).  1 = the classic one-batch round, bit-identical to every
    # prior config.
    inflight_batches: int = 1
    # Opt-in density-only refresh marches: a PARTIAL radiance hit also
    # marches its warp-valid rays through the color-free march (the
    # fused kernel skips the color chain), recovering exact acc/depth so
    # the warped frame re-enters the radiance cache instead of being
    # a reuse dead-end.  Off by default: refreshed frames keep their
    # warped rgb, so enabling this trades a bounded quality drift
    # (min_valid_fraction / refresh_every still apply) for reuse reach.
    density_refresh: bool = False
    # Request-lifecycle scheduling policy (serve/scheduler.py): None or
    # "fifo" = arrived requests in queue order, bit-identical to the
    # pre-scheduler engine; "edf" drains slots earliest-deadline-first;
    # "shed" additionally degrades a request's sample-budget tier
    # (never below its class's shed floor) when the admission stall it
    # absorbed ate its deadline slack.  Also accepts a policy instance.
    policy: Optional[object] = None
    # Observability (repro.obs): None = tracing fully off — every
    # instrumented call site takes the null-span fast path, and frames +
    # deterministic counters are bit-identical either way (spans only
    # read ids/clocks, never steer scheduling; tests/test_obs.py gates
    # this across executors x prefetch depths).  A TraceConfig names the
    # export paths, flight-recorder mode, and metrics snapshot cadence.
    trace: Optional[TraceConfig] = None


@dataclasses.dataclass
class RenderRequest:
    rid: int
    scene: str                         # key into the engine's field table
    cam: scene.Camera
    image: Optional[np.ndarray] = None   # (H, W, 3) on completion
    stats: Dict = dataclasses.field(default_factory=dict)
    latency_s: float = 0.0
    # request-lifecycle contract (serve/scheduler.py): the SLO class,
    # the open-loop arrival offset (seconds after render() entry; 0 =
    # closed loop, already arrived — the latency clock starts at
    # arrival, so queue wait is measured from when the client showed
    # up, not from batch submission), and the MUTABLE budget tier the
    # scheduler may degrade (``degrades`` counts the steps taken).
    cls: RequestClass = DEFAULT_CLASS
    arrival_s: float = 0.0
    tier: int = -1                     # -1: start at cls.tier
    degrades: int = 0

    def __post_init__(self):
        if self.tier < 0:
            self.tier = self.cls.tier


def _radiance_token(rplan) -> tuple:
    """The radiance-side fingerprint a speculated layout depends on: a
    hit's basis pins the exact warped arrays (march_idx/base_rgb), any
    miss marches every ray regardless of reason."""
    if rplan is None:
        return ("none",)
    return ("hit", rplan.basis) if rplan.kind == "hit" else ("miss",)


@dataclasses.dataclass
class Prepared:
    """Stage-A speculation for one queued request: pure plans plus their
    executed device work and block layout, awaiting admission commit."""
    req: RenderRequest
    rplan: Optional["fc_radiance.RadiancePlan"]
    pplan: Optional["fc_probe.ProbePlan"]
    maps: Optional[ProbeMaps]
    layout: pool_lib.BlockLayout
    r_token: tuple
    prep_s: float
    dens_layout: Optional[pool_lib.BlockLayout] = None
    # budget tier the layout was built at: admission re-prepares when
    # the scheduler degraded the request after this speculation ran
    tier: int = 0

    def block_until_ready(self):
        """Wait for the speculated device buffers (threaded executors
        call this on the WORKER, so probe/warp device time is done before
        the engine thread ever looks)."""
        m, rays = self.maps, self.layout.rays
        executor_lib.block_until_ready(
            rays[0], rays[1],
            *((m.counts, m.opacity, m.depth) if m is not None else ()))


# Engine-thread-only depth counter marking the Stage-B commit section —
# pool.build_layout and the framecache execute stages must never run
# inside it (tests/test_executor.py instruments this).
_commit_depth = 0


def commit_active() -> bool:
    return _commit_depth > 0


def prepare(engine, req: RenderRequest) -> Prepared:
    """Stage A: speculate the admission's device work — radiance warp,
    probe/warp maps, and the padded/sorted block layout — without
    touching any cache.  Pure, thread-safe (plans snapshot entry state
    under the cache locks), dispatchable while live requests march."""
    t0 = time.time()
    acfg: ASDRConfig = engine.acfg
    with trace_lib.span("stage_a.prepare", req=req.rid, scene=req.scene):
        rad = engine.radiance_caches.get(req.scene)
        rplan = (fc_radiance.plan_lookup(rad, req.cam, acfg)
                 if rad is not None else None)
        pplan = maps = None
        if rplan is None or not rplan.full_hit:
            cache = engine.probe_caches.get(req.scene)
            pplan = fc_probe.plan_probe(cache, req.cam, acfg)
            maps = fc_probe.execute_probe_plan(
                engine.fields[req.scene], acfg, req.cam, pplan,
                engine._probe_key(req),
                rcfg=cache.rcfg if cache is not None else None)
        warped = rplan.warped if (rplan is not None
                                  and rplan.kind == "hit") else None
        tier = req.tier
        scale = scheduler_lib.budget_scale_for(req)
        with trace_lib.span("stage_a.layout", req=req.rid, tier=tier):
            layout = pool_lib.build_layout(acfg, req.cam, maps, warped,
                                           budget_scale=scale)
            dens_layout = None
            if (engine.rcfg.density_refresh and warped is not None
                    and maps is not None):
                dens_layout = pool_lib.build_density_layout(
                    acfg, req.cam, maps, warped, budget_scale=scale)
    return Prepared(req, rplan, pplan, maps, layout,
                    _radiance_token(rplan), time.time() - t0, dens_layout,
                    tier=tier)


def admit(engine, req: RenderRequest, prepared: Prepared,
          t_enqueue: Optional[float] = None) -> "Slot":
    """Stage B: revalidate the speculation against current cache state,
    re-executing stale pieces, then commit.  Engine thread only."""
    with trace_lib.span("stage_b.admit", req=req.rid, scene=req.scene):
        return _admit(engine, req, prepared, t_enqueue)


def _admit(engine, req: RenderRequest, prepared: Prepared,
           t_enqueue: Optional[float]) -> "Slot":
    global _commit_depth
    acfg: ASDRConfig = engine.acfg
    counters = engine.counters

    # ---- tier revalidation: the scheduler degraded this request AFTER
    # its speculation ran.  Probe maps and radiance plans are
    # tier-INDEPENDENT (the tier only scales the layout's budgets), so
    # the plans below revalidate normally and only the layout is
    # rebuilt — at the current tier, via the Stage-A code path, still
    # pre-commit.  ``shed_reprepares`` counts the discarded layouts.
    tier_stale = prepared.tier != req.tier
    if tier_stale:
        counters.shed_reprepares += 1

    # ---- revalidation: pure re-plans; stale speculation re-executes
    # here via Stage-A code paths, BEFORE the commit section
    rad = engine.radiance_caches.get(req.scene)
    rplan = None
    if rad is not None:
        sp = prepared.rplan
        rplan = fc_radiance.plan_lookup(rad, req.cam, acfg, prepared=sp)
        if (sp is not None and sp.warped is not None
                and sp.basis != rplan.basis):
            # the speculated warp's source entry changed (rebase /
            # eviction) between Stage A and admission — re-warped
            counters.misprepares += 1
    # what commit_lookup will return: the plan's warp on a hit, None on
    # any miss — needed pre-commit for the layout decision
    warped = rplan.warped if (rplan is not None
                              and rplan.kind == "hit") else None
    probe_skipped = warped is not None and warped.full_hit
    cache = engine.probe_caches.get(req.scene)
    if probe_skipped:
        if prepared.maps is not None:
            # speculated a probe for a frame that turned out fully
            # warp-served (its source finished after Stage A ran)
            counters.misprepares += 1
        pplan = maps = None
    else:
        pplan = fc_probe.plan_probe(cache, req.cam, acfg)
        if (prepared.pplan is not None
                and prepared.pplan.basis == pplan.basis):
            maps = prepared.maps
        else:
            counters.misprepares += 1
            maps = fc_probe.execute_probe_plan(
                engine.fields[req.scene], acfg, req.cam, pplan,
                engine._probe_key(req),
                rcfg=cache.rcfg if cache is not None else None)
    # layout revalidation: reusable iff the maps are the speculated ones
    # AND the radiance side resolved to the same warp (same march_idx)
    # AND the budget tier didn't degrade since the layout was built
    if (maps is prepared.maps and not tier_stale
            and _radiance_token(rplan) == prepared.r_token):
        layout = prepared.layout
        dens_layout = prepared.dens_layout
    else:
        layout = pool_lib.build_layout(
            acfg, req.cam, maps, warped,
            budget_scale=scheduler_lib.budget_scale_for(req))
        dens_layout = None
    if (engine.rcfg.density_refresh and dens_layout is None
            and warped is not None and maps is not None):
        dens_layout = pool_lib.build_density_layout(
            acfg, req.cam, maps, warped,
            budget_scale=scheduler_lib.budget_scale_for(req))

    # ---- commit section: cache bookkeeping ONLY — no device-shape work
    _commit_depth += 1
    try:
        with trace_lib.span("commit", req=req.rid, scene=req.scene):
            counters.admissions += 1
            if rad is not None:
                fc_radiance.commit_lookup(rad, rplan)
            reused = False
            if probe_skipped:
                if cache is not None:
                    cache.note_skip()
                counters.full_radiance_hits += 1
            else:
                reused = fc_probe.commit_probe_plan(cache, req.cam, acfg,
                                                    pplan, maps)
            slot = Slot(req, layout, maps, reused, acfg.block_size,
                        probe_skipped=probe_skipped, t_enqueue=t_enqueue,
                        dens_layout=dens_layout)
    finally:
        _commit_depth -= 1
    return slot


class Slot:
    """A live request: its block layout and result buffers.

    With radiance reuse, ``layout.march_idx`` selects the disoccluded
    rays the slot actually marches (None = all rays) and
    ``layout.base_rgb`` holds the warped cached frame the marched rays
    composite over.
    """

    def __init__(self, req: RenderRequest, layout: pool_lib.BlockLayout,
                 maps: Optional[ProbeMaps], reused: bool, block_size: int,
                 probe_skipped: bool = False,
                 t_enqueue: Optional[float] = None,
                 dens_layout: Optional[pool_lib.BlockLayout] = None):
        self.req = req
        self.layout = layout
        self.rays = layout.rays          # padded (origins, dirs)
        self.order = layout.order
        self.budgets = layout.budgets
        self.pad = layout.pad
        self.maps = maps                 # None on a full radiance hit
        self.reused = reused
        self.probe_skipped = probe_skipped
        self.block_size = block_size
        self.march_idx = layout.march_idx
        self.base_rgb = layout.base_rgb
        self.warp_valid_fraction = layout.valid_fraction
        n_blocks = layout.budgets.shape[0]
        self.rgb = np.zeros((n_blocks, block_size, 3), np.float32)
        self.acc = np.zeros((n_blocks, block_size), np.float32)
        self.depth = np.zeros((n_blocks, block_size), np.float32)
        self.chunks = np.zeros((n_blocks,), np.int64)
        self.cached_blocks = 0        # delivered from the scene store
        self.cached_chunks = 0
        # density-only refresh (opt-in): a second block layout over the
        # warp-VALID rays whose acc/depth a color-free march recovers
        self.dens_layout = dens_layout
        n_dens = 0
        if dens_layout is not None:
            n_dens = dens_layout.budgets.shape[0]
            self.dens_acc = np.zeros((n_dens, block_size), np.float32)
            self.dens_depth = np.zeros((n_dens, block_size), np.float32)
            self.dens_chunks = np.zeros((n_dens,), np.int64)
        self.pending = n_blocks + n_dens
        # latency clock starts at ENQUEUE (render() entry), not slot
        # construction — latency_s must cover queue wait + admission
        # (probe/warp) + march end-to-end under the double-buffered path
        self.t0 = time.time() if t_enqueue is None else t_enqueue
        self.admission_s = 0.0        # total Stage-A + Stage-B work time
        self.admit_stall_s = 0.0      # blocking admission time (Stage B
        #                               + any inline/awaited Stage A)

    def emit_blocks(self, origins, dirs):
        """(slot, block_index, o (B,3), d (B,3), budget) work items."""
        B = self.block_size
        o_s = origins[self.order].reshape(-1, B, 3)
        d_s = dirs[self.order].reshape(-1, B, 3)
        for bi in range(self.budgets.shape[0]):
            yield (self, bi, o_s[bi], d_s[bi], int(self.budgets[bi]))

    def emit_density_blocks(self):
        """Density-refresh work items, same shape as ``emit_blocks`` —
        the pool tags them so ``collect`` routes to deliver_density."""
        if self.dens_layout is None:
            return
        lay = self.dens_layout
        B = self.block_size
        o_s = lay.rays[0][lay.order].reshape(-1, B, 3)
        d_s = lay.rays[1][lay.order].reshape(-1, B, 3)
        for bi in range(lay.budgets.shape[0]):
            yield (self, bi, o_s[bi], d_s[bi], int(lay.budgets[bi]))

    def deliver(self, bi: int, rgb, acc, depth, chunks, cached: bool = False):
        self.rgb[bi] = rgb
        self.acc[bi] = acc
        self.depth[bi] = depth
        self.chunks[bi] = chunks
        if cached:
            self.cached_blocks += 1
            self.cached_chunks += int(chunks)
        self.pending -= 1

    def deliver_density(self, bi: int, acc, depth, chunks):
        self.dens_acc[bi] = acc
        self.dens_depth[bi] = depth
        self.dens_chunks[bi] = chunks
        self.pending -= 1

    def finalize(self, acfg: ASDRConfig) -> RenderRequest:
        req = self.req
        H, W = req.cam.height, req.cam.width
        R = H * W
        Rp = self.order.shape[0]
        if Rp:
            inv = np.zeros((Rp,), np.int64)
            inv[np.asarray(self.order)] = np.arange(Rp)
            flat = self.rgb.reshape(Rp, 3)[inv]
            acc_flat = self.acc.reshape(Rp)[inv]
            depth_flat = self.depth.reshape(Rp)[inv]
        else:
            flat = np.zeros((0, 3), np.float32)
            acc_flat = np.zeros((0,), np.float32)
            depth_flat = np.zeros((0,), np.float32)
        if self.march_idx is None:
            img_flat = flat[:R]
            self.acc_full = acc_flat[:R]
            # the march's per-ray termination depth: what the radiance
            # cache warps this frame with (sharper than the probe's
            # stride-d proxy at depth edges)
            self.depth_full = depth_flat[:R]
            rays_marched = R
        else:
            img_flat = self.base_rgb.copy()
            img_flat[self.march_idx] = flat[: self.march_idx.size]
            if self.dens_layout is not None:
                # density refresh: every image ray now has an exact
                # marched acc/depth — disoccluded rays from the color
                # march, warp-valid rays from the density-only march —
                # so this warped frame IS radiance-cacheable
                lay = self.dens_layout
                dRp = lay.order.shape[0]
                dinv = np.zeros((dRp,), np.int64)
                dinv[np.asarray(lay.order)] = np.arange(dRp)
                dacc = self.dens_acc.reshape(dRp)[dinv]
                ddep = self.dens_depth.reshape(dRp)[dinv]
                acc_full = np.zeros((R,), np.float32)
                depth_full = np.zeros((R,), np.float32)
                acc_full[self.march_idx] = acc_flat[: self.march_idx.size]
                depth_full[self.march_idx] = depth_flat[: self.march_idx.size]
                acc_full[lay.march_idx] = dacc[: lay.march_idx.size]
                depth_full[lay.march_idx] = ddep[: lay.march_idx.size]
                self.acc_full, self.depth_full = acc_full, depth_full
            else:
                self.acc_full = None   # warped frames are never re-cached
                self.depth_full = None
            rays_marched = int(self.march_idx.size)
        req.image = img_flat.reshape(H, W, 3)
        req.latency_s = time.time() - self.t0
        # rays delivered straight from the warp: had they marched, the
        # fixed-budget baseline would have spent ns_full samples each —
        # the same convention baseline_samples uses — so zero-march
        # frames report reused compute instead of silently vanishing
        # from the samples split
        warp_rays = 0 if self.march_idx is None else R - rays_marched
        req.stats = {
            "probe_samples": 0 if self.maps is None else self.maps.cost,
            "probe_reused": self.reused,
            "probe_skipped": self.probe_skipped,
            "radiance_reused": self.march_idx is not None,
            "rays_marched": rays_marched,
            "rays_total": R,
            "warp_valid_fraction": self.warp_valid_fraction,
            # compute actually spent: scene-store hits replay stored
            # outputs without marching, so their chunks count as REUSED
            # samples, not processed ones — the compute-fraction metrics
            # must show the scene tier's savings.  Density-refresh chunks
            # are real (color-free) march compute and count as processed.
            "samples_processed":
                (int(self.chunks.sum()) - self.cached_chunks
                 + (int(self.dens_chunks.sum())
                    if self.dens_layout is not None else 0))
                * self.block_size * acfg.chunk,
            "density_rays": (0 if self.dens_layout is None
                             else int(self.dens_layout.march_idx.size)),
            "samples_reused": self.cached_chunks
            * self.block_size * acfg.chunk + warp_rays * acfg.ns_full,
            "scene_block_hits": self.cached_blocks,
            # padded ray count, matching render_adaptive's stats — the
            # numerator includes the pad rays' chunks, so the denominator
            # must too or the fraction inflates (and can exceed 1.0)
            "baseline_samples": Rp * acfg.ns_full,
            "admission_s": self.admission_s,
            "admit_stall_s": self.admit_stall_s,
            # request-lifecycle accounting (serve/scheduler.py): the SLO
            # class this frame was served under, the tier it ENDED at,
            # how many degrade steps the scheduler applied, and whether
            # the end-to-end latency met the class deadline (inf-deadline
            # classes always do)
            "class": req.cls.name,
            "tier": req.tier,
            "degrades": req.degrades,
            "deadline_met": req.latency_s * 1e3 <= req.cls.deadline_ms,
        }
        return req


def probe_key_for(rcfg: RenderServeConfig, req: RenderRequest):
    return (None if rcfg.probe_seed is None
            else jax.random.PRNGKey(rcfg.probe_seed + req.rid))
