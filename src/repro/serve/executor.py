"""Pluggable Stage-A execution backends for the serving pipeline.

The executor contract (see serve/README.md):

  * ``submit(key, fn)`` — schedule ``fn()`` (a Stage-A ``prepare``
    closure: plans + probe/warp device work + pad/sort layout) for
    ``key``.  Idempotent: a key already submitted and not yet taken is
    NOT resubmitted.  Raises RuntimeError after ``close()``.
  * ``take(key)`` — the finished result, blocking if still in flight;
    None if the key was never submitted (the engine then prepares
    inline).  Engine thread only.  Every submitted key must eventually
    be taken or reset — ``pending()`` counts what hasn't been (the leak
    check in tests/test_executor.py).
  * ``reset()`` — drop pending speculation (end of a render() call).
    Idempotent.
  * ``close()`` — release worker resources.  Idempotent; the executor
    rejects new submissions afterwards.

Backends move WHERE and WHEN the speculation executes; they never change
WHAT is committed — Stage B revalidates every plan against current cache
state on the engine thread, so rendered frames and the deterministic
counters are bit-identical across backends (gated by
tests/test_executor.py, tests/test_fleet.py, and the ``--workers`` /
fleet benchmarks).

``SyncExecutor`` (the default) runs ``fn`` inline at submit time on the
engine thread — byte-for-byte the pre-executor engine: the speculation
overlaps only the HOST-side gap while the dispatched round — up to
``inflight_batches`` back-to-back march batches (pool.dispatch_round) —
is in flight.
``ThreadedExecutor`` runs it on a worker pool and blocks each worker
until the result's device buffers are READY, so probe/warp device time
genuinely overlaps march device time.  ``DeviceExecutor`` additionally
PLACES each speculation on a secondary jax device (round-robin over
``jax.devices()[1:]``) while the pooled march keeps device 0 — the
scale-out placement the fleet tier runs on (multi-device CI forces host
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=K``).
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

import jax

from ..obs import trace as trace_lib


def _available_devices() -> List:
    """The jax device list (module hook so tests can model single- and
    multi-device hosts without touching global jax state)."""
    return jax.devices()


class SyncExecutor:
    """Inline (engine-thread) Stage-A execution — the default backend."""

    workers = 0
    backend = "sync"

    def __init__(self):
        self._done: Dict = {}
        self._closed = False

    def submit(self, key, fn: Callable):
        if self._closed:
            raise RuntimeError("submit() on a closed executor")
        if key not in self._done:
            # sync backend runs the closure AT submit — the span covers
            # the actual Stage-A execution on the engine lane
            with trace_lib.span("executor.submit", backend=self.backend):
                self._done[key] = fn()

    def take(self, key):
        return self._done.pop(key, None)

    def pending(self) -> int:
        """Submitted-but-not-taken keys (0 after a clean render())."""
        return len(self._done)

    def depth(self) -> Dict[str, int]:
        """Queue-depth gauge sample (scheduler stall projections read
        this through the metrics registry).  Sync results are complete
        at submit, so nothing is ever in flight."""
        return {"pending": len(self._done), "inflight": 0}

    def reset(self):
        """Drop pending speculation (end of a render() call): results are
        keyed by id(request), and a key must never outlive the call that
        submitted it — a later call's request can reuse the id."""
        self._done.clear()

    def close(self):
        self._done.clear()
        self._closed = True


class _FutureExecutor:
    """Shared future-backed machinery for the off-thread backends.

    Subclasses provide ``_spawn(key, fn) -> Future``.  ``take`` WORK-
    STEALS: a speculation still queued (its future never started) is
    cancelled and run inline on the engine thread instead of waiting for
    a busy worker — the engine must never stall behind speculation it
    could execute itself (the threaded-stall-p99 regression fix; see
    tests/test_executor.py::test_take_steals_queued_speculation).
    """

    def __init__(self):
        self._futs: Dict[object, Tuple[Future, Callable]] = {}
        self._closed = False

    def _spawn(self, key, fn: Callable) -> Future:
        raise NotImplementedError

    def submit(self, key, fn: Callable):
        if self._closed:
            raise RuntimeError("submit() on a closed executor")
        if key not in self._futs:
            self._futs[key] = (self._spawn(key, fn), fn)

    backend = "future"

    def take(self, key):
        ent = self._futs.pop(key, None)
        if ent is None:
            return None
        fut, fn = ent
        if fut.cancel():          # never started: steal it inline
            with trace_lib.span("executor.take", backend=self.backend,
                                stolen=True):
                return fn()
        # the span covers the engine-side WAIT for a busy worker — on an
        # idle executor it closes immediately; long takes here mean
        # speculation is not keeping ahead of admission
        with trace_lib.span("executor.take", backend=self.backend,
                            stolen=False):
            return fut.result()

    def pending(self) -> int:
        return len(self._futs)

    def depth(self) -> Dict[str, int]:
        """Queue-depth gauge sample: ``pending`` = submitted-not-taken
        speculations, ``inflight`` = the subset actually EXECUTING on a
        worker/device right now (the rest are queued behind the
        concurrency cap — a growing pending/inflight gap means
        speculation is falling behind admission)."""
        running = sum(1 for fut, _fn in self._futs.values()
                      if fut.running())
        return {"pending": len(self._futs), "inflight": running}

    def reset(self):
        """Drop pending speculation (see SyncExecutor.reset).  Unstarted
        futures are cancelled; running ones finish on their worker and
        are discarded.  Idempotent."""
        for fut, _fn in self._futs.values():
            fut.cancel()
        self._futs.clear()

    def close(self):
        self.reset()
        self._closed = True


def _wait_device_ready(out):
    ready = getattr(out, "block_until_ready", None)
    if ready is not None:
        ready()


class ThreadedExecutor(_FutureExecutor):
    """Worker-thread Stage-A execution.

    Workers run the prepare closure AND wait on its device buffers
    (``block_until_ready``), so the device work completes off the engine
    thread.  Commits still happen only on the engine thread in admission
    order — ``take`` blocks until the worker finishes (or steals a
    still-queued closure inline), and Stage B revalidates the result, so
    worker scheduling can never reorder or alter commits.

    ``max_concurrent`` bounds how many speculations EXECUTE at once
    (queued submissions wait on a semaphore, FIFO): worker count is an
    API/capacity property, but useful execution concurrency is a HOST
    property — on a 2-core CPU container, four concurrent probe/warp
    executions would fight the in-flight march (and each other) for the
    same ALUs and triple tail latency instead of hiding it.  The default
    leaves one core's worth of concurrency for the engine thread + march.
    On a multi-stream accelerator host, pass workers explicitly sized to
    the streams and the cap follows.
    """

    backend = "threaded"

    def __init__(self, workers: int, max_concurrent: Optional[int] = None):
        super().__init__()
        assert workers > 0
        self.workers = workers
        if max_concurrent is None:
            max_concurrent = min(workers,
                                 max(1, (os.cpu_count() or 2) - 1))
        self.max_concurrent = max_concurrent
        self._sem = threading.Semaphore(max_concurrent)
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="serve-stage-a")

    def _run(self, fn: Callable):
        with self._sem:
            # recorded on the worker's own lane (thread name) — the
            # speculation that overlaps the in-flight march
            with trace_lib.span("executor.run", backend=self.backend):
                out = fn()
                _wait_device_ready(out)
        return out

    def _spawn(self, key, fn: Callable) -> Future:
        return self._pool.submit(self._run, fn)

    def close(self):
        super().close()
        self._pool.shutdown(wait=False)


class DeviceExecutor(_FutureExecutor):
    """Multi-device Stage-A execution: speculation on secondary devices.

    Placement rule (the fleet contract, serve/README.md): the pooled
    march owns device 0 — Stage-A probe/warp closures are placed on the
    SECONDARY devices (``jax.devices()[1:]`` by default), round-robin
    per submitted slot, each device backed by its own single-thread
    queue (the host-side stand-in for a per-device stream).  The closure
    runs under ``jax.default_device(dev)``, so its jitted probe/warp
    computations compile and execute on that device; its result arrays
    transfer to device 0 implicitly when the commit path consumes them.

    Determinism: host platform devices share one codegen, so a probe
    executed on device k is bit-identical to the same probe on device 0
    — and on hosts where that may not hold, Stage-B revalidation still
    bounds the blast radius to the speculated maps a commit chose to
    reuse.  tests/test_fleet.py gates frames and deterministic counters
    against the SyncExecutor for devices {1, 2, 4} x prefetch {0, 2}
    under ``--xla_force_host_platform_device_count=4``.

    A stolen ``take`` (speculation still queued when the engine needs
    it) runs inline on the engine thread / device 0, exactly like the
    sync backend — placement is best-effort under load, never a stall.
    """

    backend = "device"

    def __init__(self, devices: Optional[List] = None):
        super().__init__()
        if devices is None:
            devices = _available_devices()[1:]
        assert devices, "DeviceExecutor needs at least one device"
        self.devices = list(devices)
        self.workers = len(self.devices)
        self._pools = [
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix=f"serve-dev{i}")
            for i in range(len(self.devices))]
        self._rr = 0

    def _run(self, dev, fn: Callable):
        with jax.default_device(dev):
            # device attr records the PLACEMENT; the lane (serve-dev*)
            # records the per-device queue that executed it
            with trace_lib.span("executor.run", backend=self.backend,
                                device=str(dev)):
                out = fn()
                _wait_device_ready(out)
        return out

    def _spawn(self, key, fn: Callable) -> Future:
        i = self._rr % len(self.devices)
        self._rr += 1
        return self._pools[i].submit(self._run, self.devices[i], fn)

    def close(self):
        super().close()
        for pool in self._pools:
            pool.shutdown(wait=False)


def make_executor(workers: int, devices: int = 0):
    """The backend for a (workers, devices) config.

    ``devices=n > 0`` asks for Stage-A placement on up to n secondary
    jax devices.  Graceful fallback: a single-device host has no
    secondary device to place on, so the config degrades to the
    bit-identical SyncExecutor instead of failing — the same binary
    serves a laptop and a fleet host (tests/test_executor.py and
    tests/test_fleet.py cover both sides).  Otherwise ``workers=n > 0``
    selects the ThreadedExecutor; the default is synchronous.
    """
    if devices > 0:
        avail = _available_devices()
        if len(avail) > 1:
            return DeviceExecutor(avail[1:1 + devices])
        return SyncExecutor()
    return ThreadedExecutor(workers) if workers > 0 else SyncExecutor()


def block_until_ready(*arrays):
    """Wait until every (possibly-None, possibly-host) array is ready."""
    jax.block_until_ready([a for a in arrays if a is not None])
