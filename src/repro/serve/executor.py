"""Pluggable Stage-A execution backends for the serving pipeline.

The executor contract (see serve/README.md):

  * ``submit(key, fn)`` — schedule ``fn()`` (a Stage-A ``prepare``
    closure: plans + probe/warp device work + pad/sort layout) for
    ``key``.  Idempotent: a key already submitted and not yet taken is
    NOT resubmitted.
  * ``take(key)`` — the finished result, blocking if still in flight;
    None if the key was never submitted (the engine then prepares
    inline).  Engine thread only.
  * ``close()`` — release worker resources.

Backends move WHERE and WHEN the speculation executes; they never change
WHAT is committed — Stage B revalidates every plan against current cache
state on the engine thread, so rendered frames and the deterministic
counters are bit-identical across backends (gated by
tests/test_executor.py and the ``--workers`` benchmark).

``SyncExecutor`` (workers=0, the default) runs ``fn`` inline at submit
time on the engine thread — byte-for-byte the pre-executor engine: the
speculation overlaps only the HOST-side gap while the dispatched march
is in flight.  ``ThreadedExecutor`` runs it on a worker pool and blocks
each worker until the result's device buffers are READY, so probe/warp
device time genuinely overlaps march device time and the engine thread
never waits on speculated device work it could have overlapped.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Optional

import jax


class SyncExecutor:
    """Inline (engine-thread) Stage-A execution — the default backend."""

    workers = 0

    def __init__(self):
        self._done: Dict = {}

    def submit(self, key, fn: Callable):
        if key not in self._done:
            self._done[key] = fn()

    def take(self, key):
        return self._done.pop(key, None)

    def reset(self):
        """Drop pending speculation (end of a render() call): results are
        keyed by id(request), and a key must never outlive the call that
        submitted it — a later call's request can reuse the id."""
        self._done.clear()

    def close(self):
        self._done.clear()


class ThreadedExecutor:
    """Worker-thread Stage-A execution.

    Workers run the prepare closure AND wait on its device buffers
    (``block_until_ready``), so the device work completes off the engine
    thread.  Commits still happen only on the engine thread in admission
    order — ``take`` blocks until the worker finishes, and Stage B
    revalidates the result, so worker scheduling can never reorder or
    alter commits.

    ``max_concurrent`` bounds how many speculations EXECUTE at once
    (queued submissions wait on a semaphore, FIFO): worker count is an
    API/capacity property, but useful execution concurrency is a HOST
    property — on a 2-core CPU container, four concurrent probe/warp
    executions would fight the in-flight march (and each other) for the
    same ALUs and triple tail latency instead of hiding it.  The default
    leaves one core's worth of concurrency for the engine thread + march.
    On a multi-stream accelerator host, pass workers explicitly sized to
    the streams and the cap follows.
    """

    def __init__(self, workers: int, max_concurrent: Optional[int] = None):
        assert workers > 0
        self.workers = workers
        if max_concurrent is None:
            max_concurrent = min(workers,
                                 max(1, (os.cpu_count() or 2) - 1))
        self.max_concurrent = max_concurrent
        self._sem = threading.Semaphore(max_concurrent)
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="serve-stage-a")
        self._futs: Dict[object, Future] = {}

    def _run(self, fn: Callable):
        with self._sem:
            out = fn()
            ready = getattr(out, "block_until_ready", None)
            if ready is not None:
                ready()
        return out

    def submit(self, key, fn: Callable):
        if key not in self._futs:
            self._futs[key] = self._pool.submit(self._run, fn)

    def take(self, key):
        fut = self._futs.pop(key, None)
        return fut.result() if fut is not None else None

    def reset(self):
        """Drop pending speculation (see SyncExecutor.reset).  Unstarted
        futures are cancelled; running ones finish on their worker and
        are discarded."""
        for fut in self._futs.values():
            fut.cancel()
        self._futs.clear()

    def close(self):
        self._pool.shutdown(wait=False)
        self._futs.clear()


def make_executor(workers: int):
    """The backend for a worker count: 0 = synchronous (bit-identical
    default), n > 0 = a ThreadedExecutor with n workers."""
    return ThreadedExecutor(workers) if workers > 0 else SyncExecutor()


def block_until_ready(*arrays):
    """Wait until every (possibly-None, possibly-host) array is ready."""
    jax.block_until_ready([a for a in arrays if a is not None])
