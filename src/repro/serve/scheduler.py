"""Request-lifecycle scheduler: pluggable admission policies.

The serving engine's admission loop used to be hard-wired FIFO: pop the
queue head, prepare, admit.  This module makes the policy a seam:

  * ``RequestClass`` — the SLO contract a request arrives with: a
    deadline, a ladder of sample-budget tiers (scale factors applied to
    the per-ray probe counts before ``pool.build_layout`` pads and
    budget-sorts), and a shed floor (the deepest tier load-shedding may
    degrade it to).  ``DEFAULT_CLASS`` has no deadline and a single
    full-budget tier — requests that never mention a class behave
    exactly as before.
  * ``FifoPolicy`` — the default: admit ARRIVED requests in queue order.
    With every request at ``arrival_s == 0`` (the closed-loop tests and
    benches) the operation sequence is bit-identical to the pre-policy
    engine: same pops, same spans, same commits, same counters.
  * ``DeadlinePolicy`` — EDF slot draining: among arrived requests,
    admit the one with the earliest absolute deadline
    (``arrival_s + deadline_ms``); ties resolve to the lowest queue
    position, so ordering is deterministic under equal deadlines.
  * ``ShedPolicy`` — EDF plus load-shedding: when the admission stall a
    request already absorbed has eaten into its deadline slack, degrade
    its budget tier (never below ``shed_floor``) instead of letting it
    queue into a miss.  The projection is the EWMA of recent service
    times scaled by the candidate tier's budget factor.

Degrade points (the bit-identity contract):

  * ``budget_scale_for`` is consulted by Stage-A ``prepare`` — a
    degraded request's layout is built with scaled per-ray counts, so
    the pool's budget-sorted batching and in-batch dedup see the
    degraded budgets natively (scenecache keys include budgets: a
    degraded block can never false-share a full-budget entry).
  * ``admission.admit`` re-prepares when the scheduler degraded a
    request AFTER its speculation ran (``Prepared.tier`` mismatch) —
    Stage A is re-preparable, counted in ``shed_reprepares``, still
    pre-commit.
  * Commits stay on the engine thread in admission order.  Policies
    reorder WHICH request is admitted next and at WHAT tier; they never
    touch the commit path, so FIFO stays bit-identical and the other
    policies keep every cache-coherence invariant.

Scheduler state (service-time EWMA) lives for the engine lifetime;
per-``render()`` state (queue, enqueue clock) is passed per call.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional, Tuple

from ..obs import trace as trace_lib


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """The SLO contract of a request: deadline, budget ladder, floor.

    ``tiers`` are sample-budget scale factors, best first; ``tier``
    indexes the starting rung and ``shed_floor`` the deepest rung
    shedding may reach (``<= tier`` disables degradation).  Deadlines
    are relative to the request's ``arrival_s``; ``inf`` means "no
    deadline" and is never shed.
    """
    name: str = "default"
    deadline_ms: float = float("inf")
    tiers: Tuple[float, ...] = (1.0,)
    tier: int = 0
    shed_floor: int = 0

    def deadline_at(self, arrival_s: float) -> float:
        """Absolute deadline on the enqueue-relative clock."""
        return arrival_s + self.deadline_ms * 1e-3


DEFAULT_CLASS = RequestClass()


def budget_scale_for(req) -> float:
    """The sample-budget scale of a request's CURRENT tier (1.0 for the
    default class — callers skip the scaling ops entirely then)."""
    tiers = req.cls.tiers
    return tiers[min(req.tier, len(tiers) - 1)]


# --------------------------------------------------------------- policies
@dataclasses.dataclass(frozen=True)
class FifoPolicy:
    """Arrived requests in queue order — the bit-identical default."""
    shed = False

    def select(self, queue, now_rel: float) -> Optional[int]:
        """Index of the next request to admit among ARRIVED ones (their
        ``arrival_s`` has passed on the enqueue-relative clock), or None
        when nothing has arrived yet."""
        for i, r in enumerate(queue):
            if r.arrival_s <= now_rel:
                return i
        return None

    def prefetch_order(self, queue, now_rel: float) -> List:
        """Arrived requests in the order speculation should run."""
        return [r for r in queue if r.arrival_s <= now_rel]


@dataclasses.dataclass(frozen=True)
class DeadlinePolicy(FifoPolicy):
    """EDF slot draining: earliest absolute deadline first; ties (equal
    deadlines, including the no-deadline default class) resolve to the
    lowest queue position — deterministic for any queue content."""

    def select(self, queue, now_rel: float) -> Optional[int]:
        best = best_key = None
        for i, r in enumerate(queue):
            if r.arrival_s > now_rel:
                continue
            key = (r.cls.deadline_at(r.arrival_s), i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def prefetch_order(self, queue, now_rel: float) -> List:
        arrived = [(r.cls.deadline_at(r.arrival_s), i, r)
                   for i, r in enumerate(queue) if r.arrival_s <= now_rel]
        arrived.sort(key=lambda t: t[:2])
        return [r for _, _, r in arrived]


@dataclasses.dataclass(frozen=True)
class ShedPolicy(DeadlinePolicy):
    """EDF + load-shedding: degrade the budget tier of a request whose
    remaining deadline slack no longer covers its projected service
    time, instead of queueing it into a certain miss.  ``headroom``
    scales the projection (>1 sheds earlier, <1 later)."""
    headroom: float = 1.0
    shed = True


def make_policy(spec) -> FifoPolicy:
    """Resolve a policy spec: None -> FIFO, a name ('fifo'/'edf'/'shed'),
    or a policy instance passed through."""
    if spec is None:
        return FifoPolicy()
    if isinstance(spec, str):
        try:
            return {"fifo": FifoPolicy, "edf": DeadlinePolicy,
                    "shed": ShedPolicy}[spec]()
        except KeyError:
            raise ValueError(f"unknown scheduler policy: {spec!r}")
    return spec


# -------------------------------------------------------------- scheduler
class Scheduler:
    """The engine's admission driver: owns request selection, arrival
    gating (open-loop traffic), shed/degrade decisions, and Stage-A
    prefetch candidate selection.  One per engine, living across
    ``render()`` calls (the service-time EWMA is cross-call state).
    """

    #: EWMA weight of the newest normalized service-time sample.
    EWMA_ALPHA = 0.2

    def __init__(self, policy, counters, metrics=None):
        self.policy = make_policy(policy)
        self.counters = counters
        self.metrics = metrics
        # EWMA of FULL-BUDGET-equivalent service seconds (admission ->
        # finalize, divided by the served tier's scale): the projection
        # basis for shed decisions.  0.0 until the first finalize — no
        # sample means no projection, so nothing sheds on a cold engine.
        self.ewma_service_s = 0.0

    # ------------------------------------------------------- admission
    def admit_ready(self, engine, queue, live, pool, ex, t_enqueue):
        """Fill free slots from the queue per the policy.  Blocks only
        for Stage-A work of the admitted request (exactly the pre-policy
        loop) or — open-loop traffic, nothing live yet — until the next
        arrival.  Mutates ``queue``/``live``/``pool`` in place."""
        from . import admission
        rcfg = engine.rcfg
        self._observe_depth(ex)
        while queue and len(live) < rcfg.slots:
            now_rel = time.time() - t_enqueue
            idx = self.policy.select(queue, now_rel)
            if idx is None:
                if live:
                    break              # march what's live; arrivals later
                self._sleep_until_arrival(queue, t_enqueue)
                continue
            req = queue.pop(idx)
            if self.policy.shed:
                self._maybe_shed(req, now_rel - req.arrival_s)
            t0 = time.time()
            # admission.wait covers the BLOCKING admission window
            # (take/steal + inline Stage A + Stage B) — the flight
            # recorder's stall trigger watches this span
            with trace_lib.span("admission.wait", req=req.rid,
                                scene=req.scene):
                prepared = ex.take(id(req))
                speculated = prepared is not None
                if prepared is None:  # never speculated: A inline
                    prepared = admission.prepare(engine, req)
                slot = admission.admit(
                    engine, req, prepared,
                    t_enqueue=t_enqueue + req.arrival_s)
            # blocking admission time; speculated Stage-A work adds
            # its (overlapped) duration to admission_s only
            slot.admit_stall_s = time.time() - t0
            slot.admission_s = slot.admit_stall_s + (
                prepared.prep_s if speculated else 0.0)
            slot.t_admit = t0
            live.append(slot)
            pool.add_slot(slot)

    def speculate(self, engine, queue, live, ex, t_enqueue):
        """Submit Stage-A speculation for up to ``prefetch`` queued
        requests, in policy order over the ARRIVED ones (clamped: a
        negative prefetch must mean "off", not a near-full slice).

        Under a shedding policy the degrade decision runs HERE first,
        against the PROJECTED admission stall (wait so far + slots
        occupied/queued ahead, each a projected service time), so the
        speculated layout is usually built at the tier the request will
        be admitted at — admission re-degrades only when the projection
        was optimistic, and then rebuilds just the layout."""
        from . import admission
        rcfg = engine.rcfg
        n = max(rcfg.prefetch, 0)
        if n == 0 or not queue:
            return
        now_rel = time.time() - t_enqueue
        ordered = self.policy.prefetch_order(queue, now_rel)[:n]
        for pos, req in enumerate(ordered):
            if self.policy.shed:
                ahead = len(live) + pos
                projected = (now_rel - req.arrival_s
                             + ahead * self.ewma_service_s
                             / max(rcfg.slots, 1))
                self._maybe_shed(req, projected)
            ex.submit(id(req), partial(admission.prepare, engine, req))

    def note_finalized(self, slot):
        """Fold one finished request's service time (admission start ->
        finalize, normalized to full budget) into the EWMA — the shed
        projection basis.  Per-class ledgers live in stats.py."""
        req = slot.req
        t_admit = getattr(slot, "t_admit", None)
        if t_admit is not None:
            norm = (time.time() - t_admit) / max(budget_scale_for(req),
                                                 1e-6)
            if self.ewma_service_s == 0.0:
                self.ewma_service_s = norm
            else:
                a = self.EWMA_ALPHA
                self.ewma_service_s = a * norm + (1 - a) * self.ewma_service_s

    # ----------------------------------------------------------- internals
    def _maybe_shed(self, req, waited_s: float):
        """Degrade ``req``'s tier while the deadline slack left after the
        stall it already absorbed cannot cover the projected service time
        at the current tier.  Stops at the class's shed floor (a floored
        request may still miss; that is counted, never dropped)."""
        cls = req.cls
        est = self.ewma_service_s * self.policy.headroom
        if est <= 0.0 or cls.deadline_ms == float("inf"):
            return
        slack = cls.deadline_ms * 1e-3 - waited_s
        while (req.tier < cls.shed_floor
               and est * cls.tiers[req.tier] > slack):
            req.tier += 1
            req.degrades += 1
            self.counters.shed_degrades += 1
            trace_lib.instant("scheduler.shed", req=req.rid, cls=cls.name,
                              tier=req.tier, waited_ms=waited_s * 1e3)

    def _sleep_until_arrival(self, queue, t_enqueue):
        """Open-loop gap: nothing live, nothing arrived — sleep until the
        earliest queued arrival."""
        gap = min(r.arrival_s for r in queue) + t_enqueue - time.time()
        if gap > 0:
            with trace_lib.span("scheduler.idle", gap_ms=gap * 1e3):
                time.sleep(gap)

    def _observe_depth(self, ex):
        """Publish the executor's speculation queue depth as gauges so
        stall projections are observable next to the latency series."""
        if self.metrics is None:
            return
        depth = getattr(ex, "depth", None)
        if depth is None:
            return
        d = depth()
        self.metrics.gauge("executor_pending_depth").set(d["pending"])
        self.metrics.gauge("executor_inflight_depth").set(d["inflight"])
