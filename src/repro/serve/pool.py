"""Block pooling layer: pad/sort layouts, batching, dedup, the march cache.

Owns every device SHAPE decision of the serving pipeline:

  * ``build_layout`` — a request's rays padded to whole blocks and
    budget-sorted (``pipeline.pad_rays_to_blocks`` + ``block_sort``).
    Stage-A code: the admission layer calls it speculatively (prefetch /
    worker threads) keyed on the plan bases, so the Stage-B commit never
    performs pad/sort device work (``tests/test_executor.py`` instruments
    this invariant).
  * ``BlockPool`` — the per-``render()`` pool of undispatched blocks from
    all live slots: scene-store admission/sweep delivery, budget-sorted
    batch selection, in-batch key dedup, fixed-size batch padding, and
    the dispatch/collect split the engine overlaps Stage A with.
  * the module-level jitted-march LRU shared across engine instances.

Invariant owned here: batches have a fixed block count
(``blocks_per_batch``); the trailing partial batch is padded with
unit-budget dummy blocks so each scene compiles exactly ONE batched
march, and budget-descending selection keeps batches budget-homogeneous
(what launch/render_serve.py relies on to shard a batch over the
``data`` mesh axis without stragglers).  Selection is deadline-PRIMARY
(serve/scheduler.py request classes): an earlier-deadline slot's blocks
march before a later/no-deadline slot's, budget-descending within —
for default-class traffic (every deadline inf) this reduces to the
pure budget sort exactly, so the bit-identity contract is untouched.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from collections import OrderedDict
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..obs import trace as trace_lib
from ..scenecache import key as scenecache_key

# jitted batched marches shared across engine instances: keyed by the
# (FieldFns, ASDRConfig) pair (both hashable), so an engine restart or a
# parallel engine over the same scene reuses the compiled executable.
# LRU-bounded: a reloaded/retrained scene makes fresh FieldFns closures,
# and without eviction the stale executables (and the params their
# closures capture) would pile up for the process lifetime.
# Locked: a fleet runs engine REPLICAS on separate threads (one engine
# thread each, benchmarks/render_fleet.py), and they share this cache.
# NOTE: the march closes over fns — fine for analytic fields (no arrays);
# an NGP-backed production path should pass params as jit ARGS instead,
# which is exactly what launch/render_serve.build_pooled_march_cell does.
_MARCH_CACHE: OrderedDict = OrderedDict()
_MARCH_CACHE_MAX = 32
_MARCH_CACHE_LOCK = threading.Lock()


def batched_march(fns, acfg, density_only: bool = False):
    """One jitted (N, B)-block march per (field, config, density flag) —
    LRU-shared across engine instances AND fleet replica threads (the
    lock covers only the OrderedDict bookkeeping; jax.jit itself is
    thread-safe and compilation happens lazily at the first call).

    Routes through ``pipeline.march_blocks``, so a FieldFns carrying
    fused-march resources under ``march_backend="fused"`` compiles the
    single-kernel streaming march; everything else gets the chunked
    reference march.  ``density_only`` marches skip the color MLP
    entirely (rgb reads zero) — the cheap acc/depth refresh for rays
    whose radiance came from the warp/radiance tiers.
    """
    key = (fns, acfg, density_only)
    with _MARCH_CACHE_LOCK:
        if key not in _MARCH_CACHE:
            _MARCH_CACHE[key] = jax.jit(partial(
                pipeline.march_blocks, fns, acfg,
                density_only=density_only))
            while len(_MARCH_CACHE) > _MARCH_CACHE_MAX:
                _MARCH_CACHE.popitem(last=False)
        _MARCH_CACHE.move_to_end(key)
        return _MARCH_CACHE[key]


@dataclasses.dataclass
class BlockLayout:
    """A request's padded, budget-sorted block geometry plus its
    radiance-warp composition inputs — everything Stage B needs to build
    a slot without touching device shapes.

    ``march_idx`` selects the disoccluded rays the slot actually marches
    (None = all rays); ``base_rgb`` is the warped cached frame those rays
    composite over.  A full radiance hit has zero blocks and an empty
    ``march_idx``.
    """
    rays: tuple                  # padded (origins, dirs) of marched rays
    order: np.ndarray
    budgets: np.ndarray
    pad: int
    march_idx: Optional[np.ndarray] = None
    base_rgb: Optional[np.ndarray] = None
    valid_fraction: float = 0.0


def _scale_counts(counts, budget_scale: float):
    """Degrade per-ray sample counts to a budget tier: ceil(n * scale),
    floored at one sample.  Block budgets are per-block maxima of these
    counts (pipeline.block_sort), so scaling counts scales the while-loop
    trip budgets of every downstream march — ASDR's adaptive-sampling
    knob repurposed as the scheduler's load-shedding actuator."""
    return jnp.maximum(
        jnp.ceil(counts.astype(jnp.float32) * budget_scale)
        .astype(counts.dtype), 1)


def build_layout(acfg, cam, maps, warped,
                 budget_scale: float = 1.0) -> BlockLayout:
    """Pad + budget-sort one request's marched rays (Stage-A device work).

    ``maps`` None means a full radiance hit: zero blocks, the frame is
    delivered entirely from ``warped``.  With a partial ``warped`` only
    the disoccluded rays enter the block layout.  ``budget_scale`` < 1
    is a degraded tier (serve/scheduler.py): per-ray counts scale BEFORE
    pad/sort, so budgets, block order, and scenecache keys (which
    include budgets) all see the degraded tier natively; 1.0 skips the
    scaling ops entirely — bit-identical to the pre-scheduler layout.
    """
    march_idx = base_rgb = None
    vf = 0.0
    if warped is not None:
        march_idx = np.flatnonzero(~warped.valid)
        base_rgb = np.asarray(warped.rgb)
        vf = warped.valid_fraction
    if maps is None:
        rays = (jnp.zeros((0, 3)), jnp.zeros((0, 3)))
        order = np.zeros((0,), np.int64)
        budgets = np.zeros((0,), np.int64)
        pad = 0
    else:
        o, d = scene.camera_rays(cam)
        counts, opacity = maps.counts, maps.opacity
        if march_idx is not None:
            sel = jnp.asarray(march_idx, jnp.int32)
            o, d = o[sel], d[sel]
            counts, opacity = counts[sel], opacity[sel]
        if budget_scale != 1.0:
            counts = _scale_counts(counts, budget_scale)
        o, d, counts, opacity, pad = pipeline.pad_rays_to_blocks(
            acfg, o, d, counts, opacity)
        order_j, budgets_j = pipeline.block_sort(acfg, counts, opacity)
        rays = (o, d)
        order, budgets = np.asarray(order_j), np.asarray(budgets_j)
    return BlockLayout(rays, order, budgets, pad, march_idx, base_rgb, vf)


def build_density_layout(acfg, cam, maps, warped,
                         budget_scale: float = 1.0) -> Optional[BlockLayout]:
    """Pad + budget-sort the WARP-VALID rays of a partial radiance hit
    for a density-only refresh march (opt-in via
    ``RenderServeConfig.density_refresh``).

    These rays' rgb is served by the warp, but without acc/depth the
    warped frame can never re-enter the radiance cache ("warps never
    chain").  A density-only march (no color MLP — the fused kernel
    skips the color chain outright) recovers exact acc/depth for them,
    so the finalized frame becomes cacheable again.  ``march_idx`` here
    holds the VALID-ray image indices the density outputs scatter back
    to.  None when the warp left no valid rays (nothing to refresh).
    """
    valid_idx = np.flatnonzero(warped.valid)
    if valid_idx.size == 0:
        return None
    o, d = scene.camera_rays(cam)
    sel = jnp.asarray(valid_idx, jnp.int32)
    counts = maps.counts[sel]
    if budget_scale != 1.0:
        counts = _scale_counts(counts, budget_scale)
    o, d, counts, opacity, pad = pipeline.pad_rays_to_blocks(
        acfg, o[sel], d[sel], counts, maps.opacity[sel])
    order_j, budgets_j = pipeline.block_sort(acfg, counts, opacity)
    return BlockLayout((o, d), np.asarray(order_j), np.asarray(budgets_j),
                       pad, valid_idx)


class BlockPool:
    """The per-render() pool of undispatched blocks across live slots.

    Items are (slot, block_index, o, d, budget, key, cell, dens)
    tuples — key/cell are None with the scene tier off, and the
    pooled-march path is then byte-for-byte the pre-scenecache behavior.
    ``dens`` marks a DENSITY-ONLY block (acc/depth refresh for
    warp-served rays): those never carry a scene key — their rgb-less
    outputs must not collide with color entries in the shared store.
    """

    def __init__(self, acfg, blocks_per_batch: int, scenecache, counters):
        self.acfg = acfg
        self.blocks_per_batch = blocks_per_batch
        self.scenecache = scenecache
        self.counters = counters
        self.items: List[tuple] = []
        self._batch_seq = 0          # trace batch ids, per render() call

    def __len__(self) -> int:
        return len(self.items)

    # ------------------------------------------------------------ admit
    def add_slot(self, slot):
        """Pool a freshly admitted slot's blocks.  Blocks already
        resident in the scene store deliver HERE (their one counted
        lookup) and never enter the pool."""
        items = list(slot.emit_blocks(*slot.rays))
        dens_items = [it + (None, None, True)
                      for it in slot.emit_density_blocks()]
        if self.scenecache is None or not items:
            self.items.extend(it + (None, None, False) for it in items)
            self.items.extend(dens_items)
            return
        o_np = np.stack([np.asarray(it[2]) for it in items])
        d_np = np.stack([np.asarray(it[3]) for it in items])
        buds = np.asarray([it[4] for it in items])
        kcs = scenecache_key.block_keys(
            self.scenecache.cfg, slot.req.scene, self.acfg, o_np, d_np, buds)
        for it, kc in zip(items, kcs):
            out = self.scenecache.lookup(kc[0])
            if out is None:
                self.items.append(it + kc + (False,))
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.counters.scene_blocks_hit += 1
        self.items.extend(dens_items)

    def sweep(self):
        """Deliver every pooled block whose key BECAME resident; keep the
        rest.

        Runs once per scheduling round, so a block marched (and stored)
        for one request satisfies an identical block another client
        pooled in the SAME round — cross-request sharing without any
        inter-slot coordination.  Pool items already recorded their miss
        at admission, so these re-checks don't count misses (hits do).

        This sweep is the fleet tier's ASYNC-FETCH JOIN POINT: against a
        store exposing ``fetch_async`` (scenecache/sharded.py) the
        re-checks fan out as one future per pooled block — concurrent
        across shards, the stand-in for remote shard RPCs — and are
        joined here before the round's dispatch.  Delivery order and
        semantics are identical to the synchronous path; only the fetch
        latency overlaps.
        """
        if self.scenecache is None or not self.items:
            return
        with trace_lib.span("pool.sweep", items=len(self.items)):
            fetch = getattr(self.scenecache, "fetch_async", None)
            if fetch is not None:
                futs = [fetch(it[5], count_miss=False)
                        if it[5] is not None else None
                        for it in self.items]
                with trace_lib.span(
                        "pool.fetch_join",
                        fetches=sum(f is not None for f in futs)):
                    self._join_and_deliver(futs)
                return
            outs = [self.scenecache.lookup(it[5], count_miss=False)
                    if it[5] is not None else None for it in self.items]
            rest = []
            for it, out in zip(self.items, outs):
                if self._deliver_swept(it, out):
                    rest.append(it)
            self.items = rest

    def _join_and_deliver(self, futs):
        """Join async shard fetches as they COMPLETE, delivering the done
        prefix immediately — a slow shard delays only the items queued
        behind it in submission order, not the whole sweep (delivery
        order itself stays exactly the submission order, so frames and
        counters are identical to the synchronous join)."""
        results: dict = {}
        owner = {f: i for i, f in enumerate(futs) if f is not None}
        rest, next_i = [], 0

        def drain(limit):
            nonlocal next_i
            while next_i < limit and (futs[next_i] is None
                                      or next_i in results):
                it = self.items[next_i]
                if self._deliver_swept(it, results.get(next_i)):
                    rest.append(it)
                next_i += 1

        for f in concurrent.futures.as_completed(owner):
            results[owner[f]] = f.result()
            drain(len(futs))
        drain(len(futs))
        self.items = rest

    def _deliver_swept(self, it, out) -> bool:
        """Deliver one swept lookup result; True = keep pooled."""
        if out is None:
            return True
        it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                      out.chunks, cached=True)
        self.counters.scene_blocks_hit += 1
        return False

    # --------------------------------------------------------- dispatch
    def dispatch(self, march_for):
        """Back-compat single-batch round: the first handle of a
        ``dispatch_round`` capped at one batch (or None, empty pool)."""
        handles = self.dispatch_round(march_for, 1)
        return handles[0] if handles else None

    def dispatch_round(self, march_for, max_batches: int = 1):
        """The STREAMING scheduler: assemble and DISPATCH up to
        ``max_batches`` batches (device-async) for one round; returns the
        in-flight handles for ``collect`` in dispatch order.

        Each batch is drawn from the pool's current largest-budget
        (scene, density-flag) group, so batches stay budget- and
        compile-homogeneous; when the head group runs out of blocks, the
        NEXT largest group fills the remaining dispatch slots — at large
        slot counts one batch per round left every other scene (and all
        density refreshes) idle on the host.  All batches are launched
        before any is collected, so batch k+1's host->device transfer
        and compute overlap batch k's march (double buffering — the
        engine additionally overlaps Stage-A speculation with the whole
        in-flight round).  ``march_for(scene_id, density_only)`` maps a
        group to its jitted batched march.
        """
        handles = []
        with trace_lib.span("pool.dispatch_round",
                            pooled=len(self.items)):
            while self.items and len(handles) < max_batches:
                handles.append(self._dispatch_one(march_for))
        return handles

    def _dispatch_one(self, march_for):
        # deadline-primary, budget-descending within: a slot with an
        # earlier absolute deadline marches ALL its blocks before a
        # later/no-deadline slot's — without this, a shed-DEGRADED
        # request's scaled-down budgets would sort its blocks behind
        # every full-budget bulk block and the degrade would buy
        # nothing (priority inversion).  Default-class slots are all
        # (inf, -budget), which compares exactly like the pre-scheduler
        # pure-budget sort — the bit-identity path is unchanged.
        self.items.sort(key=lambda it: (
            it[0].req.cls.deadline_at(it[0].req.arrival_s), -it[4]))
        head = self.items[0]
        group = (head[0].req.scene, head[7])
        batch = [it for it in self.items
                 if (it[0].req.scene, it[7]) == group][:self.blocks_per_batch]
        taken = set(map(id, batch))
        self.items = [it for it in self.items if id(it) not in taken]
        self._batch_seq += 1

        # in-batch dedup: identical keys selected together (two clients
        # admitted the same round) march once; followers receive the
        # leader's outputs
        followers: List[tuple] = []
        if self.scenecache is not None:
            uniq, seen = [], {}
            for it in batch:
                if it[5] is not None and it[5] in seen:
                    followers.append((it, seen[it[5]]))
                else:
                    if it[5] is not None:
                        seen[it[5]] = len(uniq)
                    uniq.append(it)
            batch = uniq

        bid = self._batch_seq
        with trace_lib.span("pool.dispatch", batch=bid, scene=group[0],
                            density=group[1], blocks=len(batch),
                            reqs=sorted({it[0].req.rid
                                         for it in batch})) as sp:
            B = self.acfg.block_size
            N = self.blocks_per_batch
            n_pad = N - len(batch)
            o_b = jnp.stack([it[2] for it in batch]
                            + [jnp.zeros((B, 3))] * n_pad)
            d_b = jnp.stack([it[3] for it in batch]
                            + [jnp.tile(jnp.asarray([[0., 0., 1.]]),
                                        (B, 1))] * n_pad)
            budgets = jnp.asarray([it[4] for it in batch] + [1] * n_pad,
                                  jnp.int32)
            # dispatch only — device arrays are fetched in collect(),
            # after the engine has overlapped Stage-A speculation.
            # With tracing on, the launch is bracketed with a jax
            # profiler annotation so a device profile's timeline carries
            # the same batch id as the host spans.
            if trace_lib.active() is not None:
                with jax.profiler.TraceAnnotation(f"fused_march.batch{bid}"):
                    out = march_for(group[0], group[1])(o_b, d_b, budgets)
            else:
                out = march_for(group[0], group[1])(o_b, d_b, budgets)
        # dispatch-span attrs dict + launch-end timestamp ride the handle:
        # collect() stamps ``device_ms`` (launch -> arrays ready) back
        # onto the already-closed span, splitting its host wall time into
        # queue/assembly vs device execution at export.
        disp_attrs = getattr(sp, "attrs", None)
        return (batch, followers, n_pad, out, bid, disp_attrs,
                time.perf_counter())

    def collect(self, inflight):
        """Fetch a dispatched batch and deliver/store its outputs.

        The ``pool.collect`` span covers the device fetch wait — the
        per-batch march time the engine could not overlap; its ``batch``
        id matches the ``pool.dispatch`` span that launched it, so a
        frame's lineage chains admission -> dispatch -> collect."""
        batch, followers, n_pad, out, bid, disp_attrs, t_launch = inflight
        with trace_lib.span("pool.collect", batch=bid,
                            blocks=len(batch),
                            reqs=sorted({it[0].req.rid for it in batch})):
            rgb, acc, depth, chunks, ray_chunks = (
                np.asarray(a) for a in out)
            if disp_attrs is not None:
                disp_attrs["device_ms"] = (time.perf_counter()
                                           - t_launch) * 1e3
            if self.acfg.per_ray_early_exit and batch:
                # sample work the per-ray exit skipped: rays that went
                # dead ride chunks - ray_chunks masked chunks each, at
                # chunk samples per ray per chunk (real blocks only)
                nb = len(batch)
                skipped = (chunks[:nb, None] - ray_chunks[:nb]).sum()
                self.counters.ray_exit_samples_skipped += (
                    int(skipped) * self.acfg.chunk)
            for i, it in enumerate(batch):
                if it[7]:
                    it[0].deliver_density(it[1], acc[i], depth[i],
                                          chunks[i])
                    continue
                it[0].deliver(it[1], rgb[i], acc[i], depth[i], chunks[i])
                if it[5] is not None:
                    self.scenecache.store(it[5], it[6], rgb[i], acc[i],
                                          depth[i], int(chunks[i]))
            for it, li in followers:
                it[0].deliver(it[1], rgb[li], acc[li], depth[li],
                              chunks[li], cached=True)
                self.counters.scene_blocks_hit += 1
        self.counters.batches += 1
        self.counters.blocks_marched += len(batch)
        self.counters.pad_blocks += n_pad
