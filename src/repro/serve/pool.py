"""Block pooling layer: pad/sort layouts, batching, dedup, the march cache.

Owns every device SHAPE decision of the serving pipeline:

  * ``build_layout`` — a request's rays padded to whole blocks and
    budget-sorted (``pipeline.pad_rays_to_blocks`` + ``block_sort``).
    Stage-A code: the admission layer calls it speculatively (prefetch /
    worker threads) keyed on the plan bases, so the Stage-B commit never
    performs pad/sort device work (``tests/test_executor.py`` instruments
    this invariant).
  * ``BlockPool`` — the per-``render()`` pool of undispatched blocks from
    all live slots: scene-store admission/sweep delivery, budget-sorted
    batch selection, in-batch key dedup, fixed-size batch padding, and
    the dispatch/collect split the engine overlaps Stage A with.
  * the module-level jitted-march LRU shared across engine instances.

Invariant owned here: batches have a fixed block count
(``blocks_per_batch``); the trailing partial batch is padded with
unit-budget dummy blocks so each scene compiles exactly ONE batched
march, and budget-descending selection keeps batches budget-homogeneous
(what launch/render_serve.py relies on to shard a batch over the
``data`` mesh axis without stragglers).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..scenecache import key as scenecache_key

# jitted batched marches shared across engine instances: keyed by the
# (FieldFns, ASDRConfig) pair (both hashable), so an engine restart or a
# parallel engine over the same scene reuses the compiled executable.
# LRU-bounded: a reloaded/retrained scene makes fresh FieldFns closures,
# and without eviction the stale executables (and the params their
# closures capture) would pile up for the process lifetime.
# Locked: a fleet runs engine REPLICAS on separate threads (one engine
# thread each, benchmarks/render_fleet.py), and they share this cache.
# NOTE: the march closes over fns — fine for analytic fields (no arrays);
# an NGP-backed production path should pass params as jit ARGS instead,
# which is exactly what launch/render_serve.build_pooled_march_cell does.
_MARCH_CACHE: OrderedDict = OrderedDict()
_MARCH_CACHE_MAX = 32
_MARCH_CACHE_LOCK = threading.Lock()


def batched_march(fns, acfg):
    """One jitted (N, B)-block march per (field, config) — LRU-shared
    across engine instances AND fleet replica threads (the lock covers
    only the OrderedDict bookkeeping; jax.jit itself is thread-safe and
    compilation happens lazily at the first call)."""
    key = (fns, acfg)
    with _MARCH_CACHE_LOCK:
        if key not in _MARCH_CACHE:
            march = partial(pipeline._march_block, fns, acfg)
            _MARCH_CACHE[key] = jax.jit(
                lambda o, d, b: jax.lax.map(lambda a: march(*a), (o, d, b)))
            while len(_MARCH_CACHE) > _MARCH_CACHE_MAX:
                _MARCH_CACHE.popitem(last=False)
        _MARCH_CACHE.move_to_end(key)
        return _MARCH_CACHE[key]


@dataclasses.dataclass
class BlockLayout:
    """A request's padded, budget-sorted block geometry plus its
    radiance-warp composition inputs — everything Stage B needs to build
    a slot without touching device shapes.

    ``march_idx`` selects the disoccluded rays the slot actually marches
    (None = all rays); ``base_rgb`` is the warped cached frame those rays
    composite over.  A full radiance hit has zero blocks and an empty
    ``march_idx``.
    """
    rays: tuple                  # padded (origins, dirs) of marched rays
    order: np.ndarray
    budgets: np.ndarray
    pad: int
    march_idx: Optional[np.ndarray] = None
    base_rgb: Optional[np.ndarray] = None
    valid_fraction: float = 0.0


def build_layout(acfg, cam, maps, warped) -> BlockLayout:
    """Pad + budget-sort one request's marched rays (Stage-A device work).

    ``maps`` None means a full radiance hit: zero blocks, the frame is
    delivered entirely from ``warped``.  With a partial ``warped`` only
    the disoccluded rays enter the block layout.
    """
    march_idx = base_rgb = None
    vf = 0.0
    if warped is not None:
        march_idx = np.flatnonzero(~warped.valid)
        base_rgb = np.asarray(warped.rgb)
        vf = warped.valid_fraction
    if maps is None:
        rays = (jnp.zeros((0, 3)), jnp.zeros((0, 3)))
        order = np.zeros((0,), np.int64)
        budgets = np.zeros((0,), np.int64)
        pad = 0
    else:
        o, d = scene.camera_rays(cam)
        counts, opacity = maps.counts, maps.opacity
        if march_idx is not None:
            sel = jnp.asarray(march_idx, jnp.int32)
            o, d = o[sel], d[sel]
            counts, opacity = counts[sel], opacity[sel]
        o, d, counts, opacity, pad = pipeline.pad_rays_to_blocks(
            acfg, o, d, counts, opacity)
        order_j, budgets_j = pipeline.block_sort(acfg, counts, opacity)
        rays = (o, d)
        order, budgets = np.asarray(order_j), np.asarray(budgets_j)
    return BlockLayout(rays, order, budgets, pad, march_idx, base_rgb, vf)


class BlockPool:
    """The per-render() pool of undispatched blocks across live slots.

    Items are (slot, block_index, o, d, budget, key, cell) tuples —
    key/cell are None with the scene tier off, and the pooled-march path
    is then byte-for-byte the pre-scenecache behavior.
    """

    def __init__(self, acfg, blocks_per_batch: int, scenecache, counters):
        self.acfg = acfg
        self.blocks_per_batch = blocks_per_batch
        self.scenecache = scenecache
        self.counters = counters
        self.items: List[tuple] = []

    def __len__(self) -> int:
        return len(self.items)

    # ------------------------------------------------------------ admit
    def add_slot(self, slot):
        """Pool a freshly admitted slot's blocks.  Blocks already
        resident in the scene store deliver HERE (their one counted
        lookup) and never enter the pool."""
        items = list(slot.emit_blocks(*slot.rays))
        if self.scenecache is None or not items:
            self.items.extend(it + (None, None) for it in items)
            return
        o_np = np.stack([np.asarray(it[2]) for it in items])
        d_np = np.stack([np.asarray(it[3]) for it in items])
        buds = np.asarray([it[4] for it in items])
        kcs = scenecache_key.block_keys(
            self.scenecache.cfg, slot.req.scene, self.acfg, o_np, d_np, buds)
        for it, kc in zip(items, kcs):
            out = self.scenecache.lookup(kc[0])
            if out is None:
                self.items.append(it + kc)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.counters.scene_blocks_hit += 1

    def sweep(self):
        """Deliver every pooled block whose key BECAME resident; keep the
        rest.

        Runs once per scheduling round, so a block marched (and stored)
        for one request satisfies an identical block another client
        pooled in the SAME round — cross-request sharing without any
        inter-slot coordination.  Pool items already recorded their miss
        at admission, so these re-checks don't count misses (hits do).

        This sweep is the fleet tier's ASYNC-FETCH JOIN POINT: against a
        store exposing ``fetch_async`` (scenecache/sharded.py) the
        re-checks fan out as one future per pooled block — concurrent
        across shards, the stand-in for remote shard RPCs — and are
        joined here before the round's dispatch.  Delivery order and
        semantics are identical to the synchronous path; only the fetch
        latency overlaps.
        """
        if self.scenecache is None or not self.items:
            return
        fetch = getattr(self.scenecache, "fetch_async", None)
        if fetch is not None:
            futs = [fetch(it[5], count_miss=False)
                    if it[5] is not None else None for it in self.items]
            outs = [f.result() if f is not None else None for f in futs]
        else:
            outs = [self.scenecache.lookup(it[5], count_miss=False)
                    if it[5] is not None else None for it in self.items]
        rest = []
        for it, out in zip(self.items, outs):
            if out is None:
                rest.append(it)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.counters.scene_blocks_hit += 1
        self.items = rest

    # --------------------------------------------------------- dispatch
    def dispatch(self, march_for):
        """Assemble and DISPATCH one batch (device-async); returns an
        in-flight handle for ``collect``, or None with an empty pool.

        One batch per round, drawn from the largest-budget scene group so
        batches stay budget-homogeneous across requests.  ``march_for``
        maps a scene id to its jitted batched march.
        """
        if not self.items:
            return None
        self.items.sort(key=lambda it: -it[4])
        scene_id = self.items[0][0].req.scene
        batch = [it for it in self.items
                 if it[0].req.scene == scene_id][:self.blocks_per_batch]
        taken = set(map(id, batch))
        self.items = [it for it in self.items if id(it) not in taken]

        # in-batch dedup: identical keys selected together (two clients
        # admitted the same round) march once; followers receive the
        # leader's outputs
        followers: List[tuple] = []
        if self.scenecache is not None:
            uniq, seen = [], {}
            for it in batch:
                if it[5] is not None and it[5] in seen:
                    followers.append((it, seen[it[5]]))
                else:
                    if it[5] is not None:
                        seen[it[5]] = len(uniq)
                    uniq.append(it)
            batch = uniq

        B = self.acfg.block_size
        N = self.blocks_per_batch
        n_pad = N - len(batch)
        o_b = jnp.stack([it[2] for it in batch]
                        + [jnp.zeros((B, 3))] * n_pad)
        d_b = jnp.stack([it[3] for it in batch]
                        + [jnp.tile(jnp.asarray([[0., 0., 1.]]),
                                    (B, 1))] * n_pad)
        budgets = jnp.asarray([it[4] for it in batch] + [1] * n_pad,
                              jnp.int32)
        # dispatch only — device arrays are fetched in collect(), after
        # the engine has overlapped Stage-A speculation with them
        return (batch, followers, n_pad,
                march_for(scene_id)(o_b, d_b, budgets))

    def collect(self, inflight):
        """Fetch a dispatched batch and deliver/store its outputs."""
        batch, followers, n_pad, out = inflight
        rgb, acc, depth, chunks = (np.asarray(a) for a in out)
        for i, it in enumerate(batch):
            it[0].deliver(it[1], rgb[i], acc[i], depth[i], chunks[i])
            if it[5] is not None:
                self.scenecache.store(it[5], it[6], rgb[i], acc[i],
                                      depth[i], int(chunks[i]))
        for it, li in followers:
            it[0].deliver(it[1], rgb[li], acc[li], depth[li],
                          chunks[li], cached=True)
            self.counters.scene_blocks_hit += 1
        self.counters.batches += 1
        self.counters.blocks_marched += len(batch)
        self.counters.pad_blocks += n_pad
