"""Batched multi-view render serving engine — the pipeline facade.

The render analogue of serve/engine.py's slot-based LM engine: render
requests (camera pose + scene) occupy ``slots``; every scheduling round
the Phase-II blocks of ALL live requests are pooled, sorted by sample
budget, and marched through a single jitted batched march — continuous
batching for rays.  Cross-frame reuse goes through ``repro.framecache``
(warped probe maps, warped radiance), cross-user block reuse through
``repro.scenecache``.

This module is deliberately SMALL (make lint fails if it regrows past
250 lines): it owns only the scheduling loop and the public surface.
The pipeline lives in four layers — see serve/README.md:

  * ``admission``  — Stage-A speculation (plans + probe/warp device work
    + pad/sort layout) and the Stage-B commit (revalidate, book, slot);
  * ``pool``       — block pooling, batch assembly, in-batch dedup,
    scene-store delivery, the shared jitted-march LRU;
  * ``executor``   — WHERE Stage A executes: inline (the bit-identical
    default), on worker threads, or placed on secondary jax devices
    (the fleet tier) — all overlap probe device time with the in-flight
    march, which owns device 0;
  * ``stats``      — counters and aggregate reporting.

Invariant spanning all layers: speculation (any thread, any depth) only
moves device work earlier — commits happen on the engine thread in
admission order, so rendered frames and the deterministic counters are
bit-identical at every ``prefetch`` depth and ``workers`` count.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax.numpy as jnp

from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from ..framecache.probe import ProbeCache, ProbeMaps, ProbeReuseConfig
from ..framecache.radiance import RadianceCache, RadianceReuseConfig
from ..obs import Registry, engine_tracer, trace as trace_lib
from ..scenecache import SceneBlockCache
from . import admission, executor as executor_lib, pool as pool_lib
from . import scheduler as scheduler_lib
from . import stats as stats_lib
from .admission import RenderRequest, RenderServeConfig  # noqa: F401
from .scheduler import (DEFAULT_CLASS, DeadlinePolicy,  # noqa: F401
                        FifoPolicy, RequestClass, ShedPolicy)

__all__ = ["RenderRequest", "RenderServeConfig", "RenderServingEngine",
           "ProbeReuseConfig", "RadianceReuseConfig", "ProbeMaps",
           "RequestClass", "DEFAULT_CLASS", "FifoPolicy", "DeadlinePolicy",
           "ShedPolicy"]


class RenderServingEngine:
    def __init__(self, fields: Dict[str, FieldFns], acfg: ASDRConfig,
                 rcfg: RenderServeConfig = RenderServeConfig(),
                 scenecache: Optional[SceneBlockCache] = None):
        self.fields = fields
        self.acfg = acfg
        self.rcfg = rcfg
        self.probe_caches: Dict[str, ProbeCache] = {
            name: ProbeCache(rcfg.reuse) for name in fields
        } if rcfg.reuse is not None else {}
        self.radiance_caches: Dict[str, RadianceCache] = {
            name: RadianceCache(rcfg.radiance) for name in fields
        } if rcfg.radiance is not None else {}
        # scene-space block store: an explicitly passed instance is SHARED
        # (several engines over one scene pool their hits); otherwise the
        # engine owns one iff the config asks for it.  Keys carry the
        # scene id, so one store safely serves all of this engine's scenes.
        if scenecache is None and rcfg.scenecache is not None:
            scenecache = SceneBlockCache(rcfg.scenecache)
        self.scenecache = scenecache
        # engine counters (across render() calls) — see serve/stats.py
        self.counters = stats_lib.EngineCounters()
        # observability: the metrics registry always exists (engine_stats
        # reads through it); the tracer only when rcfg.trace asks — None
        # keeps every instrumented call site on the null-span fast path
        self.metrics = Registry()
        self.tracer = engine_tracer(rcfg.trace, self.metrics)
        self._rounds = 0
        self.executor = executor_lib.make_executor(rcfg.workers,
                                                   rcfg.devices)
        # request-lifecycle scheduler (serve/scheduler.py): owns request
        # selection, open-loop arrival gating, and shed/degrade
        # decisions; rcfg.policy None/"fifo" is bit-identical FIFO
        self.scheduler = scheduler_lib.Scheduler(rcfg.policy, self.counters,
                                                 metrics=self.metrics)

    # counter back-compat: eng.blocks_marched etc. read through to the
    # stats layer (only consulted when normal attribute lookup fails)
    def __getattr__(self, name):
        if name in stats_lib.COUNTER_FIELDS:
            return getattr(self.counters, name)
        raise AttributeError(name)

    def close(self):
        """Release executor workers; flush + uninstall the tracer."""
        self.executor.close()
        if self.tracer is not None:
            tcfg = self.rcfg.trace
            if tcfg.metrics_jsonl:     # closing-state snapshot, so short
                self.engine_stats()    # runs still get >= 1 line
                self.metrics.jsonl_snapshot(
                    tcfg.metrics_jsonl,
                    extra={"round": self._rounds, "final": True})
            self.tracer.finish()       # final drain + configured exports
            trace_lib.uninstall(self.tracer)
            self.tracer = None

    def _probe_key(self, req: RenderRequest):
        return admission.probe_key_for(self.rcfg, req)

    def _march_for(self, scene_id: str, density_only: bool = False):
        return pool_lib.batched_march(self.fields[scene_id], self.acfg,
                                      density_only)

    # ---------------------------------------------------------------- serve
    def render(self, requests: List[RenderRequest]) -> List[RenderRequest]:
        """Serve all requests; returns them completed, in finish order.

        Continuous batching: undispatched blocks from every live request
        sit in one budget-sorted pool; each round marches ONE fixed-size
        batch drawn from the pool's largest-budget scene group, then
        finalizes any request whose blocks all returned and admits queued
        requests into freed slots — so new requests enter while older
        ones are still mid-flight, and a batch freely mixes blocks from
        different requests of the same scene.  A radiance-warped frame
        with no disoccluded rays contributes zero blocks and finalizes on
        the round it was admitted.

        Double buffering: after the round's march batch is DISPATCHED
        (async on device) and before its outputs are fetched, Stage A is
        speculated for up to ``prefetch`` queued requests — inline here
        (sync executor) or on worker threads — so probing/warping of
        queued requests overlaps marching of live ones, and admission
        consumes the prepared work with only the commit left to do.
        """
        rcfg = self.rcfg
        t_enqueue = time.time()    # latency clock: queue wait counts
        queue = list(requests)
        live: List[admission.Slot] = []
        done: List[RenderRequest] = []
        pool = pool_lib.BlockPool(self.acfg, rcfg.blocks_per_batch,
                                  self.scenecache, self.counters)
        ex = self.executor
        try:
            return self._serve(queue, live, done, pool, ex, t_enqueue)
        finally:
            # speculation keys are id(request): they must never survive
            # this call (a later call's request can reuse a freed id,
            # and a mid-call exception would otherwise strand results)
            ex.reset()

    def _serve(self, queue, live, done, pool, ex, t_enqueue):
        rcfg = self.rcfg
        sched = self.scheduler
        while queue or live:
            # admission per the scheduler policy: FIFO by default (the
            # bit-identical pre-scheduler loop), EDF/shed opt-in — see
            # serve/scheduler.py for the selection/degrade contract
            sched.admit_ready(self, queue, live, pool, ex, t_enqueue)

            pool.sweep()
            # streaming dispatch: up to inflight_batches batches launch
            # back-to-back (next group fills idle launches), ALL in
            # flight before any collect — see pool.dispatch_round
            t_march = time.time()
            inflights = pool.dispatch_round(
                self._march_for, max(rcfg.inflight_batches, 1))

            # Stage-A prefetch: speculate admissions for the policy's
            # next arrived requests while the round is in flight
            sched.speculate(self, queue, live, ex, t_enqueue)

            for inflight in inflights:
                pool.collect(inflight)
            if inflights:
                self.counters.note_round(time.time() - t_march,
                                         len(inflights))

            still = []
            for slot in live:
                if slot.pending == 0:
                    done.append(self._finalize(slot))
                else:
                    still.append(slot)
            live = still
            if self.tracer is not None:
                self._obs_round()
        return done

    def _obs_round(self):
        """Per-round observability housekeeping (tracing on only):
        drain thread buffers into the tracer store / flight recorder /
        span histograms, and emit a periodic metrics JSONL snapshot."""
        self.tracer.drain()
        tcfg = self.rcfg.trace
        self._rounds += 1
        if (tcfg.metrics_jsonl
                and self._rounds % max(tcfg.metrics_every, 1) == 0):
            self.engine_stats()        # refresh the registry gauges
            self.metrics.jsonl_snapshot(tcfg.metrics_jsonl,
                                        extra={"round": self._rounds})

    def _finalize(self, slot: admission.Slot) -> RenderRequest:
        req = slot.finalize(self.acfg)
        self.counters.note_finalized(req.stats, req.latency_s)
        self.scheduler.note_finalized(slot)   # service-time EWMA feed
        # only frames with full marched acc/depth feed the radiance cache
        # (framecache safety invariant: warps never chain) — that means
        # fully-rendered frames, plus density-REFRESHED warped frames
        # (opt-in), whose warp-valid rays re-marched acc/depth through
        # the color-free path.  The stored depth is the MARCH's per-ray
        # termination depth — always pose-aligned (so even dilation-mode
        # probe-reuse frames, whose probe maps carry depth=None, are
        # cacheable) and sharper than the probe's stride-d proxy.
        rad = self.radiance_caches.get(req.scene)
        if rad is not None and slot.acc_full is not None:
            R = req.cam.height * req.cam.width
            rad.store(req.cam, self.acfg,
                      jnp.asarray(req.image.reshape(R, 3)),
                      jnp.asarray(slot.acc_full),
                      jnp.asarray(slot.depth_full))
        return req

    # ---------------------------------------------------------------- stats
    def engine_stats(self) -> Dict:
        return stats_lib.engine_stats(self.counters, self.probe_caches,
                                      self.radiance_caches, self.scenecache,
                                      registry=self.metrics)
