"""Batched multi-view render serving engine with cross-frame reuse.

The render analogue of serve/engine.py's slot-based LM engine: render
requests (camera pose + scene) occupy ``slots``; every scheduling round the
Phase-II blocks of ALL live requests are pooled, sorted by sample budget,
and marched through a single jitted batched ``_march_block`` — so MXU/VPU
utilization depends only on the pooled block stream, not on which request
each block belongs to (continuous batching for rays).

Cross-frame reuse goes through ``repro.framecache``:

  * Phase I — ``framecache.probe``: a request whose pose is within the
    configured angular/translation distance of a previously probed pose
    gets that pose's count/opacity/depth maps reprojected by the pose
    delta (warped, disocclusions filled conservatively), so most frames
    of a smooth trajectory pay zero probe cost.
  * Phase II — ``framecache.radiance`` (opt-in via
    ``RenderServeConfig.radiance``): a finished frame within the radiance
    radius is warped to the requesting pose; the slot marches ONLY the
    disoccluded rays and composites them over the warp — most rays skip
    the field network entirely.

Scene-space block reuse (``repro.scenecache``, opt-in via
``RenderServeConfig.scenecache`` or a shared ``SceneBlockCache`` passed
to the constructor) sits below both: every pooled block carries a key
derived from its quantized voxel footprint + view bucket; blocks whose
key is resident in the shared byte-budgeted store skip the march and
composite directly, and marched blocks populate it — so N concurrent
users of one scene share hits and bounded memory instead of N per-pose
LRUs.  ``scenecache=None`` (default) leaves the pooled-march path
bit-identical to the pre-scenecache engine.

Batches have a fixed block count (``blocks_per_batch``); the trailing
partial batch is padded with unit-budget dummy blocks, so each scene
compiles exactly one batched march.  Budget-descending order keeps batches
budget-homogeneous — the property launch/render_serve.py relies on to
shard a batch's blocks over the ``data`` mesh axis without stragglers.

Single-device in this container; launch/render_serve.py lowers the same
pooled march sharded over the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from ..framecache.probe import (ProbeCache, ProbeMaps, ProbeReuseConfig,
                                cached_probe_maps)
from ..framecache.radiance import RadianceCache, RadianceReuseConfig
from ..scenecache import SceneBlockCache, SceneCacheConfig
from ..scenecache import key as scenecache_key


# jitted batched marches shared across engine instances: keyed by the
# (FieldFns, ASDRConfig) pair (both hashable), so an engine restart or a
# parallel engine over the same scene reuses the compiled executable.
# LRU-bounded: a reloaded/retrained scene makes fresh FieldFns closures,
# and without eviction the stale executables (and the params their
# closures capture) would pile up for the process lifetime.
# NOTE: the march closes over fns — fine for analytic fields (no arrays);
# an NGP-backed production path should pass params as jit ARGS instead,
# which is exactly what launch/render_serve.build_pooled_march_cell does.
_MARCH_CACHE: OrderedDict = OrderedDict()
_MARCH_CACHE_MAX = 32


@dataclasses.dataclass(frozen=True)
class RenderServeConfig:
    slots: int = 4
    blocks_per_batch: int = 16
    reuse: Optional[ProbeReuseConfig] = ProbeReuseConfig()
    # warped-radiance reuse is opt-in: None keeps the engine bit-identical
    # to the single-image pipeline (the identity tests rely on this)
    radiance: Optional[RadianceReuseConfig] = None
    # scene-space block reuse (repro.scenecache) is likewise opt-in: None
    # leaves the pooled-march path untouched.  An explicit SceneBlockCache
    # instance passed to the engine constructor overrides this config —
    # that is how several engines over one scene share a single store.
    scenecache: Optional[SceneCacheConfig] = None
    probe_seed: Optional[int] = None   # None = deterministic midpoint probe


@dataclasses.dataclass
class RenderRequest:
    rid: int
    scene: str                         # key into the engine's field table
    cam: scene.Camera
    image: Optional[np.ndarray] = None   # (H, W, 3) on completion
    stats: Dict = dataclasses.field(default_factory=dict)
    latency_s: float = 0.0


class _Slot:
    """A live request: its sorted-block layout and result buffers.

    With radiance reuse, ``march_idx`` selects the disoccluded rays the
    slot actually marches (None = all rays) and ``base_rgb`` holds the
    warped cached frame the marched rays composite over.
    """

    def __init__(self, req: RenderRequest, rays, order, budgets, pad: int,
                 maps: ProbeMaps, reused: bool, block_size: int,
                 march_idx: Optional[np.ndarray] = None,
                 base_rgb: Optional[np.ndarray] = None,
                 warp_valid_fraction: float = 0.0):
        self.req = req
        self.rays = rays                 # padded (origins, dirs) of marched rays
        self.order = order
        self.budgets = budgets
        self.pad = pad
        self.maps = maps
        self.reused = reused
        self.block_size = block_size
        self.march_idx = march_idx
        self.base_rgb = base_rgb
        self.warp_valid_fraction = warp_valid_fraction
        n_blocks = budgets.shape[0]
        self.rgb = np.zeros((n_blocks, block_size, 3), np.float32)
        self.acc = np.zeros((n_blocks, block_size), np.float32)
        self.depth = np.zeros((n_blocks, block_size), np.float32)
        self.chunks = np.zeros((n_blocks,), np.int64)
        self.cached_blocks = 0        # delivered from the scene store
        self.cached_chunks = 0
        self.pending = n_blocks
        self.t0 = time.time()

    def emit_blocks(self, origins, dirs):
        """(slot, block_index, o (B,3), d (B,3), budget) work items."""
        B = self.block_size
        o_s = origins[self.order].reshape(-1, B, 3)
        d_s = dirs[self.order].reshape(-1, B, 3)
        for bi in range(self.budgets.shape[0]):
            yield (self, bi, o_s[bi], d_s[bi], int(self.budgets[bi]))

    def deliver(self, bi: int, rgb, acc, depth, chunks, cached: bool = False):
        self.rgb[bi] = rgb
        self.acc[bi] = acc
        self.depth[bi] = depth
        self.chunks[bi] = chunks
        if cached:
            self.cached_blocks += 1
            self.cached_chunks += int(chunks)
        self.pending -= 1

    def finalize(self, acfg: ASDRConfig) -> RenderRequest:
        req = self.req
        H, W = req.cam.height, req.cam.width
        R = H * W
        Rp = self.order.shape[0]
        if Rp:
            inv = np.zeros((Rp,), np.int64)
            inv[np.asarray(self.order)] = np.arange(Rp)
            flat = self.rgb.reshape(Rp, 3)[inv]
            acc_flat = self.acc.reshape(Rp)[inv]
            depth_flat = self.depth.reshape(Rp)[inv]
        else:
            flat = np.zeros((0, 3), np.float32)
            acc_flat = np.zeros((0,), np.float32)
            depth_flat = np.zeros((0,), np.float32)
        if self.march_idx is None:
            img_flat = flat[:R]
            self.acc_full = acc_flat[:R]
            # the march's per-ray termination depth: what the radiance
            # cache warps this frame with (sharper than the probe's
            # stride-d proxy at depth edges)
            self.depth_full = depth_flat[:R]
            rays_marched = R
        else:
            img_flat = self.base_rgb.copy()
            img_flat[self.march_idx] = flat[: self.march_idx.size]
            self.acc_full = None       # warped frames are never re-cached
            self.depth_full = None
            rays_marched = int(self.march_idx.size)
        req.image = img_flat.reshape(H, W, 3)
        req.latency_s = time.time() - self.t0
        req.stats = {
            "probe_samples": self.maps.cost,
            "probe_reused": self.reused,
            "radiance_reused": self.march_idx is not None,
            "rays_marched": rays_marched,
            "rays_total": R,
            "warp_valid_fraction": self.warp_valid_fraction,
            # compute actually spent: scene-store hits replay stored
            # outputs without marching, so their chunks count as REUSED
            # samples, not processed ones — the compute-fraction metrics
            # must show the scene tier's savings
            "samples_processed":
                (int(self.chunks.sum()) - self.cached_chunks)
                * self.block_size * acfg.chunk,
            "samples_reused": self.cached_chunks
            * self.block_size * acfg.chunk,
            "scene_block_hits": self.cached_blocks,
            # padded ray count, matching render_adaptive's stats — the
            # numerator includes the pad rays' chunks, so the denominator
            # must too or the fraction inflates (and can exceed 1.0)
            "baseline_samples": Rp * acfg.ns_full,
        }
        return req


class RenderServingEngine:
    def __init__(self, fields: Dict[str, FieldFns], acfg: ASDRConfig,
                 rcfg: RenderServeConfig = RenderServeConfig(),
                 scenecache: Optional[SceneBlockCache] = None):
        self.fields = fields
        self.acfg = acfg
        self.rcfg = rcfg
        self.probe_caches: Dict[str, ProbeCache] = {
            name: ProbeCache(rcfg.reuse) for name in fields
        } if rcfg.reuse is not None else {}
        self.radiance_caches: Dict[str, RadianceCache] = {
            name: RadianceCache(rcfg.radiance) for name in fields
        } if rcfg.radiance is not None else {}
        # scene-space block store: an explicitly passed instance is SHARED
        # (several engines over one scene pool their hits); otherwise the
        # engine owns one iff the config asks for it.  Keys carry the
        # scene id, so one store safely serves all of this engine's scenes.
        if scenecache is None and rcfg.scenecache is not None:
            scenecache = SceneBlockCache(rcfg.scenecache)
        self.scenecache = scenecache
        # engine counters (across render() calls)
        self.frames = 0
        self.batches = 0
        self.blocks_marched = 0
        self.pad_blocks = 0
        self.rays_marched = 0
        self.rays_total = 0
        self.scene_blocks_hit = 0

    # ---------------------------------------------------------------- march
    def _batched_march(self, scene_id: str):
        """One jitted (N, B)-block march per scene — N = blocks_per_batch."""
        fns = self.fields[scene_id]
        key = (fns, self.acfg)
        if key not in _MARCH_CACHE:
            march = partial(pipeline._march_block, fns, self.acfg)
            _MARCH_CACHE[key] = jax.jit(
                lambda o, d, b: jax.lax.map(lambda a: march(*a), (o, d, b))
            )
            while len(_MARCH_CACHE) > _MARCH_CACHE_MAX:
                _MARCH_CACHE.popitem(last=False)
        _MARCH_CACHE.move_to_end(key)
        return _MARCH_CACHE[key]

    # ---------------------------------------------------------------- admit
    def _admit(self, req: RenderRequest) -> _Slot:
        acfg = self.acfg
        fns = self.fields[req.scene]
        cache = self.probe_caches.get(req.scene)
        key = (None if self.rcfg.probe_seed is None
               else jax.random.PRNGKey(self.rcfg.probe_seed + req.rid))
        maps, reused = cached_probe_maps(fns, acfg, req.cam, cache, key)
        o, d = scene.camera_rays(req.cam)
        counts, opacity = maps.counts, maps.opacity

        rad = self.radiance_caches.get(req.scene)
        warped = rad.lookup(req.cam, acfg) if rad is not None else None
        march_idx = base_rgb = None
        vf = 0.0
        if warped is not None:
            march_idx = np.flatnonzero(~warped.valid)
            base_rgb = np.asarray(warped.rgb)
            vf = warped.valid_fraction
            sel = jnp.asarray(march_idx, jnp.int32)
            o, d = o[sel], d[sel]
            counts, opacity = counts[sel], opacity[sel]

        o, d, counts, opacity, pad = pipeline.pad_rays_to_blocks(
            acfg, o, d, counts, opacity)
        order, budgets = pipeline.block_sort(acfg, counts, opacity)
        return _Slot(req, (o, d), np.asarray(order), np.asarray(budgets),
                     pad, maps, reused, acfg.block_size,
                     march_idx=march_idx, base_rgb=base_rgb,
                     warp_valid_fraction=vf)

    def _keyed_items(self, slot: _Slot) -> List[tuple]:
        """The slot's work items, extended to (..., key, cell) — blocks
        already resident in the scene store deliver HERE (their one
        counted lookup) and never enter the pool.

        With the scene tier off both fields are None and the pooled-march
        path below is byte-for-byte the pre-scenecache behavior.
        """
        items = list(slot.emit_blocks(*slot.rays))
        if self.scenecache is None or not items:
            return [it + (None, None) for it in items]
        o_np = np.stack([np.asarray(it[2]) for it in items])
        d_np = np.stack([np.asarray(it[3]) for it in items])
        buds = np.asarray([it[4] for it in items])
        kcs = scenecache_key.block_keys(
            self.scenecache.cfg, slot.req.scene, self.acfg, o_np, d_np, buds)
        pending = []
        for it, kc in zip(items, kcs):
            out = self.scenecache.lookup(kc[0])
            if out is None:
                pending.append(it + kc)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.scene_blocks_hit += 1
        return pending

    def _sweep_pool(self, pool: List[tuple]) -> List[tuple]:
        """Deliver every pooled block whose key BECAME resident; keep the
        rest.

        Runs once per scheduling round, so a block marched (and stored)
        for one request satisfies an identical block another client
        pooled in the SAME round — cross-request sharing without any
        inter-slot coordination.  Pool items already recorded their miss
        at admission, so these re-checks don't count misses (hits do).
        """
        rest = []
        for it in pool:
            out = (self.scenecache.lookup(it[5], count_miss=False)
                   if it[5] is not None else None)
            if out is None:
                rest.append(it)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.scene_blocks_hit += 1
        return rest

    # ---------------------------------------------------------------- serve
    def render(self, requests: List[RenderRequest]) -> List[RenderRequest]:
        """Serve all requests; returns them completed, in finish order.

        Continuous batching: undispatched blocks from every live request
        sit in one budget-sorted pool; each round marches ONE fixed-size
        batch drawn from the pool's largest-budget scene group, then
        finalizes any request whose blocks all returned and admits queued
        requests into freed slots — so new requests enter while older
        ones are still mid-flight, and a batch freely mixes blocks from
        different requests of the same scene.  A radiance-warped frame
        with no disoccluded rays contributes zero blocks and finalizes on
        the round it was admitted.
        """
        rcfg = self.rcfg
        B = self.acfg.block_size
        queue = list(requests)
        live: List[_Slot] = []
        pool: List[tuple] = []   # undispatched (slot, bi, o, d, budget)
        done: List[RenderRequest] = []

        while queue or live:
            while queue and len(live) < rcfg.slots:
                slot = self._admit(queue.pop(0))
                live.append(slot)
                pool.extend(self._keyed_items(slot))

            if self.scenecache is not None and pool:
                pool = self._sweep_pool(pool)

            if pool:
                # one batch per round: the largest-budget scene group
                # first, so batches stay budget-homogeneous across requests
                pool.sort(key=lambda it: -it[4])
                scene_id = pool[0][0].req.scene
                batch = [it for it in pool
                         if it[0].req.scene == scene_id][:rcfg.blocks_per_batch]
                taken = set(map(id, batch))
                pool = [it for it in pool if id(it) not in taken]

                # in-batch dedup: identical keys selected together (two
                # clients admitted the same round) march once; followers
                # receive the leader's outputs
                followers: List[tuple] = []
                if self.scenecache is not None:
                    uniq, seen = [], {}
                    for it in batch:
                        if it[5] is not None and it[5] in seen:
                            followers.append((it, seen[it[5]]))
                        else:
                            if it[5] is not None:
                                seen[it[5]] = len(uniq)
                            uniq.append(it)
                    batch = uniq

                march = self._batched_march(scene_id)
                N = rcfg.blocks_per_batch
                n_pad = N - len(batch)
                o_b = jnp.stack([it[2] for it in batch]
                                + [jnp.zeros((B, 3))] * n_pad)
                d_b = jnp.stack([it[3] for it in batch]
                                + [jnp.tile(jnp.asarray([[0., 0., 1.]]),
                                            (B, 1))] * n_pad)
                budgets = jnp.asarray(
                    [it[4] for it in batch] + [1] * n_pad, jnp.int32)
                rgb, acc, depth, chunks = march(o_b, d_b, budgets)
                rgb = np.asarray(rgb)
                acc = np.asarray(acc)
                depth = np.asarray(depth)
                chunks = np.asarray(chunks)
                for i, it in enumerate(batch):
                    it[0].deliver(it[1], rgb[i], acc[i], depth[i], chunks[i])
                    if it[5] is not None:
                        self.scenecache.store(it[5], it[6], rgb[i], acc[i],
                                              depth[i], int(chunks[i]))
                for it, li in followers:
                    it[0].deliver(it[1], rgb[li], acc[li], depth[li],
                                  chunks[li], cached=True)
                    self.scene_blocks_hit += 1
                self.batches += 1
                self.blocks_marched += len(batch)
                self.pad_blocks += n_pad

            still = []
            for slot in live:
                if slot.pending == 0:
                    done.append(self._finalize(slot))
                else:
                    still.append(slot)
            live = still
        return done

    def _finalize(self, slot: _Slot) -> RenderRequest:
        req = slot.finalize(self.acfg)
        self.frames += 1
        self.rays_marched += req.stats["rays_marched"]
        self.rays_total += req.stats["rays_total"]
        # only fully-rendered frames feed the radiance cache (framecache
        # safety invariant: warps never chain).  The stored depth is the
        # MARCH's per-ray termination depth — always pose-aligned (so even
        # dilation-mode probe-reuse frames, whose probe maps carry
        # depth=None, are cacheable) and sharper than the probe's stride-d
        # proxy at depth edges.
        rad = self.radiance_caches.get(req.scene)
        if rad is not None and slot.march_idx is None:
            R = req.cam.height * req.cam.width
            rad.store(req.cam, self.acfg,
                      jnp.asarray(req.image.reshape(R, 3)),
                      jnp.asarray(slot.acc_full),
                      jnp.asarray(slot.depth_full))
        return req

    # ---------------------------------------------------------------- stats
    def engine_stats(self) -> Dict:
        out = {
            "frames": self.frames,
            "batches": self.batches,
            "blocks_marched": self.blocks_marched,
            "pad_block_fraction": (
                self.pad_blocks / max(self.blocks_marched + self.pad_blocks, 1)
            ),
            "rays_marched": self.rays_marched,
            "rays_total": self.rays_total,
            "rays_marched_fraction": (
                self.rays_marched / max(self.rays_total, 1)),
        }
        hits = sum(c.hits for c in self.probe_caches.values())
        misses = sum(c.misses for c in self.probe_caches.values())
        out["probe_hits"] = hits
        out["probe_misses"] = misses
        out["reused_probe_fraction"] = hits / max(hits + misses, 1)
        out["probe_refreshes"] = sum(
            c.refreshes for c in self.probe_caches.values())
        r_hits = sum(c.hits for c in self.radiance_caches.values())
        r_miss = sum(c.misses for c in self.radiance_caches.values())
        out["radiance_hits"] = r_hits
        out["radiance_misses"] = r_miss
        out["reused_radiance_fraction"] = r_hits / max(r_hits + r_miss, 1)
        # scene-space block tier: hit rate over blocks that needed output
        # (delivered from the shared store vs actually marched; pad blocks
        # excluded from both sides)
        out["scene_block_hits"] = self.scene_blocks_hit
        out["scene_block_hit_rate"] = self.scene_blocks_hit / max(
            self.scene_blocks_hit + self.blocks_marched, 1)
        if self.scenecache is not None:
            out["scenecache"] = self.scenecache.stats()
        return out
