"""Batched multi-view render serving engine with cross-frame reuse.

The render analogue of serve/engine.py's slot-based LM engine: render
requests (camera pose + scene) occupy ``slots``; every scheduling round the
Phase-II blocks of ALL live requests are pooled, sorted by sample budget,
and marched through a single jitted batched ``_march_block`` — so MXU/VPU
utilization depends only on the pooled block stream, not on which request
each block belongs to (continuous batching for rays).

Cross-frame reuse goes through ``repro.framecache``:

  * Phase I — ``framecache.probe``: a request whose pose is within the
    configured angular/translation distance of a previously probed pose
    gets that pose's count/opacity/depth maps reprojected by the pose
    delta (warped, disocclusions filled conservatively), so most frames
    of a smooth trajectory pay zero probe cost.
  * Phase II — ``framecache.radiance`` (opt-in via
    ``RenderServeConfig.radiance``): a finished frame within the radiance
    radius is warped to the requesting pose; the slot marches ONLY the
    disoccluded rays and composites them over the warp — most rays skip
    the field network entirely.

Admission is RADIANCE-FIRST and double-buffered: the radiance lookup
runs before Phase I, so a full warp hit (zero disoccluded rays) skips
the probe outright (booked via ``ProbeCache.note_skip``), and Stage A of
admission (``_prepare`` — the plans plus their probe/warp device work)
is speculated for queued requests while the round's march batch is in
flight, with all cache bookkeeping committed only when a slot is
actually consumed (``_admit``) — so rendered frames and counters are
bit-identical at every ``RenderServeConfig.prefetch`` depth.

Scene-space block reuse (``repro.scenecache``, opt-in via
``RenderServeConfig.scenecache`` or a shared ``SceneBlockCache`` passed
to the constructor) sits below both: every pooled block carries a key
derived from its quantized voxel footprint + view bucket; blocks whose
key is resident in the shared byte-budgeted store skip the march and
composite directly, and marched blocks populate it — so N concurrent
users of one scene share hits and bounded memory instead of N per-pose
LRUs.  ``scenecache=None`` (default) leaves the pooled-march path
bit-identical to the pre-scenecache engine.

Batches have a fixed block count (``blocks_per_batch``); the trailing
partial batch is padded with unit-budget dummy blocks, so each scene
compiles exactly one batched march.  Budget-descending order keeps batches
budget-homogeneous — the property launch/render_serve.py relies on to
shard a batch's blocks over the ``data`` mesh axis without stragglers.

Single-device in this container; launch/render_serve.py lowers the same
pooled march sharded over the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from ..framecache import probe as fc_probe
from ..framecache import radiance as fc_radiance
from ..framecache.probe import ProbeCache, ProbeMaps, ProbeReuseConfig
from ..framecache.radiance import RadianceCache, RadianceReuseConfig
from ..scenecache import SceneBlockCache, SceneCacheConfig
from ..scenecache import key as scenecache_key


# jitted batched marches shared across engine instances: keyed by the
# (FieldFns, ASDRConfig) pair (both hashable), so an engine restart or a
# parallel engine over the same scene reuses the compiled executable.
# LRU-bounded: a reloaded/retrained scene makes fresh FieldFns closures,
# and without eviction the stale executables (and the params their
# closures capture) would pile up for the process lifetime.
# NOTE: the march closes over fns — fine for analytic fields (no arrays);
# an NGP-backed production path should pass params as jit ARGS instead,
# which is exactly what launch/render_serve.build_pooled_march_cell does.
_MARCH_CACHE: OrderedDict = OrderedDict()
_MARCH_CACHE_MAX = 32


@dataclasses.dataclass(frozen=True)
class RenderServeConfig:
    slots: int = 4
    blocks_per_batch: int = 16
    reuse: Optional[ProbeReuseConfig] = ProbeReuseConfig()
    # warped-radiance reuse is opt-in: None keeps the engine bit-identical
    # to the single-image pipeline (the identity tests rely on this)
    radiance: Optional[RadianceReuseConfig] = None
    # scene-space block reuse (repro.scenecache) is likewise opt-in: None
    # leaves the pooled-march path untouched.  An explicit SceneBlockCache
    # instance passed to the engine constructor overrides this config —
    # that is how several engines over one scene share a single store.
    scenecache: Optional[SceneCacheConfig] = None
    probe_seed: Optional[int] = None   # None = deterministic midpoint probe
    # Stage-A lookahead: up to this many QUEUED requests have their
    # radiance lookup + probe speculated each round while the dispatched
    # march is still in flight (0 = fully synchronous admission).  All
    # cache bookkeeping commits at admission regardless, so rendered
    # frames and counters are bit-identical at every prefetch depth —
    # speculation only moves the device work earlier.
    prefetch: int = 2


@dataclasses.dataclass
class RenderRequest:
    rid: int
    scene: str                         # key into the engine's field table
    cam: scene.Camera
    image: Optional[np.ndarray] = None   # (H, W, 3) on completion
    stats: Dict = dataclasses.field(default_factory=dict)
    latency_s: float = 0.0


@dataclasses.dataclass
class _Prepared:
    """Stage-A speculation for one queued request (see _prepare): pure
    plans plus their executed device work, awaiting admission commit."""
    req: RenderRequest
    rplan: Optional["fc_radiance.RadiancePlan"]
    pplan: Optional["fc_probe.ProbePlan"]
    maps: Optional[ProbeMaps]
    prep_s: float


class _Slot:
    """A live request: its sorted-block layout and result buffers.

    With radiance reuse, ``march_idx`` selects the disoccluded rays the
    slot actually marches (None = all rays) and ``base_rgb`` holds the
    warped cached frame the marched rays composite over.
    """

    def __init__(self, req: RenderRequest, rays, order, budgets, pad: int,
                 maps: Optional[ProbeMaps], reused: bool, block_size: int,
                 march_idx: Optional[np.ndarray] = None,
                 base_rgb: Optional[np.ndarray] = None,
                 warp_valid_fraction: float = 0.0,
                 probe_skipped: bool = False,
                 t_enqueue: Optional[float] = None):
        self.req = req
        self.rays = rays                 # padded (origins, dirs) of marched rays
        self.order = order
        self.budgets = budgets
        self.pad = pad
        self.maps = maps                 # None on a full radiance hit (skip)
        self.reused = reused
        self.probe_skipped = probe_skipped
        self.block_size = block_size
        self.march_idx = march_idx
        self.base_rgb = base_rgb
        self.warp_valid_fraction = warp_valid_fraction
        n_blocks = budgets.shape[0]
        self.rgb = np.zeros((n_blocks, block_size, 3), np.float32)
        self.acc = np.zeros((n_blocks, block_size), np.float32)
        self.depth = np.zeros((n_blocks, block_size), np.float32)
        self.chunks = np.zeros((n_blocks,), np.int64)
        self.cached_blocks = 0        # delivered from the scene store
        self.cached_chunks = 0
        self.pending = n_blocks
        # latency clock starts at ENQUEUE (render() entry), not slot
        # construction — latency_s must cover queue wait + admission
        # (probe/warp) + march end-to-end under the double-buffered path
        self.t0 = time.time() if t_enqueue is None else t_enqueue
        self.admission_s = 0.0        # total Stage-A + Stage-B work time
        self.admit_stall_s = 0.0      # blocking Stage-B time at admission

    def emit_blocks(self, origins, dirs):
        """(slot, block_index, o (B,3), d (B,3), budget) work items."""
        B = self.block_size
        o_s = origins[self.order].reshape(-1, B, 3)
        d_s = dirs[self.order].reshape(-1, B, 3)
        for bi in range(self.budgets.shape[0]):
            yield (self, bi, o_s[bi], d_s[bi], int(self.budgets[bi]))

    def deliver(self, bi: int, rgb, acc, depth, chunks, cached: bool = False):
        self.rgb[bi] = rgb
        self.acc[bi] = acc
        self.depth[bi] = depth
        self.chunks[bi] = chunks
        if cached:
            self.cached_blocks += 1
            self.cached_chunks += int(chunks)
        self.pending -= 1

    def finalize(self, acfg: ASDRConfig) -> RenderRequest:
        req = self.req
        H, W = req.cam.height, req.cam.width
        R = H * W
        Rp = self.order.shape[0]
        if Rp:
            inv = np.zeros((Rp,), np.int64)
            inv[np.asarray(self.order)] = np.arange(Rp)
            flat = self.rgb.reshape(Rp, 3)[inv]
            acc_flat = self.acc.reshape(Rp)[inv]
            depth_flat = self.depth.reshape(Rp)[inv]
        else:
            flat = np.zeros((0, 3), np.float32)
            acc_flat = np.zeros((0,), np.float32)
            depth_flat = np.zeros((0,), np.float32)
        if self.march_idx is None:
            img_flat = flat[:R]
            self.acc_full = acc_flat[:R]
            # the march's per-ray termination depth: what the radiance
            # cache warps this frame with (sharper than the probe's
            # stride-d proxy at depth edges)
            self.depth_full = depth_flat[:R]
            rays_marched = R
        else:
            img_flat = self.base_rgb.copy()
            img_flat[self.march_idx] = flat[: self.march_idx.size]
            self.acc_full = None       # warped frames are never re-cached
            self.depth_full = None
            rays_marched = int(self.march_idx.size)
        req.image = img_flat.reshape(H, W, 3)
        req.latency_s = time.time() - self.t0
        # rays delivered straight from the warp: had they marched, the
        # fixed-budget baseline would have spent ns_full samples each —
        # the same convention baseline_samples uses — so zero-march
        # frames report reused compute instead of silently vanishing
        # from the samples split
        warp_rays = 0 if self.march_idx is None else R - rays_marched
        req.stats = {
            "probe_samples": 0 if self.maps is None else self.maps.cost,
            "probe_reused": self.reused,
            "probe_skipped": self.probe_skipped,
            "radiance_reused": self.march_idx is not None,
            "rays_marched": rays_marched,
            "rays_total": R,
            "warp_valid_fraction": self.warp_valid_fraction,
            # compute actually spent: scene-store hits replay stored
            # outputs without marching, so their chunks count as REUSED
            # samples, not processed ones — the compute-fraction metrics
            # must show the scene tier's savings
            "samples_processed":
                (int(self.chunks.sum()) - self.cached_chunks)
                * self.block_size * acfg.chunk,
            "samples_reused": self.cached_chunks
            * self.block_size * acfg.chunk + warp_rays * acfg.ns_full,
            "scene_block_hits": self.cached_blocks,
            # padded ray count, matching render_adaptive's stats — the
            # numerator includes the pad rays' chunks, so the denominator
            # must too or the fraction inflates (and can exceed 1.0)
            "baseline_samples": Rp * acfg.ns_full,
            "admission_s": self.admission_s,
            "admit_stall_s": self.admit_stall_s,
        }
        return req


class RenderServingEngine:
    def __init__(self, fields: Dict[str, FieldFns], acfg: ASDRConfig,
                 rcfg: RenderServeConfig = RenderServeConfig(),
                 scenecache: Optional[SceneBlockCache] = None):
        self.fields = fields
        self.acfg = acfg
        self.rcfg = rcfg
        self.probe_caches: Dict[str, ProbeCache] = {
            name: ProbeCache(rcfg.reuse) for name in fields
        } if rcfg.reuse is not None else {}
        self.radiance_caches: Dict[str, RadianceCache] = {
            name: RadianceCache(rcfg.radiance) for name in fields
        } if rcfg.radiance is not None else {}
        # scene-space block store: an explicitly passed instance is SHARED
        # (several engines over one scene pool their hits); otherwise the
        # engine owns one iff the config asks for it.  Keys carry the
        # scene id, so one store safely serves all of this engine's scenes.
        if scenecache is None and rcfg.scenecache is not None:
            scenecache = SceneBlockCache(rcfg.scenecache)
        self.scenecache = scenecache
        # engine counters (across render() calls)
        self.frames = 0
        self.batches = 0
        self.blocks_marched = 0
        self.pad_blocks = 0
        self.rays_marched = 0
        self.rays_total = 0
        self.scene_blocks_hit = 0
        self.admissions = 0
        self.full_radiance_hits = 0   # admissions that skipped Phase I
        self.misprepares = 0          # speculated Stage-A work discarded
        self.samples_processed = 0
        self.samples_reused = 0

    # ---------------------------------------------------------------- march
    def _batched_march(self, scene_id: str):
        """One jitted (N, B)-block march per scene — N = blocks_per_batch."""
        fns = self.fields[scene_id]
        key = (fns, self.acfg)
        if key not in _MARCH_CACHE:
            march = partial(pipeline._march_block, fns, self.acfg)
            _MARCH_CACHE[key] = jax.jit(
                lambda o, d, b: jax.lax.map(lambda a: march(*a), (o, d, b))
            )
            while len(_MARCH_CACHE) > _MARCH_CACHE_MAX:
                _MARCH_CACHE.popitem(last=False)
        _MARCH_CACHE.move_to_end(key)
        return _MARCH_CACHE[key]

    # ---------------------------------------------------------------- admit
    #
    # Admission is a two-stage, radiance-first pipeline:
    #
    #   Stage A (_prepare) — PURE speculation, run ahead of need for
    #     queued requests while the dispatched march is in flight:
    #     radiance plan first (warp included), and ONLY on a non-full
    #     hit a probe plan + its device execution.  No cache mutates.
    #   Stage B (_admit) — the scheduling round consumes a slot: every
    #     plan is revalidated against the CURRENT cache state and the
    #     bookkeeping commits here, so admission decisions — and hence
    #     rendered frames and counters — are bit-identical at every
    #     prefetch depth; a stale speculation is simply recomputed
    #     (counted in ``misprepares``).
    #
    # Ordering is the bugfix: the radiance lookup runs BEFORE Phase I,
    # so a full warp hit (zero disoccluded rays) never pays the probe it
    # would immediately discard — the skip is booked explicitly via
    # ProbeCache.note_skip so reuse fractions and staleness bounds stay
    # coherent.

    def _probe_key(self, req: RenderRequest):
        return (None if self.rcfg.probe_seed is None
                else jax.random.PRNGKey(self.rcfg.probe_seed + req.rid))

    def _prepare(self, req: RenderRequest) -> "_Prepared":
        """Stage A: speculate the admission's device work (radiance warp,
        probe/warp maps) without touching any cache — dispatchable while
        live requests are still marching."""
        t0 = time.time()
        acfg = self.acfg
        rad = self.radiance_caches.get(req.scene)
        rplan = (fc_radiance.plan_lookup(rad, req.cam, acfg)
                 if rad is not None else None)
        pplan = maps = None
        if rplan is None or not rplan.full_hit:
            cache = self.probe_caches.get(req.scene)
            pplan = fc_probe.plan_probe(cache, req.cam, acfg)
            maps = fc_probe.execute_probe_plan(
                self.fields[req.scene], acfg, req.cam, pplan,
                self._probe_key(req),
                rcfg=cache.rcfg if cache is not None else None)
        return _Prepared(req, rplan, pplan, maps, time.time() - t0)

    def _admit(self, req: RenderRequest,
               prepared: Optional["_Prepared"] = None,
               t_enqueue: Optional[float] = None) -> _Slot:
        """Stage B: commit the admission against current cache state."""
        t0 = time.time()
        acfg = self.acfg
        fns = self.fields[req.scene]
        self.admissions += 1

        # radiance FIRST: a full warp hit delivers without ever probing
        rad = self.radiance_caches.get(req.scene)
        warped = None
        if rad is not None:
            sp_rplan = prepared.rplan if prepared is not None else None
            rplan = fc_radiance.plan_lookup(rad, req.cam, acfg,
                                            prepared=sp_rplan)
            if (sp_rplan is not None and sp_rplan.warped is not None
                    and sp_rplan.basis != rplan.basis):
                # the speculated warp's source entry changed (rebase /
                # eviction) between Stage A and admission — re-warped
                self.misprepares += 1
            warped = fc_radiance.commit_lookup(rad, rplan)

        cache = self.probe_caches.get(req.scene)
        probe_skipped = warped is not None and warped.full_hit
        if probe_skipped:
            if cache is not None:
                cache.note_skip()
            self.full_radiance_hits += 1
            if prepared is not None and prepared.maps is not None:
                # speculated a probe for a frame that turned out fully
                # warp-served (its source finished after Stage A ran)
                self.misprepares += 1
            maps, reused = None, False
        else:
            pplan = fc_probe.plan_probe(cache, req.cam, acfg)
            if (prepared is not None and prepared.pplan is not None
                    and prepared.pplan.basis == pplan.basis):
                maps = prepared.maps
            else:
                if prepared is not None:
                    self.misprepares += 1
                maps = fc_probe.execute_probe_plan(
                    fns, acfg, req.cam, pplan, self._probe_key(req),
                    rcfg=cache.rcfg if cache is not None else None)
            reused = fc_probe.commit_probe_plan(cache, req.cam, acfg,
                                                pplan, maps)

        march_idx = base_rgb = None
        vf = 0.0
        if warped is not None:
            march_idx = np.flatnonzero(~warped.valid)
            base_rgb = np.asarray(warped.rgb)
            vf = warped.valid_fraction
        if maps is None:
            # full radiance hit: zero blocks — finalizes on the round it
            # was admitted, marching nothing and having probed nothing
            rays = (jnp.zeros((0, 3)), jnp.zeros((0, 3)))
            order = np.zeros((0,), np.int64)
            budgets = np.zeros((0,), np.int64)
            pad = 0
        else:
            o, d = scene.camera_rays(req.cam)
            counts, opacity = maps.counts, maps.opacity
            if march_idx is not None:
                sel = jnp.asarray(march_idx, jnp.int32)
                o, d = o[sel], d[sel]
                counts, opacity = counts[sel], opacity[sel]
            o, d, counts, opacity, pad = pipeline.pad_rays_to_blocks(
                acfg, o, d, counts, opacity)
            order_j, budgets_j = pipeline.block_sort(acfg, counts, opacity)
            rays = (o, d)
            order, budgets = np.asarray(order_j), np.asarray(budgets_j)

        slot = _Slot(req, rays, order, budgets, pad, maps, reused,
                     acfg.block_size, march_idx=march_idx, base_rgb=base_rgb,
                     warp_valid_fraction=vf, probe_skipped=probe_skipped,
                     t_enqueue=t_enqueue)
        slot.admit_stall_s = time.time() - t0
        slot.admission_s = slot.admit_stall_s + (
            prepared.prep_s if prepared is not None else 0.0)
        return slot

    def _keyed_items(self, slot: _Slot) -> List[tuple]:
        """The slot's work items, extended to (..., key, cell) — blocks
        already resident in the scene store deliver HERE (their one
        counted lookup) and never enter the pool.

        With the scene tier off both fields are None and the pooled-march
        path below is byte-for-byte the pre-scenecache behavior.
        """
        items = list(slot.emit_blocks(*slot.rays))
        if self.scenecache is None or not items:
            return [it + (None, None) for it in items]
        o_np = np.stack([np.asarray(it[2]) for it in items])
        d_np = np.stack([np.asarray(it[3]) for it in items])
        buds = np.asarray([it[4] for it in items])
        kcs = scenecache_key.block_keys(
            self.scenecache.cfg, slot.req.scene, self.acfg, o_np, d_np, buds)
        pending = []
        for it, kc in zip(items, kcs):
            out = self.scenecache.lookup(kc[0])
            if out is None:
                pending.append(it + kc)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.scene_blocks_hit += 1
        return pending

    def _sweep_pool(self, pool: List[tuple]) -> List[tuple]:
        """Deliver every pooled block whose key BECAME resident; keep the
        rest.

        Runs once per scheduling round, so a block marched (and stored)
        for one request satisfies an identical block another client
        pooled in the SAME round — cross-request sharing without any
        inter-slot coordination.  Pool items already recorded their miss
        at admission, so these re-checks don't count misses (hits do).
        """
        rest = []
        for it in pool:
            out = (self.scenecache.lookup(it[5], count_miss=False)
                   if it[5] is not None else None)
            if out is None:
                rest.append(it)
            else:
                it[0].deliver(it[1], out.rgb, out.acc, out.depth,
                              out.chunks, cached=True)
                self.scene_blocks_hit += 1
        return rest

    # ---------------------------------------------------------------- serve
    def render(self, requests: List[RenderRequest]) -> List[RenderRequest]:
        """Serve all requests; returns them completed, in finish order.

        Continuous batching: undispatched blocks from every live request
        sit in one budget-sorted pool; each round marches ONE fixed-size
        batch drawn from the pool's largest-budget scene group, then
        finalizes any request whose blocks all returned and admits queued
        requests into freed slots — so new requests enter while older
        ones are still mid-flight, and a batch freely mixes blocks from
        different requests of the same scene.  A radiance-warped frame
        with no disoccluded rays contributes zero blocks and finalizes on
        the round it was admitted.

        Double buffering: after the round's march batch is DISPATCHED
        (async on device) and before its outputs are fetched, Stage A
        (_prepare) speculates the admission work of up to ``prefetch``
        queued requests — probing/warping of queued requests overlaps
        marching of live ones, and the slot-filling loop consumes the
        pre-admitted work with only the commit left to do.
        """
        rcfg = self.rcfg
        B = self.acfg.block_size
        t_enqueue = time.time()    # latency clock: queue wait counts
        queue = list(requests)
        live: List[_Slot] = []
        pool: List[tuple] = []   # undispatched (slot, bi, o, d, budget)
        done: List[RenderRequest] = []
        ready: Dict[int, _Prepared] = {}   # id(req) -> Stage-A speculation

        while queue or live:
            while queue and len(live) < rcfg.slots:
                req = queue.pop(0)
                slot = self._admit(req, prepared=ready.pop(id(req), None),
                                   t_enqueue=t_enqueue)
                live.append(slot)
                pool.extend(self._keyed_items(slot))

            if self.scenecache is not None and pool:
                pool = self._sweep_pool(pool)

            marched = None
            if pool:
                # one batch per round: the largest-budget scene group
                # first, so batches stay budget-homogeneous across requests
                pool.sort(key=lambda it: -it[4])
                scene_id = pool[0][0].req.scene
                batch = [it for it in pool
                         if it[0].req.scene == scene_id][:rcfg.blocks_per_batch]
                taken = set(map(id, batch))
                pool = [it for it in pool if id(it) not in taken]

                # in-batch dedup: identical keys selected together (two
                # clients admitted the same round) march once; followers
                # receive the leader's outputs
                followers: List[tuple] = []
                if self.scenecache is not None:
                    uniq, seen = [], {}
                    for it in batch:
                        if it[5] is not None and it[5] in seen:
                            followers.append((it, seen[it[5]]))
                        else:
                            if it[5] is not None:
                                seen[it[5]] = len(uniq)
                            uniq.append(it)
                    batch = uniq

                march = self._batched_march(scene_id)
                N = rcfg.blocks_per_batch
                n_pad = N - len(batch)
                o_b = jnp.stack([it[2] for it in batch]
                                + [jnp.zeros((B, 3))] * n_pad)
                d_b = jnp.stack([it[3] for it in batch]
                                + [jnp.tile(jnp.asarray([[0., 0., 1.]]),
                                            (B, 1))] * n_pad)
                budgets = jnp.asarray(
                    [it[4] for it in batch] + [1] * n_pad, jnp.int32)
                # dispatch only — device arrays are fetched after the
                # Stage-A prefetch below has been overlapped with them
                marched = (batch, followers, n_pad,
                           march(o_b, d_b, budgets))

            # Stage-A prefetch: speculate admissions for the queue head
            # while the dispatched march is in flight (clamped: a
            # negative prefetch must mean "off", not a near-full slice)
            for req in queue[:max(rcfg.prefetch, 0)]:
                if id(req) not in ready:
                    ready[id(req)] = self._prepare(req)

            if marched is not None:
                batch, followers, n_pad, out = marched
                rgb, acc, depth, chunks = (np.asarray(a) for a in out)
                for i, it in enumerate(batch):
                    it[0].deliver(it[1], rgb[i], acc[i], depth[i], chunks[i])
                    if it[5] is not None:
                        self.scenecache.store(it[5], it[6], rgb[i], acc[i],
                                              depth[i], int(chunks[i]))
                for it, li in followers:
                    it[0].deliver(it[1], rgb[li], acc[li], depth[li],
                                  chunks[li], cached=True)
                    self.scene_blocks_hit += 1
                self.batches += 1
                self.blocks_marched += len(batch)
                self.pad_blocks += n_pad

            still = []
            for slot in live:
                if slot.pending == 0:
                    done.append(self._finalize(slot))
                else:
                    still.append(slot)
            live = still
        return done

    def _finalize(self, slot: _Slot) -> RenderRequest:
        req = slot.finalize(self.acfg)
        self.frames += 1
        self.rays_marched += req.stats["rays_marched"]
        self.rays_total += req.stats["rays_total"]
        self.samples_processed += req.stats["samples_processed"]
        self.samples_reused += req.stats["samples_reused"]
        # only fully-rendered frames feed the radiance cache (framecache
        # safety invariant: warps never chain).  The stored depth is the
        # MARCH's per-ray termination depth — always pose-aligned (so even
        # dilation-mode probe-reuse frames, whose probe maps carry
        # depth=None, are cacheable) and sharper than the probe's stride-d
        # proxy at depth edges.
        rad = self.radiance_caches.get(req.scene)
        if rad is not None and slot.march_idx is None:
            R = req.cam.height * req.cam.width
            rad.store(req.cam, self.acfg,
                      jnp.asarray(req.image.reshape(R, 3)),
                      jnp.asarray(slot.acc_full),
                      jnp.asarray(slot.depth_full))
        return req

    # ---------------------------------------------------------------- stats
    def engine_stats(self) -> Dict:
        out = {
            "frames": self.frames,
            "batches": self.batches,
            "blocks_marched": self.blocks_marched,
            "pad_block_fraction": (
                self.pad_blocks / max(self.blocks_marched + self.pad_blocks, 1)
            ),
            "rays_marched": self.rays_marched,
            "rays_total": self.rays_total,
            "rays_marched_fraction": (
                self.rays_marched / max(self.rays_total, 1)),
        }
        out["admissions"] = self.admissions
        out["full_radiance_hits"] = self.full_radiance_hits
        out["misprepares"] = self.misprepares
        out["samples_processed"] = self.samples_processed
        out["samples_reused"] = self.samples_reused
        hits = sum(c.hits for c in self.probe_caches.values())
        misses = sum(c.misses for c in self.probe_caches.values())
        skips = sum(c.skips for c in self.probe_caches.values())
        out["probe_hits"] = hits
        out["probe_misses"] = misses
        # skips are admissions that never needed Phase I (full radiance
        # hit) — they paid zero probe samples, so the reuse fraction
        # counts them with the hits; with probe reuse ENABLED,
        # probes + skips == admissions holds as misses + hits + skips ==
        # admissions (every admission either probed [miss/refresh],
        # reused maps [hit], or skipped).  The ledger is the probe
        # caches' own: with reuse=None nothing is booked and the
        # fraction reads 0.0, not a fake 1.0 (full_radiance_hits still
        # counts engine-wide skips in that config).
        out["probe_skips"] = skips
        out["reused_probe_fraction"] = (
            (hits + skips) / max(hits + misses + skips, 1))
        out["probe_refreshes"] = sum(
            c.refreshes for c in self.probe_caches.values())
        r_hits = sum(c.hits for c in self.radiance_caches.values())
        r_miss = sum(c.misses for c in self.radiance_caches.values())
        out["radiance_hits"] = r_hits
        out["radiance_misses"] = r_miss
        out["reused_radiance_fraction"] = r_hits / max(r_hits + r_miss, 1)
        # scene-space block tier: hit rate over blocks that needed output
        # (delivered from the shared store vs actually marched; pad blocks
        # excluded from both sides)
        out["scene_block_hits"] = self.scene_blocks_hit
        out["scene_block_hit_rate"] = self.scene_blocks_hit / max(
            self.scene_blocks_hit + self.blocks_marched, 1)
        if self.scenecache is not None:
            out["scenecache"] = self.scenecache.stats()
        return out
