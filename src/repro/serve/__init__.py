from .engine import ServeConfig, ServingEngine
from .render_engine import (RenderRequest, RenderServeConfig,
                            RenderServingEngine)
