from .engine import ServeConfig, ServingEngine
from .render_engine import (RenderRequest, RenderServeConfig,
                            RenderServingEngine)
from .executor import SyncExecutor, ThreadedExecutor, make_executor
