from .engine import ServeConfig, ServingEngine
