"""Structured trace spans for the serving pipeline.

A ``Tracer`` records nestable wall-time spans into PER-THREAD
append-only buffers — no locks anywhere on the hot path (a lock is
taken only the first time a thread emits a span, to register its
buffer).  The engine thread calls ``drain()`` once per scheduling
round, splicing every buffer's completed spans into the tracer's store,
feeding the flight recorder and the per-span-name metrics histograms.

Zero-overhead-when-off contract: instrumented call sites go through the
module-level ``span()`` / ``instant()`` helpers.  With no tracer
installed they return the shared ``NULL_SPAN`` singleton / return
immediately — a constant number of transient allocations per call site
(the kwargs dict), no buffers, no ids, no timestamps.  Frames and the
deterministic counters are bit-identical with tracing on or off: spans
only READ ids and clocks, never steer scheduling
(tests/test_obs.py gates both properties).

Span identity: process-wide ids from one atomic counter; each span
records its parent (the innermost open span on ITS thread), so a
frame's lineage — admission -> stage_a -> probe/warp -> pool dispatch
-> collect — reconstructs from parent edges plus the structured attrs
(req/slot/batch/scene/shard/device ids) each layer stamps on its spans.
Lane = the recording thread's name (engine / serve-stage-a_* worker /
serve-dev* device queue / shard-* fetch pools).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional

from . import metrics as metrics_lib


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Engine-facing observability switchboard (RenderServeConfig.trace).

    All fields default to "collect in memory only"; exports happen at
    ``finish()`` (engine close).  ``metrics_jsonl``/``metrics_every``
    drive periodic registry snapshots from the engine loop.
    """
    path: Optional[str] = None           # Chrome/Perfetto JSON on finish
    jsonl: Optional[str] = None          # span-log JSONL on finish
    buffer_cap: int = 1 << 16            # per-thread buffer bound
    max_spans: int = 1 << 20             # drained-store bound
    flight: bool = False                 # keep a flight-recorder ring
    flight_capacity: int = 2048
    flight_path: Optional[str] = None    # default out/trace_flight.json
    # auto-arm a flight-recorder trigger: dump when an admission stall
    # span exceeds this many milliseconds (None = no auto trigger)
    stall_dump_ms: Optional[float] = None
    # rate triggers (export.rate_trigger), each one-shot with rearm like
    # the stall trigger, each dumping to its own suffixed flight path:
    # an eviction storm is >= count scenecache.evict instants inside
    # window_ms; a shed burst is the same over scheduler.shed instants.
    # count 0 = trigger off.
    evict_storm_count: int = 0
    evict_storm_window_ms: float = 1000.0
    shed_burst_count: int = 0
    shed_burst_window_ms: float = 1000.0
    metrics_jsonl: Optional[str] = None  # periodic registry snapshots
    metrics_every: int = 16              # rounds between snapshots
    # cross-replica timeline identity: ``replica`` stamps every exported
    # event's Chrome ``pid`` (and a process_name metadata row), so
    # per-replica trace files merge into one timeline
    # (export.merge_chrome_traces) with one process group per replica.
    # ``epoch`` is a shared wall-clock origin (time.time() at fleet
    # start): exports rebase their timestamps onto it, so replicas
    # traced by SEPARATE tracers/processes line up on one clock.
    replica: Optional[int] = None
    epoch: Optional[float] = None


@dataclasses.dataclass
class Span:
    """One closed span: [t0, t1) seconds on the tracer's clock."""
    name: str
    sid: int
    parent: int              # 0 = root
    lane: str                # recording thread's name
    t0: float
    t1: float
    attrs: Dict

    @property
    def dur_ms(self) -> float:
        return (self.t1 - self.t0) * 1e3


class _ThreadBuf:
    """One thread's append-only span buffer + open-span stack.  Only the
    owner thread appends/pushes; only the drainer slices the front."""
    __slots__ = ("lane", "spans", "stack", "dropped")

    def __init__(self, lane: str):
        self.lane = lane
        self.spans: List[Span] = []
        self.stack: List[int] = []
        self.dropped = 0


class _SpanCtx:
    """Context manager for one live span (one per ``span()`` call)."""
    __slots__ = ("_tracer", "_buf", "name", "attrs", "sid", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tr = self._tracer
        buf = tr._buf()
        self._buf = buf
        self.sid = next(tr._ids)
        buf.stack.append(self.sid)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        buf = self._buf
        buf.stack.pop()
        parent = buf.stack[-1] if buf.stack else 0
        if len(buf.spans) >= self._tracer.cfg.buffer_cap:
            buf.dropped += 1
        else:
            buf.spans.append(Span(self.name, self.sid, parent, buf.lane,
                                  self._t0, t1, self.attrs))
        return False


class _NullSpan:
    """The disabled-mode singleton: enter/exit do nothing, allocate
    nothing.  Identity-tested by the zero-overhead gate."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    def __init__(self, cfg: TraceConfig = TraceConfig(),
                 registry: Optional[metrics_lib.Registry] = None,
                 recorder=None):
        self.cfg = cfg
        self.registry = registry        # span_ms histograms fed on drain
        self.recorder = recorder        # export.FlightRecorder or None
        self.t_origin = time.perf_counter()
        self.wall_origin = time.time()  # epoch anchor for export rebasing
        self._ids = itertools.count(1)  # atomic under the GIL
        self._tls = threading.local()
        self._bufs: List[_ThreadBuf] = []
        self._reg_lock = threading.Lock()
        self.spans: List[Span] = []     # drained store (engine thread)
        self.dropped = 0

    # ------------------------------------------------------- hot path
    def _buf(self) -> _ThreadBuf:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = _ThreadBuf(threading.current_thread().name)
            self._tls.buf = buf
            with self._reg_lock:        # once per (thread, tracer)
                self._bufs.append(buf)
        return buf

    def span(self, name: str, **attrs) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def instant(self, name: str, **attrs):
        """Zero-duration marker span."""
        buf = self._buf()
        t = time.perf_counter()
        if len(buf.spans) >= self.cfg.buffer_cap:
            buf.dropped += 1
            return
        parent = buf.stack[-1] if buf.stack else 0
        buf.spans.append(Span(name, next(self._ids), parent, buf.lane,
                              t, t, attrs))

    # ---------------------------------------------------- engine side
    def drain(self) -> int:
        """Move every thread's completed spans into the tracer store
        (engine thread, once per round).  Owner threads keep appending
        concurrently: we copy the first n and delete exactly those, so
        no span is lost or double-drained."""
        moved = 0
        with self._reg_lock:
            bufs = list(self._bufs)
        for buf in bufs:
            n = len(buf.spans)
            if n:
                self.spans.extend(buf.spans[:n])
                del buf.spans[:n]
                moved += n
            if buf.dropped:
                self.dropped += buf.dropped
                buf.dropped = 0
        if moved:
            if len(self.spans) > self.cfg.max_spans:
                over = len(self.spans) - self.cfg.max_spans
                del self.spans[:over]
                self.dropped += over
            new = self.spans[-moved:]
            if self.recorder is not None:
                self.recorder.record(new)
            if self.registry is not None:
                for s in new:
                    self.registry.histogram(
                        f"span_ms_{s.name}").observe(s.dur_ms)
        return moved

    def export_origin(self) -> float:
        """The t_origin exports subtract: the tracer's own start, or —
        with a shared ``epoch`` configured — the start rebased onto that
        wall clock, so separately-traced replicas share one timeline."""
        if self.cfg.epoch is None:
            return self.t_origin
        return self.t_origin - (self.wall_origin - self.cfg.epoch)

    def finish(self):
        """Final drain + configured exports.  Idempotent."""
        from . import export as export_lib
        self.drain()
        origin = self.export_origin()
        if self.cfg.path:
            export_lib.write_chrome_trace(self.cfg.path, self.spans,
                                          t_origin=origin,
                                          dropped=self.dropped,
                                          replica=self.cfg.replica)
        if self.cfg.jsonl:
            export_lib.write_span_jsonl(self.cfg.jsonl, self.spans,
                                        t_origin=origin,
                                        replica=self.cfg.replica)


# ------------------------------------------------------- module surface
_active: Optional[Tracer] = None


def install(tracer: Tracer):
    """Make ``tracer`` the process-wide active tracer.  One at a time:
    installing over a live tracer raises — a fleet that wants per-replica
    traces should trace one replica (or use explicit Tracer objects)."""
    global _active
    if _active is not None and _active is not tracer:
        raise RuntimeError("a tracer is already installed")
    _active = tracer


def uninstall(tracer: Optional[Tracer] = None):
    """Remove the active tracer (no-op if ``tracer`` isn't it)."""
    global _active
    if tracer is None or _active is tracer:
        _active = None


def active() -> Optional[Tracer]:
    return _active


def span(name: str, **attrs):
    """The instrumented-call-site helper: a real span when a tracer is
    installed, the shared NULL_SPAN singleton otherwise."""
    t = _active
    return NULL_SPAN if t is None else t.span(name, **attrs)


def instant(name: str, **attrs):
    t = _active
    if t is not None:
        t.instant(name, **attrs)
