"""repro.obs — tracing + metrics for the serving stack.

Three modules, one contract (README.md):

  * ``trace``   — Tracer, nestable spans, per-thread lock-free buffers,
    the zero-overhead-when-off ``span()`` helper;
  * ``export``  — Chrome/Perfetto JSON + span JSONL + FlightRecorder;
  * ``metrics`` — Counter/Gauge/Histogram/Series primitives, Registry,
    the canonical nearest-rank ``percentile``.
"""
from . import export, metrics, trace  # noqa: F401
from .metrics import Registry, percentile  # noqa: F401
from .trace import (NULL_SPAN, Span, TraceConfig, Tracer, active,  # noqa: F401
                    install, instant, span, uninstall)

__all__ = ["trace", "export", "metrics", "Registry", "percentile",
           "TraceConfig", "Tracer", "Span", "span", "instant", "install",
           "uninstall", "active", "NULL_SPAN", "engine_tracer"]


def engine_tracer(cfg, registry=None):
    """Build + INSTALL a Tracer for a ``TraceConfig`` (None -> None).

    The engine-side constructor: wires the flight recorder (with the
    auto stall trigger when ``stall_dump_ms`` is set) and the metrics
    registry into the tracer, then makes it the process-wide active
    tracer so every instrumented layer records into it.  The caller
    owns the lifecycle: ``tracer.finish()`` + ``uninstall(tracer)`` on
    engine close.
    """
    if cfg is None:
        return None
    recorder = None
    if cfg.flight or cfg.stall_dump_ms is not None:
        recorder = export.FlightRecorder(cfg.flight_capacity)
        if cfg.stall_dump_ms is not None:
            recorder.dump_on(
                export.stall_trigger(cfg.stall_dump_ms),
                cfg.flight_path or "out/trace_flight.json")
    tracer = Tracer(cfg, registry=registry, recorder=recorder)
    if recorder is not None:
        recorder.t_origin = tracer.t_origin
    install(tracer)
    return tracer
