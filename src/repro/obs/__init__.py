"""repro.obs — tracing + metrics for the serving stack.

Three modules, one contract (README.md):

  * ``trace``   — Tracer, nestable spans, per-thread lock-free buffers,
    the zero-overhead-when-off ``span()`` helper;
  * ``export``  — Chrome/Perfetto JSON + span JSONL + FlightRecorder;
  * ``metrics`` — Counter/Gauge/Histogram/Series primitives, Registry,
    the canonical nearest-rank ``percentile``.
"""
from . import export, metrics, trace  # noqa: F401
from .metrics import Registry, percentile  # noqa: F401
from .trace import (NULL_SPAN, Span, TraceConfig, Tracer, active,  # noqa: F401
                    install, instant, span, uninstall)

__all__ = ["trace", "export", "metrics", "Registry", "percentile",
           "TraceConfig", "Tracer", "Span", "span", "instant", "install",
           "uninstall", "active", "NULL_SPAN", "engine_tracer"]


def engine_tracer(cfg, registry=None):
    """Build + INSTALL a Tracer for a ``TraceConfig`` (None -> None).

    The engine-side constructor: wires the flight recorder (auto-arming
    the stall / eviction-storm / shed-burst triggers the config asks
    for, each dumping to its own suffixed flight path) and the metrics
    registry into the tracer, then makes it the process-wide active
    tracer so every instrumented layer records into it.  The caller
    owns the lifecycle: ``tracer.finish()`` + ``uninstall(tracer)`` on
    engine close.
    """
    if cfg is None:
        return None
    recorder = None
    want_triggers = (cfg.stall_dump_ms is not None
                     or cfg.evict_storm_count > 0
                     or cfg.shed_burst_count > 0)
    if cfg.flight or want_triggers:
        recorder = export.FlightRecorder(cfg.flight_capacity,
                                         replica=cfg.replica)
        base = cfg.flight_path or "out/trace_flight.json"
        if cfg.stall_dump_ms is not None:
            recorder.dump_on(export.stall_trigger(cfg.stall_dump_ms), base)
        if cfg.evict_storm_count > 0:
            recorder.dump_on(
                export.evict_storm_trigger(cfg.evict_storm_count,
                                           cfg.evict_storm_window_ms),
                export.trigger_path(base, "evict_storm"))
        if cfg.shed_burst_count > 0:
            recorder.dump_on(
                export.shed_burst_trigger(cfg.shed_burst_count,
                                          cfg.shed_burst_window_ms),
                export.trigger_path(base, "shed_burst"))
    tracer = Tracer(cfg, registry=registry, recorder=recorder)
    if recorder is not None:
        recorder.t_origin = tracer.export_origin()
    install(tracer)
    return tracer
