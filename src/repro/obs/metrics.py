"""Metrics primitives + registry — the serving stack's ONE ledger idiom.

Before this module, every layer grew its own ad-hoc aggregates: the
engine kept unbounded ``march_ms`` lists, each benchmark carried its own
percentile copy, and ``stats._percentile`` had a nearest-rank
off-by-one (``int(n * q / 100)`` maps p50 of 2 samples to the MAX).
Everything numeric now goes through four primitives:

  * ``Counter``   — monotone integer (mergeable by addition);
  * ``Gauge``     — last-write-wins value;
  * ``Histogram`` — fixed-bucket counts (mergeable by bucket addition;
    percentiles are bucket-upper-bound estimates, memory O(buckets));
  * ``Series``    — bounded ring of the most recent samples with EXACT
    percentiles over the window (memory O(capacity)).  This is what the
    engine's wall-time ledgers (march_ms, latency_ms) use: long-running
    engines stay O(1) while p50/p99 keep their semantics over the
    recent window.

``Registry`` names metrics, snapshots them as a flat dict (what
``engine_stats()`` returns), writes Prometheus text exposition, and
appends JSONL snapshots for the benches to consume.

``percentile`` is the canonical nearest-rank implementation: the
smallest sample whose cumulative rank covers q% (rank = ceil(q/100*n)).
serve/stats.py and benchmarks/common.py both import it — no more
per-module copies.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from collections import deque
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest element whose cumulative
    rank reaches q% (rank = ceil(q/100 * n), 1-clamped).  0.0 on an
    empty series so stats stay JSON-clean before any sample landed.

    This fixes the historical ``int(len(s) * q / 100)`` bias: p50 of two
    samples is the LOWER one (rank ceil(1.0) = 1), not the max.
    """
    n = len(xs)
    if n == 0:
        return 0.0
    s = sorted(xs)
    rank = min(max(int(math.ceil(q / 100.0 * n)), 1), n)
    return float(s[rank - 1])


class Counter:
    """Monotone event count.  ``inc`` is the only mutator."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n

    def merge(self, other: "Counter"):
        self.value += other.value

    def read(self):
        return self.value


class Gauge:
    """Last-write-wins value (numeric or not; non-numerics are skipped
    by the Prometheus exposition but kept in dict snapshots)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, value=0.0):
        self.value = value

    def set(self, v):
        self.value = v

    def read(self):
        return self.value


# default buckets for millisecond timings: ~1 us .. 16 s, x2 steps
DEFAULT_MS_BUCKETS = tuple(0.001 * 2 ** i for i in range(25))


class Histogram:
    """Fixed-bucket histogram: O(buckets) memory, mergeable by bucket
    addition (fleet replicas sum their histograms), percentile estimates
    quantized to bucket upper bounds."""

    kind = "histogram"

    def __init__(self, buckets: Iterable[float] = DEFAULT_MS_BUCKETS):
        self.bounds: List[float] = sorted(buckets)
        self.counts = [0] * (len(self.bounds) + 1)   # +inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float):
        lo, hi = 0, len(self.bounds)
        while lo < hi:                    # first bound >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other: "Histogram"):
        assert self.bounds == other.bounds, "histogram buckets differ"
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket holding the nearest-rank sample
        (exact ``max`` for the overflow bucket)."""
        if self.count == 0:
            return 0.0
        rank = min(max(int(math.ceil(q / 100.0 * self.count)), 1),
                   self.count)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return (self.bounds[i] if i < len(self.bounds)
                        else self.max)
        return self.max

    def read(self):
        return {"count": self.count, "sum": self.sum,
                "min": 0.0 if self.count == 0 else self.min,
                "max": 0.0 if self.count == 0 else self.max,
                "p50": self.percentile(50.0), "p99": self.percentile(99.0)}


class Series:
    """Bounded ring buffer of the most recent samples.

    EXACT nearest-rank percentiles over the retained window; ``count``
    keeps the all-time observation total.  This replaces the unbounded
    ``march_ms`` / latency lists: a long-running engine holds at most
    ``capacity`` floats per series while p50/p99 keep their meaning
    (percentiles of the recent window — for a bounded replay run,
    identical to the full-history percentiles).
    """

    kind = "series"

    def __init__(self, capacity: int = 4096):
        assert capacity > 0
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.count = 0      # all-time observations (not window size)

    def observe(self, v: float):
        self._ring.append(float(v))
        self.count += 1

    def append(self, v: float):          # list-API compat
        self.observe(v)

    def __len__(self) -> int:
        return len(self._ring)

    def window(self) -> List[float]:
        return list(self._ring)

    def percentile(self, q: float) -> float:
        return percentile(self._ring, q)

    def read(self):
        return {"count": self.count, "p50": self.percentile(50.0),
                "p99": self.percentile(99.0)}


@dataclasses.dataclass
class _Named:
    metric: object
    help: str = ""


class Registry:
    """A named set of metrics with dict / Prometheus / JSONL views.

    ``engine_stats()`` is a read of a registry: serve/stats.py publishes
    every stats key as a gauge (``set_value``) next to the engine's
    structural counters, so one object backs the legacy dict, the text
    exposition, and the periodic snapshots.  Creation is
    get-or-create by (name, kind) — re-registering a name with a
    different kind raises.
    """

    def __init__(self):
        self._metrics: Dict[str, _Named] = {}
        self._lock = threading.Lock()

    # ---------------------------------------------------- constructors
    def _get(self, name: str, kind: str, factory):
        with self._lock:
            ent = self._metrics.get(name)
            if ent is None:
                ent = _Named(factory())
                self._metrics[name] = ent
            elif ent.metric.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{ent.metric.kind}, not {kind}")
            return ent.metric

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter", Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge", Gauge)

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_MS_BUCKETS
                  ) -> Histogram:
        return self._get(name, "histogram", lambda: Histogram(buckets))

    def series(self, name: str, capacity: int = 4096) -> Series:
        return self._get(name, "series", lambda: Series(capacity))

    def set_value(self, name: str, value):
        """Publish a computed value as a gauge (the engine_stats path)."""
        self.gauge(name).set(value)

    # ----------------------------------------------------------- views
    def names(self) -> List[str]:
        with self._lock:
            return list(self._metrics)

    def get(self, name: str):
        ent = self._metrics.get(name)
        return None if ent is None else ent.metric

    def snapshot(self) -> Dict:
        """Flat {name: value} dict — gauges/counters read raw, series
        and histograms read as summary sub-dicts.  Insertion-ordered, so
        publishing in engine_stats order preserves the legacy key
        order exactly."""
        with self._lock:
            return {name: ent.metric.read()
                    for name, ent in self._metrics.items()}

    def prometheus(self) -> str:
        """Text exposition.  Non-numeric gauges are skipped; dict-valued
        gauges flatten to ``name{key="k"}`` sample lines; histograms and
        series emit _count/_sum/quantile samples."""
        lines = []
        for name, ent in list(self._metrics.items()):
            m = ent.metric
            pname = _prom_name(name)
            if m.kind in ("counter", "gauge"):
                v = m.read()
                if isinstance(v, bool):
                    v = int(v)
                if isinstance(v, (int, float)):
                    lines += [f"# TYPE {pname} {('counter' if m.kind == 'counter' else 'gauge')}",
                              f"{pname} {v}"]
                elif isinstance(v, dict):
                    num = {k: x for k, x in v.items()
                           if isinstance(x, (int, float))
                           and not isinstance(x, bool)}
                    if num:
                        lines.append(f"# TYPE {pname} gauge")
                        lines += [f'{pname}{{key="{k}"}} {x}'
                                  for k, x in num.items()]
            elif m.kind == "histogram":
                lines.append(f"# TYPE {pname} histogram")
                seen = 0
                for bound, c in zip(m.bounds, m.counts):
                    seen += c
                    lines.append(f'{pname}_bucket{{le="{bound:g}"}} {seen}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_count {m.count}")
                lines.append(f"{pname}_sum {m.sum}")
            elif m.kind == "series":
                lines.append(f"# TYPE {pname} summary")
                lines.append(f'{pname}{{quantile="0.5"}} '
                             f'{m.percentile(50.0)}')
                lines.append(f'{pname}{{quantile="0.99"}} '
                             f'{m.percentile(99.0)}')
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"

    def jsonl_snapshot(self, path, extra: Optional[Dict] = None):
        """Append one JSON line {ts, **extra, metrics: snapshot()} —
        the periodic form the benches consume."""
        rec = {"ts": time.time(), **(extra or {}),
               "metrics": self.snapshot()}
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "a") as f:
            f.write(json.dumps(rec, default=str) + "\n")


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out
