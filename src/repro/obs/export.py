"""Trace exports: Chrome/Perfetto JSON, span-log JSONL, flight recorder.

The Chrome trace-event format (``{"traceEvents": [...]}``) opens
directly in https://ui.perfetto.dev (or chrome://tracing): every span
becomes one complete event (``ph: "X"``) with microsecond ts/dur, one
lane (``tid``) per recording thread, and the structured attrs —
req/slot/batch/scene/shard/device ids plus the span/parent ids — under
``args``.  Lane names are declared with ``thread_name`` metadata
events, which is what tools/check_trace.py validates against.

``FlightRecorder`` is the post-mortem mode: a bounded ring of the most
recent spans plus ``dump_on(predicate)`` triggers.  Each trigger is
ONE-SHOT — the first breaching span writes the ring to its path and
disarms the trigger (re-arm explicitly with ``rearm()``), so a
pathological steady-state (every admission stalling) produces one
post-mortem trace, not a disk-filling stream.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

from .trace import Span


def chrome_trace(spans: Sequence[Span], t_origin: float = 0.0,
                 dropped: int = 0,
                 replica: Optional[int] = None) -> Dict:
    """The Chrome trace-event dict for a span list (ts relative to
    ``t_origin`` so timelines start near zero).

    ``replica`` becomes the Chrome ``pid`` of every event (plus a
    process_name metadata row), reserving the process axis for engine
    replicas: per-replica exports rebased onto a shared epoch
    (TraceConfig.replica/epoch) merge into one fleet timeline via
    ``merge_chrome_traces`` with one process group per replica."""
    pid = 1 if replica is None else int(replica)
    lanes: Dict[str, int] = {}
    events: List[Dict] = []
    for s in spans:
        tid = lanes.setdefault(s.lane, len(lanes) + 1)
        events.append({
            "name": s.name, "ph": "X", "pid": pid, "tid": tid,
            "ts": (s.t0 - t_origin) * 1e6,
            "dur": (s.t1 - s.t0) * 1e6,
            "args": {**s.attrs, "sid": s.sid, "parent": s.parent},
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": lane}} for lane, tid in lanes.items()]
    if replica is not None:
        meta.insert(0, {"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"name": f"replica-{pid}"}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": dropped}}


def write_chrome_trace(path, spans: Sequence[Span], t_origin: float = 0.0,
                       dropped: int = 0,
                       replica: Optional[int] = None) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(spans, t_origin, dropped,
                                         replica=replica),
                            default=str))
    return p


def merge_chrome_traces(traces: Sequence) -> Dict:
    """Merge per-replica Chrome trace exports into ONE timeline dict.

    Inputs are trace dicts or paths to trace files, each as written by
    ``write_chrome_trace`` with a distinct ``replica`` (pid) and a
    shared ``epoch`` (so their ts values are already on one clock —
    this function only concatenates, it never rebases).  Events keep
    their pid; span/parent ids live under per-pid namespaces, which is
    how tools/check_trace.py validates merged files."""
    events: List[Dict] = []
    dropped = 0
    seen_pids = set()
    for t in traces:
        if not isinstance(t, dict):
            t = json.loads(Path(t).read_text())
        pids = {e.get("pid") for e in t["traceEvents"]}
        overlap = pids & seen_pids
        if overlap:
            raise ValueError(f"duplicate replica pid(s) in merge: "
                             f"{sorted(overlap)} — stamp each replica's "
                             f"TraceConfig.replica uniquely")
        seen_pids |= pids
        events.extend(t["traceEvents"])
        dropped += t.get("otherData", {}).get("dropped_spans", 0)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": dropped,
                          "replicas": sorted(seen_pids)}}


def write_span_jsonl(path, spans: Sequence[Span],
                     t_origin: float = 0.0,
                     replica: Optional[int] = None) -> Path:
    """One JSON object per span — the grep/jq-friendly log form."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    rep = {} if replica is None else {"replica": int(replica)}
    with open(p, "a") as f:
        for s in spans:
            f.write(json.dumps({
                "name": s.name, "sid": s.sid, "parent": s.parent,
                "lane": s.lane, "t0_us": (s.t0 - t_origin) * 1e6,
                "dur_us": (s.t1 - s.t0) * 1e6, **rep, **s.attrs,
            }, default=str) + "\n")
    return p


@dataclasses.dataclass
class _Trigger:
    predicate: Callable[[Span], bool]
    path: str
    armed: bool = True
    fired: int = 0
    fired_on: Optional[int] = None     # sid of the breaching span


class FlightRecorder:
    """Bounded ring of recent spans + one-shot dump triggers.

    ``record`` is called from the tracer's drain (engine thread): spans
    enter the ring, then every ARMED trigger tests them; the first
    breach writes the ring (breaching span included) as a Chrome trace
    to the trigger's path and disarms it — exactly one dump per breach
    episode (tests/test_obs.py gates the exactly-once property).
    """

    def __init__(self, capacity: int = 2048, t_origin: float = 0.0,
                 replica: Optional[int] = None):
        self.ring: deque = deque(maxlen=capacity)
        self.triggers: List[_Trigger] = []
        self.t_origin = t_origin
        self.replica = replica

    def dump_on(self, predicate: Callable[[Span], bool],
                path) -> _Trigger:
        """Arm a trigger: the first recorded span with
        ``predicate(span)`` true dumps the ring to ``path``."""
        trig = _Trigger(predicate, str(path))
        self.triggers.append(trig)
        return trig

    def rearm(self):
        for trig in self.triggers:
            trig.armed = True

    def record(self, spans: Sequence[Span]) -> int:
        fired = 0
        for s in spans:
            self.ring.append(s)
            for trig in self.triggers:
                if trig.armed and trig.predicate(s):
                    trig.armed = False
                    trig.fired += 1
                    trig.fired_on = s.sid
                    write_chrome_trace(trig.path, list(self.ring),
                                       t_origin=self.t_origin,
                                       replica=self.replica)
                    fired += 1
        return fired


def stall_trigger(threshold_ms: float) -> Callable[[Span], bool]:
    """The canonical auto-trigger: an admission wait/stall span longer
    than ``threshold_ms`` (what ``TraceConfig.stall_dump_ms`` arms)."""
    def pred(s: Span) -> bool:
        return s.name == "admission.wait" and s.dur_ms > threshold_ms
    return pred


def rate_trigger(name: str, count: int,
                 window_ms: float) -> Callable[[Span], bool]:
    """A BURST trigger: fires when the ``count``-th span named ``name``
    lands within ``window_ms`` of the first of its sliding window.

    Stateful by design: the closure keeps the last ``count`` matching
    timestamps.  While the owning ``_Trigger`` is disarmed the recorder
    never calls the predicate, so the window freezes and resumes on
    ``rearm()`` — still one dump per breach episode."""
    assert count >= 1
    times: deque = deque(maxlen=count)

    def pred(s: Span) -> bool:
        if s.name != name:
            return False
        times.append(s.t0)
        return (len(times) == count
                and (times[-1] - times[0]) * 1e3 <= window_ms)
    return pred


def evict_storm_trigger(count: int, window_ms: float) -> Callable:
    """Eviction storm: ``count`` scenecache evictions inside
    ``window_ms`` — the cache is thrashing (budget too small for the
    working set, or a scan-shaped workload)."""
    return rate_trigger("scenecache.evict", count, window_ms)


def shed_burst_trigger(count: int, window_ms: float) -> Callable:
    """Shed burst: ``count`` scheduler degrade steps inside
    ``window_ms`` — sustained overload, the shed policy is actively
    trading quality for deadlines."""
    return rate_trigger("scheduler.shed", count, window_ms)


def trigger_path(base, tag: str) -> str:
    """A trigger's own dump path: ``base`` with ``_tag`` suffixed to the
    stem, so multiple armed triggers never clobber one file."""
    p = Path(base)
    return str(p.with_name(f"{p.stem}_{tag}{p.suffix}"))
