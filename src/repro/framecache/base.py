"""Shared skeleton for pose-keyed caches of per-pixel device maps.

ProbeCache (Phase-I maps) and RadianceCache (finished frames) share their
entire matching and retention policy; keeping it in one place locks their
semantics together — a change to, say, the focal tolerance or the score
normalization cannot silently apply to one tier and not the other.

Subclasses provide entry objects with ``cam`` / ``acfg`` / ``last_used``
attributes and an ``rcfg`` carrying ``max_angle_deg``, ``max_translation``
and ``max_entries``.  Host-side bookkeeping only (pure python, one lookup
per request); the maps themselves stay on device.

Thread-safety contract (the serving engine's speculative executor runs
plan/execute stages on worker threads):

  * every MUTATION of cache state — counters, the entry list, and any
    entry field including its ``version`` stamp — happens under
    ``self.lock``, and only the engine thread commits;
  * plan stages acquire ``self.lock`` just long enough to match an entry
    and SNAPSHOT everything execution will read (array refs + version);
    execution then runs lock-free on the snapshot;
  * entries are rebased by field REASSIGNMENT (``entry.maps = new``,
    never in-place array mutation) with the version bump in the same
    critical section, so a snapshot taken under the lock can never be
    torn: its arrays and its version stamp always belong to the same
    rebase generation.
"""
from __future__ import annotations

import threading

import numpy as np

from ..core import adaptive


class PoseKeyedCache:
    def __init__(self, rcfg):
        self.rcfg = rcfg
        self._entries: list = []
        self._clock = 0
        self._seq = 0
        self.hits = 0
        self.misses = 0
        self.refreshes = 0
        # guards ALL mutation and the plan stages' entry-state snapshots
        # (see module docstring).  RLock: commit paths re-enter via _store.
        self.lock = threading.RLock()

    def __len__(self):
        return len(self._entries)

    def resident_bytes(self) -> int:
        """Total bytes held by cached maps/frames.

        Feeds the shared-budget accounting that covers all reuse tiers
        (the scene-space block tier bounds itself in bytes; these pose
        tiers report theirs so an operator can see the whole footprint).
        """
        return sum(self._entry_nbytes(e) for e in self._entries)

    @staticmethod
    def _arrays_nbytes(*arrays) -> int:
        return sum(getattr(a, "nbytes", 0) for a in arrays if a is not None)

    def _entry_nbytes(self, entry) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    @property
    def reused_fraction(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _match(self, cam, acfg):
        """Nearest usable entry: (entry, angle, translation) or None."""
        max_ang = np.deg2rad(self.rcfg.max_angle_deg)
        max_tr = self.rcfg.max_translation
        best, best_score = None, np.inf
        for e in self._entries:
            # image geometry and render config must match exactly: the maps
            # are per-pixel and acfg-specific; a different focal (zoom)
            # changes every ray even at an identical pose.  Filtering here
            # (not post-hoc) lets entries for different configs coexist
            # instead of shadowing each other.
            if e.acfg != acfg:
                continue
            if (e.cam.height, e.cam.width) != (cam.height, cam.width):
                continue
            if abs(e.cam.focal - cam.focal) > 1e-6 * max(cam.focal, 1.0):
                continue
            ang, tr = adaptive.pose_distance(cam, e.cam)
            if ang > max_ang or tr > max_tr:
                continue
            score = ang / max(max_ang, 1e-9) + tr / max(max_tr, 1e-9)
            if score < best_score:
                best, best_score = (e, ang, tr), score
        return best

    def _append_with_eviction(self, entry):
        """Add an entry, evicting the least-recently-used past capacity.

        Totally ordered: exact recency ties break by insertion sequence
        (oldest first), never by list position — rebased entries keep
        their slot in ``_entries``, so position is NOT insertion order
        and must not decide evictions.
        """
        entry.seq = self._seq
        self._seq += 1
        if len(self._entries) >= self.rcfg.max_entries:
            self._entries.remove(
                min(self._entries,
                    key=lambda e: (e.last_used, getattr(e, "seq", 0))))
        self._entries.append(entry)
