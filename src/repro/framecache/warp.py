"""Depth-guided reprojection of per-pixel maps between nearby camera poses.

The cross-frame reuse primitive (Cicero-style, arXiv 2404.11852): a map
computed per-pixel at pose A — Phase-I sample counts, probe opacity, or a
finished Phase-II radiance image — is *forward-warped* to a nearby pose B
by lifting every source pixel to a world point with its proxy depth
(the probe's expected termination distance), projecting that point into
B's image, and splatting the map value at the landing pixel.

Two reductions cover the two map kinds:

  * ``scatter_max`` — conservative max over all source pixels landing on a
    target pixel; used for sample-count maps, where over-sampling is safe
    and under-sampling is not.
  * ``nearest_source`` — z-buffered winner (smallest distance in the target
    frame, ties to the lowest source index, so the result is deterministic
    under XLA's unordered scatter); used for radiance/opacity/depth, where
    the nearest surface is the correct value.

Target pixels no source pixel lands on are *disocclusions* (content the
cached pose never saw — revealed by translation, or entering from
off-screen) and come back with ``valid=False``: callers must fill them
conservatively (counts -> ns_full) or march them fresh (radiance).

Everything here is jnp on flat (H*W,) maps — warps run on device, one
scatter/gather per reused frame, no Python per-pixel work.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..core import scene
from ..obs import trace as trace_lib


def project_to_camera(points: jnp.ndarray, cam) -> Tuple[jnp.ndarray,
                                                         jnp.ndarray,
                                                         jnp.ndarray]:
    """Project world points into a camera's pixel grid.

    points: (N, 3).  Returns (flat pixel index (N,), ok (N,) bool,
    distance (N,)): ``ok`` is False for points behind the camera or
    landing outside the image; ``distance`` is the euclidean eye distance
    (the depth a ray from ``cam`` through that pixel would record).
    """
    H, W = cam.height, cam.width
    rel = (points - jnp.asarray(cam.origin)) @ jnp.asarray(cam.c2w_rot)
    z = rel[:, 2]
    in_front = z > 1e-6
    zs = jnp.where(in_front, z, 1.0)
    # inverse of scene.camera_rays' pixel -> direction mapping
    i = jnp.round(rel[:, 0] / zs * cam.focal + 0.5 * W - 0.5).astype(jnp.int32)
    j = jnp.round(-rel[:, 1] / zs * cam.focal + 0.5 * H - 0.5).astype(jnp.int32)
    ok = in_front & (i >= 0) & (i < W) & (j >= 0) & (j < H)
    dist = jnp.linalg.norm(points - jnp.asarray(cam.origin), axis=-1)
    return j * W + i, ok, dist


def forward_warp(cam_src, cam_dst, depth_src: jnp.ndarray):
    """Reproject every source pixel into the destination image.

    depth_src: (H*W,) distance along each source ray (unit directions, so
    world point = origin + depth * dir).  Returns (target flat index,
    ok mask, distance in the destination frame), each (H*W,).
    """
    o, d = scene.camera_rays(cam_src)
    pts = o + depth_src[:, None] * d
    return project_to_camera(pts, cam_dst)


def scatter_max(values: jnp.ndarray, tgt_idx: jnp.ndarray, ok: jnp.ndarray,
                n_pixels: int, fill) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Max-splat ``values`` onto an ``n_pixels`` map.

    Returns (warped (n_pixels,), valid (n_pixels,) bool); pixels nothing
    landed on hold ``fill`` and valid=False.  Max over contributors is the
    conservative reduction for count maps: when several source pixels
    collapse onto one target pixel (occlusion fold-over), the target gets
    the most demanding count among them.
    """
    idx = jnp.where(ok, tgt_idx, n_pixels)        # off-image spill bin
    out = jnp.full((n_pixels + 1,), fill, values.dtype).at[idx].max(values)
    hit = jnp.zeros((n_pixels + 1,), jnp.int32).at[idx].add(1)
    return out[:n_pixels], hit[:n_pixels] > 0


def nearest_source(tgt_idx: jnp.ndarray, ok: jnp.ndarray, dist: jnp.ndarray,
                   n_pixels: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Z-buffered winning source pixel per target pixel.

    Returns (src (n_pixels,) int32 — index into the source map, clamped to
    0 where invalid — and valid (n_pixels,) bool).  The winner is the
    contributor with the smallest destination-frame distance; among
    near-ties (within a relative epsilon, e.g. coplanar splats) the lowest
    source index wins, making the scatter deterministic.
    """
    N = tgt_idx.shape[0]
    idx = jnp.where(ok, tgt_idx, n_pixels)
    best = jnp.full((n_pixels + 1,), jnp.inf).at[idx].min(
        jnp.where(ok, dist, jnp.inf))
    is_best = ok & (dist <= best[idx] * (1.0 + 1e-5) + 1e-6)
    cand = jnp.where(is_best, idx, n_pixels)
    win = jnp.full((n_pixels + 1,), N, jnp.int32).at[cand].min(
        jnp.arange(N, dtype=jnp.int32))
    win = win[:n_pixels]
    valid = win < N
    return jnp.where(valid, win, 0), valid


def warp_count_map(counts: jnp.ndarray, depth: jnp.ndarray, cam_src, cam_dst,
                   ns_full: int, margin: int = 1, projection=None):
    """Warp a Phase-I sample-count map from cam_src to cam_dst.

    Conservative by construction: contributors reduce by max, disoccluded
    pixels (no contributor) get the full count ``ns_full`` (the probe never
    saw their content), and an optional ``margin``-radius max-dilation of
    the warped map absorbs the <=0.5 px registration error of the
    round-to-nearest splat.  Returns (counts (H*W,) int32, valid mask).

    ``projection`` — a precomputed ``forward_warp(cam_src, cam_dst, depth)``
    result, so a caller warping several maps between the same pose pair
    (probe.py warps counts AND opacity/depth per hit) projects once.
    """
    H, W = cam_dst.height, cam_dst.width
    with trace_lib.span("warp.count_map", pixels=H * W):
        tgt, ok, _ = (projection if projection is not None
                      else forward_warp(cam_src, cam_dst, depth))
        warped, valid = scatter_max(counts, tgt, ok, H * W, fill=0)
        warped = jnp.where(valid, warped, ns_full)
        if margin > 0:
            from ..core import adaptive
            warped = adaptive.dilate_count_map(warped, (H, W), margin,
                                               border_fill=ns_full)
        return warped, valid


def warp_image(rgb: jnp.ndarray, acc: jnp.ndarray, depth: jnp.ndarray,
               cam_src, cam_dst, background: float = 1.0):
    """Warp a finished radiance frame (rgb (H*W,3), acc, depth) to cam_dst.

    Z-buffered nearest-surface warp; disoccluded pixels come back as
    ``background`` rgb / zero acc / FAR depth with valid=False — the caller
    marches exactly those rays through Phase II and composites.
    Returns (rgb, acc, depth, valid), all in the destination frame.
    """
    H, W = cam_dst.height, cam_dst.width
    with trace_lib.span("warp.image", pixels=H * W):
        tgt, ok, dist = forward_warp(cam_src, cam_dst, depth)
        src, valid = nearest_source(tgt, ok, dist, H * W)
        rgb_w = jnp.where(valid[:, None], rgb[src], background)
        acc_w = jnp.where(valid, acc[src], 0.0)
        depth_w = jnp.where(valid, dist[src], scene.FAR)
        return rgb_w, acc_w, depth_w, valid
