"""Cross-frame reuse subsystem: pose-delta warping of probe maps and
cached radiance.

Three reuse tiers (README.md in this package):
  1. intra-frame dedup — core/reuse.py + the Pallas encode kernel;
  2. warped Phase-I probe maps — probe.py (counts/opacity/depth transfer
     between nearby poses, reprojected by the pose delta);
  3. warped Phase-II radiance — radiance.py (finished frames warp to new
     poses; only disoccluded rays re-march).
warp.py holds the shared depth-guided reprojection primitive.
"""
from .probe import (ProbeCache, ProbeMaps, ProbePlan,  # noqa: F401
                    ProbeReuseConfig, cached_probe_maps,
                    commit_probe_plan, execute_probe_plan, plan_probe,
                    probe_phase_cached)
from .radiance import (RadianceCache, RadiancePlan,  # noqa: F401
                       RadianceReuseConfig, WarpedRadiance,
                       commit_lookup, plan_lookup)
from .render import (FrameCache, make_frame_cache,  # noqa: F401
                     render_asdr_image_cached)
from . import warp  # noqa: F401
