"""Single-image rendering through the full cross-frame reuse stack.

``render_asdr_image_cached`` is ``core.pipeline.render_asdr_image`` plus a
per-scene ``FrameCache``: Phase I goes through the warped probe cache,
Phase II first asks the radiance cache for a warp of a nearby finished
frame and marches only the disoccluded rays.  The serving engine
(serve/render_engine.py) pools the same per-frame work across requests;
this path is the sequential reference the engine is tested against, and
what the reuse-radius sweep benchmark drives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from ..scenecache import SceneBlockCache
from ..scenecache.render import render_adaptive_cached
from .probe import ProbeCache, ProbeReuseConfig, cached_probe_maps
from .radiance import RadianceCache, RadianceReuseConfig


@dataclasses.dataclass
class FrameCache:
    """The per-scene reuse state: probe maps + finished radiance.

    ``scene`` optionally plugs in the scene-space block tier
    (repro.scenecache) — unlike the two pose tiers it may be SHARED
    between FrameCaches of different scenes/users (block keys carry the
    scene id); ``scene_id`` names this scene inside that shared store.
    """
    probe: Optional[ProbeCache] = None
    radiance: Optional[RadianceCache] = None
    scene: Optional[SceneBlockCache] = None
    scene_id: str = "scene"


def make_frame_cache(
    probe_cfg: ProbeReuseConfig | None = ProbeReuseConfig(),
    radiance_cfg: RadianceReuseConfig | None = RadianceReuseConfig(),
    scene_cache: SceneBlockCache | None = None,
    scene_id: str = "scene",
) -> FrameCache:
    """Build the per-scene reuse state.

    ``scene_cache`` takes an already-constructed ``SceneBlockCache`` (not
    a config): the scene tier's whole point is that one store is shared
    across users/scenes, so the caller owns its lifetime.  Sharing makes
    ``scene_id`` load-bearing — block keys are pure ray geometry plus the
    id, so two scenes under one id would silently serve each other's
    radiance.  An explicit id is therefore required with a shared store.
    """
    if scene_cache is not None and scene_id == "scene":
        raise ValueError(
            "make_frame_cache(scene_cache=...) requires an explicit "
            "scene_id: block keys disambiguate scenes ONLY by this id, so "
            "two scenes sharing a store under the default would serve "
            "each other's cached blocks")
    return FrameCache(
        probe=ProbeCache(probe_cfg) if probe_cfg is not None else None,
        radiance=(RadianceCache(radiance_cfg)
                  if radiance_cfg is not None else None),
        scene=scene_cache,
        scene_id=scene_id,
    )


def render_asdr_image_cached(fns: FieldFns, acfg: ASDRConfig, cam,
                             fc: FrameCache | None = None, probe_key=None):
    """Two-phase ASDR render with cross-frame reuse.

    Returns (image (H,W,3), stats).  With fc=None this is exactly
    ``pipeline.render_asdr_image`` (modulo the always-on opacity sort key).
    Stats gain: probe_reused, probe_skipped, radiance_reused, rays_marched,
    rays_total, warp_valid_fraction, scene_block_hits, scene_block_misses.

    Same radiance-first admission ordering as the serving engine: the
    radiance lookup runs BEFORE Phase I, and a full warp hit (every pixel
    valid) skips the probe outright — the skip is booked explicitly on
    the probe cache so its reuse fraction and staleness bounds stay
    coherent (``ProbeCache.note_skip``).
    """
    H, W = cam.height, cam.width
    R = H * W
    fc = fc or FrameCache()
    warped = fc.radiance.lookup(cam, acfg) if fc.radiance is not None else None
    probe_skipped = warped is not None and warped.full_hit
    if probe_skipped:
        # zero disoccluded rays: nobody reads the count/opacity maps, so
        # Phase I is pure waste — skip it without aging the probe cache
        if fc.probe is not None:
            fc.probe.note_skip()
        maps, probe_reused = None, False
    else:
        maps, probe_reused = cached_probe_maps(
            fns, acfg, cam, fc.probe, probe_key)
    o, d = scene.camera_rays(cam)

    if warped is None:
        o_p, d_p, c_p, op_p, _pad = pipeline.pad_rays_to_blocks(
            acfg, o, d, maps.counts, maps.opacity)
        rgb, acc, stats = render_adaptive_cached(
            fns, acfg, o_p, d_p, c_p, op_p, fc.scene, fc.scene_id)
        img_flat = np.asarray(rgb[:R])
        # stored under the MARCH's per-ray termination depth (sharper than
        # the probe's stride-d proxy at depth edges, and pose-aligned even
        # when a dilation-mode probe reuse left maps.depth = None)
        if fc.radiance is not None:
            fc.radiance.store(cam, acfg, rgb[:R], acc[:R],
                              stats["term_depth"][:R])
        rays_marched, valid_fraction = R, 0.0
        stats = dict(stats)
    else:
        march_idx = np.flatnonzero(~warped.valid)
        img_flat = np.asarray(warped.rgb).copy()
        stats = {"samples_processed": jnp.asarray(0),
                 "samples_reused": 0, "baseline_samples": 0,
                 "scene_block_hits": 0, "scene_block_misses": 0}
        if march_idx.size:
            sel = jnp.asarray(march_idx, jnp.int32)
            o_p, d_p, c_p, op_p, _pad = pipeline.pad_rays_to_blocks(
                acfg, o[sel], d[sel], maps.counts[sel], maps.opacity[sel])
            rgb, _acc, stats = render_adaptive_cached(
                fns, acfg, o_p, d_p, c_p, op_p, fc.scene, fc.scene_id)
            stats = dict(stats)
            img_flat[march_idx] = np.asarray(rgb[: march_idx.size])
        # rays delivered straight from the warp count as REUSED compute
        # at the fixed-march baseline rate (the baseline_samples
        # convention) — zero-march frames must not vanish from the split
        stats["samples_reused"] = (int(stats.get("samples_reused", 0))
                                   + (R - march_idx.size) * acfg.ns_full)
        rays_marched, valid_fraction = int(march_idx.size), warped.valid_fraction

    stats["probe_samples"] = 0 if maps is None else maps.cost
    stats["probe_reused"] = probe_reused
    stats["probe_skipped"] = probe_skipped
    stats["radiance_reused"] = warped is not None
    stats["rays_marched"] = rays_marched
    stats["rays_total"] = R
    stats["warp_valid_fraction"] = valid_fraction
    return img_flat.reshape(H, W, 3), stats
