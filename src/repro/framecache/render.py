"""Single-image rendering through the full cross-frame reuse stack.

``render_asdr_image_cached`` is ``core.pipeline.render_asdr_image`` plus a
per-scene ``FrameCache``: Phase I goes through the warped probe cache,
Phase II first asks the radiance cache for a warp of a nearby finished
frame and marches only the disoccluded rays.  The serving engine
(serve/render_engine.py) pools the same per-frame work across requests;
this path is the sequential reference the engine is tested against, and
what the reuse-radius sweep benchmark drives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from .probe import ProbeCache, ProbeReuseConfig, cached_probe_maps
from .radiance import RadianceCache, RadianceReuseConfig


@dataclasses.dataclass
class FrameCache:
    """The per-scene reuse state: probe maps + finished radiance."""
    probe: Optional[ProbeCache] = None
    radiance: Optional[RadianceCache] = None


def make_frame_cache(
    probe_cfg: ProbeReuseConfig | None = ProbeReuseConfig(),
    radiance_cfg: RadianceReuseConfig | None = RadianceReuseConfig(),
) -> FrameCache:
    return FrameCache(
        probe=ProbeCache(probe_cfg) if probe_cfg is not None else None,
        radiance=(RadianceCache(radiance_cfg)
                  if radiance_cfg is not None else None),
    )


def render_asdr_image_cached(fns: FieldFns, acfg: ASDRConfig, cam,
                             fc: FrameCache | None = None, probe_key=None):
    """Two-phase ASDR render with cross-frame reuse.

    Returns (image (H,W,3), stats).  With fc=None this is exactly
    ``pipeline.render_asdr_image`` (modulo the always-on opacity sort key).
    Stats gain: probe_reused, radiance_reused, rays_marched, rays_total,
    warp_valid_fraction.
    """
    H, W = cam.height, cam.width
    R = H * W
    fc = fc or FrameCache()
    maps, probe_reused = cached_probe_maps(
        fns, acfg, cam, fc.probe, probe_key)

    warped = fc.radiance.lookup(cam, acfg) if fc.radiance is not None else None
    o, d = scene.camera_rays(cam)

    if warped is None:
        o_p, d_p, c_p, op_p, _pad = pipeline.pad_rays_to_blocks(
            acfg, o, d, maps.counts, maps.opacity)
        rgb, acc, stats = pipeline.render_adaptive(
            fns, acfg, o_p, d_p, c_p, op_p)
        img_flat = np.asarray(rgb[:R])
        # maps.depth is None on a dilation-mode probe reuse (depth would be
        # misaligned with this pose) — such frames are not cacheable
        if fc.radiance is not None and maps.depth is not None:
            fc.radiance.store(cam, acfg, rgb[:R], acc[:R], maps.depth)
        rays_marched, valid_fraction = R, 0.0
        stats = dict(stats)
    else:
        march_idx = np.flatnonzero(~warped.valid)
        img_flat = np.asarray(warped.rgb).copy()
        stats = {"samples_processed": jnp.asarray(0),
                 "baseline_samples": 0}
        if march_idx.size:
            sel = jnp.asarray(march_idx, jnp.int32)
            o_p, d_p, c_p, op_p, _pad = pipeline.pad_rays_to_blocks(
                acfg, o[sel], d[sel], maps.counts[sel], maps.opacity[sel])
            rgb, _acc, stats = pipeline.render_adaptive(
                fns, acfg, o_p, d_p, c_p, op_p)
            stats = dict(stats)
            img_flat[march_idx] = np.asarray(rgb[: march_idx.size])
        rays_marched, valid_fraction = int(march_idx.size), warped.valid_fraction

    stats["probe_samples"] = maps.cost
    stats["probe_reused"] = probe_reused
    stats["radiance_reused"] = warped is not None
    stats["rays_marched"] = rays_marched
    stats["rays_total"] = R
    stats["warp_valid_fraction"] = valid_fraction
    return img_flat.reshape(H, W, 3), stats
