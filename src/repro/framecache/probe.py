"""Cross-frame Phase-I reuse: pose-keyed probe maps, warped by pose delta.

The paper's §5.2.2 data reuse extended to the temporal axis: Phase-I
count/opacity/depth maps transfer between nearby camera poses, so most
frames of a smooth trajectory skip the probe entirely.

Two transfer modes, selected by ``ProbeReuseConfig.warp``:

  * warp=True (default) — the cached maps are reprojected to the
    requesting pose with the entry's own probe depth (warp.warp_count_map
    / warp.nearest_source).  Only disoccluded pixels fall back to the
    conservative fill (ns_full), plus a small fixed ``warp_margin``
    dilation for splat rounding — so the usable pose radius is bounded by
    the match thresholds, not by a global dilation cap.
  * warp=False — PR-1 behavior: maps transfer untransformed and the WHOLE
    map is dilated by the worst-case pixel shift of the pose delta; a
    radius above ``dilate_cap`` is a miss.  Kept for the reuse-radius
    sweep benchmark and as the conservative fallback.

A pose delta whose worst-case pixel displacement rounds to zero skips the
warp entirely and returns the entry's maps untransformed — zero-distance
reuse is bit-exactly a re-probe (tests and the replay benchmark gate on
this).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core import adaptive, pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from ..obs import trace as trace_lib
from . import warp as warp_lib
from .base import PoseKeyedCache


@dataclasses.dataclass(frozen=True)
class ProbeReuseConfig:
    """When (and how) may a frame reuse another pose's Phase-I maps?

    A cached entry matches when BOTH the FULL relative-rotation angle
    (geodesic on SO(3) — an in-plane roll counts, since it permutes every
    pixel's ray) and the eye translation to the requesting pose are under
    the thresholds, and the image geometry (HxW, focal) is identical.
    ``refresh_every = k`` forces a fresh probe after an entry has been
    reused k times, bounding count-map staleness on long trajectories;
    0 disables refreshing.
    """
    max_angle_deg: float = 4.0
    max_translation: float = 0.08
    refresh_every: int = 8
    max_entries: int = 64
    # warp=True: reproject cached maps by the pose delta (depth-guided);
    # warp_margin is a FIXED post-warp max-dilation radius absorbing the
    # round-to-nearest splat error — NOT scaled with the pose delta.
    warp: bool = True
    warp_margin: int = 1
    # warp=False fallback: conservative whole-map dilation scaled to the
    # worst-case pixel shift (adaptive.reuse_dilation_radius); a pose delta
    # whose radius exceeds dilate_cap is a MISS (re-probe) — never a
    # smaller-than-safe dilation.
    dilate_margin: float = 1.5
    dilate_cap: int = 8


@dataclasses.dataclass
class ProbeMaps:
    """Phase-I products for one frame, all flat (H*W,) on device.

    cost is the probe's sample count — 0 when the maps were reused.
    depth is None on a dilation-mode (warp=False) reuse at nonzero pose
    delta: the entry's depth belongs to the CACHED pose's pixel grid and
    transferring it unwarped would misregister anything built on it.
    (The radiance store no longer consumes this map at all — finished
    frames are cached under the Phase-II march's own termination depth,
    which is pose-aligned by construction.)"""
    counts: jnp.ndarray
    opacity: jnp.ndarray
    depth: jnp.ndarray | None
    cost: int


@dataclasses.dataclass
class _ProbeEntry:
    cam: "scene.Camera"
    acfg: ASDRConfig          # config the maps were probed under
    maps: ProbeMaps
    reuses_since_probe: int = 0
    last_used: int = 0
    seq: int = 0              # insertion order — eviction tie-break
    version: int = 0          # bumped on rebase — invalidates prepared plans


class ProbeCache(PoseKeyedCache):
    """Pose-keyed cache of Phase-I (counts, opacity, depth) maps.

    Matching/retention policy in base.PoseKeyedCache (shared with the
    radiance tier).  One cache per scene — poses from different fields
    must never share count maps.
    """

    def __init__(self, rcfg: ProbeReuseConfig | None = None):
        super().__init__(rcfg or ProbeReuseConfig())
        # admissions that consumed NO probe maps (full radiance hit
        # upstream): they are neither hits nor misses — the maps were
        # never needed — and MUST NOT age any entry (see note_skip)
        self.skips = 0

    def note_skip(self):
        """Record an admission that skipped Phase I entirely.

        A full radiance hit delivers the frame before the probe would
        run, so the admission consumes no count/opacity maps.  Counting
        it as a hit would age the matched entry (``reuses_since_probe``)
        and eventually force a refresh probe for maps nobody reads;
        counting it as a miss would run that probe immediately.  The skip
        is its own ledger line: the staleness bound stays "at most
        ``refresh_every`` CONSUMED reuses between probes", and
        ``hits + misses + skips`` equals admissions exactly.
        """
        with self.lock:
            self.skips += 1

    @property
    def no_probe_fraction(self) -> float:
        """Fraction of admissions that paid zero probe samples (hits via
        reuse plus full-radiance-hit skips) — the replay gate metric."""
        total = self.hits + self.misses + self.skips
        return (self.hits + self.skips) / total if total else 0.0

    def _entry_nbytes(self, entry) -> int:
        m = entry.maps
        return self._arrays_nbytes(m.counts, m.opacity, m.depth)

    def _store(self, cam, acfg, maps: ProbeMaps, replacing=None):
        clock = self._tick()
        if replacing is not None:
            replacing.cam = cam
            replacing.acfg = acfg
            replacing.maps = maps
            replacing.reuses_since_probe = 0
            replacing.last_used = clock
            replacing.version += 1
            return
        self._append_with_eviction(_ProbeEntry(cam, acfg, maps,
                                               last_used=clock))


def _fresh_probe(fns: FieldFns, acfg: ASDRConfig, cam, probe_key) -> ProbeMaps:
    counts, cost, opacity, depth = pipeline.probe_phase(
        fns, acfg, cam, probe_key, return_opacity=True, return_depth=True)
    return ProbeMaps(counts, opacity, depth, cost)


def _warped_maps(src: ProbeMaps, src_cam, cam, acfg: ASDRConfig,
                 rcfg: ProbeReuseConfig) -> ProbeMaps:
    """A snapshot's maps reprojected to the requesting pose."""
    H, W = cam.height, cam.width
    tgt, ok, dist = warp_lib.forward_warp(src_cam, cam, src.depth)
    counts, _cvalid = warp_lib.warp_count_map(
        src.counts, src.depth, src_cam, cam, acfg.ns_full,
        margin=rcfg.warp_margin, projection=(tgt, ok, dist))
    sidx, valid = warp_lib.nearest_source(tgt, ok, dist, H * W)
    # disoccluded pixels: opacity 1.0 sorts them with the expensive rays
    # their ns_full count already makes them; depth parks at FAR so a
    # radiance frame built on these maps warps them as background.
    opacity = jnp.where(valid, src.opacity[sidx], 1.0)
    depth = jnp.where(valid, dist[sidx], scene.FAR)
    return ProbeMaps(counts, opacity, depth, 0)


# --------------------------------------------------------------- planning
#
# Phase I is split into three stages so the serving engine can speculate
# it ahead of need (double-buffered admission) without committing cache
# state it may have to revise:
#
#   plan_probe    — PURE decision against a snapshot of the cache;
#   execute_plan  — PURE device work (fresh probe / warp / dilate);
#   commit_plan   — the ONLY mutating stage (counters, stores, aging).
#
# A prepared (plan, maps) pair is valid for reuse iff the plan's
# ``basis`` — a fingerprint of every input the execution reads — still
# matches a freshly computed plan at commit time.  Fresh and refresh
# probes share the basis ``("probe",)``: both execute the same
# _fresh_probe(fns, acfg, cam, key), so speculated fresh maps survive a
# decision flip between them.  ``cached_probe_maps`` chains the three
# stages and is bit-identical to the pre-split single call.

@dataclasses.dataclass
class ProbePlan:
    """A pure Phase-I admission decision.

    kind: "fresh" (no usable entry), "reuse" (serve from ``entry`` in
    ``mode`` exact/warp/dilate), or "refresh" (entry matched but stale or
    past the dilation cap — probe now and rebase it).

    ``src_maps``/``src_cam`` are the entry state SNAPSHOT execution reads,
    captured atomically under the cache lock at plan time: the live entry
    may be rebased (fields reassigned, version bumped) by a commit on the
    engine thread while a worker executes this plan, but the snapshot
    stays internally consistent and the ``basis`` version stamp flags the
    result stale at commit.
    """
    kind: str
    entry: object | None = None
    mode: str = "probe"        # reuse flavor: "exact" | "warp" | "dilate"
    radius: int = 0            # dilate-mode dilation radius
    basis: tuple = ("probe",)  # fingerprint of the inputs execution reads
    src_maps: ProbeMaps | None = None
    src_cam: object | None = None


def plan_probe(cache: ProbeCache | None, cam, acfg: ASDRConfig) -> ProbePlan:
    """Decide how this admission gets its Phase-I maps.  Pure: reads the
    cache, mutates nothing — safe to run speculatively (from any thread)
    and re-run at commit time to revalidate a prepared plan.  The entry
    read is a consistent snapshot taken under the cache lock."""
    with trace_lib.span("probe.plan") as sp:
        plan = _plan_probe(cache, cam, acfg)
        if sp is not trace_lib.NULL_SPAN:
            # the decision is the payload — stamped after it's made
            sp.attrs["kind"] = plan.kind
            sp.attrs["mode"] = plan.mode
        return plan


def _plan_probe(cache, cam, acfg: ASDRConfig) -> ProbePlan:
    if cache is None:
        return ProbePlan("fresh")
    with cache.lock:
        match = cache._match(cam, acfg)
        if match is None:
            return ProbePlan("fresh")
        entry, ang, tr = match
        rcfg = cache.rcfg
        k = rcfg.refresh_every
        stale = k > 0 and entry.reuses_since_probe >= k
        # worst-case pixel displacement of the delta (margin 1.0 = the
        # true bound): 0 means no content crossed a pixel boundary and
        # the maps transfer bit-exactly, warp or no warp
        shift = adaptive.reuse_dilation_radius(cam, ang, tr, scene.NEAR,
                                               margin=1.0)
        if rcfg.warp:
            usable, radius = not stale, 0
        else:
            radius = adaptive.reuse_dilation_radius(
                cam, ang, tr, scene.NEAR, margin=rcfg.dilate_margin,
            ) if rcfg.dilate_margin > 0 else 0
            usable = radius <= rcfg.dilate_cap and not stale
        if not usable:
            # re-probe at the CURRENT pose and rebase the entry: either a
            # scheduled refresh (k-th consumed reuse) or — in dilation
            # mode — a pose delta whose radius overflows dilate_cap
            return ProbePlan("refresh", entry)
        mode = "exact" if shift == 0 else ("warp" if rcfg.warp else "dilate")
        return ProbePlan("reuse", entry, mode, radius,
                         basis=(mode, id(entry), entry.version, radius),
                         src_maps=entry.maps, src_cam=entry.cam)


def execute_probe_plan(fns: FieldFns, acfg: ASDRConfig, cam,
                       plan: ProbePlan, probe_key=None,
                       rcfg: ProbeReuseConfig | None = None) -> ProbeMaps:
    """Run the device work the plan calls for.  Pure, and touches only
    the plan's snapshot (never the live entry) — dispatchable on a worker
    thread while an earlier march is still in flight."""
    with trace_lib.span("probe.execute", kind=plan.kind, mode=plan.mode):
        if plan.kind in ("fresh", "refresh"):
            return _fresh_probe(fns, acfg, cam, probe_key)
        if plan.mode == "exact":
            return dataclasses.replace(plan.src_maps, cost=0)
        if plan.mode == "warp":
            return _warped_maps(plan.src_maps, plan.src_cam, cam, acfg,
                                rcfg)
        counts = adaptive.dilate_count_map(
            plan.src_maps.counts, (cam.height, cam.width), plan.radius,
            border_fill=acfg.ns_full)
        # depth=None: the entry's depth is in the CACHED pose's pixel
        # grid and this mode (by definition) does not warp — see
        # ProbeMaps docstring
        return ProbeMaps(counts, plan.src_maps.opacity, None, 0)


def commit_probe_plan(cache: ProbeCache | None, cam, acfg: ASDRConfig,
                      plan: ProbePlan, maps: ProbeMaps) -> bool:
    """Apply the plan's bookkeeping; returns reused.  The only stage that
    mutates the cache, so all aging/stores happen at one deterministic
    point (admission, engine thread) regardless of how early — or on
    which thread — the maps were computed."""
    if cache is None:
        return False
    with trace_lib.span("probe.commit", kind=plan.kind), cache.lock:
        if plan.kind == "reuse":
            cache.hits += 1
            plan.entry.reuses_since_probe += 1
            plan.entry.last_used = cache._tick()
            return True
        if plan.kind == "refresh":
            cache.refreshes += 1
            cache.misses += 1
            cache._store(cam, acfg, maps, replacing=plan.entry)
            return False
        cache.misses += 1
        cache._store(cam, acfg, maps)
        return False


def cached_probe_maps(fns: FieldFns, acfg: ASDRConfig, cam,
                      cache: ProbeCache | None, probe_key=None):
    """Phase I with cross-frame reuse: returns (ProbeMaps, reused: bool).

    maps.cost is 0 on a cache hit — the whole point: a reused frame pays
    only Phase II.  Opacity/depth are always produced so the serving
    engine can sort pooled blocks and feed the radiance cache.
    Plan + execute + commit in one synchronous step — the sequential
    path; the serving engine drives the stages separately to overlap
    execution with the pooled march.
    """
    plan = plan_probe(cache, cam, acfg)
    maps = execute_probe_plan(fns, acfg, cam, plan, probe_key,
                              rcfg=cache.rcfg if cache is not None else None)
    reused = commit_probe_plan(cache, cam, acfg, plan, maps)
    return maps, reused


def probe_phase_cached(fns: FieldFns, acfg: ASDRConfig, cam,
                       cache: ProbeCache | None, probe_key=None):
    """Compat wrapper with the pre-framecache contract.

    Returns (counts (H*W,), probe_cost, opacity (H*W,), reused: bool) —
    exactly what core.pipeline.probe_phase_cached returned before the
    subsystem moved here.  New code should use ``cached_probe_maps``.
    """
    maps, reused = cached_probe_maps(fns, acfg, cam, cache, probe_key)
    return maps.counts, maps.cost, maps.opacity, reused
