"""Cross-frame Phase-I reuse: pose-keyed probe maps, warped by pose delta.

The paper's §5.2.2 data reuse extended to the temporal axis: Phase-I
count/opacity/depth maps transfer between nearby camera poses, so most
frames of a smooth trajectory skip the probe entirely.

Two transfer modes, selected by ``ProbeReuseConfig.warp``:

  * warp=True (default) — the cached maps are reprojected to the
    requesting pose with the entry's own probe depth (warp.warp_count_map
    / warp.nearest_source).  Only disoccluded pixels fall back to the
    conservative fill (ns_full), plus a small fixed ``warp_margin``
    dilation for splat rounding — so the usable pose radius is bounded by
    the match thresholds, not by a global dilation cap.
  * warp=False — PR-1 behavior: maps transfer untransformed and the WHOLE
    map is dilated by the worst-case pixel shift of the pose delta; a
    radius above ``dilate_cap`` is a miss.  Kept for the reuse-radius
    sweep benchmark and as the conservative fallback.

A pose delta whose worst-case pixel displacement rounds to zero skips the
warp entirely and returns the entry's maps untransformed — zero-distance
reuse is bit-exactly a re-probe (tests and the replay benchmark gate on
this).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core import adaptive, pipeline, scene
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from . import warp as warp_lib
from .base import PoseKeyedCache


@dataclasses.dataclass(frozen=True)
class ProbeReuseConfig:
    """When (and how) may a frame reuse another pose's Phase-I maps?

    A cached entry matches when BOTH the FULL relative-rotation angle
    (geodesic on SO(3) — an in-plane roll counts, since it permutes every
    pixel's ray) and the eye translation to the requesting pose are under
    the thresholds, and the image geometry (HxW, focal) is identical.
    ``refresh_every = k`` forces a fresh probe after an entry has been
    reused k times, bounding count-map staleness on long trajectories;
    0 disables refreshing.
    """
    max_angle_deg: float = 4.0
    max_translation: float = 0.08
    refresh_every: int = 8
    max_entries: int = 64
    # warp=True: reproject cached maps by the pose delta (depth-guided);
    # warp_margin is a FIXED post-warp max-dilation radius absorbing the
    # round-to-nearest splat error — NOT scaled with the pose delta.
    warp: bool = True
    warp_margin: int = 1
    # warp=False fallback: conservative whole-map dilation scaled to the
    # worst-case pixel shift (adaptive.reuse_dilation_radius); a pose delta
    # whose radius exceeds dilate_cap is a MISS (re-probe) — never a
    # smaller-than-safe dilation.
    dilate_margin: float = 1.5
    dilate_cap: int = 8


@dataclasses.dataclass
class ProbeMaps:
    """Phase-I products for one frame, all flat (H*W,) on device.

    cost is the probe's sample count — 0 when the maps were reused.
    depth is None on a dilation-mode (warp=False) reuse at nonzero pose
    delta: the entry's depth belongs to the CACHED pose's pixel grid and
    transferring it unwarped would misregister anything built on it.
    (The radiance store no longer consumes this map at all — finished
    frames are cached under the Phase-II march's own termination depth,
    which is pose-aligned by construction.)"""
    counts: jnp.ndarray
    opacity: jnp.ndarray
    depth: jnp.ndarray | None
    cost: int


@dataclasses.dataclass
class _ProbeEntry:
    cam: "scene.Camera"
    acfg: ASDRConfig          # config the maps were probed under
    maps: ProbeMaps
    reuses_since_probe: int = 0
    last_used: int = 0
    seq: int = 0              # insertion order — eviction tie-break


class ProbeCache(PoseKeyedCache):
    """Pose-keyed cache of Phase-I (counts, opacity, depth) maps.

    Matching/retention policy in base.PoseKeyedCache (shared with the
    radiance tier).  One cache per scene — poses from different fields
    must never share count maps.
    """

    def __init__(self, rcfg: ProbeReuseConfig | None = None):
        super().__init__(rcfg or ProbeReuseConfig())

    def _entry_nbytes(self, entry) -> int:
        m = entry.maps
        return self._arrays_nbytes(m.counts, m.opacity, m.depth)

    def _store(self, cam, acfg, maps: ProbeMaps, replacing=None):
        clock = self._tick()
        if replacing is not None:
            replacing.cam = cam
            replacing.acfg = acfg
            replacing.maps = maps
            replacing.reuses_since_probe = 0
            replacing.last_used = clock
            return
        self._append_with_eviction(_ProbeEntry(cam, acfg, maps,
                                               last_used=clock))


def _fresh_probe(fns: FieldFns, acfg: ASDRConfig, cam, probe_key) -> ProbeMaps:
    counts, cost, opacity, depth = pipeline.probe_phase(
        fns, acfg, cam, probe_key, return_opacity=True, return_depth=True)
    return ProbeMaps(counts, opacity, depth, cost)


def _warped_maps(entry: _ProbeEntry, cam, acfg: ASDRConfig,
                 rcfg: ProbeReuseConfig) -> ProbeMaps:
    """Entry's maps reprojected to the requesting pose."""
    src = entry.maps
    H, W = cam.height, cam.width
    tgt, ok, dist = warp_lib.forward_warp(entry.cam, cam, src.depth)
    counts, _cvalid = warp_lib.warp_count_map(
        src.counts, src.depth, entry.cam, cam, acfg.ns_full,
        margin=rcfg.warp_margin, projection=(tgt, ok, dist))
    sidx, valid = warp_lib.nearest_source(tgt, ok, dist, H * W)
    # disoccluded pixels: opacity 1.0 sorts them with the expensive rays
    # their ns_full count already makes them; depth parks at FAR so a
    # radiance frame built on these maps warps them as background.
    opacity = jnp.where(valid, src.opacity[sidx], 1.0)
    depth = jnp.where(valid, dist[sidx], scene.FAR)
    return ProbeMaps(counts, opacity, depth, 0)


def cached_probe_maps(fns: FieldFns, acfg: ASDRConfig, cam,
                      cache: ProbeCache | None, probe_key=None):
    """Phase I with cross-frame reuse: returns (ProbeMaps, reused: bool).

    maps.cost is 0 on a cache hit — the whole point: a reused frame pays
    only Phase II.  Opacity/depth are always produced so the serving
    engine can sort pooled blocks and feed the radiance cache.
    """
    if cache is None:
        return _fresh_probe(fns, acfg, cam, probe_key), False
    match = cache._match(cam, acfg)
    if match is not None:
        entry, ang, tr = match
        rcfg = cache.rcfg
        k = rcfg.refresh_every
        stale = k > 0 and entry.reuses_since_probe >= k
        # worst-case pixel displacement of the delta (margin 1.0 = the
        # true bound): 0 means no content crossed a pixel boundary and
        # the maps transfer bit-exactly, warp or no warp
        shift = adaptive.reuse_dilation_radius(cam, ang, tr, scene.NEAR,
                                               margin=1.0)
        if rcfg.warp:
            usable = not stale
        else:
            radius = adaptive.reuse_dilation_radius(
                cam, ang, tr, scene.NEAR, margin=rcfg.dilate_margin,
            ) if rcfg.dilate_margin > 0 else 0
            usable = radius <= rcfg.dilate_cap and not stale
        if usable:
            cache.hits += 1
            entry.reuses_since_probe += 1
            entry.last_used = cache._tick()
            if shift == 0:
                return dataclasses.replace(entry.maps, cost=0), True
            if rcfg.warp:
                return _warped_maps(entry, cam, acfg, rcfg), True
            counts = adaptive.dilate_count_map(
                entry.maps.counts, (cam.height, cam.width), radius,
                border_fill=acfg.ns_full)
            # depth=None: the entry's depth is in the CACHED pose's pixel
            # grid and this mode (by definition) does not warp — see
            # ProbeMaps docstring
            return ProbeMaps(counts, entry.maps.opacity, None, 0), True
        # re-probe at the CURRENT pose and rebase the entry: either a
        # scheduled refresh (k-th reuse) or — in dilation mode — a pose
        # delta whose conservative radius overflows dilate_cap
        maps = _fresh_probe(fns, acfg, cam, probe_key)
        cache.refreshes += 1
        cache.misses += 1
        cache._store(cam, acfg, maps, replacing=entry)
        return maps, False
    maps = _fresh_probe(fns, acfg, cam, probe_key)
    cache.misses += 1
    cache._store(cam, acfg, maps)
    return maps, False


def probe_phase_cached(fns: FieldFns, acfg: ASDRConfig, cam,
                       cache: ProbeCache | None, probe_key=None):
    """Compat wrapper with the pre-framecache contract.

    Returns (counts (H*W,), probe_cost, opacity (H*W,), reused: bool) —
    exactly what core.pipeline.probe_phase_cached returned before the
    subsystem moved here.  New code should use ``cached_probe_maps``.
    """
    maps, reused = cached_probe_maps(fns, acfg, cam, cache, probe_key)
    return maps.counts, maps.cost, maps.opacity, reused
