"""Cross-frame reuse of finished Phase-II radiance — the big frame lever.

A completed frame (rgb, acc) plus its per-ray march termination depth
(full resolution, from the Phase-II while_loop — sharper at depth edges
than the probe's stride-d proxy it replaced) is cached keyed by
(scene, pose, acfg).  A later request within the radiance-reuse
radius warps the cached frame to its own pose (warp.warp_image, z-buffered
nearest-surface) and receives a per-pixel validity mask: VALID pixels take
the warped radiance directly and skip Phase II entirely; only the INVALID
(disoccluded) rays are marched through the block pipeline and composited
over the warp.  On a smooth trajectory most rays of most frames never
touch the field network.

Safety invariants:

  * only FULLY-rendered frames are stored — a frame assembled from a warp
    is never re-cached, so warps never chain and drift is bounded by one
    reprojection from an honestly rendered frame;
  * ``refresh_every`` forces a full render after an entry has been reused
    k times, bounding staleness on long dwells;
  * a warp whose valid fraction drops below ``min_valid_fraction`` is a
    MISS (full render), so a degenerate warp can never dominate a frame;
  * zero pixel displacement skips the warp — replaying a pose returns the
    cached frame bit-exactly.

Host-side bookkeeping mirrors probe.ProbeCache; the frames stay on device.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import adaptive, scene
from ..core.pipeline import ASDRConfig
from . import warp as warp_lib
from .base import PoseKeyedCache


@dataclasses.dataclass(frozen=True)
class RadianceReuseConfig:
    """When may a frame reuse another pose's finished radiance?

    Deliberately tighter defaults than ProbeReuseConfig: warped radiance
    is the final image (errors are visible), while warped counts only
    steer sampling (errors cost samples, not quality).
    """
    max_angle_deg: float = 2.0
    max_translation: float = 0.04
    refresh_every: int = 4
    max_entries: int = 32
    min_valid_fraction: float = 0.6


@dataclasses.dataclass
class WarpedRadiance:
    """A cached frame reprojected to the requesting pose.

    Deliberately rgb + validity only: warped frames are never re-cached
    (invariant above), so consumers have no use for warped acc/depth —
    they composite marched rays over ``rgb`` where ``valid`` is False.
    """
    rgb: jnp.ndarray       # (H*W, 3)
    valid: np.ndarray      # (H*W,) bool, host-side — drives ray selection
    valid_fraction: float


@dataclasses.dataclass
class _RadianceEntry:
    cam: "scene.Camera"
    acfg: ASDRConfig
    rgb: jnp.ndarray
    acc: jnp.ndarray
    depth: jnp.ndarray
    reuses_since_render: int = 0
    last_used: int = 0
    seq: int = 0              # insertion order — eviction tie-break


class RadianceCache(PoseKeyedCache):
    """Pose-keyed cache of finished Phase-II frames, one per scene.

    Matching/retention policy in base.PoseKeyedCache (shared with the
    probe tier)."""

    def __init__(self, rcfg: RadianceReuseConfig | None = None):
        super().__init__(rcfg or RadianceReuseConfig())
        self.low_valid_misses = 0

    def _entry_nbytes(self, entry) -> int:
        return self._arrays_nbytes(entry.rgb, entry.acc, entry.depth)

    # ------------------------------------------------------------- lookup
    def lookup(self, cam, acfg: ASDRConfig) -> WarpedRadiance | None:
        """Warped cached frame for this pose, or None (= render fully).

        A None return already counted as a miss; the caller should render
        the frame normally and hand it back via ``store``.
        """
        match = self._match(cam, acfg)
        if match is None:
            self.misses += 1
            return None
        entry, ang, tr = match
        k = self.rcfg.refresh_every
        if k > 0 and entry.reuses_since_render >= k:
            self.refreshes += 1
            self.misses += 1
            return None
        shift = adaptive.reuse_dilation_radius(cam, ang, tr, scene.NEAR,
                                               margin=1.0)
        if shift == 0:
            rgb = entry.rgb
            valid = np.ones((cam.height * cam.width,), bool)
            vf = 1.0
        else:
            rgb, _acc, _depth, valid_j = warp_lib.warp_image(
                entry.rgb, entry.acc, entry.depth, entry.cam, cam)
            valid = np.asarray(valid_j)
            vf = float(valid.mean())
            if vf < self.rcfg.min_valid_fraction:
                self.low_valid_misses += 1
                self.misses += 1
                return None
        self.hits += 1
        entry.reuses_since_render += 1
        entry.last_used = self._tick()
        return WarpedRadiance(rgb, valid, vf)

    # -------------------------------------------------------------- store
    def store(self, cam, acfg: ASDRConfig, rgb, acc, depth):
        """Cache a FULLY-rendered frame (never a warped composite)."""
        clock = self._tick()
        match = self._match(cam, acfg)
        if match is not None:        # rebase the nearby entry (refresh)
            entry, _, _ = match
            entry.cam = cam
            entry.acfg = acfg
            entry.rgb, entry.acc, entry.depth = rgb, acc, depth
            entry.reuses_since_render = 0
            entry.last_used = clock
            return
        self._append_with_eviction(_RadianceEntry(cam, acfg, rgb, acc, depth,
                                                  last_used=clock))
