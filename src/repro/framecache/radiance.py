"""Cross-frame reuse of finished Phase-II radiance — the big frame lever.

A completed frame (rgb, acc) plus its per-ray march termination depth
(full resolution, from the Phase-II while_loop — sharper at depth edges
than the probe's stride-d proxy it replaced) is cached keyed by
(scene, pose, acfg).  A later request within the radiance-reuse
radius warps the cached frame to its own pose (warp.warp_image, z-buffered
nearest-surface) and receives a per-pixel validity mask: VALID pixels take
the warped radiance directly and skip Phase II entirely; only the INVALID
(disoccluded) rays are marched through the block pipeline and composited
over the warp.  On a smooth trajectory most rays of most frames never
touch the field network.

Safety invariants:

  * only FULLY-rendered frames are stored — a frame assembled from a warp
    is never re-cached, so warps never chain and drift is bounded by one
    reprojection from an honestly rendered frame;
  * ``refresh_every`` forces a full render after an entry has been reused
    k times, bounding staleness on long dwells;
  * a warp whose valid fraction drops below ``min_valid_fraction`` is a
    MISS (full render), so a degenerate warp can never dominate a frame;
  * zero pixel displacement skips the warp — replaying a pose returns the
    cached frame bit-exactly.

Host-side bookkeeping mirrors probe.ProbeCache; the frames stay on device.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import adaptive, scene
from ..core.pipeline import ASDRConfig
from ..obs import trace as trace_lib
from . import warp as warp_lib
from .base import PoseKeyedCache


@dataclasses.dataclass(frozen=True)
class RadianceReuseConfig:
    """When may a frame reuse another pose's finished radiance?

    Deliberately tighter defaults than ProbeReuseConfig: warped radiance
    is the final image (errors are visible), while warped counts only
    steer sampling (errors cost samples, not quality).
    """
    max_angle_deg: float = 2.0
    max_translation: float = 0.04
    refresh_every: int = 4
    max_entries: int = 32
    min_valid_fraction: float = 0.6


@dataclasses.dataclass
class WarpedRadiance:
    """A cached frame reprojected to the requesting pose.

    Deliberately rgb + validity only: warped frames are never re-cached
    (invariant above), so consumers have no use for warped acc/depth —
    they composite marched rays over ``rgb`` where ``valid`` is False.
    """
    rgb: jnp.ndarray       # (H*W, 3)
    valid: np.ndarray      # (H*W,) bool, host-side — drives ray selection
    valid_fraction: float

    @property
    def full_hit(self) -> bool:
        """Every pixel valid: the frame is delivered entirely from the
        warp — zero rays march, and Phase I can be skipped outright."""
        return bool(self.valid.all())


@dataclasses.dataclass
class _RadianceEntry:
    cam: "scene.Camera"
    acfg: ASDRConfig
    rgb: jnp.ndarray
    acc: jnp.ndarray
    depth: jnp.ndarray
    reuses_since_render: int = 0
    last_used: int = 0
    seq: int = 0              # insertion order — eviction tie-break
    version: int = 0          # bumped on rebase — invalidates prepared plans


class RadianceCache(PoseKeyedCache):
    """Pose-keyed cache of finished Phase-II frames, one per scene.

    Matching/retention policy in base.PoseKeyedCache (shared with the
    probe tier)."""

    def __init__(self, rcfg: RadianceReuseConfig | None = None):
        super().__init__(rcfg or RadianceReuseConfig())
        self.low_valid_misses = 0

    def _entry_nbytes(self, entry) -> int:
        return self._arrays_nbytes(entry.rgb, entry.acc, entry.depth)

    # ------------------------------------------------------------- lookup
    def lookup(self, cam, acfg: ASDRConfig) -> WarpedRadiance | None:
        """Warped cached frame for this pose, or None (= render fully).

        A None return already counted as a miss; the caller should render
        the frame normally and hand it back via ``store``.  Plan + commit
        in one synchronous step — the sequential path; the serving engine
        drives the stages separately (plan_lookup speculatively ahead of
        need, commit_lookup at admission).
        """
        return commit_lookup(self, plan_lookup(self, cam, acfg))

    # -------------------------------------------------------------- store
    def store(self, cam, acfg: ASDRConfig, rgb, acc, depth):
        """Cache a FULLY-rendered frame (never a warped composite).

        A rebase reassigns the entry's array fields and bumps its version
        in one critical section — concurrent plan snapshots (taken under
        the same lock) therefore always see arrays and version of ONE
        generation (never a torn entry)."""
        with self.lock:
            clock = self._tick()
            match = self._match(cam, acfg)
            if match is not None:    # rebase the nearby entry (refresh)
                entry, _, _ = match
                entry.cam = cam
                entry.acfg = acfg
                entry.rgb, entry.acc, entry.depth = rgb, acc, depth
                entry.reuses_since_render = 0
                entry.last_used = clock
                entry.version += 1
                return
            self._append_with_eviction(
                _RadianceEntry(cam, acfg, rgb, acc, depth, last_used=clock))


# --------------------------------------------------------------- planning
#
# The radiance lookup split the same way as framecache.probe: a PURE plan
# stage the serving engine may run speculatively (double-buffered
# admission), and a commit stage — the only mutating one — applied at the
# deterministic admission point.  Unlike the probe, the warp itself is
# part of the DECISION (the low-valid-fraction miss needs the warped
# validity mask), so plan_lookup computes it; a prepared plan whose
# ``basis`` still matches hands its arrays over without re-warping.

@dataclasses.dataclass
class RadiancePlan:
    """A pure Phase-II-reuse decision.

    kind "hit" carries the warped frame; kind "miss" carries the reason
    ("no_match" | "refresh" | "low_valid") so commit books the right
    counter.
    """
    kind: str
    reason: str | None = None
    entry: object | None = None
    warped: WarpedRadiance | None = None
    basis: tuple | None = None

    @property
    def full_hit(self) -> bool:
        return self.kind == "hit" and self.warped.full_hit


def plan_lookup(cache: RadianceCache | None, cam, acfg: ASDRConfig,
                prepared: RadiancePlan | None = None) -> RadiancePlan:
    """Decide (and, for hits, execute) the warp for this pose.  Pure:
    mutates nothing — re-run at admission to revalidate, where a still-
    matching ``prepared`` plan donates its warped arrays.

    Thread contract: the entry state (arrays + version) is snapshotted
    atomically under the cache lock; the warp itself — the expensive
    device work — runs OUTSIDE the lock on the snapshot, so worker-thread
    speculation never serializes against engine-thread commits."""
    with trace_lib.span("radiance.plan") as sp:
        plan = _plan_lookup(cache, cam, acfg, prepared)
        if sp is not trace_lib.NULL_SPAN:
            sp.attrs["kind"] = plan.kind
            if plan.reason is not None:
                sp.attrs["reason"] = plan.reason
        return plan


def _plan_lookup(cache, cam, acfg, prepared=None) -> RadiancePlan:
    if cache is None:
        return RadiancePlan("miss", "no_match")
    with cache.lock:
        match = cache._match(cam, acfg)
        if match is None:
            return RadiancePlan("miss", "no_match")
        entry, ang, tr = match
        k = cache.rcfg.refresh_every
        if k > 0 and entry.reuses_since_render >= k:
            return RadiancePlan("miss", "refresh", entry)
        shift = adaptive.reuse_dilation_radius(cam, ang, tr, scene.NEAR,
                                               margin=1.0)
        basis = (id(entry), entry.version, shift == 0)
        src_rgb, src_acc, src_depth = entry.rgb, entry.acc, entry.depth
        src_cam = entry.cam
    if (prepared is not None and prepared.warped is not None
            and prepared.basis == basis):
        warped = prepared.warped
    elif shift == 0:
        warped = WarpedRadiance(
            src_rgb, np.ones((cam.height * cam.width,), bool), 1.0)
    else:
        rgb, _acc, _depth, valid_j = warp_lib.warp_image(
            src_rgb, src_acc, src_depth, src_cam, cam)
        valid = np.asarray(valid_j)
        warped = WarpedRadiance(rgb, valid, float(valid.mean()))
    if shift != 0 and warped.valid_fraction < cache.rcfg.min_valid_fraction:
        return RadiancePlan("miss", "low_valid", entry, warped, basis)
    return RadiancePlan("hit", None, entry, warped, basis)


def commit_lookup(cache: RadianceCache | None,
                  plan: RadiancePlan) -> WarpedRadiance | None:
    """Apply the plan's bookkeeping; returns the warp to composite over
    (None = render fully).  The only mutating stage — engine thread only,
    under the cache lock."""
    if cache is None:
        return None
    with trace_lib.span("radiance.commit", kind=plan.kind), cache.lock:
        if plan.kind == "miss":
            if plan.reason == "refresh":
                cache.refreshes += 1
            elif plan.reason == "low_valid":
                cache.low_valid_misses += 1
            cache.misses += 1
            return None
        cache.hits += 1
        plan.entry.reuses_since_render += 1
        plan.entry.last_used = cache._tick()
        return plan.warped
