import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This file MUST set XLA_FLAGS before any jax import (done above) — jax
locks the device count on first init.  512 placeholder host devices cover
both the single-pod (16,16)=256 and multi-pod (2,16,16)=512 meshes.

Per cell it emits a JSON record with:
  * memory_analysis (bytes per device: args/outputs/temps/peak)
  * cost_analysis   (HLO flops / bytes accessed, per device under SPMD)
  * collective bytes parsed from the optimized HLO (per collective kind)
  * roofline terms (launch/roofline.py) + MODEL_FLOPS ratio
  * lower/compile wall times

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

import repro.configs as configs
from repro.launch import analytic
from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.models import lm
from repro.models.config import SHAPES
from repro.sharding import rules as rules_lib
from repro.train.step import TrainConfig, make_train_step

# long_500k requires sub-quadratic attention: run for SSM/hybrid and the
# local+global alternating gemma family (O(seq) decode against a sharded
# cache, window-bounded local layers); skip for pure full-attention archs
# and whisper (decoder context is architecturally bounded).
LONG_OK = {"gemma2-27b", "gemma3-12b", "mamba2-780m", "hymba-1.5b"}


def cell_is_skipped(arch: str, shape: str) -> bool:
    return shape == "long_500k" and arch not in LONG_OK


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict or (older jax) [dict]."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _spec(axes, rules, mesh):
    return NamedSharding(mesh, rules_lib.resolve_spec(axes, rules, mesh))


def _tree_specs(axes_tree, rules, mesh):
    return jax.tree.map(
        lambda a: _spec(a, rules, mesh), axes_tree,
        is_leaf=rules_lib.is_axes_leaf,
    )


def _bf16(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype,
        ),
        tree,
    )


def microbatches_for(shape, mesh) -> int:
    """Bound per-microbatch rows-per-device to <=2 (activation/logit peaks)."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    rows = max(1, shape.global_batch // dp)
    return max(1, rows // 2)


def build_train_cell(api, shape, mesh, variant="baseline"):
    rules = rules_lib.TRAIN_RULES
    vals, axes = api.abstract()
    mb = microbatches_for(shape, mesh)
    if variant == "opt":
        # §Perf C1+C2+C3: bf16 gathers, half the microbatches (half the
        # per-step param re-gathers), grads pinned to param shardings
        # (reduce-scatter, not replicated all-reduce)
        tcfg = TrainConfig(microbatches=max(1, mb // 2),
                           cast_params_bf16=True)
        step, opt_init = make_train_step(api.loss_fn, tcfg, rules, mesh,
                                         param_axes=axes)
    else:
        tcfg = TrainConfig(microbatches=mb)
        step, opt_init = make_train_step(api.loss_fn, tcfg, rules, mesh)
    opt_abs = jax.eval_shape(opt_init, vals)

    p_sh = _tree_specs(axes, rules, mesh)
    scalar = NamedSharding(mesh, PartitionSpec())
    opt_sh = {"m": p_sh, "v": p_sh, "count": scalar}
    b_axes = api.input_axes()
    batch_specs = api.input_specs(shape)
    b_sh = {k: _spec(b_axes[k], rules, mesh) for k in batch_specs}

    jitted = jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, b_sh, scalar),
        out_shardings=(p_sh, opt_sh, None),
    )
    args = (vals, opt_abs, batch_specs, jax.ShapeDtypeStruct((), jnp.int32))
    layers = api.cfg.n_layers + getattr(api.cfg, "encoder_layers", 0)
    return jitted, args, {
        "microbatches": tcfg.microbatches,
        # scan bodies are listed once in HLO; structurally known trips:
        "scan_multiplier": layers * tcfg.microbatches,
    }


def build_prefill_cell(api, shape, mesh, variant="baseline"):
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    rules = (rules_lib.SERVE_RULES if shape.global_batch >= dp
             else rules_lib.LONG_CONTEXT_SERVE_RULES)
    vals, axes = api.abstract()
    vals = _bf16(vals)
    p_sh = _tree_specs(axes, rules, mesh)
    b_axes = api.input_axes()
    batch_specs = api.input_specs(shape)
    b_sh = {k: _spec(b_axes[k], rules, mesh) for k in batch_specs}

    def prefill(values, batch):
        from repro.sharding.activation import activation_sharding

        with activation_sharding(rules, mesh):
            return api.prefill_fn(values, batch)

    jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
    layers = api.cfg.n_layers + getattr(api.cfg, "encoder_layers", 0)
    return jitted, (vals, batch_specs), {
        "rules": "serve",
        "scan_multiplier": layers,
    }


def build_decode_cell(api, shape, mesh, variant="baseline"):
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    long_ctx = shape.global_batch < dp
    rules = (rules_lib.LONG_CONTEXT_SERVE_RULES if long_ctx
             else rules_lib.SERVE_RULES)
    if variant == "opt" and not long_ctx:
        rules = rules_lib.DECODE_SP_RULES  # §Perf: cache seq over model
    vals, axes = api.abstract()
    vals = _bf16(vals)
    p_sh = _tree_specs(axes, rules, mesh)
    scalar = NamedSharding(mesh, PartitionSpec())

    B, S = shape.global_batch, shape.seq_len
    cache_specs = api.decode_cache_specs(B, S)
    cache_axes = api.decode_cache_axes(B, S)
    c_sh = jax.tree.map(
        lambda a: _spec(a, rules, mesh), cache_axes,
        is_leaf=rules_lib.is_axes_leaf,
    )
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = _spec(("batch", None), rules, mesh)

    def decode(values, caches, token, pos):
        from repro.sharding.activation import activation_sharding

        with activation_sharding(rules, mesh):
            return api.decode_fn(values, caches, token, pos)

    # donate caches: decode updates them in place (without donation XLA
    # holds input AND output caches + per-layer copies — §Perf dbrx cell)
    donate = (1,) if variant == "opt" else ()
    jitted = jax.jit(decode, in_shardings=(p_sh, c_sh, tok_sh, scalar),
                     donate_argnums=donate)
    args = (vals, cache_specs, tok_spec,
            jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args, {
        "rules": "long_ctx" if long_ctx else "serve",
        "scan_multiplier": 1,  # decode unrolls layers in python
    }


def run_asdr_cell(shape_name: str, multi_pod: bool, variant="baseline"):
    """The paper's own model (ingp-asdr) as extra dry-run cells."""
    from repro.launch import asdr_steps

    bundle = configs.get("ingp-asdr")
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    if shape_name == "asdr_render":
        jitted, args, extra = asdr_steps.build_render_cell(
            bundle, mesh, variant=variant)
    elif shape_name == "asdr_train":
        jitted, args, extra = asdr_steps.build_train_cell_ngp(bundle, mesh)
    elif shape_name == "render_serve":
        # the serving engine's pooled multi-view march as a mesh cell, so
        # render-serve rows land in the EXPERIMENTS tables next to the LM
        # cells (same JSON record schema)
        from repro.launch import render_serve
        jitted, args, extra = render_serve.build_pooled_march_cell(
            bundle, mesh)
    else:
        raise ValueError(shape_name)

    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = roofline.collective_bytes(
        compiled.as_text(), body_multiplier=extra.get("scan_multiplier", 1))
    flops = float(cost.get("flops", 0.0)) * extra.get("scan_multiplier", 1)
    bts = float(cost.get("bytes accessed", 0.0)) * extra.get(
        "scan_multiplier", 1)
    terms = roofline.roofline_terms(flops, bts, coll["total"])
    n_chips = 512 if multi_pod else 256
    if shape_name == "render_serve":
        # the scene-space block tier's reuse numbers ride along in the
        # serving cell's record: a tiny concrete multi-client run (host
        # devices) reporting cross-client block hit rate, resident bytes
        # vs budget, and evictions — the march-cost AND march-avoided
        # halves of the serving story in one JSON row
        from repro.launch import render_serve as rs_mod
        extra = dict(extra)
        extra["scenecache"] = rs_mod.scenecache_smoke()
    return {
        "arch": "ingp-asdr", "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        },
        "cost_scan_corrected": {"flops": flops, "bytes": bts},
        "collectives": coll, "roofline": terms,
        "useful_flops_ratio": 1.0,
        **extra,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline"):
    if arch == "ingp-asdr":
        return run_asdr_cell(shape_name, multi_pod, variant)
    shape = SHAPES[shape_name]
    cfg = configs.get(arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    api = lm.build(cfg)

    builders = {
        "train": build_train_cell,
        "prefill": build_prefill_cell,
        "decode": build_decode_cell,
    }
    jitted, args, extra = builders[shape.kind](api, shape, mesh,
                                               variant=variant)

    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    mult = extra.get("scan_multiplier", 1)
    coll = roofline.collective_bytes(hlo, body_multiplier=mult)

    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    # scan-corrected HLO terms (bodies listed once; see analytic.py)
    flops_hlo = flops_raw * mult
    bytes_hlo = bytes_raw * mult

    # analytic executed-FLOPs model (exact trip counts, incl. remat)
    an_f = analytic.cell_flops(cfg, shape)
    an_b = analytic.cell_hbm_bytes(cfg, shape, extra.get("microbatches", 1))
    an_flops_chip = an_f["total_flops"] / n_chips
    an_bytes_chip = an_b["total_bytes"] / n_chips

    terms = roofline.roofline_terms(an_flops_chip, an_bytes_chip,
                                    coll["total"])
    terms_hlo = roofline.roofline_terms(flops_hlo, bytes_hlo, coll["total"])

    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    mf = roofline.model_flops(cfg, tokens, shape.kind)
    mf_per_chip = mf / n_chips

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                  mem.temp_size_in_bytes),
        },
        "cost_raw": {"flops": flops_raw, "bytes_accessed": bytes_raw},
        "cost_scan_corrected": {"flops": flops_hlo, "bytes": bytes_hlo},
        "analytic": {**an_f, **an_b},
        "collectives": coll,
        "roofline": terms,            # analytic flops/bytes + HLO collectives
        "roofline_hlo": terms_hlo,    # scan-corrected HLO flops/bytes
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": (mf_per_chip / an_flops_chip)
                              if an_flops_chip else 0.0,
        **extra,
    }
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"],
                    help="opt = §Perf hillclimb configuration")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = configs.list_archs() if (args.all or not args.arch) else [args.arch]

    def shapes_for(arch):
        # ingp-asdr has its own shape set — pairing it with the LM SHAPES
        # (as a naive product would under --all) makes every cell error
        if arch == "ingp-asdr":
            return (["asdr_render", "asdr_train", "render_serve"]
                    if not args.shape else [args.shape])
        return list(SHAPES) if (args.all or not args.shape) else [args.shape]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes_for(a):
            for m in meshes:
                cells.append((a, s, m))

    suffix = "" if args.variant == "baseline" else f"_{args.variant}"
    for arch, shape_name, mesh_kind in cells:
        tag = f"{arch}_{shape_name}_{mesh_kind}{suffix}"
        out_path = outdir / f"{tag}.json"
        if out_path.exists():
            print(f"[skip-done] {tag}")
            continue
        if cell_is_skipped(arch, shape_name):
            out_path.write_text(json.dumps(
                {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "skipped": True,
                 "reason": "long_500k needs sub-quadratic attention "
                           "(see DESIGN.md)"}, indent=1))
            print(f"[skip] {tag}: full-attention arch")
            continue
        print(f"[run ] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, mesh_kind == "multi",
                           variant=args.variant)
            rec["variant"] = args.variant
            out_path.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"[ok  ] {tag}: compile {rec['compile_s']}s "
                f"compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
                f"coll {r['collective_s']:.4f}s -> {r['bottleneck']}",
                flush=True,
            )
        except Exception as e:  # noqa
            err = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "error": str(e)[:2000],
                   "traceback": traceback.format_exc()[-4000:]}
            (outdir / f"{tag}.error.json").write_text(json.dumps(err, indent=1))
            print(f"[FAIL] {tag}: {str(e)[:200]}", flush=True)


if __name__ == "__main__":
    main()
