"""Render-serve launcher: pooled multi-view Phase-II blocks on the data mesh.

Two modes:

  concrete (this container, 1 device) — run the slot-based render serving
  engine end-to-end on analytic scenes over a camera trajectory:
    PYTHONPATH=src python -m repro.launch.render_serve --poses 10 --size 32

  dry-run (production mesh, forced host devices) — lower + compile the
  engine's batched march with the pooled block axis sharded over
  (pod,)data and the NGP params replicated per chip:
    PYTHONPATH=src python -m repro.launch.render_serve --dryrun [--multi-pod]

The pooled march is the serving engine's inner loop lifted to the mesh:
blocks pooled from ALL live requests form one (pool_blocks, block, 3)
batch whose leading axis shards over ``data`` — every chip marches its
slice of the pool, so multi-user throughput scales with chips while each
request's blocks stay difficulty-sorted (budget-homogeneous slices).
"""
import os
import sys

if "--dryrun" in sys.argv:
    # must precede the first jax import (jax locks device count on init);
    # APPEND so a user's pre-existing XLA_FLAGS don't silently drop the
    # forced device count (mesh construction would fail with 1 device)
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# pooled blocks per sharded march call; divisible by the 16-wide data axis
POOL_BLOCKS = 64


def build_pooled_march_cell(bundle, mesh, pool_blocks: int = POOL_BLOCKS):
    """The serving engine's batched march as a production-mesh cell.

    Grid tables replicate per chip (asdr_steps' 'opt' variant — the paper's
    §5.2.1 replication insight), so marching a pooled block touches no
    cross-chip collectives; the block axis shards over (pod,)data.
    """
    from repro.core import model as model_lib, pipeline
    from repro.launch import asdr_steps

    cfg = bundle.model
    acfg = dataclasses.replace(bundle.asdr,
                               block_size=asdr_steps.RENDER_BLOCK)

    def march(params, origins, dirs, budgets):
        fns = model_lib.field_fns(params, cfg)
        m = partial(pipeline._march_block, fns, acfg)
        return jax.lax.map(lambda a: m(*a), (origins, dirs, budgets))

    b = asdr_steps._batch_spec(mesh)
    p_sh = asdr_steps.param_shardings(cfg, mesh, shard_tables=False)
    blk_sh = NamedSharding(mesh, P(b, None, None))
    bud_sh = NamedSharding(mesh, P(b))
    jitted = jax.jit(march, in_shardings=(p_sh, blk_sh, blk_sh, bud_sh))
    B = acfg.block_size
    args = (
        asdr_steps.abstract_params(cfg),
        jax.ShapeDtypeStruct((pool_blocks, B, 3), jnp.float32),
        jax.ShapeDtypeStruct((pool_blocks, B, 3), jnp.float32),
        jax.ShapeDtypeStruct((pool_blocks,), jnp.int32),
    )
    # lax.map is a scan: the block body appears once in HLO but runs
    # pool_blocks times — dryrun's cost model multiplies by this
    return jitted, args, {"pool_blocks": pool_blocks, "block": B,
                          "rays_per_call": pool_blocks * B,
                          "scan_multiplier": pool_blocks}


def _dryrun(multi_pod: bool):
    from repro.configs.ingp_asdr import CONFIG as bundle
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    jitted, args, meta = build_pooled_march_cell(bundle, mesh)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    print(f"[render_serve dryrun] mesh={tuple(mesh.shape.items())} "
          f"pool={meta['pool_blocks']}x{meta['block']} rays/call="
          f"{meta['rays_per_call']}")
    print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
    print(f"  per-device bytes: args={mem.argument_size_in_bytes} "
          f"temps={mem.temp_size_in_bytes} "
          f"peak={mem.temp_size_in_bytes + mem.argument_size_in_bytes}")


def scenecache_smoke(size: int = 16, poses: int = 3, clients: int = 2,
                     budget_bytes: int = 4 << 20) -> dict:
    """Tiny concrete scene-block-reuse run for the dryrun JSON record.

    ``clients`` request streams replay the SAME poses of one scene
    through an engine whose only reuse tier is the shared scene-space
    block store — the cross-client hit rate, resident bytes, and eviction
    count land next to the compile-cell numbers so the serving record
    carries both halves of the story (march cost AND reuse).
    """
    from repro.core import fields, pipeline, scene
    from repro.scenecache import SceneCacheConfig
    from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                           RenderServingEngine)

    acfg = pipeline.ASDRConfig(ns_full=48, probe_stride=4,
                               candidates=(8, 16, 32), block_size=64,
                               chunk=16, sort_by_opacity=False)
    flds = {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}
    eng = RenderServingEngine(flds, acfg, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None,
        scenecache=SceneCacheConfig(byte_budget=budget_bytes)))
    reqs = [RenderRequest(rid=c * poses + i, scene="mic",
                          cam=scene.look_at_camera(size, size,
                                                   theta=0.6 + 0.05 * i,
                                                   phi=0.5))
            for c in range(clients) for i in range(poses)]
    eng.render(reqs)
    st = eng.engine_stats()
    return {
        "clients": clients, "poses": poses, "size": size,
        "scene_block_hits": st["scene_block_hits"],
        "scene_block_hit_rate": st["scene_block_hit_rate"],
        "blocks_marched": st["blocks_marched"],
        **{k: st["scenecache"][k]
           for k in ("resident_bytes", "byte_budget", "evictions",
                     "entries")},
    }


def _concrete(args):
    from repro.core import fields, pipeline, scene
    from repro.framecache import ProbeReuseConfig, RadianceReuseConfig
    from repro.scenecache import SceneCacheConfig, ShardedSceneCache
    from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                           RenderServingEngine, RequestClass)

    acfg = pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, candidates=(12, 24, 48),
        block_size=args.block, chunk=16, sort_by_opacity=True)
    flds = {s: fields.analytic_field_fns(scene.make_scene(s))
            for s in ("mic", "hotdog")}
    # --shards > 1 shares one sharded store INSTANCE (the fleet form);
    # otherwise the engine builds its own plain store from the config
    sc_cfg = (SceneCacheConfig(byte_budget=int(args.scenecache_mb * (1 << 20)))
              if args.scenecache_mb > 0 else None)
    shared = (ShardedSceneCache(sc_cfg, shards=args.shards)
              if sc_cfg is not None and args.shards > 1 else None)
    if args.march_backend != "reference":
        acfg = dataclasses.replace(acfg, march_backend=args.march_backend)
    # observability switchboard: any of --trace / --trace-jsonl /
    # --metrics-jsonl / --flight-recorder turns the tracer on; all off
    # (the default) keeps every call site on the null-span fast path
    tcfg = None
    if (args.trace or args.trace_jsonl or args.metrics_jsonl
            or args.flight_recorder):
        from repro.obs import TraceConfig
        tcfg = TraceConfig(
            path=args.trace, jsonl=args.trace_jsonl,
            metrics_jsonl=args.metrics_jsonl,
            flight=args.flight_recorder,
            stall_dump_ms=args.stall_dump_ms)
    eng = RenderServingEngine(flds, acfg, RenderServeConfig(
        slots=args.slots, blocks_per_batch=args.blocks_per_batch,
        reuse=ProbeReuseConfig(),
        radiance=None if args.no_radiance else RadianceReuseConfig(),
        scenecache=None if shared is not None else sc_cfg,
        prefetch=args.prefetch, workers=args.workers,
        devices=args.devices, inflight_batches=args.inflight_batches,
        density_refresh=args.density_refresh, trace=tcfg,
        policy=args.policy),
        scenecache=shared)

    # SLO knobs: --deadline-ms attaches a deadline class (with a degrade
    # ladder the shed policy may walk); --arrival-rate replays the poses
    # as open-loop Poisson traffic instead of an all-at-once queue
    cls = (RequestClass("rt", deadline_ms=args.deadline_ms,
                        tiers=(1.0, 0.5, 0.25), shed_floor=2)
           if args.deadline_ms > 0 else None)
    arrivals = np.zeros(args.poses)
    if args.arrival_rate > 0:
        rng = np.random.default_rng(7)
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             args.poses))
    reqs = []
    for i in range(args.poses):
        sc = "mic" if i % 2 == 0 else "hotdog"   # interleaved multi-scene
        reqs.append(RenderRequest(
            rid=i, scene=sc,
            cam=scene.look_at_camera(args.size, args.size,
                                     theta=0.6 + 0.01 * (i // 2), phi=0.5),
            arrival_s=float(arrivals[i]),
            **({"cls": cls} if cls is not None else {})))
    t0 = time.time()
    done = eng.render(reqs)
    dt = time.time() - t0
    st = eng.engine_stats()
    print(f"[render_serve] {len(done)} frames {args.size}x{args.size} in "
          f"{dt:.2f}s = {len(done)/dt:.2f} fps")
    print(f"  reused-probe fraction : {st['reused_probe_fraction']:.2f} "
          f"({st['probe_hits']} hits + {st['probe_skips']} skips / "
          f"{st['probe_misses']} probes; "
          f"{st['full_radiance_hits']} full radiance hits)")
    # first-class engine ledgers (stats.py Series) — no per-launcher
    # re-aggregation of RenderRequest fields
    print(f"  latency               : p50 {st['latency_ms_p50']:.1f} ms  "
          f"p99 {st['latency_ms_p99']:.1f} ms (end-to-end, "
          f"{st['frames']} frames)")
    print(f"  admission stall       : p50 {st['admit_stall_ms_p50']:.1f} ms  "
          f"p99 {st['admit_stall_ms_p99']:.1f} ms "
          f"(prefetch {args.prefetch}, workers {args.workers}, "
          f"{st['misprepares']} misprepares)")
    print(f"  radiance reuse        : {st['reused_radiance_fraction']:.2f} "
          f"of frames, rays marched "
          f"{100 * st['rays_marched_fraction']:.1f}% of total")
    print(f"  pooled batches        : {st['batches']} "
          f"(pad fraction {st['pad_block_fraction']:.2f})")
    print(f"  march rounds          : {st['march_rounds']} "
          f"(march p50 {st['march_ms_p50']:.1f} ms  "
          f"p99 {st['march_ms_p99']:.1f} ms; batches/round "
          f"{st['batches_per_round']})")
    if cls is not None or args.policy not in (None, "fifo"):
        print(f"  scheduler ({args.policy:<5})   : "
              f"{st['requests_shed']} shed / {st['requests_full']} full "
              f"({st['shed_degrades']} degrade steps, "
              f"{st['shed_reprepares']} re-prepares), "
              f"{st['deadline_misses']} deadline misses")
        for name, led in st["class_stats"].items():
            print(f"    class {name:<12}: {led['frames']} frames  "
                  f"p50 {led['latency_ms_p50']:.1f} ms  "
                  f"p99 {led['latency_ms_p99']:.1f} ms  "
                  f"({led['shed']} shed, {led['deadline_misses']} missed)")
    if eng.scenecache is not None:
        sc = st["scenecache"]
        print(f"  scene-block reuse     : hit rate "
              f"{st['scene_block_hit_rate']:.2f} "
              f"({st['scene_block_hits']} hits), resident "
              f"{sc['resident_bytes'] / (1 << 20):.2f} MB / "
              f"{sc['byte_budget'] / (1 << 20):.0f} MB budget, "
              f"{sc['evictions']} evictions")
    marched = [r for r in done if r.stats["rays_marched"]]
    mean_frac = np.mean([r.stats["samples_processed"]
                         / r.stats["baseline_samples"]
                         for r in marched]) if marched else 0.0
    print(f"  phase-II samples      : {100 * mean_frac:.1f}% of fixed-"
          f"{acfg.ns_full} baseline (marched frames)")
    if args.stats:
        import json
        print(json.dumps(st, indent=2, default=str))
    eng.close()      # flush + export the trace (no-op with tracing off)
    if tcfg is not None:
        for label, p in (("trace", tcfg.path), ("span log", tcfg.jsonl),
                         ("metrics", tcfg.metrics_jsonl)):
            if p:
                print(f"  wrote {label:<9}: {p}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--poses", type=int, default=10)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--block", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--blocks-per-batch", type=int, default=16)
    ap.add_argument("--no-radiance", action="store_true",
                    help="disable warped-radiance reuse (probe reuse stays)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="Stage-A admission lookahead depth (0 = fully "
                         "synchronous admission)")
    ap.add_argument("--workers", type=int, default=0,
                    help="Stage-A executor worker threads (0 = synchronous "
                         "executor; N overlaps probe/warp device work with "
                         "the in-flight march on N threads)")
    ap.add_argument("--devices", type=int, default=0,
                    help="place Stage-A speculation on up to N secondary "
                         "jax devices (0 = off; takes precedence over "
                         "--workers; degrades to the synchronous executor "
                         "on a single-device host)")
    ap.add_argument("--inflight-batches", type=int, default=1,
                    help="batches dispatched per scheduling round (the "
                         "streaming scheduler; >1 lets the next-largest "
                         "scene group fill idle launches and double-"
                         "buffers host<->device transfers)")
    ap.add_argument("--march-backend", choices=("reference", "fused"),
                    default="reference",
                    help="Phase-II march backend; 'fused' runs the "
                         "single-kernel streaming Pallas march for "
                         "FieldFns that carry fused resources (analytic "
                         "fields fall back to the reference march)")
    ap.add_argument("--density-refresh", action="store_true",
                    help="march warp-served rays through the color-free "
                         "density march so warped frames regain exact "
                         "acc/depth and re-enter the radiance cache")
    ap.add_argument("--stats", action="store_true",
                    help="dump the full engine_stats() dict as JSON "
                         "(includes march_ms percentiles and the "
                         "batches-per-round histogram)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace JSON on exit "
                         "(open at ui.perfetto.dev); enables the tracer")
    ap.add_argument("--trace-jsonl", default=None, metavar="PATH",
                    help="write the raw span log as JSONL on exit")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append periodic metrics-registry snapshots "
                         "(one JSON object per line) during serving")
    ap.add_argument("--flight-recorder", action="store_true",
                    help="keep a bounded in-memory ring of recent spans "
                         "(with --stall-dump-ms: dump it to a trace file "
                         "the first time an admission stalls past the "
                         "threshold)")
    ap.add_argument("--stall-dump-ms", type=float, default=None,
                    help="arm the flight recorder to dump on the first "
                         "admission.wait span exceeding this many ms")
    ap.add_argument("--policy", choices=("fifo", "edf", "shed"),
                    default="fifo",
                    help="admission policy (serve/scheduler.py): 'fifo' "
                         "is the bit-identical default, 'edf' drains "
                         "slots earliest-deadline-first, 'shed' adds "
                         "sample-budget load-shedding under overload")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="attach a per-frame deadline class to every "
                         "request (tiers 1.0/0.5/0.25, shed floor at "
                         "0.25); 0 = no deadline (nothing ever sheds)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrivals at this rate in "
                         "requests/s (seeded); 0 = closed loop, every "
                         "request enqueued at t=0")
    ap.add_argument("--scenecache-mb", type=float, default=0.0,
                    help="enable scene-space block reuse with this byte "
                         "budget in MB (0 = off)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the scene cache N ways (with "
                         "--scenecache-mb; >1 uses the fleet-shared "
                         "ShardedSceneCache routed by key bytes)")
    args = ap.parse_args()
    if args.dryrun:
        _dryrun(args.multi_pod)
    else:
        _concrete(args)


if __name__ == "__main__":
    main()
