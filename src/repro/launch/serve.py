"""Production serve driver: batched generation with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
      --requests 8 --prompt-len 16 --max-new 32 [--smoke]

On this container the reduced config runs concretely; the FULL config's
prefill/decode steps are the ones the dry-run lowers at (16,16)/(2,16,16)
(launch/dryrun.py --shape prefill_32k / decode_32k).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    api = lm.build(cfg, remat_policy=None)
    values = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(api, values, ServeConfig(
        max_seq=args.prompt_len + args.max_new + 8,
        slots=args.slots, temperature=args.temperature,
    ))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = eng.generate(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve {cfg.name}] {len(done)} requests, {tok} tokens, "
          f"{dt:.2f}s, {tok/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
