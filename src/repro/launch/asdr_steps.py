"""Production-mesh steps for the paper's own model (ingp-asdr, 11th config).

The ASDR renderer and NGP trainer run through the same launcher/dry-run
path as the LM zoo — the paper's technique as a first-class feature:

  * ``asdr_render``: Phase II of an 800x800 frame — rays + per-pixel
    counts (Phase I output) sharded over (pod, data); difficulty-sorted
    blocks march in a chunked while_loop with early termination; the
    color MLP runs on every ``group``-th sample only (§4.3).
  * ``asdr_train``: photometric training step over 2^18 rays — grid
    tables sharded over ``model`` rows (the Mem-Xbar distribution
    analogue: each model shard owns a slice of every level's table and
    GSPMD turns lookups into partial-gather + psum), ray batch over
    (pod, data), AdamW update.

Both lower with ShapeDtypeStructs only (no allocation), like every LM cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim
from ..core import model as model_lib
from ..core import pipeline
from ..core.model import NGPConfig


RENDER_HW = (800, 800)          # paper's Synthetic-NeRF resolution
RENDER_BLOCK = 4096
TRAIN_RAYS = 1 << 18
TRAIN_SAMPLES = 128


def _batch_spec(mesh):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return tuple(axes) if len(axes) > 1 else axes[0]


def param_shardings(cfg: NGPConfig, mesh, shard_tables: bool):
    table_spec = P(None, "model", None) if shard_tables else P()
    return {
        "grid": NamedSharding(mesh, table_spec),
        "mlps": {
            "density": [NamedSharding(mesh, P()) for _ in range(2)],
            "color": [NamedSharding(mesh, P()) for _ in range(
                4 if cfg.net.color_layers == 3 else 3)],
        },
    }


def abstract_params(cfg: NGPConfig):
    return jax.eval_shape(
        lambda k: model_lib.init_ngp(k, cfg), jax.random.PRNGKey(0)
    )


def build_render_cell(bundle, mesh, variant: str = "baseline"):
    """baseline: grid tables sharded over `model` rows (the literal Mem-Xbar
    distribution — every voxel-corner lookup crosses shards).
    opt (§Perf): the paper's OWN §5.2.1 insight re-targeted at TPU — the
    tables are small enough (67 MB) to REPLICATE per chip, exactly like the
    paper replicates de-hashed low-res tables into spare crossbar rows:
    lookups become chip-local and the gather collectives disappear."""
    cfg = bundle.model
    acfg = bundle.asdr
    H, W = RENDER_HW
    R = -(-H * W // RENDER_BLOCK) * RENDER_BLOCK  # pad to block multiple

    def render(params, origins, dirs, counts):
        fns = model_lib.field_fns(params, cfg)
        import dataclasses

        a = dataclasses.replace(acfg, block_size=RENDER_BLOCK)
        rgb, acc, stats = pipeline.render_adaptive(fns, a, origins, dirs,
                                                   counts)
        return rgb

    b = _batch_spec(mesh)
    p_sh = param_shardings(cfg, mesh, shard_tables=(variant != "opt"))
    ray_sh = NamedSharding(mesh, P(b, None))
    cnt_sh = NamedSharding(mesh, P(b))
    jitted = jax.jit(render, in_shardings=(p_sh, ray_sh, ray_sh, cnt_sh))
    args = (
        abstract_params(cfg),
        jax.ShapeDtypeStruct((R, 3), jnp.float32),
        jax.ShapeDtypeStruct((R, 3), jnp.float32),
        jax.ShapeDtypeStruct((R,), jnp.int32),
    )
    return jitted, args, {"scan_multiplier": R // RENDER_BLOCK,
                          "rays": R, "block": RENDER_BLOCK}


def build_train_cell_ngp(bundle, mesh):
    cfg = bundle.model
    opt_cfg = optim.AdamWConfig(lr=5e-3, b2=0.99, eps=1e-15)

    def step(params, opt_state, origins, dirs, ref, lr):
        def loss_fn(p):
            rgb, _ = model_lib.render_fixed(
                p, cfg, origins, dirs, TRAIN_SAMPLES
            )
            return jnp.mean((rgb - ref) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = optim.clip_by_global_norm(grads, 1.0)
        params, opt_state = optim.adamw_update(
            grads, opt_state, params, opt_cfg, lr
        )
        return params, opt_state, loss

    b = _batch_spec(mesh)
    p_sh = param_shardings(cfg, mesh, shard_tables=True)
    o_sh = {"m": p_sh, "v": p_sh,
            "count": NamedSharding(mesh, P())}
    ray_sh = NamedSharding(mesh, P(b, None))
    scalar = NamedSharding(mesh, P())
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, ray_sh, ray_sh, ray_sh, scalar),
        out_shardings=(p_sh, o_sh, None),
    )
    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(lambda p: optim.adamw_init(p, opt_cfg),
                             params_abs)
    args = (
        params_abs, opt_abs,
        jax.ShapeDtypeStruct((TRAIN_RAYS, 3), jnp.float32),
        jax.ShapeDtypeStruct((TRAIN_RAYS, 3), jnp.float32),
        jax.ShapeDtypeStruct((TRAIN_RAYS, 3), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return jitted, args, {"scan_multiplier": 1, "rays": TRAIN_RAYS}
