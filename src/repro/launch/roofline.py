"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals, already per-partition under SPMD — see note below). collective
bytes are NOT in cost_analysis: we parse the post-optimization HLO text and
sum the *output* operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

SPMD accounting note: XLA lowers to a single per-device program, so
cost_analysis() reports per-device FLOPs/bytes; the roofline denominator is
then per-chip peak (not multiplied by chips).  Collective bytes parsed from
the HLO are likewise per-device payloads.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> byte size; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, body_multiplier: int = 1) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the HLO text.

    XLA's HLO lists a while-loop (scan) body computation ONCE regardless of
    trip count, so collectives inside scan bodies (the per-layer FSDP
    all-gathers / TP all-reduces) are undercounted by the trip count.  We
    therefore track which computation each collective appears in: ops in
    the ENTRY computation count once; ops in any sub-computation are
    multiplied by ``body_multiplier`` (the caller passes the structurally
    known scan trip product, e.g. n_layers * microbatches for a train
    step).  This slightly overcounts collectives in non-loop
    sub-computations (rare) — documented in EXPERIMENTS.md.

    Returns {op_kind: bytes, ..., "entry": b, "body_raw": b,
             "total": corrected bytes, "count": n}.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    entry_b = 0
    body_b = 0
    count = 0
    in_entry = False
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY "):
            in_entry = True
            continue
        if s.startswith("}"):
            in_entry = False
            continue
        m = re.match(r"[%\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        mult = 1 if in_entry else body_multiplier
        out[kind] += b * mult
        if in_entry:
            entry_b += b
        else:
            body_b += b
        count += 1
    out["entry"] = entry_b
    out["body_raw"] = body_b
    out["total"] = entry_b + body_b * body_multiplier
    out["count"] = count
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / ICI_BW
    terms = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["roofline_fraction_compute"] = compute / total if total else 0.0
    return terms


def model_flops(cfg, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference fwd), N = active."""
    n = cfg.active_param_count() if hasattr(cfg, "active_param_count") else 0
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
