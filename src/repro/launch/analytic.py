"""Analytic FLOP/byte models per (arch, shape) cell — the scan-proof
compute-term source.

XLA's HloCostAnalysis visits each while-body once (scan trip counts are
invisible to it), so the dry-run's raw ``cost_analysis`` numbers
undercount everything inside the layers/microbatch/attention-chunk scans.
These closed-form models count what the step ACTUALLY executes —
including remat recomputation, GQA attention context, window clipping,
MoE top-k routing, and SSD chunk quadratics — and are cross-checked
against (scan-corrected) HLO numbers in EXPERIMENTS.md.

All numbers are GLOBAL (whole step, all chips); divide by chips for the
per-chip roofline term.
"""
from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig, ShapeCell


def _attn_context(S: int, window: int, kind: str) -> float:
    """Average attended KV length per query token."""
    if kind == "decode":
        ctx = float(S)              # one new token vs S-token cache
        return min(ctx, window) if window else ctx
    full_avg = (S + 1) / 2.0        # causal average
    if window and window < S:
        return (window + 1) / 2.0 + max(0.0, (S - window)) / S * (window / 2.0)
    return full_avg


def layer_forward_flops(cfg: ModelConfig, S: int, kind: str) -> Dict[str, float]:
    """Per-layer forward FLOPs for a single sequence of S tokens
    (decode: S=1 new token against a `ctx` cache)."""
    d = cfg.d_model
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    toks = 1 if kind == "decode" else S
    out: Dict[str, float] = {}

    if cfg.family != "ssm":
        qkv = 2 * toks * d * (H + 2 * KV) * Dh
        o = 2 * toks * H * Dh * d
        # attention scores+values; context depends on window/kind
        kinds = cfg.layer_kinds()
        # average over layers handled by caller; here assume global, caller
        # passes per-layer window via layer_flops_by_window
        out["attn_proj"] = qkv + o
    if cfg.family == "moe":
        out["ffn"] = (
            2 * toks * d * cfg.n_experts                       # router
            + 2 * 3 * toks * d * cfg.moe_d_ff
            * (cfg.top_k + cfg.n_shared_experts)
        )
    elif cfg.family != "ssm" and cfg.d_ff > 0:
        out["ffn"] = 2 * 3 * toks * d * cfg.d_ff
    if cfg.family in ("ssm", "hybrid"):
        di, N, Hs, Ps = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                         cfg.ssm_head_dim)
        proj = 2 * toks * d * (2 * di + 2 * N + Hs) + 2 * toks * di * d
        if kind == "decode":
            ssd = 4 * toks * Hs * Ps * N                     # state update+out
        else:
            Q = min(cfg.ssm_chunk, S)
            # intra-chunk quadratic (masked) + state path
            ssd = toks * Q * (2 * N + 2 * Hs * Ps) + 4 * toks * Hs * Ps * N
        out["ssm"] = proj + ssd
    return out


def cell_flops(cfg: ModelConfig, shape: ShapeCell,
               remat: bool = True) -> Dict[str, float]:
    """Global executed FLOPs for one step of this cell."""
    S, B = shape.seq_len, shape.global_batch
    kind = shape.kind
    toks = B * (1 if kind == "decode" else S)
    d = cfg.d_model
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    # layer_forward_flops is per sequence -> x layers x batch for global
    per_layer = layer_forward_flops(cfg, S, kind)
    body = sum(per_layer.values()) * cfg.n_layers * B

    # attention score/value FLOPs with per-layer windows
    attn_sv = 0.0
    if cfg.family != "ssm":
        for w in cfg.layer_kinds():
            ctx = _attn_context(S, w, kind)
            q_toks = 1 if kind == "decode" else S
            attn_sv += 2 * 2 * q_toks * H * Dh * ctx
        attn_sv *= B

    logits = 2 * toks * d * cfg.padded_vocab
    encoder = 0.0
    if cfg.is_encoder_decoder:
        Se = cfg.encoder_seq
        q_toks = 1 if kind == "decode" else S
        if kind != "decode":
            # encoder runs at train/prefill only; decode reuses cached
            # cross-K/V (plain GELU MLP: 2 matmuls, not 3)
            enc_layer = (2 * Se * d * (H + 2 * KV) * Dh
                         + 2 * Se * H * Dh * d
                         + 2 * 2 * Se * d * cfg.d_ff
                         + 2 * 2 * Se * H * Dh * (Se / 2))
            encoder = enc_layer * cfg.encoder_layers * B
            # cross-attention K/V projection over encoder output
            encoder += 2 * Se * d * 2 * KV * Dh * cfg.n_layers * B
        # cross attention (scores+values) per decoder token
        encoder += (2 * q_toks * d * (H + KV * 0) * Dh
                    + 2 * 2 * q_toks * H * Dh * Se) * cfg.n_layers * B

    fwd = body + attn_sv + logits + encoder
    if kind == "train":
        mult = 4.0 if remat else 3.0   # fwd + 2x bwd (+1x remat recompute)
        total = fwd * mult
    else:
        total = fwd
    return {
        "forward_flops": fwd,
        "total_flops": total,
        "attention_flops": attn_sv,
        "logits_flops": logits,
    }


def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeCell, microbatches: int,
                   param_bytes: int = 4) -> Dict[str, float]:
    """Coarse global HBM traffic model for one step (documented lower
    bound: weights + cache + logits + residual activations; ignores
    fused intermediates which HLO 'bytes accessed' overcounts)."""
    S, B = shape.seq_len, shape.global_batch
    kind = shape.kind
    n_params = cfg.param_count()

    if kind == "train":
        # per microbatch: fwd read + remat read + bwd read; grads written
        # once per mb; optimizer reads m,v + params, writes all three.
        weight_traffic = n_params * param_bytes * (3 * microbatches + 6)
        act = B * S * cfg.d_model * 2 * cfg.n_layers * 3   # bf16 carries
        logits = B * S * cfg.padded_vocab * 4 * 2
        cache = 0.0
    else:
        weight_traffic = n_params * 2  # bf16 serve, one read
        act = B * (1 if kind == "decode" else S) * cfg.d_model * 2 * cfg.n_layers * 2
        logits = B * (1 if kind == "decode" else S) * cfg.padded_vocab * 2
        cache = 0.0
        if cfg.family != "ssm":
            KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
            for w in cfg.layer_kinds():
                slots = min(w, S) if w else S
                rw = 1 if kind == "decode" else 1  # read (decode) / write (prefill)
                cache += B * slots * KV * Dh * 2 * 2 * rw
        if cfg.family in ("ssm", "hybrid"):
            cache += (B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                      * 4 * 2 * cfg.n_layers)
    return {
        "weight_bytes": float(weight_traffic),
        "activation_bytes": float(act),
        "logits_bytes": float(logits),
        "cache_bytes": float(cache),
        "total_bytes": float(weight_traffic + act + logits + cache),
    }
