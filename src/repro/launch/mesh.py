"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax import and only then calls it.

Mesh shapes:
  single-pod : (16, 16)        axes (data, model)   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16)     axes (pod, data, model) = 512 chips

Axis roles: ``data`` = DP + ZeRO/FSDP (+ sequence parallelism for the
long-context serve cells); ``model`` = TP/EP; ``pod`` = cross-pod DP over
the slower inter-pod links (the axis gradient compression targets).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)"
        )
    import numpy as np

    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI tests (requires >=4 host devices)."""
    import numpy as np

    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
