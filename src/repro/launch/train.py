"""Production train driver: checkpoint/restart, straggler monitor, retries.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-27b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --max-restarts 2

Fault-tolerance mechanics exercised here (scaled down to this container,
mechanisms identical at pod scale):
  * resume-from-latest on start (elastic: restore re-shards to the current
    mesh via ckpt/manager.py);
  * step-time EMA straggler monitor — a step slower than
    ``straggler_factor``x the EMA is logged (at scale: triggers the
    scheduler to replace the slow host; here: visibility);
  * in-process retry loop: a step raising (simulated via
    --fail-at-step for tests) restarts from the last checkpoint up to
    --max-restarts times — the data pipeline is deterministic-by-step so
    replay is exact.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.ckpt import CheckpointManager
from repro.data import TokenPipeline
from repro.models import lm
from repro.train.step import TrainConfig, make_train_step


def train_loop(api, tcfg: TrainConfig, steps: int, batch: int, seq: int,
               ckpt_dir=None, ckpt_every: int = 20, max_restarts: int = 0,
               fail_at_step: int = -1, straggler_factor: float = 3.0,
               verbose: bool = True):
    cfg = api.cfg
    pipe = TokenPipeline(vocab=cfg.vocab, batch=batch, seq_len=seq)
    step_fn, opt_init = make_train_step(api.loss_fn, tcfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    values = api.init(jax.random.PRNGKey(0))
    opt_state = opt_init(values)
    start = 0
    if mgr and mgr.latest_step() is not None:
        (values, opt_state), start = mgr.restore((values, opt_state))
        start += 1
        if verbose:
            print(f"[train] resumed from step {start - 1}")

    restarts = 0
    losses = []
    ema = None
    i = start
    while i < steps:
        try:
            t0 = time.time()
            tokens = pipe.batch_at(i)
            if i == fail_at_step and restarts < max_restarts:
                raise RuntimeError("injected failure (simulated node loss)")
            b = {"tokens": tokens}
            if cfg.family == "vlm":
                b["img_embeds"] = jnp.zeros(
                    (batch, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16
                )
            if cfg.family == "encdec":
                b["frames"] = jnp.zeros(
                    (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
                )
            values, opt_state, metrics = step_fn(
                values, opt_state, b, jnp.asarray(i, jnp.int32)
            )
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > straggler_factor * ema and i > start + 3:
                print(f"[straggler] step {i} took {dt:.2f}s (ema {ema:.2f}s)")
            losses.append((i, loss))
            if verbose and (i % 10 == 0 or i == steps - 1):
                print(f"[train {cfg.name}] step {i:5d} loss {loss:.4f} "
                      f"({dt:.2f}s)")
            if mgr and (i % ckpt_every == 0 or i == steps - 1):
                mgr.save(i, (values, opt_state))
            i += 1
        except Exception as e:  # noqa — restart-from-checkpoint path
            restarts += 1
            if restarts > max_restarts or mgr is None:
                raise
            print(f"[restart {restarts}/{max_restarts}] step {i} failed: {e}")
            values = api.init(jax.random.PRNGKey(0))
            opt_state = opt_init(values)
            (values, opt_state), last = mgr.restore((values, opt_state))
            i = last + 1
    if mgr:
        mgr.wait()
    return values, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--max-restarts", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    api = lm.build(cfg, remat_policy=None if args.smoke else "full")
    tcfg = TrainConfig(
        microbatches=args.microbatches, lr=args.lr,
        warmup_steps=max(1, args.steps // 10), total_steps=args.steps,
    )
    t0 = time.time()
    _, _, losses = train_loop(
        api, tcfg, args.steps, args.batch, args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        max_restarts=args.max_restarts, fail_at_step=args.fail_at_step,
    )
    print(f"[done] {len(losses)} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")


if __name__ == "__main__":
    main()
