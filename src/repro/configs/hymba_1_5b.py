"""hymba-1.5b [hybrid] — 32L d1600 25H (GQA kv=5, head_dim 64) d_ff 5504
vocab 32001, parallel attention + mamba heads in every layer (ssm_state 16),
sliding-window attention except first/middle/last global layers
[arXiv:2411.13676].  (Meta-tokens omitted — noted in DESIGN.md.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024, local_global_pattern="ends_global",
    parallel_ssm=True,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    window=8, local_global_pattern="ends_global",
    parallel_ssm=True,
    ssm_state=8, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
    act="silu", tie_embeddings=True,
)
