"""The paper's own model: Instant-NGP + ASDR two-phase rendering.

This is the 11th config — the one the technique lives in end-to-end.
``CONFIG`` is the paper-scale setup (2^19 tables, 16 levels, 192 samples,
paper MLP split 8%/92%); ``SMOKE`` is the CPU-trainable reduction used by
tests/examples.  launch/dryrun.py lowers its *render* and *train* steps
data-parallel over rays (see launch/asdr_steps.py).
"""
import dataclasses

from repro.core.model import NGPConfig
from repro.core.pipeline import ASDRConfig


@dataclasses.dataclass(frozen=True)
class NGPBundle:
    name: str
    model: NGPConfig
    asdr: ASDRConfig
    image_hw: tuple
    train_batch_rays: int


CONFIG = NGPBundle(
    name="ingp-asdr",
    model=NGPConfig.make(paper_mlp=True),
    asdr=ASDRConfig(ns_full=192, probe_stride=5, delta=1.0 / 2048.0,
                    group=2, block_size=4096, chunk=32),
    image_hw=(800, 800),
    train_batch_rays=1 << 18,
)

SMOKE = NGPBundle(
    name="ingp-asdr-smoke",
    model=NGPConfig.small(),
    asdr=ASDRConfig(ns_full=64, probe_stride=4, group=2,
                    block_size=64, chunk=16, candidates=(8, 16, 32)),
    image_hw=(48, 48),
    train_batch_rays=512,
)
