"""dbrx-132b [moe] — 40L d6144 48H (GQA kv=8) vocab 100352, 16 experts
top-4 with per-expert d_ff 10752 (fine-grained) [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    n_experts=16, top_k=4, moe_d_ff=10752,
    act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512,
    n_experts=4, top_k=2, moe_d_ff=96, moe_group_size=64,
    act="silu", tie_embeddings=False,
)
