"""whisper-medium [audio] — enc-dec, 24L each, d1024 16H d_ff 4096
vocab 51865.  Conv audio frontend STUBBED per task spec: input_specs()
provides 1500 precomputed frame embeddings (30 s @ 50 Hz post-conv)
[arXiv:2212.04356].  Note: the real model caps decoder context at 448;
the assigned decode_32k/train_4k shapes exercise the backbone beyond that
(documented in DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865,
    is_encoder_decoder=True, encoder_layers=24, encoder_seq=1500,
    act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    is_encoder_decoder=True, encoder_layers=2, encoder_seq=16,
    act="gelu", tie_embeddings=True,
)
