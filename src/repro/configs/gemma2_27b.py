"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) d_ff 36864 vocab 256000.
Local+global alternating attention (window 4096), attn/final logit
softcaps, sandwich norms, GeGLU [arXiv:2408.00118]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    window=4096, local_global_pattern="alternating",
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    act="geglu", embed_scale=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    window=8, local_global_pattern="alternating",
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    act="geglu", embed_scale=True, tie_embeddings=True,
)
