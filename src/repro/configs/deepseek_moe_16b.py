"""deepseek-moe-16b [moe] — 28L d2048 16H (MHA kv=16) vocab 102400,
64 routed experts top-6 + 2 shared experts, fine-grained d_ff 1408
[arXiv:2401.06066].  (The real model's first layer is a dense FFN; we keep
all layers MoE for scan homogeneity — noted in DESIGN.md.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=48, vocab=512,
    n_experts=8, top_k=2, n_shared_experts=2, moe_d_ff=48, moe_group_size=64,
    act="silu", tie_embeddings=False,
)
