"""qwen3-14b [dense] — 40L d5120 40H (GQA kv=8) d_ff 17408 vocab 151936.
QK-RMSNorm on attention heads [hf:Qwen/Qwen3-14B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    qk_norm=True, rope_theta=1e6,
    act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    qk_norm=True, rope_theta=1e6,
    act="silu", tie_embeddings=False,
)
