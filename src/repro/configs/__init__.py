"""Architecture registry: ``get(name)`` -> full config, ``get_smoke(name)``
-> reduced same-family config for CPU smoke tests.

The 10 assigned architectures are LM-family; the paper's own model
(Instant-NGP + ASDR) is the 11th entry and returns an NGPBundle instead of
a ModelConfig (launch/dryrun.py dispatches on the type).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "gemma2_27b",
    "minitron_8b",
    "qwen3_14b",
    "gemma3_12b",
    "paligemma_3b",
    "whisper_medium",
    "dbrx_132b",
    "deepseek_moe_16b",
    "mamba2_780m",
    "hymba_1_5b",
]

# canonical spec names (shown in CLIs, dry-run records, EXPERIMENTS.md)
CANONICAL = {
    "gemma2_27b": "gemma2-27b",
    "minitron_8b": "minitron-8b",
    "qwen3_14b": "qwen3-14b",
    "gemma3_12b": "gemma3-12b",
    "paligemma_3b": "paligemma-3b",
    "whisper_medium": "whisper-medium",
    "dbrx_132b": "dbrx-132b",
    "deepseek_moe_16b": "deepseek-moe-16b",
    "mamba2_780m": "mamba2-780m",
    "hymba_1_5b": "hymba-1.5b",
}

ALIAS = {
    "gemma2-27b": "gemma2_27b",
    "minitron-8b": "minitron_8b",
    "qwen3-14b": "qwen3_14b",
    "gemma3-12b": "gemma3_12b",
    "paligemma-3b": "paligemma_3b",
    "whisper-medium": "whisper_medium",
    "dbrx-132b": "dbrx_132b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-780m": "mamba2_780m",
    "hymba-1.5b": "hymba_1_5b",
    "ingp-asdr": "ingp_asdr",
}


def _module(name: str):
    name = ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def list_archs() -> List[str]:
    return [CANONICAL[a] for a in ARCHS]
