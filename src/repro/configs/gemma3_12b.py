"""gemma3-12b [dense] — 48L d3840 16H (GQA kv=8, head_dim 256) d_ff 15360
vocab 262144.  5:1 local:global (window 1024), qk-norm, 128k context
[hf:google/gemma-3-12b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    window=1024, local_global_pattern="five_to_one",
    qk_norm=True, post_norms=True, rope_theta=1e6,
    act="geglu", embed_scale=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    window=8, local_global_pattern="five_to_one",
    qk_norm=True, post_norms=True, rope_theta=1e6,
    act="geglu", embed_scale=True, tie_embeddings=True,
)
