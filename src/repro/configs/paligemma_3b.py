"""paligemma-3b [vlm] — 18L d2048 8H (MQA kv=1, head_dim 256) d_ff 16384
vocab 257216.  SigLIP vision tower STUBBED per task spec: input_specs()
provides 256 precomputed patch embeddings; the text backbone attends to
them as a bidirectional prefix (prefix-LM mask) [arXiv:2407.07726]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    prefix_tokens=256,
    act="geglu", embed_scale=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="paligemma-smoke", family="vlm",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512,
    prefix_tokens=8,
    act="geglu", embed_scale=True, tie_embeddings=True,
)
