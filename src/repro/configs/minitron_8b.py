"""minitron-8b [dense] — 32L d4096 32H (GQA kv=8) d_ff 16384 vocab 256000.
Width/depth-pruned nemotron [arXiv:2407.14679]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=256000,
    act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    act="silu", tie_embeddings=False,
)
