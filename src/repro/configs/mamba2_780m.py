"""mamba2-780m [ssm] — 48L d1536 attention-free, vocab 50280,
SSD state 128, head_dim 64, expand 2 (d_inner 3072, 48 SSM heads)
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=512,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
    tie_embeddings=True,
)
