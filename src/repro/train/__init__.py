from .step import TrainConfig, make_train_step, make_loss_and_grads
