"""Train-step factory: microbatched grad accumulation, remat, ZeRO sharding,
clipping, AdamW, schedules, optional cross-pod int8 gradient compression.

The returned ``train_step(values, opt_state, batch, step)`` is a pure
function suitable for ``jax.jit`` with in/out shardings from
sharding/rules.py.  Activation sharding constraints fire inside the traced
body via the ``activation_sharding`` context (no-op when rules is None).

Memory posture at scale (the reason for each knob):
  * params f32, compute bf16 (models cast at block entry);
  * grads accumulate in f32, sharded like params (data x model) —
    reduce-scatter semantics fall out of GSPMD;
  * microbatching bounds logits/activation peaks: per-microbatch
    batch_per_device rows instead of the full per-device batch;
  * remat="full" re-computes each layer in backward, so the live set is
    one layer + the scan carry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .. import optim
from ..sharding import activation as act_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    max_grad_norm: float = 1.0
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    compress_pod_grads: bool = False   # int8+EF all-reduce over "pod"
    # Cast the whole param tree to bf16 BEFORE the layer scan: the cast is
    # elementwise on the sharded (local) leaves, so every FSDP all-gather
    # inside the scan moves bf16 instead of f32 — 2x less collective bytes.
    # f32 master params stay in the optimizer path (grads flow through the
    # cast and come out f32).  §Perf hillclimb C1 for train cells.
    cast_params_bf16: bool = False


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def make_loss_and_grads(loss_fn, microbatches: int, constrain_grads=None):
    """Returns grads_fn(values, batch) -> (mean loss, mean grads) with
    ``lax.scan`` gradient accumulation over microbatches.

    constrain_grads: optional fn(tree)->tree applying the PARAM sharding to
    gradients.  Without it GSPMD can leave the grad accumulator (a scan
    carry) replicated — every per-microbatch gradient then moves through a
    full-shape all-reduce instead of a reduce-scatter (measured 16x more
    collective bytes on gemma2-27b train; see EXPERIMENTS.md §Perf)."""

    def single(values, batch):
        loss, grads = jax.value_and_grad(loss_fn)(values, batch)
        if constrain_grads is not None:
            grads = constrain_grads(grads)
        return loss, grads

    if microbatches <= 1:
        return single

    def accumulated(values, batch):
        def to_mb(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree.map(to_mb, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(values, mb)
            if constrain_grads is not None:
                grads = constrain_grads(grads)
            return (loss_acc + loss, _tree_add(grads_acc, grads)), None

        acc0 = _tree_zeros_f32(values)
        if constrain_grads is not None:
            acc0 = constrain_grads(acc0)
        init = (jnp.zeros((), jnp.float32), acc0)
        (loss_sum, grads_sum), _ = jax.lax.scan(body, init, mbs)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)

    return accumulated


def make_train_step(loss_fn, tcfg: TrainConfig, rules=None, mesh=None,
                    param_axes=None):
    """loss_fn(values, batch) -> scalar.  Returns (train_step, opt_init).

    param_axes: logical-axes tree matching the param tree — used to pin
    gradient shardings to the param shardings (reduce-scatter instead of
    replicated all-reduce; see make_loss_and_grads)."""
    opt_cfg = optim.AdamWConfig(
        lr=tcfg.lr, b1=tcfg.b1, b2=tcfg.b2,
        weight_decay=tcfg.weight_decay,
    )
    sched = optim.linear_warmup_cosine(
        tcfg.lr, tcfg.warmup_steps, tcfg.total_steps
    )

    constrain_grads = None
    if param_axes is not None and rules is not None and mesh is not None:
        from jax.sharding import NamedSharding

        from ..sharding import rules as rules_lib

        shardings = jax.tree.map(
            lambda a: NamedSharding(
                mesh, rules_lib.resolve_spec(a, rules, mesh)),
            param_axes, is_leaf=rules_lib.is_axes_leaf,
        )

        def constrain_grads(grads):  # noqa: F811
            return jax.tree.map(jax.lax.with_sharding_constraint,
                                grads, shardings)

    eff_loss = loss_fn
    if tcfg.cast_params_bf16:
        from ..models import params as pp

        def eff_loss(v, b):  # noqa: F811
            cast = pp.cast_tree(v, jnp.bfloat16)
            if constrain_grads is not None:
                # pin the bf16 copies to the SHARDED spec: otherwise GSPMD
                # may place the FSDP all-gather BEFORE the convert and move
                # f32 over the wire (observed on gemma2-27b; §Perf H1 It.3)
                cast = jax.tree.map(jax.lax.with_sharding_constraint,
                                    cast, shardings)
            return loss_fn(cast, b)

    grads_fn = make_loss_and_grads(eff_loss, tcfg.microbatches,
                                   constrain_grads)

    def opt_init(values):
        return optim.adamw_init(values, opt_cfg)

    def train_step(values, opt_state, batch, step):
        ctx = (act_lib.activation_sharding(rules, mesh)
               if rules is not None else _null_ctx())
        with ctx:
            loss, grads = grads_fn(values, batch)
            grads, grad_norm = optim.clip_by_global_norm(
                grads, tcfg.max_grad_norm
            )
            lr = sched(step)
            new_values, new_opt = optim.adamw_update(
                grads, opt_state, values, opt_cfg, lr
            )
        metrics = {"loss": loss, "grad_norm": grad_norm, "lr": lr}
        return new_values, new_opt, metrics

    return train_step, opt_init


import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield
