"""Stable byte serialization for scene-block cache keys and entries.

The scenecache keys are already stable bytes (blake2b digests over
quantized ray geometry — key.py), which is what makes them shard
naturally across an external/multi-host store (ROADMAP).  This module
fixes the REST of the wire format: a versioned, endian-pinned byte
layout for the (key, coverage cell) pair and for a full cache entry
(key + cell + BlockOutput), so two processes — or a process and an
external key-value store — can exchange cached blocks without sharing
Python object state.

Layout rules (all integers little-endian, floats IEEE-754 f32 LE):

  key record    'SCK1' | u16 digest_len | digest
                | u16 scene_len | scene utf8 | u16 n_ints | n_ints * i64
  entry record  'SCE1' | key record | i64 chunks | u32 block_size
                | rgb f32[B*3] | acc f32[B] | depth f32[B]

The 4-byte magic carries the format version; bump it when the layout
changes — stale records must fail loudly (``ValueError``), never alias.
Host-side only, no device arrays cross this boundary.
"""
from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

from .store import BlockOutput

KEY_MAGIC = b"SCK1"
ENTRY_MAGIC = b"SCE1"

_F32 = np.dtype("<f4")
_I64 = np.dtype("<i8")


def key_to_bytes(key: bytes, cell: tuple) -> bytes:
    """Serialize a (digest, coverage cell) pair; stable across processes."""
    scene_id = cell[0]
    ints = [int(v) for v in cell[1:]]
    scene_b = scene_id.encode()
    return b"".join([
        KEY_MAGIC,
        struct.pack("<H", len(key)), key,
        struct.pack("<H", len(scene_b)), scene_b,
        struct.pack("<H", len(ints)),
        np.asarray(ints, _I64).tobytes(),
    ])


def key_from_bytes(buf: bytes) -> Tuple[bytes, tuple]:
    """Inverse of ``key_to_bytes``; raises ValueError on a foreign,
    stale-version, or truncated record."""
    try:
        key, cell, off = _read_key(buf, 0)
    except struct.error as e:
        # the documented contract is ValueError for ANY malformed record
        # — a header truncated mid-field must not leak struct.error
        raise ValueError(f"truncated key record: {e}") from e
    if off != len(buf):
        raise ValueError(f"trailing bytes after key record ({len(buf)-off})")
    return key, cell


def _read_key(buf: bytes, off: int):
    if buf[off:off + 4] != KEY_MAGIC:
        raise ValueError(f"not a scenecache key record "
                         f"(magic {buf[off:off + 4]!r} != {KEY_MAGIC!r})")
    off += 4
    (klen,) = struct.unpack_from("<H", buf, off)
    off += 2
    key = bytes(buf[off:off + klen])
    off += klen
    (slen,) = struct.unpack_from("<H", buf, off)
    off += 2
    scene_id = buf[off:off + slen].decode()
    off += slen
    (n,) = struct.unpack_from("<H", buf, off)
    off += 2
    ints = np.frombuffer(buf, _I64, count=n, offset=off)
    off += n * 8
    return key, (scene_id, *(int(v) for v in ints)), off


def entry_to_bytes(key: bytes, cell: tuple, out: BlockOutput) -> bytes:
    """Serialize one finished block (key + cell + outputs)."""
    B = out.acc.shape[0]
    return b"".join([
        ENTRY_MAGIC,
        key_to_bytes(key, cell),
        struct.pack("<qI", int(out.chunks), B),
        np.ascontiguousarray(out.rgb, _F32).tobytes(),
        np.ascontiguousarray(out.acc, _F32).tobytes(),
        np.ascontiguousarray(out.depth, _F32).tobytes(),
    ])


def peek_entry_key(buf: bytes) -> bytes:
    """The key digest of a serialized entry WITHOUT decoding its arrays.

    The sharded store routes wire records by key bytes (sharded.py), so
    replication needs the key before it knows which shard's ``load_entry``
    should decode the record.  Raises ValueError like the full parsers.
    """
    if buf[:4] != ENTRY_MAGIC:
        raise ValueError(f"not a scenecache entry record "
                         f"(magic {buf[:4]!r} != {ENTRY_MAGIC!r})")
    try:
        key, _cell, _off = _read_key(buf, 4)
    except struct.error as e:
        raise ValueError(f"truncated entry record: {e}") from e
    return key


def entry_from_bytes(buf: bytes) -> Tuple[bytes, tuple, BlockOutput]:
    """Inverse of ``entry_to_bytes``.  The arrays are fresh host copies
    (the record buffer is not aliased)."""
    if buf[:4] != ENTRY_MAGIC:
        raise ValueError(f"not a scenecache entry record "
                         f"(magic {buf[:4]!r} != {ENTRY_MAGIC!r})")
    try:
        key, cell, off = _read_key(buf, 4)
        chunks, B = struct.unpack_from("<qI", buf, off)
    except struct.error as e:
        raise ValueError(f"truncated entry record: {e}") from e
    off += 12
    def take(n):
        nonlocal off
        a = np.frombuffer(buf, _F32, count=n, offset=off).copy()
        off += n * 4
        return a
    rgb = take(B * 3).reshape(B, 3)
    acc = take(B)
    depth = take(B)
    if off != len(buf):
        raise ValueError(f"trailing bytes after entry record "
                         f"({len(buf) - off})")
    return key, cell, BlockOutput(rgb, acc, depth, int(chunks))
