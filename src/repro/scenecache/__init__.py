"""Scene-space block reuse: a shared, memory-bounded cache of Phase-II
block outputs keyed by (voxel footprint, view bucket).

The fourth reuse tier (framecache/README.md).  The framecache tiers
replay ONE user's trajectory cheaply — their entries are per-pose
full-resolution maps, so memory grows with distinct poses and hits never
cross users.  This tier caches at the granularity the compute actually
happens — the Phase-II block march — under a scene-space key, behind one
store with an explicit byte budget, so N concurrent users of one scene
share hits and bounded memory.

  key.py    — block key derivation (quantized voxel footprint + view
              bucket) and the coarse coverage cell;
  store.py  — SceneBlockCache: byte-budgeted, coverage-aware
              deterministic LRU;
  render.py — render_adaptive_cached, the single-image consumer
              (framecache/render.py); the serving engine pools the same
              lookups across requests (serve/render_engine.py);
  serial.py — stable to_bytes/from_bytes layouts for keys and entries —
              the wire format an external/sharded multi-host store
              exchanges (keys are stable digests, so they shard);
  sharded.py— ShardedSceneCache: N shard stores routed by key bytes,
              per-shard byte budgets + locks, async fetch futures joined
              at the serving engine's pool sweep — the store the render
              fleet's engine replicas share.
"""
from .key import acfg_token, block_keys  # noqa: F401
from .render import render_adaptive_cached  # noqa: F401
from .serial import (entry_from_bytes, entry_to_bytes,  # noqa: F401
                     key_from_bytes, key_to_bytes, peek_entry_key)
from .sharded import ShardedSceneCache, shard_of  # noqa: F401
from .store import (BlockOutput, SceneBlockCache,  # noqa: F401
                    SceneCacheConfig)
