"""Block-level cached Phase II: ``render_adaptive`` with scene-space reuse.

Drop-in for ``core.pipeline.render_adaptive`` (same inputs, same
(rgb, acc, stats) contract, stats gain ``scene_block_hits`` /
``scene_block_misses``): blocks whose key hits the shared store composite
directly from the cached outputs; only the missing blocks — deduplicated,
so two identical blocks in one call march once — go through the batched
march, and their outputs populate the store.

With ``cache=None`` this delegates straight to ``render_adaptive``:
bit-identical, zero overhead.  The all-miss first call is also
bit-identical — ``_march_block`` is deterministic per block, so marching
the miss subset under ``lax.map`` reproduces the full-map results exactly
(the same property the serving engine's pooled batching relies on).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pipeline
from ..core.fields import FieldFns
from ..core.pipeline import ASDRConfig
from . import key as key_lib
from .store import SceneBlockCache


def render_adaptive_cached(fns: FieldFns, acfg: ASDRConfig, origins, dirs,
                           counts, opacity=None,
                           cache: SceneBlockCache | None = None,
                           scene_id: str = "scene"):
    """Sorted-block adaptive render with shared block reuse.

    origins/dirs: (R, 3) with R % block_size == 0 (pad upstream);
    returns (rgb (R,3), acc (R,), stats).
    """
    if cache is None:
        rgb, acc, stats = pipeline.render_adaptive(
            fns, acfg, origins, dirs, counts, opacity)
        stats = dict(stats)
        stats["samples_reused"] = 0
        stats["scene_block_hits"] = 0
        stats["scene_block_misses"] = int(counts.shape[0]) // acfg.block_size
        return rgb, acc, stats

    R = origins.shape[0]
    B = acfg.block_size
    order, budgets = pipeline.block_sort(acfg, counts, opacity)
    order_np = np.asarray(order)
    o_np = np.asarray(origins[order].reshape(-1, B, 3))
    d_np = np.asarray(dirs[order].reshape(-1, B, 3))
    bud_np = np.asarray(budgets)
    nb = bud_np.shape[0]
    keycells = key_lib.block_keys(cache.cfg, scene_id, acfg,
                                  o_np, d_np, bud_np)

    rgb_s = np.zeros((nb, B, 3), np.float32)
    acc_s = np.zeros((nb, B), np.float32)
    dep_s = np.zeros((nb, B), np.float32)
    chunks = np.zeros((nb,), np.int64)
    miss, hit_chunks = [], 0
    for i, (k, _cell) in enumerate(keycells):
        out = cache.lookup(k)
        if out is None:
            miss.append(i)
        else:
            rgb_s[i], acc_s[i], dep_s[i] = out.rgb, out.acc, out.depth
            chunks[i] = out.chunks
            hit_chunks += out.chunks

    if miss:
        # march each DISTINCT missing key once; duplicate blocks within
        # this call (two image regions quantizing identically) ride along
        leader_of = {}
        leaders = []
        for i in miss:
            k = keycells[i][0]
            if k not in leader_of:
                leader_of[k] = len(leaders)
                leaders.append(i)
        march = partial(pipeline._march_block, fns, acfg)
        rgb_m, acc_m, dep_m, ch_m, _rc_m = jax.lax.map(
            lambda a: march(*a),
            (jnp.asarray(o_np[leaders]), jnp.asarray(d_np[leaders]),
             jnp.asarray(bud_np[leaders], jnp.int32)))
        rgb_m, acc_m = np.asarray(rgb_m), np.asarray(acc_m)
        dep_m, ch_m = np.asarray(dep_m), np.asarray(ch_m)
        for j, i in enumerate(leaders):
            k, cell = keycells[i]
            cache.store(k, cell, rgb_m[j], acc_m[j], dep_m[j], int(ch_m[j]))
        for i in miss:
            j = leader_of[keycells[i][0]]
            rgb_s[i], acc_s[i], dep_s[i] = rgb_m[j], acc_m[j], dep_m[j]
            chunks[i] = ch_m[j]

    inv = np.zeros((R,), np.int64)
    inv[order_np] = np.arange(R)
    # stats mirror pipeline.render_adaptive's dict field-for-field (the
    # bit-identity test gates the outputs; keep any new field in BOTH),
    # except samples split by whether the compute actually ran: hits
    # replay stored outputs, so their chunks are REUSED, not processed
    stats = {
        "samples_processed": (int(chunks.sum()) - hit_chunks)
        * B * acfg.chunk,
        "samples_reused": hit_chunks * B * acfg.chunk,
        "baseline_samples": R * acfg.ns_full,
        "chunks_per_block": chunks,
        "budgets": bud_np,
        "term_depth": jnp.asarray(dep_s.reshape(R)[inv]),
        "scene_block_hits": nb - len(miss),
        "scene_block_misses": len(miss),
    }
    return (jnp.asarray(rgb_s.reshape(R, 3)[inv]),
            jnp.asarray(acc_s.reshape(R)[inv]), stats)
