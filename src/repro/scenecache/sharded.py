"""Sharded scene-block cache: N shard stores routed by key bytes.

The scale-out form of ``SceneBlockCache`` (ROADMAP "distributed render
fleet"): several engine replicas serve one scene against one shared
store, so the store must (a) bound memory per shard, not just globally,
(b) admit concurrent access from many engine threads, and (c) tolerate
fetch latency — a shard in a real fleet is a network peer, not a dict.

Design:

  * **Routing** is a pure function of the key bytes alone —
    ``shard_of(key, n) = int.from_bytes(key[:8], 'little') % n``.  Keys
    are blake2b digests (key.py), so the low 8 bytes are uniform and the
    mapping is stable across processes, hosts, and Python hash
    randomization: every replica of a fleet computes the same shard for
    the same block without coordination (property-tested in
    tests/test_scenecache.py).
  * **Per-shard byte budgets**: the configured ``byte_budget`` splits
    evenly (floor) across shards; each shard is a full
    ``SceneBlockCache`` enforcing ``resident_bytes() <= budget // n``
    with its own coverage-aware deterministic LRU.  Total resident bytes
    therefore never exceed the configured budget, and one hot shard can
    never starve the others' coverage.
  * **Concurrency**: one lock per shard wraps every store/lookup — N
    replicas contend per shard, not on one global lock, which is the
    point of sharding a write-through cache.
  * **Async fetch**: ``fetch_async(key)`` resolves the lookup on a small
    fetch pool and returns a ``Future`` — the host-side stand-in for a
    remote shard RPC.  The serving engine's ``BlockPool.sweep`` is the
    JOIN POINT: it fans out one fetch per pooled block and joins them at
    the end of the sweep (pool.py), so N outstanding shard fetches
    overlap instead of serializing, while delivery stays inside the
    deterministic per-round sweep.
  * **Replication** reuses the serial.py wire format per shard:
    ``dump_entry`` reads the owning shard, ``load_entry`` routes the
    record by its key (``serial.peek_entry_key``) and inserts through
    that shard's budgeted store path.

``ShardedSceneCache`` is interface-compatible with ``SceneBlockCache``
(lookup/store/dump_entry/load_entry/resident_bytes/stats/clear), so it
drops into ``RenderServingEngine(scenecache=...)`` unchanged; at
``shards=1`` its observable semantics equal the plain store's
(property-tested).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import replace
from typing import List, Optional

from ..obs import trace as trace_lib
from . import serial
from .store import BlockOutput, SceneBlockCache, SceneCacheConfig


def shard_of(key: bytes, n_shards: int) -> int:
    """The shard index owning ``key`` — a pure function of the key bytes.

    Uses the little-endian integer of the first 8 digest bytes modulo
    the shard count: no Python ``hash()`` (randomized per process), no
    object identity — two processes always agree.
    """
    return int.from_bytes(key[:8], "little") % n_shards


class ShardedSceneCache:
    def __init__(self, cfg: Optional[SceneCacheConfig] = None,
                 shards: int = 4, fetch_workers: Optional[int] = None):
        assert shards >= 1
        self.cfg = cfg or SceneCacheConfig()
        self.n_shards = shards
        per_budget = self.cfg.byte_budget // shards
        self.shards: List[SceneBlockCache] = [
            SceneBlockCache(replace(self.cfg, byte_budget=per_budget))
            for _ in range(shards)]
        self._locks = [threading.Lock() for _ in range(shards)]
        self._fetch_pool = ThreadPoolExecutor(
            max_workers=fetch_workers or min(shards, 4),
            thread_name_prefix="scenecache-fetch")
        self._closed = False

    # ------------------------------------------------------------ routing
    def _shard(self, key: bytes) -> int:
        return shard_of(key, self.n_shards)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def resident_bytes(self) -> int:
        return sum(s.resident_bytes() for s in self.shards)

    # ----------------------------------------------------- lookup / store
    def lookup(self, key: bytes,
               count_miss: bool = True) -> Optional[BlockOutput]:
        i = self._shard(key)
        # the span covers lock wait + shard read: on the fetch pool its
        # lane is scenecache-fetch_*, the async-fetch side of the trace
        with trace_lib.span("scenecache.lookup", shard=i):
            with self._locks[i]:
                return self.shards[i].lookup(key, count_miss=count_miss)

    def fetch_async(self, key: bytes,
                    count_miss: bool = True) -> "Future[Optional[BlockOutput]]":
        """The lookup as a Future resolved on the fetch pool.

        BlockPool.sweep fans these out (one per pooled block, hitting
        different shards concurrently) and joins them before the round's
        dispatch — the documented join point.  After ``close()`` falls
        back to an immediately-resolved inline lookup so draining
        callers never race the pool shutdown.
        """
        if self._closed:
            fut: Future = Future()
            fut.set_result(self.lookup(key, count_miss=count_miss))
            return fut
        return self._fetch_pool.submit(self.lookup, key,
                                       count_miss=count_miss)

    def store(self, key: bytes, cell: tuple, rgb, acc, depth,
              chunks: int) -> bool:
        i = self._shard(key)
        with trace_lib.span("scenecache.shard_store", shard=i):
            with self._locks[i]:
                return self.shards[i].store(key, cell, rgb, acc, depth,
                                            chunks)

    # ------------------------------------------------------- replication
    def dump_entry(self, key: bytes) -> Optional[bytes]:
        """The owning shard's resident entry as a serial.py record."""
        i = self._shard(key)
        with self._locks[i]:
            return self.shards[i].dump_entry(key)

    def load_entry(self, data: bytes) -> Optional[bytes]:
        """Insert a wire record into the shard its KEY routes to — the
        record's own bytes decide placement, so replicated entries land
        on the same shard everywhere.  Returns the key, or None if the
        owning shard's budget can never fit the entry."""
        i = self._shard(serial.peek_entry_key(data))
        with self._locks[i]:
            return self.shards[i].load_entry(data)

    def clear(self):
        for lock, s in zip(self._locks, self.shards):
            with lock:
                s.clear()

    def close(self):
        """Shut down the fetch pool (idempotent).  The stores stay
        readable — only the async path degrades to inline lookups."""
        self._closed = True
        self._fetch_pool.shutdown(wait=False)

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Shard-union stats: the same keys as ``SceneBlockCache.stats``
        with counters summed (at shards=1 the dicts agree except for the
        extra shard fields — property-tested), plus per-shard residency
        so a skewed shard is visible."""
        per = [s.stats() for s in self.shards]
        hits = sum(p["hits"] for p in per)
        misses = sum(p["misses"] for p in per)
        total = hits + misses
        return {
            "entries": sum(p["entries"] for p in per),
            "resident_bytes": sum(p["resident_bytes"] for p in per),
            "byte_budget": self.cfg.byte_budget,
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / total if total else 0.0,
            "stores": sum(p["stores"] for p in per),
            "evictions": sum(p["evictions"] for p in per),
            "rejected": sum(p["rejected"] for p in per),
            "shards": self.n_shards,
            "per_shard_budget": self.cfg.byte_budget // self.n_shards,
            "per_shard_resident_bytes": [p["resident_bytes"] for p in per],
            "per_shard_entries": [p["entries"] for p in per],
        }
