"""Scene-space block keys: quantized voxel footprint + view bucket.

A Phase-II block is a set of ``block_size`` rays marched together under
one sample budget.  Its output (rgb/acc/depth contributions per ray)
depends only on the rays' geometry, the budget, and the render config —
not on which request, user, or frame the block came from.  That makes
block outputs cacheable in *scene space*: the key is what the block
looks at, not whose frame it belongs to.

The key quantizes each ray to

  * its **voxel footprint** — the scene voxels at the near- and far-plane
    ends of the ray's chord (two ``voxel_res``-resolution cells fix the
    line up to quantization), and
  * its **view bucket** — the ray direction quantized to a
    ``view_buckets``-per-axis lattice on the direction cube (radiance is
    view-dependent: two chords through the same voxels in opposite
    directions must not collide),

then hashes the whole block's quantized arrays together with the budget,
the scene id, and the render config.  Two blocks whose rays land in the
same cells — the same pose re-requested by another user, or a pose close
enough that no ray crosses a cell boundary — get the same key and share
one march.

Alongside the exact key, each block gets a coarse **coverage cell** (the
``coverage_res``-resolution voxel of its mid-chord centroid plus a coarse
direction bucket).  The store's eviction policy uses it: entries whose
cell is covered by other resident entries are redundant and evict first
(store.py).

Host-side numpy only — keys are computed once per block per request,
never traced.
"""
from __future__ import annotations

import hashlib
import struct
from typing import List, Tuple

import numpy as np

from ..core import scene

# bump when the key layout changes: stale digests must never alias
_KEY_VERSION = 1
_CELL_VIEW_BUCKETS = 8


def acfg_token(acfg) -> bytes:
    """Stable byte token for a render config.

    ASDRConfig is a frozen dataclass of numbers/tuples/bools, so its repr
    is deterministic across processes (unlike ``hash()`` on strings).
    """
    return repr(acfg).encode()


def block_keys(cfg, scene_id: str, acfg, origins: np.ndarray,
               dirs: np.ndarray, budgets: np.ndarray
               ) -> List[Tuple[bytes, tuple]]:
    """(key digest, coverage cell) for every block in a stack.

    origins/dirs: (N, B, 3) float arrays (host or device — converted
    once); budgets: (N,) ints.  Returns N pairs, index-aligned.
    """
    o = np.asarray(origins, np.float32)
    d = np.asarray(dirs, np.float32)
    buds = np.asarray(budgets)
    p0 = o + np.float32(scene.NEAR) * d
    p1 = o + np.float32(scene.FAR) * d
    v0 = np.floor(p0 * cfg.voxel_res).astype(np.int32)
    v1 = np.floor(p1 * cfg.voxel_res).astype(np.int32)
    vb = np.floor((d * 0.5 + 0.5) * cfg.view_buckets).astype(np.int32)
    np.clip(vb, -1, cfg.view_buckets, out=vb)

    prefix = hashlib.blake2b(
        acfg_token(acfg) + b"\x00" + scene_id.encode()
        + struct.pack("<iiii", _KEY_VERSION, cfg.voxel_res,
                      cfg.view_buckets, o.shape[1]),
        digest_size=16).digest()

    mid = 0.5 * (p0 + p1).mean(axis=1)                       # (N, 3)
    cell_v = np.floor(mid * cfg.coverage_res).astype(np.int64)
    cell_d = np.floor((d.mean(axis=1) * 0.5 + 0.5)
                      * _CELL_VIEW_BUCKETS).astype(np.int64)

    out = []
    for i in range(o.shape[0]):
        h = hashlib.blake2b(prefix, digest_size=16)
        h.update(v0[i].tobytes())
        h.update(v1[i].tobytes())
        h.update(vb[i].tobytes())
        h.update(struct.pack("<q", int(buds[i])))
        cell = (scene_id, *cell_v[i].tolist(), *cell_d[i].tolist())
        out.append((h.digest(), cell))
    return out
