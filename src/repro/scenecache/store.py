"""A shared, memory-bounded cache of finished Phase-II block outputs.

One ``SceneBlockCache`` serves every user of a process: entries are keyed
by scene-space block identity (key.py), so N clients orbiting the same
scene share hits instead of each holding a private per-pose LRU — the
structural difference from the framecache tiers, whose entries are
per-pose full-resolution maps and whose memory grows with the number of
distinct trajectories.

Retention is governed by a single explicit **byte budget**, never an
entry count: ``resident_bytes() <= byte_budget`` holds after every
operation (an entry larger than the whole budget is rejected outright).
Eviction is **coverage-aware LRU**, totally ordered and deterministic:

  1. entries whose coarse coverage cell holds OTHER resident entries are
     redundant coverage of that scene region and evict first;
  2. within a group, least-recently-used evicts first;
  3. exact recency ties break by insertion sequence (oldest first).

No step consults dict iteration order beyond Python's guaranteed
insertion order, so two caches fed the same operation sequence always
hold the same entries (tests/test_scenecache.py gates this).

Outputs are stored as host numpy arrays: the cache bounds HOST memory and
never pins device buffers; a hit costs one dict lookup plus a memcpy into
the consumer's block buffers.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional

import numpy as np

from ..obs import trace as trace_lib


@dataclasses.dataclass(frozen=True)
class SceneCacheConfig:
    """Quantization + budget knobs for the scene-space block tier.

    voxel_res / view_buckets set the key quantization (key.py): higher
    values mean stricter matching (identical-pose reuse only), lower
    values let nearby poses alias into shared keys at the cost of
    approximation error.  byte_budget is the hard cap on resident bytes.
    """
    voxel_res: int = 256
    view_buckets: int = 64
    coverage_res: int = 8
    byte_budget: int = 32 << 20


@dataclasses.dataclass
class BlockOutput:
    """One block's finished Phase-II products (host-side copies)."""
    rgb: np.ndarray      # (B, 3) float32
    acc: np.ndarray      # (B,)   float32
    depth: np.ndarray    # (B,)   float32 — march termination depth
    chunks: int          # while_loop trips the march actually ran

    @property
    def nbytes(self) -> int:
        # + key digest and python bookkeeping overhead, nominal
        return self.rgb.nbytes + self.acc.nbytes + self.depth.nbytes + 64


@dataclasses.dataclass
class _Entry:
    out: BlockOutput
    cell: tuple
    last_used: int
    seq: int


class SceneBlockCache:
    def __init__(self, cfg: SceneCacheConfig | None = None):
        self.cfg = cfg or SceneCacheConfig()
        self._entries: Dict[bytes, _Entry] = {}
        self._cells: Counter = Counter()
        self._bytes = 0
        self._clock = 0
        self._seq = 0
        self.hits = 0
        self.misses = 0
        self.stores = 0
        self.evictions = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._entries)

    def resident_bytes(self) -> int:
        return self._bytes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------- lookup
    def lookup(self, key: bytes,
               count_miss: bool = True) -> Optional[BlockOutput]:
        """The cached output for a block key, or None (march + store).

        ``count_miss=False`` is for RE-checks of a key that already
        recorded its miss (the serving engine re-sweeps its pool every
        round): hits always count, but a block waiting k rounds must not
        count k misses, or ``stats()['hit_rate']`` deflates.
        """
        e = self._entries.get(key)
        if e is None:
            if count_miss:
                self.misses += 1
            return None
        self.hits += 1
        e.last_used = self._tick()
        # hits only: a span per pool re-sweep miss would dominate the
        # trace; misses are visible as the marched blocks they become
        trace_lib.instant("scenecache.hit")
        return e.out

    # -------------------------------------------------------------- store
    def store(self, key: bytes, cell: tuple, rgb, acc, depth,
              chunks: int) -> bool:
        """Insert a marched block's outputs; False if it can never fit."""
        out = BlockOutput(
            np.ascontiguousarray(np.asarray(rgb, np.float32)),
            np.ascontiguousarray(np.asarray(acc, np.float32)),
            np.ascontiguousarray(np.asarray(depth, np.float32)),
            int(chunks))
        if out.nbytes > self.cfg.byte_budget:
            self.rejected += 1
            return False
        with trace_lib.span("scenecache.store", bytes=out.nbytes):
            old = self._entries.pop(key, None)
            if old is not None:
                self._drop_bookkeeping(old)
            self._entries[key] = _Entry(out, cell, self._tick(), self._seq)
            self._seq += 1
            self._cells[cell] += 1
            self._bytes += out.nbytes
            while self._bytes > self.cfg.byte_budget:
                self._evict_one(exclude=key)
            self.stores += 1
        return True

    # ----------------------------------------------------------- eviction
    def _drop_bookkeeping(self, e: _Entry):
        self._cells[e.cell] -= 1
        if self._cells[e.cell] <= 0:
            del self._cells[e.cell]
        self._bytes -= e.out.nbytes

    def _evict_one(self, exclude: bytes | None = None):
        """Evict exactly one entry by the coverage-aware LRU total order."""
        victim_key = min(
            (k for k in self._entries if k != exclude),
            key=lambda k: (self._cells[self._entries[k].cell] <= 1,
                           self._entries[k].last_used,
                           self._entries[k].seq))
        e = self._entries.pop(victim_key)
        self._drop_bookkeeping(e)
        self.evictions += 1
        trace_lib.instant("scenecache.evict", bytes=e.out.nbytes)

    # ------------------------------------------------------ serialization
    def dump_entry(self, key: bytes) -> Optional[bytes]:
        """The resident entry as a stable byte record (serial.py), or
        None if the key is not resident.  Does not count as a hit or
        touch recency — dumping is replication, not consumption."""
        e = self._entries.get(key)
        if e is None:
            return None
        from . import serial
        return serial.entry_to_bytes(key, e.cell, e.out)

    def load_entry(self, data: bytes) -> Optional[bytes]:
        """Insert a serialized entry (e.g. fetched from a peer shard);
        returns its key, or None if the entry can never fit this cache's
        byte budget (store's rejection — the caller must not assume the
        key is resident).  Goes through ``store`` so the byte budget and
        eviction order hold exactly as for a locally marched block."""
        from . import serial
        key, cell, out = serial.entry_from_bytes(data)
        stored = self.store(key, cell, out.rgb, out.acc, out.depth,
                            out.chunks)
        return key if stored else None

    def clear(self):
        """Drop everything — required after a scene's field is retrained
        or reloaded under the same id (keys carry the scene id, not the
        field's weights)."""
        self._entries.clear()
        self._cells.clear()
        self._bytes = 0

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "resident_bytes": self._bytes,
            "byte_budget": self.cfg.byte_budget,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "stores": self.stores,
            "evictions": self.evictions,
            "rejected": self.rejected,
        }
