"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic-reshard.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json        # step, tree structure, leaf index, mesh info
        leaf_00000.npy ...   # one .npy per leaf (full/unsharded arrays)
    <root>/step_000123.tmp/  # in-flight writes (renamed atomically when done)

Design notes for 1000+-node posture (single-process here, the mechanisms
are what matter):
  * ATOMIC: writes land in ``<dir>.tmp`` and are renamed only after the
    manifest (written LAST) is fsynced — a killed writer leaves a .tmp dir
    that restore ignores and the next save garbage-collects.
  * KEEP-K: after a successful save, older steps beyond ``keep`` are
    deleted (never the one just written).
  * ASYNC: ``save_async`` snapshots arrays to host (device_get) then hands
    the serialization to a writer thread, so the train loop resumes
    immediately (double-buffered: at most one pending save).
  * ELASTIC: leaves are stored UNSHARDED; restore re-shards to whatever
    mesh/sharding the *current* job passes (e.g. resume a 512-chip ckpt on
    256 chips) via jax.device_put with the new NamedSharding.  At real
    multi-host scale the same manifest format supports per-shard files —
    the restore path already goes through device_put.
  * INTEGRITY: manifest carries per-leaf shape/dtype; mismatches fail
    loudly before any parameter is touched.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(root: Path, step: int, tree: Any, extra: Optional[dict] = None):
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _tree_paths(tree)
    index = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        index.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": index,
        "extra": extra or {},
        "time": time.time(),
    }
    # manifest written last: its presence marks the payload complete
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def restore_checkpoint(root: Path, tree_like: Any, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    shardings: optional pytree of jax.sharding.Sharding matching tree_like
    (elastic resume path: pass the CURRENT mesh's shardings).
    Returns (tree, step).
    """
    root = Path(root)
    steps = available_steps(root)
    if not steps:
        raise FileNotFoundError(f"no complete checkpoints under {root}")
    step = step if step is not None else steps[-1]
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())

    leaves, treedef = _tree_paths(tree_like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves)}"
        )
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda s: isinstance(
        s, jax.sharding.Sharding)) if shardings is not None else None)
    out = []
    for i, like in enumerate(leaves):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        want = manifest["leaves"][i]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"leaf {i} shape mismatch: {arr.shape}")
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"leaf {i}: checkpoint {arr.shape} vs model {like.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree.unflatten(treedef, out), step


def available_steps(root: Path):
    root = Path(root)
    steps = []
    if not root.exists():
        return steps
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return sorted(steps)


class CheckpointManager:
    """keep-k + async wrapper around save/restore."""

    def __init__(self, root, keep: int = 3, async_save: bool = True):
        self.root = Path(root)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        # snapshot to host synchronously (cheap vs serialization)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self.wait()  # double-buffer: at most one in-flight save
            t = threading.Thread(
                target=self._write, args=(step, host_tree, extra), daemon=True
            )
            t.start()
            self._pending = t
        else:
            self._write(step, host_tree, extra)

    def _write(self, step, host_tree, extra):
        save_checkpoint(self.root, step, host_tree, extra)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = available_steps(self.root)
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for d in self.root.glob("*.tmp"):
            shutil.rmtree(d, ignore_errors=True)

    def restore(self, tree_like, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.root, tree_like, step, shardings)

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.root)
        return steps[-1] if steps else None
