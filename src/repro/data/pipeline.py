"""Deterministic-by-step data pipelines.

Restart/straggler posture: every batch is a pure function of
(seed, step) — a restarted or rescheduled worker replays the exact same
stream with no data loss or duplication, and there is no shared queue to
drain (see DESIGN.md §5 fault tolerance).  This is the standard recipe for
reproducible large-scale training (deterministic index shuffling keyed by
step) realized with JAX PRNG folding.

TokenPipeline synthesizes language-model token batches with realistic
statistics: Zipfian unigram draws mixed with short repeated "phrases"
(so models can actually reduce loss by learning bigram structure —
pure-uniform tokens would pin CE at ln(V)).

RayPipeline yields (origin, direction, reference color) ray batches from
the analytic scenes for Instant-NGP training (the paper's substrate).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_exponent: float = 1.1
    phrase_len: int = 8

    def batch_at(self, step: int) -> jnp.ndarray:
        """(batch, seq_len) int32 — pure function of (seed, step)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        # zipf via inverse-CDF on uniform (ranks 1..V)
        u = jax.random.uniform(k1, (self.batch, self.seq_len),
                               minval=1e-6, maxval=1.0)
        ranks = jnp.floor(
            (self.vocab ** (1.0 - self.zipf_exponent) * u
             + (1 - u)) ** (1.0 / (1.0 - self.zipf_exponent))
        )
        tokens = jnp.clip(ranks.astype(jnp.int32) - 1, 0, self.vocab - 1)
        # inject learnable structure: every phrase repeats its first half
        P = self.phrase_len
        S = self.seq_len // P * P
        t = tokens[:, :S].reshape(self.batch, -1, P)
        t = jnp.concatenate([t[:, :, : P // 2], t[:, :, : P - P // 2]], axis=-1)
        tokens = tokens.at[:, :S].set(t.reshape(self.batch, S))
        return tokens

    def __iter__(self) -> Iterator[jnp.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class RayPipeline:
    """Ray batches for NGP training, deterministic by step."""
    scene: str = "lego"
    batch: int = 1024
    n_views: int = 12
    view_hw: Tuple[int, int] = (96, 96)
    seed: int = 0

    def materialize(self):
        """Precompute the ray pool (host-side, done once)."""
        from ..core import scene as scene_lib
        from ..core.train import NGPTrainConfig, _make_view_rays

        cfg = NGPTrainConfig(
            scene=self.scene, n_views=self.n_views,
            view_hw=self.view_hw, seed=self.seed,
        )
        field = scene_lib.make_scene(self.scene)
        return _make_view_rays(cfg, field)

    def batch_at(self, step: int, pool) -> Tuple[jnp.ndarray, ...]:
        o, d, c = pool
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        idx = jax.random.randint(key, (self.batch,), 0, o.shape[0])
        return o[idx], d[idx], c[idx]
