from .pipeline import TokenPipeline, RayPipeline
