from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm, clip_by_global_norm
from .schedules import cosine_schedule, linear_warmup_cosine
from .compress import int8_compress, int8_decompress, compressed_psum, ErrorFeedback
