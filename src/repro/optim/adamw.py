"""AdamW from scratch (no optax) with bf16-param / f32-master support.

The optimizer state is a pytree mirroring params:
  {"m": ..., "v": ..., "count": scalar, "master": optional f32 copy}

ZeRO-1/3 posture: the *sharding* of m/v/master follows the param sharding
rules (sharding/rules.py) — with params FSDP-sharded over the ``data`` axis
the optimizer state is automatically sharded too, and XLA's SPMD partitioner
keeps the update fully sharded (no gather of optimizer state ever happens).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3                 # used if schedule not passed to update
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    use_master: bool = False         # keep f32 master copy of bf16 params


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads: Any, state: Any, params: Any, cfg: AdamWConfig,
                 lr: Optional[jnp.ndarray] = None):
    """One AdamW step. Returns (new_params, new_state)."""
    lr = cfg.lr if lr is None else lr
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd_mv(m, v, g):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        return m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_m, new_v = [], []
    for m, v, g in zip(flat_m, flat_v, flat_g):
        m2, v2 = upd_mv(m, v, g)
        new_m.append(m2)
        new_v.append(v2)
    new_m = jax.tree.unflatten(treedef, new_m)
    new_v = jax.tree.unflatten(treedef, new_v)

    base = state.get("master", params)

    def upd_p(p, m, v):
        p32 = p.astype(jnp.float32)
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + lr * cfg.weight_decay * p32
        return p32 - step

    new_master = jax.tree.map(upd_p, base, new_m, new_v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params
    )
    new_state = {"m": new_m, "v": new_v, "count": count}
    if cfg.use_master:
        new_state["master"] = new_master
    return new_params, new_state
