"""int8 gradient compression for the slow cross-pod link (DESIGN.md §5).

Scheme: per-chunk symmetric int8 quantization (chunk = trailing axis tiles
of 256) + f32 scales; the all-reduce moves ~4x fewer bytes.  An error-
feedback accumulator re-injects quantization residuals next step, which is
what keeps SGD/Adam convergence intact (Karimireddy et al., 2019).

``compressed_psum`` is written for ``shard_map`` over the ``pod`` axis —
inside pjit we cannot intercept XLA's all-reduces, so cross-pod gradient
compression is an explicit opt-in path in train/step.py (enabled via
TrainConfig.compress_pod_grads) using shard_map around the grad reduction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CHUNK = 256


def _pad_to_chunk(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % CHUNK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, CHUNK), pad


def int8_compress(x: jnp.ndarray):
    """x -> (int8 values (Nc, CHUNK), f32 scales (Nc, 1), pad)."""
    chunks, pad = _pad_to_chunk(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(chunks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray, pad: int, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """Quantize -> psum int32 (accumulate without overflow) -> dequant.

    Bytes on the wire: 1B values + 4B/256 scales ≈ 1.016B per element vs 4B
    for f32 psum.  Scales are reduced with max so dequantization uses a
    common scale (conservative; residual goes to error feedback).
    """
    q, scale, pad = int8_compress(x)
    common = jax.lax.pmax(scale, axis_name)
    # requantize against the common scale so integer sums are consistent
    requant = jnp.clip(
        jnp.round(q.astype(jnp.float32) * scale / common), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    return int8_decompress(total, common, pad, x.shape)


class ErrorFeedback:
    """Residual accumulator: apply() returns compressed-sum gradient and the
    new residual state (pure-functional; state is a pytree of f32)."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any, axis_name: str):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        outs, new_res = [], []
        n = jax.lax.psum(1, axis_name)
        for g, r in zip(flat_g, flat_r):
            corrected = g.astype(jnp.float32) + r
            mean = compressed_psum(corrected, axis_name) / n
            # error feedback tracks the *local* quantization error
            q, s, pad = int8_compress(corrected)
            local_deq = int8_decompress(q, s, pad, g.shape)
            outs.append(mean.astype(g.dtype))
            new_res.append(corrected - local_deq)
        return (
            jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_res),
        )
