"""Fused density→color MLP Pallas kernel — the TPU analogue of the paper's
CIM MLP engine (§5.3).

CIM insight ported: ReRAM crossbars hold the MLP weights *in place* so no
weight traffic occurs per sample.  On TPU we get the same effect by giving
every weight matrix a BlockSpec whose index_map is constant across the
sample grid: the compiler keeps the (padded) weights resident in VMEM for
the whole point stream while activation tiles flow through, and each
128x128 padded matmul maps 1:1 onto one MXU pass.

Data layout (all feature dims padded to P=128 by ops.py):
  * density input  : encoding tile (TILE, P)
  * density output : cols 0..G-1 = geo feature, col G = sigma logit
                     (ops.py permutes the last weight's columns so the
                     color input needs no lane shift)
  * sh input       : direction encoding pre-placed at cols G..G+S-1
  * color input    : geo_mask(dout) + sh  — a single masked add
  * kernel output  : (TILE, P) with col 0 = sigma, cols 1..3 = rgb,
                     cols 4..3+G = geo (packed result block)

Weight count is static (unrolled); VMEM footprint = (nd+nc) * 64KB of
weights + 3 activation tiles — far under the ~16MB VMEM budget, leaving
room for the encode kernel's table block to co-reside when fused further.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

P = 128          # padded feature width (MXU lane width)
TILE = 256       # points per block program


def _relu(x):
    return jnp.maximum(x, 0.0)


def _trunc_exp(x):
    return jnp.exp(jnp.clip(x, -15.0, 15.0))


def _density_chain(x, wd_ref, nd):
    for i in range(nd):
        x = jnp.dot(x, wd_ref[i], preferred_element_type=jnp.float32)
        if i < nd - 1:
            x = _relu(x)
    return x  # (TILE, P): cols 0..G-1 geo, col G sigma logit


def _color_chain(x, wc_ref, nc):
    for i in range(nc):
        x = jnp.dot(x, wc_ref[i], preferred_element_type=jnp.float32)
        if i < nc - 1:
            x = _relu(x)
    return jax.nn.sigmoid(x)


def _fused_kernel(enc_ref, sh_ref, wd_ref, wc_ref, out_ref, *, nd, nc, geo_dim):
    dout = _density_chain(enc_ref[...].astype(jnp.float32), wd_ref, nd)
    lane = jax.lax.broadcasted_iota(jnp.int32, dout.shape, 1)
    geo = jnp.where(lane < geo_dim, dout, 0.0)
    cin = geo + sh_ref[...].astype(jnp.float32)
    rgb = _color_chain(cin, wc_ref, nc)
    sigma = _trunc_exp(dout[:, geo_dim])
    packed = jnp.concatenate(
        [
            sigma[:, None],
            rgb[:, :3],
            geo[:, :geo_dim],
            jnp.zeros((dout.shape[0], P - 4 - geo_dim), jnp.float32),
        ],
        axis=1,
    )
    out_ref[...] = packed


def _density_kernel(enc_ref, wd_ref, out_ref, *, nd, geo_dim):
    dout = _density_chain(enc_ref[...].astype(jnp.float32), wd_ref, nd)
    lane = jax.lax.broadcasted_iota(jnp.int32, dout.shape, 1)
    geo = jnp.where(lane < geo_dim, dout, 0.0)
    sigma = _trunc_exp(dout[:, geo_dim])
    packed = jnp.concatenate(
        [
            sigma[:, None],
            geo[:, :geo_dim],
            jnp.zeros((dout.shape[0], P - 1 - geo_dim), jnp.float32),
        ],
        axis=1,
    )
    out_ref[...] = packed


def _color_kernel(cin_ref, wc_ref, out_ref, *, nc):
    rgb = _color_chain(cin_ref[...].astype(jnp.float32), wc_ref, nc)
    out_ref[...] = rgb


def _weights_spec(n):
    return pl.BlockSpec((n, P, P), lambda i: (0, 0, 0))


def _tile_spec():
    return pl.BlockSpec((TILE, P), lambda i: (i, 0))


def fused_field_call(enc, sh, wd, wc, geo_dim: int, interpret: bool = True):
    """enc/sh (N, P) padded; wd (nd,P,P); wc (nc,P,P) -> packed (N, P)."""
    n = enc.shape[0]
    assert n % TILE == 0, "ops.py pads N to a TILE multiple"
    kern = functools.partial(
        _fused_kernel, nd=wd.shape[0], nc=wc.shape[0], geo_dim=geo_dim
    )
    return pl.pallas_call(
        kern,
        grid=(n // TILE,),
        in_specs=[_tile_spec(), _tile_spec(),
                  _weights_spec(wd.shape[0]), _weights_spec(wc.shape[0])],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((n, P), jnp.float32),
        interpret=interpret,
    )(enc, sh, wd, wc)


def density_call(enc, wd, geo_dim: int, interpret: bool = True):
    n = enc.shape[0]
    assert n % TILE == 0
    kern = functools.partial(_density_kernel, nd=wd.shape[0], geo_dim=geo_dim)
    return pl.pallas_call(
        kern,
        grid=(n // TILE,),
        in_specs=[_tile_spec(), _weights_spec(wd.shape[0])],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((n, P), jnp.float32),
        interpret=interpret,
    )(enc, wd)


def color_call(cin, wc, interpret: bool = True):
    n = cin.shape[0]
    assert n % TILE == 0
    kern = functools.partial(_color_kernel, nc=wc.shape[0])
    return pl.pallas_call(
        kern,
        grid=(n // TILE,),
        in_specs=[_tile_spec(), _weights_spec(wc.shape[0])],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((n, P), jnp.float32),
        interpret=interpret,
    )(cin, wc)
