"""Multi-resolution hash-grid encode Pallas kernel — the TPU analogue of the
paper's Encoding Engine (§5.2: hybrid address generator + Mem Xbars +
fusion unit).

CIM insights ported:
  * Hybrid addressing (§5.2.1): the per-level metadata carries an
    ``is_dense`` flag; dense (low-res) levels compute direct row-major
    addresses (conflict-free, perfectly coalesced — the de-hashed copies
    trick) while high-res levels hash (Eq. 2).  The select happens on
    traced scalars so one kernel serves both.
  * Data reuse (§5.2.2): one grid step holds a whole level's table block in
    VMEM while a spatially-sorted tile of points gathers against it —
    consecutive samples hit the same voxel rows (the measured 70-98%
    repetition, Fig. 15), so the gathers coalesce in VMEM instead of
    re-reading HBM.  The register-cache becomes "table-block residency".
  * Fusion unit: trilinear interpolation happens in-register before the
    features ever leave the kernel.

Grid = (n_levels, n_point_tiles); each step re-binds the level's table
(BlockSpec picks row ``l``), so tables stream through VMEM once per level
while point tiles iterate — table traffic is L*T*F bytes total regardless
of N (vs N*8*L*F naive).

Layout notes: table minor dim F=2 and the (TILE, 8) gather are interpret-
mode-validated; a production TPU lowering packs F into 128-lane rows and
uses a one-hot-matmul gather for the dense levels (see EXPERIMENTS.md
§Perf for the measured trade-off).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.hashgrid import PRIMES

TILE = 256   # points per block program
PPAD = 8     # padded point row: [x, y, z, 0...]


def encode_level(pts, res, is_dense, rows, table):
    """One level's trilinear hash encode: (M, 3) points x (T, F) table ->
    (M, F) features.

    The in-kernel building block shared by this module's per-level grid
    steps AND the fused march (fused_march.py), where the same math runs
    against either the resident table stack or a double-buffered VMEM
    streaming slot — one implementation, so the two kernels cannot drift.
    ``res``/``is_dense``/``rows`` are traced scalars (one metadata row).
    """
    scaled = pts * res.astype(jnp.float32)
    base = jnp.clip(jnp.floor(scaled).astype(jnp.int32), 0, res - 1)
    frac = scaled - base.astype(jnp.float32)             # (M, 3)

    acc = jnp.zeros((pts.shape[0], table.shape[-1]), jnp.float32)
    # unrolled 8-corner loop with python-scalar offsets (no array constants)
    for c in range(8):
        ox, oy, oz = (c >> 2) & 1, (c >> 1) & 1, c & 1
        cx = (base[:, 0] + ox).astype(jnp.uint32)
        cy = (base[:, 1] + oy).astype(jnp.uint32)
        cz = (base[:, 2] + oz).astype(jnp.uint32)
        stride = (res + 1).astype(jnp.uint32)
        dense_idx = cx + stride * (cy + stride * cz)
        h = cx * np.uint32(PRIMES[0])
        h = h ^ (cy * np.uint32(PRIMES[1]))
        h = h ^ (cz * np.uint32(PRIMES[2]))
        hash_idx = h % rows.astype(jnp.uint32)
        idx = jnp.where(is_dense > 0, dense_idx, hash_idx).astype(jnp.int32)

        feats = table[idx]                               # (M, F) gather
        wx = frac[:, 0] if ox else 1.0 - frac[:, 0]
        wy = frac[:, 1] if oy else 1.0 - frac[:, 1]
        wz = frac[:, 2] if oz else 1.0 - frac[:, 2]
        w = wx * wy * wz                                 # (M,)
        acc = acc + feats.astype(jnp.float32) * w[:, None]
    return acc


def _encode_kernel(pts_ref, meta_ref, table_ref, out_ref):
    meta = meta_ref[...]
    pts = pts_ref[...][:, :3]                            # (TILE, 3)
    out_ref[...] = encode_level(pts, meta[0], meta[1], meta[2],
                                table_ref[...])


def hash_encode_call(points_padded, meta, tables, interpret: bool = True):
    """points_padded (N, PPAD); meta (L, 8) int32 [res, is_dense, rows, ...];
    tables (L, T, F) -> features (L, N, F) f32."""
    n = points_padded.shape[0]
    L, T, F = tables.shape
    assert n % TILE == 0, "ops.py pads N to a TILE multiple"
    return pl.pallas_call(
        _encode_kernel,
        grid=(L, n // TILE),
        in_specs=[
            pl.BlockSpec((TILE, PPAD), lambda l, i: (i, 0)),
            pl.BlockSpec((None, 8), lambda l, i: (l, 0)),
            pl.BlockSpec((None, T, F), lambda l, i: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, TILE, F), lambda l, i: (l, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, n, F), jnp.float32),
        interpret=interpret,
    )(points_padded, meta, tables)
