"""Flash-attention Pallas kernel for the LM stack (beyond-paper addition).

The prefill cells' residual roofline gap is attention intermediates
(scores materialized per KV chunk by the jnp path); this kernel keeps the
online-softmax state (m, l, acc) in VMEM registers across the KV sweep so
score tiles never reach HBM — the same VMEM-residency argument as the
fused render MLP (DESIGN.md §2), applied to the zoo side.

Layout: one block program per (batch*head, q_block); K/V for that head are
resident (BlockSpec row-select) and swept in KB-sized slices with
``lax.fori_loop`` + ``pl.dynamic_slice``-style indexing.  Causal +
sliding-window masking matches models/attention.py semantics exactly
(``ref`` oracle = attend_full).  Validated interpret=True on CPU; on real
TPU the same BlockSpecs tile Q into 128-row MXU passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
QB = 128      # q rows per block program
KB = 128      # kv rows per inner step


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, *, kv_len, window,
                  softcap, scale):
    q = q_ref[...].astype(jnp.float32) * scale          # (QB, Dh)
    qb = pl.program_id(1)
    q_pos = qb * QB + jax.lax.broadcasted_iota(jnp.int32, (QB, 1), 0)[:, 0]

    nk = kv_len // KB

    def body(i, carry):
        m_run, l_run, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], i * KB, KB, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], i * KB, KB, 0)
        s = q @ k.astype(jnp.float32).T                  # (QB, KB)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = i * KB + jax.lax.broadcasted_iota(
            jnp.int32, (1, KB), 1)[0]
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc

    init = (
        jnp.full((QB,), NEG_INF, jnp.float32),
        jnp.zeros((QB,), jnp.float32),
        jnp.zeros((QB, q.shape[-1]), jnp.float32),
    )
    m_run, l_run, acc = jax.lax.fori_loop(0, nk, body, init)
    out_ref[...] = (acc / jnp.maximum(l_run, 1e-30)[:, None]).astype(
        out_ref.dtype)


def flash_attention(q, k, v, window: int = 0, softcap: float = 0.0,
                    interpret: bool = True):
    """q (B, S, H, Dh); k/v (B, S, KV, Dh) with H % KV == 0 (GQA).
    Causal (+ optional sliding-window) attention. Returns (B, S, H, Dh)."""
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    assert S % QB == 0 and S % KB == 0, "pad sequence to 128"
    scale = Dh ** -0.5

    # lay out as (B*H, S, Dh); kv broadcast per GQA group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        B * H, S, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        B * H, S, Dh)

    kern = functools.partial(
        _flash_kernel, kv_len=S, window=window, softcap=softcap, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * H, S // QB),
        in_specs=[
            pl.BlockSpec((None, QB, Dh), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((None, S, Dh), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((None, S, Dh), lambda bh, qb: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, QB, Dh), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)
