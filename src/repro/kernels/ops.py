"""Public jit'd wrappers around the Pallas kernels.

Each wrapper owns the padding/layout contract documented in the kernel
modules and exposes the *logical* shapes used by core/:

  hash_encode(points, tables, cfg)        -> (N, L*F)
  density_mlp(enc, params, cfg)           -> (sigma (N,), geo (N, G))
  color_mlp(geo, dirs, params, cfg)       -> rgb (N, 3)
  fused_field(points|enc, ...)            -> (sigma, rgb)
  volume_render(sigmas, anchors, deltas, group) -> (rgb, acc)

``field_fns(params, cfg)`` returns a kernels-backed FieldFns so the whole
ASDR pipeline (core/pipeline.py) can run on the kernel path; tests assert
it matches the pure-jnp model path.
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from ..core import mlp as mlp_lib
from ..core import rendering, scene
from ..core.fields import FieldFns
from . import fused_march as FMA
from . import fused_mlp as FM
from . import hash_encode as HE
from . import volume_render as VR

# interpret=True everywhere in this container (CPU validation); flip on TPU.
INTERPRET = True


def _pad_rows(x, mult):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
        )
    return x, pad


def _pad_cols(x, width):
    if x.shape[-1] < width:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (width - x.shape[-1],), x.dtype)],
            axis=-1,
        )
    return x


# ---------------------------------------------------------------- hash encode
def grid_meta(cfg) -> jnp.ndarray:
    """(L, 8) int32 metadata rows: [res, is_dense, table_rows, 0, ...]."""
    rows = []
    for l in range(cfg.n_levels):
        res = cfg.level_resolution(l)
        rows.append([res, int(cfg.level_is_dense(l)), cfg.table_size,
                     0, 0, 0, 0, 0])
    return jnp.asarray(rows, jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def _hash_encode_padded(points_padded, meta, tables, interpret=INTERPRET):
    return HE.hash_encode_call(points_padded, meta, tables, interpret)


def hash_encode(points, tables, cfg, interpret: bool = INTERPRET):
    """points (N,3) in [0,1] -> encoding (N, L*F), matching hashgrid.encode."""
    n = points.shape[0]
    pts = _pad_cols(points.astype(jnp.float32), HE.PPAD)
    pts, _ = _pad_rows(pts, HE.TILE)
    feats = _hash_encode_padded(pts, grid_meta(cfg), tables,
                                interpret=interpret)     # (L, Np, F)
    feats = feats[:, :n]                                  # strip row pad
    L, _, F = feats.shape
    return jnp.transpose(feats, (1, 0, 2)).reshape(n, L * F)


# ------------------------------------------------------------------ fused MLP
def pack_density_weights(params: Dict, cfg: mlp_lib.MLPConfig) -> jnp.ndarray:
    """Pad density weights to (nd, P, P); permute the last layer's output
    columns to [geo(0..G-1), sigma(G)] so no lane shift is needed in-kernel."""
    G = cfg.geo_feature_dim
    ws = []
    for i, w in enumerate(params["density"]):
        w = w.astype(jnp.float32)
        if i == len(params["density"]) - 1:
            # original cols: [sigma, geo...] -> new: [geo..., sigma]
            w = jnp.concatenate([w[:, 1 : 1 + G], w[:, :1]], axis=1)
        wp = jnp.zeros((FM.P, FM.P), jnp.float32)
        wp = wp.at[: w.shape[0], : w.shape[1]].set(w)
        ws.append(wp)
    return jnp.stack(ws)


def pack_color_weights(params: Dict) -> jnp.ndarray:
    """Pad color weights to (nc, P, P) — input layout [geo, sh] is already
    contiguous so only zero-padding is needed."""
    ws = []
    for w in params["color"]:
        w = w.astype(jnp.float32)
        wp = jnp.zeros((FM.P, FM.P), jnp.float32)
        wp = wp.at[: w.shape[0], : w.shape[1]].set(w)
        ws.append(wp)
    return jnp.stack(ws)


# Padded/permuted weight stacks are pure functions of the weight arrays,
# yet every wrapper used to rebuild them per call — repeated engine
# construction and multi-scene hot-swap re-laid-out identical weights on
# each frame.  Memoized here keyed on weight-array identity (an LRU like
# serve/pool.py's jitted-march cache); the cached entry keeps references
# to the source arrays so their ids cannot be recycled while it lives.
_PACK_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_PACK_LOCK = threading.Lock()
_PACK_MAX = 16
_PACK_STATS = {"hits": 0, "misses": 0}


def packed_weights(params: Dict, cfg: mlp_lib.MLPConfig):
    """Memoized ``(wd, wc)`` padded weight stacks for an mlps param dict."""
    key = (tuple(id(w) for w in params["density"]),
           tuple(id(w) for w in params["color"]), cfg.geo_feature_dim)
    with _PACK_LOCK:
        hit = _PACK_CACHE.get(key)
        if hit is not None:
            _PACK_CACHE.move_to_end(key)
            _PACK_STATS["hits"] += 1
            return hit[0], hit[1]
        _PACK_STATS["misses"] += 1
    wd = pack_density_weights(params, cfg)
    wc = pack_color_weights(params)
    with _PACK_LOCK:
        _PACK_CACHE[key] = (wd, wc,
                            list(params["density"]), list(params["color"]))
        _PACK_CACHE.move_to_end(key)
        while len(_PACK_CACHE) > _PACK_MAX:
            _PACK_CACHE.popitem(last=False)
    return wd, wc


def pack_cache_stats() -> Dict[str, int]:
    with _PACK_LOCK:
        return dict(_PACK_STATS, size=len(_PACK_CACHE))


def _sh_padded(dirs, cfg: mlp_lib.MLPConfig):
    """SH(dirs) placed at cols [G, G+sh_dim) of a (N, P) buffer."""
    sh = mlp_lib.sh_encode(dirs, cfg.sh_degree).astype(jnp.float32)
    n = sh.shape[0]
    buf = jnp.zeros((n, FM.P), jnp.float32)
    return buf.at[:, cfg.geo_feature_dim : cfg.geo_feature_dim + sh.shape[1]].set(sh)


@partial(jax.jit, static_argnames=("geo_dim", "interpret"))
def _fused_field_padded(enc, sh, wd, wc, geo_dim, interpret=INTERPRET):
    return FM.fused_field_call(enc, sh, wd, wc, geo_dim, interpret)


def fused_field(enc, dirs, params: Dict, cfg: mlp_lib.MLPConfig,
                interpret: bool = INTERPRET):
    """(enc (N,D), dirs (N,3)) -> (sigma (N,), rgb (N,3), geo (N,G))."""
    n = enc.shape[0]
    G = cfg.geo_feature_dim
    encp = _pad_cols(enc.astype(jnp.float32), FM.P)
    encp, _ = _pad_rows(encp, FM.TILE)
    shp, _ = _pad_rows(_sh_padded(dirs, cfg), FM.TILE)
    wd, wc = packed_weights(params, cfg)
    out = _fused_field_padded(encp, shp, wd, wc, G, interpret=interpret)[:n]
    return out[:, 0], out[:, 1:4], out[:, 4 : 4 + G]


@partial(jax.jit, static_argnames=("geo_dim", "interpret"))
def _density_padded(enc, wd, geo_dim, interpret=INTERPRET):
    return FM.density_call(enc, wd, geo_dim, interpret)


def density_mlp(enc, params: Dict, cfg: mlp_lib.MLPConfig,
                interpret: bool = INTERPRET):
    """enc (N, D) -> (sigma (N,), geo (N, G))."""
    n = enc.shape[0]
    G = cfg.geo_feature_dim
    encp = _pad_cols(enc.astype(jnp.float32), FM.P)
    encp, _ = _pad_rows(encp, FM.TILE)
    wd, _wc = packed_weights(params, cfg)
    out = _density_padded(encp, wd, G, interpret=interpret)[:n]
    return out[:, 0], out[:, 1 : 1 + G]


@partial(jax.jit, static_argnames=("interpret",))
def _color_padded(cin, wc, interpret=INTERPRET):
    return FM.color_call(cin, wc, interpret)


def color_mlp(geo, dirs, params: Dict, cfg: mlp_lib.MLPConfig,
              interpret: bool = INTERPRET):
    """(geo (N,G), dirs (N,3)) -> rgb (N,3)."""
    n = geo.shape[0]
    G = cfg.geo_feature_dim
    cin = _sh_padded(dirs, cfg).at[:, :G].set(geo.astype(jnp.float32))
    cin, _ = _pad_rows(cin, FM.TILE)
    _wd, wc = packed_weights(params, cfg)
    out = _color_padded(cin, wc, interpret=interpret)[:n]
    return out[:, :3]


# -------------------------------------------------------------- volume render
def volume_render(sigmas, anchor_colors, deltas, group: int,
                  valid=None, white_background: bool = True,
                  interpret: bool = INTERPRET):
    """Decoupled compositing. sigmas/deltas (R,S), anchors (R,A,3) with
    A = ceil(S/group) -> (rgb (R,3), acc (R,))."""
    R, S = sigmas.shape
    A = anchor_colors.shape[1]
    s_pad = -(-S // 128) * 128
    a_pad = -(-A // 128) * 128

    sig = sigmas.astype(jnp.float32)
    if valid is not None:
        sig = jnp.where(valid, sig, 0.0)
    sig = _pad_cols(sig, s_pad)
    dlt = _pad_cols(deltas.astype(jnp.float32), s_pad)
    anch = jnp.transpose(anchor_colors.astype(jnp.float32), (0, 2, 1))  # (R,3,A)
    anch = _pad_cols(anch, a_pad).reshape(R, 3 * a_pad)

    sig, _ = _pad_rows(sig, VR.RTILE)
    dlt, _ = _pad_rows(dlt, VR.RTILE)
    anch, _ = _pad_rows(anch, VR.RTILE)
    E = VR.expansion_matrix(S, s_pad, A, a_pad, group)

    out = VR.volume_render_call(sig, dlt, anch, E, a_pad, interpret)[:R]
    acc = out[:, 0]
    rgb = out[:, 1:4]
    if white_background:
        rgb = rgb + (1.0 - acc[:, None])
    return rgb, acc


# ---------------------------------------------------------------- fused march
# Per-core VMEM budget the resident/streamed auto-select lowers against.
# Re-exported from the kernel module so tests can shrink it (monkeypatch
# THIS name) and force the streamed path on scaled-down shapes.
FUSED_MARCH_VMEM_LIMIT = FMA.VMEM_LIMIT_BYTES


class FusedMarchResources:
    """Device-resident inputs for the fused streaming march kernel.

    A plain class (identity hash/eq, like the FieldFns closures) so a
    FieldFns carrying one stays hashable for serve/pool.py's jitted-march
    LRU.  Holds the grid meta/tables and the memoized packed weight
    stacks — building one is cheap after the first ``packed_weights``
    call for the params.
    """

    def __init__(self, params: Dict, cfg, interpret: bool = INTERPRET):
        self.meta = grid_meta(cfg.grid)
        self.tables = params["grid"].astype(jnp.float32)
        self.wd, self.wc = packed_weights(params["mlps"], cfg.net)
        self.net = cfg.net
        self.interpret = interpret


def fused_march_vmem_bytes(acfg, res: FusedMarchResources,
                           streamed: bool = False) -> int:
    """Estimated VMEM bytes one fused-march grid step holds live.

    The accounting behind the resident/streamed auto-select: the table
    term is the whole (L, T, F) stack when resident but only the
    (2, T, F) double-buffer pair when streamed — at the full config
    (16 x 2^19 x 2 x 4 B = 64 MB stack vs an 8 MB pair against a 16 MB
    VMEM) that difference is exactly why residency cannot ship.  The
    weight stacks, ray/SH tiles, meta and output tile are counted too
    so the select stays honest for fat blocks or deep MLPs.
    """
    L, T, F = res.tables.shape
    B = acfg.block_size
    f32 = 4
    tables = (2 if streamed else L) * T * F * f32
    weights = (res.wd.shape[0] + res.wc.shape[0]) * FMA.P * FMA.P * f32
    rays = 2 * B * FMA.PPAD * f32          # origins + dirs tiles
    sh = B * FMA.P * f32                   # per-ray SH color input
    out = B * FMA.OUT_W * f32
    meta = (L + 1) * 8 * 4                 # grid meta rows + budget row
    return tables + weights + rays + sh + out + meta


def _select_streaming(acfg, res: FusedMarchResources) -> bool:
    """Resolve ``ASDRConfig.march_table_streaming`` to a concrete path.

    "auto" streams exactly when the resident footprint would blow the
    VMEM budget; "resident" is an explicit pin that refuses (rather
    than silently overflows) configs that do not fit.
    """
    mode = getattr(acfg, "march_table_streaming", "auto")
    if mode == "streamed":
        return True
    resident_bytes = fused_march_vmem_bytes(acfg, res, streamed=False)
    if mode == "resident":
        if resident_bytes > FUSED_MARCH_VMEM_LIMIT:
            raise ValueError(
                f"resident fused march needs {resident_bytes} B of VMEM "
                f"(> {FUSED_MARCH_VMEM_LIMIT} B limit); this config only "
                "runs with march_table_streaming='streamed' (or 'auto')")
        return False
    if mode != "auto":
        raise ValueError(f"march_table_streaming={mode!r} not in "
                         "('auto', 'resident', 'streamed')")
    return resident_bytes > FUSED_MARCH_VMEM_LIMIT


def fused_march_blocks(res: FusedMarchResources, acfg, o_b, d_b, budgets,
                       density_only: bool = False):
    """Run the single-kernel streaming march over a batch of blocks.

    o_b/d_b (N, B, 3), budgets (N,) int32 -> (rgb (N,B,3), acc (N,B),
    depth (N,B), chunks (N,), ray_chunks (N,B)) with
    core.pipeline._march_block semantics (same chunk count, budget
    masking, early termination; ray_chunks counts the chunks each ray
    was still live for).  SH features are computed once per RAY here
    (the reference path recomputes them per anchor-sample every chunk)
    and placed at the color-input lanes.  Table supply (VMEM-resident
    stack vs double-buffered DMA streaming) resolves per config via
    ``_select_streaming`` — the two are bit-identical where both run.
    """
    N, B, _ = o_b.shape
    o8 = _pad_cols(o_b.astype(jnp.float32).reshape(N * B, 3), FMA.PPAD)
    d_flat = d_b.astype(jnp.float32).reshape(N * B, 3)
    d8 = _pad_cols(d_flat, FMA.PPAD)
    sh = (jnp.zeros((N * B, FMA.P), jnp.float32)
          if density_only else _sh_padded(d_flat, res.net))
    bud = jnp.zeros((N, 8), jnp.int32).at[:, 0].set(
        budgets.astype(jnp.int32))
    out = FMA.fused_march_call(
        o8, d8, sh, bud, res.meta, res.tables, res.wd, res.wc,
        block_size=B, geo_dim=res.net.geo_feature_dim, group=acfg.group,
        chunk=acfg.chunk, near=scene.NEAR, far=scene.FAR,
        log_eps_t=math.log(rendering.EARLY_TERM_TRANSMITTANCE),
        early_term=acfg.early_termination,
        white_background=acfg.white_background,
        with_color=not density_only,
        stream_tables=_select_streaming(acfg, res),
        per_ray_exit=getattr(acfg, "per_ray_early_exit", False),
        interpret=res.interpret)
    out = out.reshape(N, B, FMA.OUT_W)
    acc = out[:, :, 0]
    rgb = out[:, :, 1:4]
    depth = out[:, :, 4]
    chunks = out[:, 0, 5].astype(jnp.int32)
    ray_chunks = out[:, :, 6].astype(jnp.int32)
    return rgb, acc, depth, chunks, ray_chunks


# ------------------------------------------------------------------- FieldFns
def field_fns(params: Dict, cfg) -> FieldFns:
    """Kernel-backed FieldFns (cfg is core.model.NGPConfig).

    Carries FusedMarchResources so ``ASDRConfig.march_backend="fused"``
    routes Phase II through the single-kernel streaming march.
    """

    def density(points):
        enc = hash_encode(points, params["grid"], cfg.grid)
        sigma, geo = density_mlp(enc, params["mlps"], cfg.net)
        inside = jnp.all((points >= 0.0) & (points <= 1.0), axis=-1)
        return jnp.where(inside, sigma, 0.0), geo

    def color(geo, dirs):
        return color_mlp(geo, dirs, params["mlps"], cfg.net)

    return FieldFns(density=density, color=color,
                    fused=FusedMarchResources(params, cfg))
