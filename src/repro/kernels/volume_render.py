"""Volume-rendering Pallas kernel with fused decoupled-color interpolation —
the TPU analogue of the paper's Volume Rendering Engine (§5.4: approximation
unit + RGB unit in one pass).

CIM insight ported: the paper's approximation unit interpolates non-anchor
colors with dedicated multiplier/adder trees.  On TPU we express the
group-anchor linear interpolation (§4.3) as a *matmul against a constant
expansion matrix* E (A_pad x S_pad): colors_full = anchors @ E.  That turns
the irregular per-sample lerp into one MXU pass and fuses it with Eq. (1)
compositing, so anchor colors never round-trip to HBM.

Numerics: 1 - alpha_i = exp(-sigma_i * delta_i) exactly, so transmittance
T_i = exp(-cumsum_excl(sigma*delta)) — no log/clip needed in-kernel.

Layouts (prepared by ops.py):
  sigmas  (R_pad, S_pad) f32   — padded samples carry sigma = 0 (w = 0)
  deltas  (R_pad, S_pad) f32
  anchors (R_pad, 3*A_pad) f32 — channels packed [r | g | b] along lanes
  E       (A_pad, S_pad) f32   — constant lerp-expansion matrix
  out     (R_pad, P) f32       — col 0 = acc, cols 1..3 = rgb
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

P = 128        # output lane width
RTILE = 128    # rays per block program


def expansion_matrix(S: int, S_pad: int, A: int, A_pad: int, group: int):
    """E[a, j] — lerp weights mapping anchor a to sample j (numpy, const).

    Sample j in group gi = j // group with t = (j % group) / group gets
    (1-t) * anchor[gi] + t * anchor[min(gi+1, A-1)]  (paper §4.3).
    """
    E = np.zeros((A_pad, S_pad), np.float32)
    for j in range(S):
        gi = j // group
        t = (j % group) / group
        E[min(gi, A - 1), j] += 1.0 - t
        E[min(gi + 1, A - 1), j] += t
    return jnp.asarray(E)


def _vr_kernel(sig_ref, del_ref, col_ref, e_ref, out_ref, *, a_pad):
    sd = sig_ref[...] * del_ref[...]                      # (T, S_pad)
    excl = jax.lax.cumsum(sd, axis=1) - sd                # exclusive prefix
    trans = jnp.exp(-excl)
    w = trans * (1.0 - jnp.exp(-sd))                      # weights (T, S_pad)
    acc = jnp.sum(w, axis=1, keepdims=True)               # (T, 1)
    e = e_ref[...]
    chans = []
    for c in range(3):
        anch = col_ref[:, c * a_pad : (c + 1) * a_pad]    # (T, A_pad)
        full = jnp.dot(anch, e, preferred_element_type=jnp.float32)
        chans.append(jnp.sum(w * full, axis=1, keepdims=True))
    packed = jnp.concatenate(
        [acc] + chans + [jnp.zeros((w.shape[0], P - 4), jnp.float32)], axis=1
    )
    out_ref[...] = packed


def volume_render_call(sigmas, deltas, anchors, E, a_pad: int,
                       interpret: bool = True):
    """sigmas/deltas (R, S_pad), anchors (R, 3*A_pad), E (A_pad, S_pad)
    -> packed (R, P) with col0 = acc, cols 1..3 = rgb."""
    R, S_pad = sigmas.shape
    assert R % RTILE == 0, "ops.py pads rays to an RTILE multiple"
    kern = functools.partial(_vr_kernel, a_pad=a_pad)
    return pl.pallas_call(
        kern,
        grid=(R // RTILE,),
        in_specs=[
            pl.BlockSpec((RTILE, S_pad), lambda i: (i, 0)),
            pl.BlockSpec((RTILE, S_pad), lambda i: (i, 0)),
            pl.BlockSpec((RTILE, 3 * a_pad), lambda i: (i, 0)),
            pl.BlockSpec((a_pad, S_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((RTILE, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, P), jnp.float32),
        interpret=interpret,
    )(sigmas, deltas, anchors, E)
