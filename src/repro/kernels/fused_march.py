"""Fused streaming-march Pallas kernel — Phase II as ONE kernel launch.

The paper's CIM array wins (§5.3) come from keeping weights and sample
streams in place: a sample is generated, encoded, pushed through the
MLPs and composited without ever leaving the array.  The chunked
reference march (core/pipeline._march_block) instead calls the encode /
density / color kernels as separate jitted ops per chunk, so every
per-sample encoding and geo feature round-trips through HBM between
launches.  This kernel is the TPU port of the paper's dataflow: per
block program it

  1. generates the chunk's sample positions from the block's rays
     (ray setup is in-register; only origins/dirs/budget are read),
  2. hash-encodes them against the FULL table stack — all L levels are
     co-resident in VMEM for the whole march (hash_encode.py streams
     them once per level; here the march is long enough that residency
     beats streaming, cf. fused_mlp.py's layout notes),
  3. runs the density chain on every sample and the color chain on
     every ``group``-th anchor only — §4.3's decoupling moves INSIDE
     the kernel, so non-anchor colors are lerped in-register,
  4. composites transmittance/rgb/acc/depth and carries the running
     log-transmittance across chunks in a ``while_loop`` with the exact
     early-termination contract of the reference march (same chunk
     count, same budget masking).

Per-sample features (encodings, geo, anchor colors) never exist outside
the kernel.  The only HBM traffic per block is rays in (B x 8 x 2),
per-ray SH in (B x 128, computed ONCE per ray instead of once per
anchor-sample), and the packed (B x 8) result out.

Data layout (prepared by ops.fused_march_blocks):
  o / d    (N*B, PPAD) f32  — rays padded to 8 lanes, one block per
                              grid step
  sh       (N*B, P)    f32  — SH(dir) pre-placed at cols [G, G+S)
  budgets  (N, 8)      i32  — col 0 = per-block sample budget
  meta     (L, 8)      i32  — hash_encode.grid_meta rows
  tables   (L, T, F)   f32  — resident for all grid steps
  wd / wc  (n, P, P)   f32  — fused_mlp packed weights (sigma col
                              permuted to lane G)
  out      (N*B, 8)    f32  — [acc, r, g, b, depth, chunks, 0, 0]

``with_color=False`` is the density-only march (serve/README.md
"density-only march rule"): the color chain and lerp are skipped
entirely and rgb reads 0 — acc/depth/chunks keep full parity with the
reference density-only march.

VMEM accounting (full config): tables 16 levels x 2^19 x 2 x 4 B = 64 MB
exceeds a 16 MB VMEM — the production lowering streams table levels via
double-buffered DMA (guide §17) or shards levels over cores; THIS
container validates in interpret mode where residency is simulated, and
the small test config (8 x 2^14 x 2 = 128 KB) fits outright.  Weights:
(nd+nc) x 64 KB as in fused_mlp.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.hashgrid import PRIMES

P = 128      # padded feature width (MXU lane width) — matches fused_mlp
PPAD = 8     # padded ray row [x, y, z, 0...]    — matches hash_encode
OUT_W = 8    # packed output lanes [acc, r, g, b, depth, chunks, 0, 0]


def _relu(x):
    return jnp.maximum(x, 0.0)


def _trunc_exp(x):
    return jnp.exp(jnp.clip(x, -15.0, 15.0))


def _encode_points(flat, meta, tables, n_levels):
    """In-register hash encode: (M, 3) points -> (M, L*F) features.

    Same math as hash_encode._encode_kernel, but over the whole resident
    table stack (static level unroll) instead of one level per grid step.
    """
    feats_per_level = []
    for level in range(n_levels):
        res = meta[level, 0]
        is_dense = meta[level, 1]
        rows = meta[level, 2]
        table = tables[level]                              # (T, F)

        scaled = flat * res.astype(jnp.float32)
        base = jnp.clip(jnp.floor(scaled).astype(jnp.int32), 0, res - 1)
        frac = scaled - base.astype(jnp.float32)           # (M, 3)

        acc = jnp.zeros((flat.shape[0], table.shape[-1]), jnp.float32)
        for c in range(8):
            ox, oy, oz = (c >> 2) & 1, (c >> 1) & 1, c & 1
            cx = (base[:, 0] + ox).astype(jnp.uint32)
            cy = (base[:, 1] + oy).astype(jnp.uint32)
            cz = (base[:, 2] + oz).astype(jnp.uint32)
            stride = (res + 1).astype(jnp.uint32)
            dense_idx = cx + stride * (cy + stride * cz)
            h = cx * np.uint32(PRIMES[0])
            h = h ^ (cy * np.uint32(PRIMES[1]))
            h = h ^ (cz * np.uint32(PRIMES[2]))
            hash_idx = h % rows.astype(jnp.uint32)
            idx = jnp.where(is_dense > 0, dense_idx,
                            hash_idx).astype(jnp.int32)
            f = table[idx]                                 # (M, F) gather
            wx = frac[:, 0] if ox else 1.0 - frac[:, 0]
            wy = frac[:, 1] if oy else 1.0 - frac[:, 1]
            wz = frac[:, 2] if oz else 1.0 - frac[:, 2]
            acc = acc + f.astype(jnp.float32) * (wx * wy * wz)[:, None]
        feats_per_level.append(acc)
    return jnp.concatenate(feats_per_level, axis=-1)       # (M, L*F)


def _chains(x, w, n, final=None):
    for i in range(n):
        x = jnp.dot(x, w[i], preferred_element_type=jnp.float32)
        if i < n - 1:
            x = _relu(x)
    return final(x) if final is not None else x


def _march_kernel(o_ref, d_ref, sh_ref, bud_ref, meta_ref, tables_ref,
                  wd_ref, wc_ref, out_ref, *, nd, nc, geo_dim, group,
                  chunk, n_levels, near, far, log_eps_t, early_term,
                  white_background, with_color):
    B = o_ref.shape[0]
    C = chunk
    # read every ref up front: the loop body then touches only values
    # (tables/weights stay resident; no ref reads inside the while_loop)
    o = o_ref[...][:, :3]
    d = d_ref[...][:, :3]
    sh = sh_ref[...]
    budget = bud_ref[0]
    meta = meta_ref[...]
    tables = tables_ref[...]
    wd = wd_ref[...]
    wc = wc_ref[...]

    delta_t = (far - near) / budget.astype(jnp.float32)
    n_chunks = (budget + C - 1) // C

    # static per-chunk anchor geometry (§4.3 decoupling, in-kernel);
    # indices stay python ints — a pallas kernel cannot capture constant
    # index ARRAYS, so anchor selection / lerp expansion unroll over C
    a_idx = [int(i) for i in range(0, C, group)]
    A = len(a_idx)
    lerp_l = [min(j // group, A - 1) for j in range(C)]
    lerp_r = [min(j // group + 1, A - 1) for j in range(C)]
    lerp_t = [float((j % group) / group) for j in range(C)]

    def cond(state):
        ci, log_t = state[0], state[1]
        if not early_term:
            return ci < n_chunks
        return jnp.logical_and(ci < n_chunks, jnp.any(log_t > log_eps_t))

    def body(state):
        ci, log_t, rgb, acc, dep = state
        idx = ci * C + jnp.arange(C)
        valid = idx < budget
        ts = near + (idx.astype(jnp.float32) + 0.5) * delta_t
        pts = o[:, None, :] + ts[None, :, None] * d[:, None, :]  # (B, C, 3)
        flat = pts.reshape(B * C, 3)

        enc = _encode_points(flat, meta, tables, n_levels)   # (M, L*F)
        enc = jnp.concatenate(
            [enc, jnp.zeros((B * C, P - enc.shape[-1]), jnp.float32)],
            axis=-1)
        dout = _chains(enc, wd, nd)                          # (M, P)
        sigma = _trunc_exp(dout[:, geo_dim]).reshape(B, C)
        inside = jnp.all((flat >= 0.0) & (flat <= 1.0),
                         axis=-1).reshape(B, C)
        sigma = jnp.where(inside & valid[None, :], sigma, 0.0)

        if with_color:
            lane = jax.lax.broadcasted_iota(jnp.int32, dout.shape, 1)
            geo = jnp.where(lane < geo_dim, dout, 0.0)
            geo3 = geo.reshape(B, C, P)
            geo_a = jnp.stack([geo3[:, i] for i in a_idx], axis=1)
            cin = (geo_a + sh[:, None, :]).reshape(B * A, P)
            rgb_a = _chains(cin, wc, nc,
                            final=jax.nn.sigmoid)[:, :3].reshape(B, A, 3)
            colors = jnp.stack(
                [rgb_a[:, lerp_l[j]]
                 + (rgb_a[:, lerp_r[j]] - rgb_a[:, lerp_l[j]]) * lerp_t[j]
                 for j in range(C)], axis=1)

        alphas = 1.0 - jnp.exp(-sigma * delta_t)
        one_m = jnp.clip(1.0 - alphas, 1e-10, 1.0)
        log_steps = jnp.log(one_m)
        intra = jnp.cumsum(log_steps, axis=-1) - log_steps   # exclusive
        trans = jnp.exp(log_t[:, None] + intra)
        w = trans * alphas
        if with_color:
            rgb = rgb + jnp.sum(w[..., None] * colors, axis=1)
        acc = acc + jnp.sum(w, axis=-1)
        dep = dep + jnp.sum(w * ts[None, :], axis=-1)
        log_t = log_t + jnp.sum(log_steps, axis=-1)
        return ci + 1, log_t, rgb, acc, dep

    state = (
        jnp.asarray(0, jnp.int32),
        jnp.zeros((B,)),
        jnp.zeros((B, 3)),
        jnp.zeros((B,)),
        jnp.zeros((B,)),
    )
    ci, _, rgb, acc, dep = jax.lax.while_loop(cond, body, state)
    depth = dep + (1.0 - acc) * far
    if with_color and white_background:
        rgb = rgb + (1.0 - acc[:, None])
    out_ref[...] = jnp.concatenate(
        [acc[:, None], rgb, depth[:, None],
         jnp.broadcast_to(ci.astype(jnp.float32), (B,))[:, None],
         jnp.zeros((B, OUT_W - 6), jnp.float32)], axis=1)


def fused_march_call(o, d, sh, budgets, meta, tables, wd, wc, *,
                     block_size, geo_dim, group, chunk, near, far,
                     log_eps_t, early_term, white_background,
                     with_color, interpret=True):
    """o/d (N*B, PPAD), sh (N*B, P), budgets (N, 8) i32, meta (L, 8) i32,
    tables (L, T, F), wd (nd,P,P), wc (nc,P,P) -> packed (N*B, OUT_W)."""
    B = block_size
    n_blocks = budgets.shape[0]
    assert o.shape[0] == n_blocks * B, "one budget row per block"
    L, T, F = tables.shape
    kern = functools.partial(
        _march_kernel, nd=wd.shape[0], nc=wc.shape[0], geo_dim=geo_dim,
        group=group, chunk=chunk, n_levels=L, near=near, far=far,
        log_eps_t=log_eps_t, early_term=early_term,
        white_background=white_background, with_color=with_color)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((B, PPAD), lambda i: (i, 0)),
            pl.BlockSpec((B, PPAD), lambda i: (i, 0)),
            pl.BlockSpec((B, P), lambda i: (i, 0)),
            pl.BlockSpec((None, 8), lambda i: (i, 0)),
            pl.BlockSpec((L, 8), lambda i: (0, 0)),
            pl.BlockSpec((L, T, F), lambda i: (0, 0, 0)),
            pl.BlockSpec((wd.shape[0], P, P), lambda i: (0, 0, 0)),
            pl.BlockSpec((wc.shape[0], P, P), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, OUT_W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * B, OUT_W), jnp.float32),
        interpret=interpret,
    )(o, d, sh, budgets, meta, tables, wd, wc)
