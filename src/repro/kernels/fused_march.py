"""Fused streaming-march Pallas kernel — Phase II as ONE kernel launch.

The paper's CIM array wins (§5.3) come from keeping weights and sample
streams in place: a sample is generated, encoded, pushed through the
MLPs and composited without ever leaving the array.  The chunked
reference march (core/pipeline._march_block) instead calls the encode /
density / color kernels as separate jitted ops per chunk, so every
per-sample encoding and geo feature round-trips through HBM between
launches.  This kernel is the TPU port of the paper's dataflow: per
block program it

  1. generates the chunk's sample positions from the block's rays
     (ray setup is in-register; only origins/dirs/budget are read),
  2. hash-encodes them against the table stack — either all L levels
     co-resident in VMEM for the whole march (small configs), or, at
     production table sizes, STREAMED level-by-level through a
     double-buffered ping/pong pair of level-sized VMEM buffers with
     async DMA: level l+1's copy is launched while level l encodes, so
     the table working set in VMEM is two levels, never the stack,
  3. runs the density chain on every sample and the color chain on
     every ``group``-th anchor only — §4.3's decoupling moves INSIDE
     the kernel; anchor selection and the non-anchor lerp are lowered
     to the lane-shuffle idiom (iota-built one-hot matmuls on the MXU)
     instead of a static C-way unroll,
  4. composites transmittance/rgb/acc/depth and carries the running
     log-transmittance across chunks in a ``while_loop`` with the exact
     early-termination contract of the reference march (same chunk
     count, same budget masking), emitting per-RAY chunks_done so the
     serve layer can account (and, with
     ``ASDRConfig.per_ray_early_exit``, actually stop) the sample work
     of rays that saturate before their block does.

Per-sample features (encodings, geo, anchor colors) never exist outside
the kernel.  The only HBM traffic per block is rays in (B x 8 x 2),
per-ray SH in (B x 128, computed ONCE per ray instead of once per
anchor-sample), the packed (B x 8) result out — plus, under streaming,
the level DMAs (2 x T x F in flight, overlapped with encode compute).

Data layout (prepared by ops.fused_march_blocks):
  o / d    (N*B, PPAD) f32  — rays padded to 8 lanes, one block per
                              grid step
  sh       (N*B, P)    f32  — SH(dir) pre-placed at cols [G, G+S)
  budgets  (N, 8)      i32  — col 0 = per-block sample budget
  meta     (L, 8)      i32  — hash_encode.grid_meta rows
  tables   (L, T, F)   f32  — resident: VMEM for all grid steps;
                              streamed: stays in HBM (ANY memory
                              space), DMA'd per level into a
                              (2, T, F) VMEM scratch ping/pong pair
  wd / wc  (n, P, P)   f32  — fused_mlp packed weights (sigma col
                              permuted to lane G)
  out      (N*B, 8)    f32  — [acc, r, g, b, depth, block_chunks,
                              ray_chunks, 0]

``with_color=False`` is the density-only march (serve/README.md
"density-only march rule"): the color chain and lerp are skipped
entirely and rgb reads 0 — acc/depth/chunks keep full parity with the
reference density-only march.

VMEM accounting (ops.fused_march_vmem_bytes is the ledger): the full
config's table stack (16 levels x 2^19 x 2 x 4 B = 64 MB) exceeds a
16 MB VMEM, so residency cannot ship at production scale — the
STREAMED lowering above runs it with a 2 x T x F = 8 MB working pair.
ops.fused_march_blocks auto-selects per config: resident whenever the
stack fits (bit-identical to streamed — same gather math against the
same bytes, gated by tests), streamed otherwise.  The small test
config (8 x 2^14 x 2 = 128 KB) stays resident.  Weights: (nd+nc) x
64 KB as in fused_mlp.py.  This container validates both paths in
interpret mode (the DMA ping/pong included); on hardware the same
kernel lowers with real async copies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import hash_encode as HE

P = 128      # padded feature width (MXU lane width) — matches fused_mlp
PPAD = 8     # padded ray row [x, y, z, 0...]    — matches hash_encode
OUT_W = 8    # packed output lanes [acc, r, g, b, depth, chunks, ray_chunks, 0]

# per-core VMEM the auto-select lowers against (16 MB on current TPUs);
# tests shrink it via ops.py to force the streamed path on small shapes
VMEM_LIMIT_BYTES = 16 * 2 ** 20


def _relu(x):
    return jnp.maximum(x, 0.0)


def _trunc_exp(x):
    return jnp.exp(jnp.clip(x, -15.0, 15.0))


def _encode_points(flat, meta, read_level, n_levels):
    """In-register hash encode: (M, 3) points -> (M, L*F) features.

    Same per-level math as hash_encode (shared ``encode_level``), with
    the table source abstracted: ``read_level(l)`` returns level ``l``'s
    (T, F) block — a slice of the resident stack, or the streamed DMA
    ping/pong slot that was just waited on.
    """
    feats_per_level = []
    for level in range(n_levels):
        feats_per_level.append(HE.encode_level(
            flat, meta[level, 0], meta[level, 1], meta[level, 2],
            read_level(level)))
    return jnp.concatenate(feats_per_level, axis=-1)       # (M, L*F)


def _chains(x, w, n, final=None):
    for i in range(n):
        x = jnp.dot(x, w[i], preferred_element_type=jnp.float32)
        if i < n - 1:
            x = _relu(x)
    return final(x) if final is not None else x


def _anchor_select(C, A, group):
    """(A, C) one-hot anchor-pick matrix, built from 2-D iotas (the
    lane-shuffle idiom: a gather expressed as an MXU matmul, no
    C-way python unroll and no captured index-array constants)."""
    a_io = jax.lax.broadcasted_iota(jnp.int32, (A, C), 0)
    c_io = jax.lax.broadcasted_iota(jnp.int32, (A, C), 1)
    return (c_io == a_io * group).astype(jnp.float32)


def _lerp_expand(C, A, group):
    """(C, A) lerp-expansion matrix: row j carries weight (1 - t_j) on
    its left anchor and t_j on its right (clamped at the tail), so
    expanding anchors to all samples is one matmul —
    decouple.interpolate_group_colors as a lane shuffle."""
    j_io = jax.lax.broadcasted_iota(jnp.int32, (C, A), 0)
    a_io = jax.lax.broadcasted_iota(jnp.int32, (C, A), 1)
    gi = jnp.minimum(j_io // group, A - 1)
    ri = jnp.minimum(j_io // group + 1, A - 1)
    t = (j_io % group).astype(jnp.float32) / group
    return (jnp.where(a_io == gi, 1.0 - t, 0.0)
            + jnp.where(a_io == ri, t, 0.0))


def _march_impl(o_ref, d_ref, sh_ref, bud_ref, meta_ref, wd_ref, wc_ref,
                out_ref, encode, *, nd, nc, geo_dim, group, chunk,
                n_levels, near, far, log_eps_t, early_term, per_ray_exit,
                white_background, with_color):
    """The march body, table access abstracted behind ``encode(flat)``.

    Shared verbatim by the resident and streamed kernels — residency is
    a table-supply strategy, never a semantics change, which is what
    makes streamed-vs-resident bit-identity a testable contract.
    """
    B = o_ref.shape[0]
    C = chunk
    # read every ref up front: the loop body then touches only values
    # (weights stay resident; table refs are read through ``encode``)
    o = o_ref[...][:, :3]
    d = d_ref[...][:, :3]
    sh = sh_ref[...]
    budget = bud_ref[0]
    wd = wd_ref[...]
    wc = wc_ref[...]

    delta_t = (far - near) / budget.astype(jnp.float32)
    n_chunks = (budget + C - 1) // C
    A = len(range(0, C, group))

    def cond(state):
        ci, log_t = state[0], state[1]
        if not early_term:
            return ci < n_chunks
        return jnp.logical_and(ci < n_chunks, jnp.any(log_t > log_eps_t))

    def body(state):
        ci, log_t, rgb, acc, dep, ray_chunks = state
        # per-ray liveness at chunk start: saturated rays stop counting
        # toward ray_chunks, and — with per_ray_exit — stop contributing
        # sample work entirely (their sigma is masked, freezing log_t);
        # block-level exit timing is unchanged either way, because a
        # dead ray's log_t can never rise back above the threshold
        alive = log_t > log_eps_t
        idx = ci * C + jnp.arange(C)
        valid = idx < budget
        ts = near + (idx.astype(jnp.float32) + 0.5) * delta_t
        pts = o[:, None, :] + ts[None, :, None] * d[:, None, :]  # (B, C, 3)
        flat = pts.reshape(B * C, 3)

        enc = encode(flat)                                   # (M, L*F)
        enc = jnp.concatenate(
            [enc, jnp.zeros((B * C, P - enc.shape[-1]), jnp.float32)],
            axis=-1)
        dout = _chains(enc, wd, nd)                          # (M, P)
        sigma = _trunc_exp(dout[:, geo_dim]).reshape(B, C)
        inside = jnp.all((flat >= 0.0) & (flat <= 1.0),
                         axis=-1).reshape(B, C)
        sigma = jnp.where(inside & valid[None, :], sigma, 0.0)
        if per_ray_exit:
            sigma = jnp.where(alive[:, None], sigma, 0.0)

        if with_color:
            lane = jax.lax.broadcasted_iota(jnp.int32, dout.shape, 1)
            geo = jnp.where(lane < geo_dim, dout, 0.0)
            geo3 = geo.reshape(B, C, P)
            # anchor pick + lerp expansion as one-hot matmuls (the
            # lane-shuffle idiom) — no static C-way stack unrolls
            sel = _anchor_select(C, A, group)                # (A, C)
            geo_a = jnp.einsum("ac,bcp->bap", sel, geo3,
                               preferred_element_type=jnp.float32)
            cin = (geo_a + sh[:, None, :]).reshape(B * A, P)
            rgb_a = _chains(cin, wc, nc,
                            final=jax.nn.sigmoid)[:, :3].reshape(B, A, 3)
            lerp = _lerp_expand(C, A, group)                 # (C, A)
            colors = jnp.einsum("ca,bax->bcx", lerp, rgb_a,
                                preferred_element_type=jnp.float32)

        alphas = 1.0 - jnp.exp(-sigma * delta_t)
        one_m = jnp.clip(1.0 - alphas, 1e-10, 1.0)
        log_steps = jnp.log(one_m)
        intra = jnp.cumsum(log_steps, axis=-1) - log_steps   # exclusive
        trans = jnp.exp(log_t[:, None] + intra)
        w = trans * alphas
        if with_color:
            rgb = rgb + jnp.sum(w[..., None] * colors, axis=1)
        acc = acc + jnp.sum(w, axis=-1)
        dep = dep + jnp.sum(w * ts[None, :], axis=-1)
        log_t = log_t + jnp.sum(log_steps, axis=-1)
        ray_chunks = ray_chunks + alive.astype(jnp.int32)
        return ci + 1, log_t, rgb, acc, dep, ray_chunks

    state = (
        jnp.asarray(0, jnp.int32),
        jnp.zeros((B,)),
        jnp.zeros((B, 3)),
        jnp.zeros((B,)),
        jnp.zeros((B,)),
        jnp.zeros((B,), jnp.int32),
    )
    ci, _, rgb, acc, dep, ray_chunks = jax.lax.while_loop(cond, body, state)
    depth = dep + (1.0 - acc) * far
    if with_color and white_background:
        rgb = rgb + (1.0 - acc[:, None])
    out_ref[...] = jnp.concatenate(
        [acc[:, None], rgb, depth[:, None],
         jnp.broadcast_to(ci.astype(jnp.float32), (B,))[:, None],
         ray_chunks.astype(jnp.float32)[:, None],
         jnp.zeros((B, OUT_W - 7), jnp.float32)], axis=1)


def _march_kernel_resident(o_ref, d_ref, sh_ref, bud_ref, meta_ref,
                           tables_ref, wd_ref, wc_ref, out_ref, **kw):
    """All L levels VMEM-resident for the whole march (small configs)."""
    meta = meta_ref[...]
    tables = tables_ref[...]
    _march_impl(o_ref, d_ref, sh_ref, bud_ref, meta_ref, wd_ref, wc_ref,
                out_ref,
                lambda flat: _encode_points(flat, meta,
                                            lambda l: tables[l],
                                            kw["n_levels"]), **kw)


def _march_kernel_streamed(o_ref, d_ref, sh_ref, bud_ref, meta_ref,
                           tables_ref, wd_ref, wc_ref, out_ref,
                           tbuf, sem, **kw):
    """Production lowering: tables stay in HBM; each encode streams the
    stack through a double-buffered (2, T, F) VMEM scratch pair.

    Per level l the DMA for level l+1 is launched BEFORE waiting on
    level l, so the next copy is in flight while the current level's
    gathers and trilinear blend run — the §5.2 data-reuse dataflow with
    the table stream (not the sample stream) flowing past the compute.
    Ping/pong slot l % 2 is safe at any L (odd included): slot reuse is
    always two levels apart, and level l-1's slot was fully consumed
    before level l+1's copy into it starts.
    """
    meta = meta_ref[...]

    def copy(level, slot):
        return pltpu.make_async_copy(
            tables_ref.at[level], tbuf.at[slot], sem.at[slot])

    def read_level(level):
        if level + 1 < kw["n_levels"]:
            copy(level + 1, (level + 1) % 2).start()
        copy(level, level % 2).wait()
        return tbuf[level % 2]

    def encode(flat):
        copy(0, 0).start()                    # warm-up: first level
        return _encode_points(flat, meta, read_level, kw["n_levels"])

    _march_impl(o_ref, d_ref, sh_ref, bud_ref, meta_ref, wd_ref, wc_ref,
                out_ref, encode, **kw)


def fused_march_call(o, d, sh, budgets, meta, tables, wd, wc, *,
                     block_size, geo_dim, group, chunk, near, far,
                     log_eps_t, early_term, white_background,
                     with_color, stream_tables=False, per_ray_exit=False,
                     interpret=True):
    """o/d (N*B, PPAD), sh (N*B, P), budgets (N, 8) i32, meta (L, 8) i32,
    tables (L, T, F), wd (nd,P,P), wc (nc,P,P) -> packed (N*B, OUT_W).

    ``stream_tables`` selects the table supply: False keeps the stack
    VMEM-resident (bit-identical baseline for configs that fit), True
    runs the double-buffered DMA streaming path (the only option at
    full-config table sizes — see ops.fused_march_vmem_bytes).
    """
    B = block_size
    n_blocks = budgets.shape[0]
    assert o.shape[0] == n_blocks * B, "one budget row per block"
    L, T, F = tables.shape
    kw = dict(nd=wd.shape[0], nc=wc.shape[0], geo_dim=geo_dim,
              group=group, chunk=chunk, n_levels=L, near=near, far=far,
              log_eps_t=log_eps_t, early_term=early_term,
              per_ray_exit=per_ray_exit,
              white_background=white_background, with_color=with_color)
    if stream_tables:
        kern = functools.partial(_march_kernel_streamed, **kw)
        tables_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((2, T, F), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kern = functools.partial(_march_kernel_resident, **kw)
        tables_spec = pl.BlockSpec((L, T, F), lambda i: (0, 0, 0))
        scratch = []
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((B, PPAD), lambda i: (i, 0)),
            pl.BlockSpec((B, PPAD), lambda i: (i, 0)),
            pl.BlockSpec((B, P), lambda i: (i, 0)),
            pl.BlockSpec((None, 8), lambda i: (i, 0)),
            pl.BlockSpec((L, 8), lambda i: (0, 0)),
            tables_spec,
            pl.BlockSpec((wd.shape[0], P, P), lambda i: (0, 0, 0)),
            pl.BlockSpec((wc.shape[0], P, P), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, OUT_W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * B, OUT_W), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(o, d, sh, budgets, meta, tables, wd, wc)
