"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` mirrors the kernel's *public wrapper* semantics (ops.py), so
tests can sweep shapes/dtypes and ``assert_allclose(kernel, ref)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import hashgrid as hg
from ..core import decouple as dec
from ..core import mlp as mlp_lib
from ..core import rendering


# ---------------------------------------------------------------- hash encode
def ref_hash_encode(points, tables, resolutions, dense_flags):
    """points (N,3) in [0,1], tables (L,T,F) -> features (L, N, F).

    resolutions/dense_flags: python sequences of length L (static).
    """
    outs = []
    for l, (res, dense) in enumerate(zip(resolutions, dense_flags)):
        outs.append(hg.encode_level(points, tables[l], int(res), bool(dense)))
    return jnp.stack(outs, axis=0)


# ------------------------------------------------------------------ fused MLP
def relu(x):
    return jnp.maximum(x, 0.0)


def ref_density_mlp(enc, wd):
    """enc (N, D) x list of density weights -> (sigma (N,), geo (N, G))."""
    x = enc
    for i, w in enumerate(wd):
        x = x @ w
        if i < len(wd) - 1:
            x = relu(x)
    sigma = mlp_lib.trunc_exp(x[..., 0])
    return sigma, x[..., 1:]


def ref_color_mlp(geo, sh, wc):
    """(geo (N,G), sh (N,S)) x color weights -> rgb (N,3) in [0,1]."""
    x = jnp.concatenate([geo, sh], axis=-1)
    for i, w in enumerate(wc):
        x = x @ w
        if i < len(wc) - 1:
            x = relu(x)
    return jax.nn.sigmoid(x)


def ref_fused_field(enc, sh, wd, wc):
    """Full density->color chain. Returns (sigma (N,), rgb (N,3), geo)."""
    sigma, geo = ref_density_mlp(enc, wd)
    rgb = ref_color_mlp(geo, sh, wc)
    return sigma, rgb, geo


# --------------------------------------------------------------- fused march
def ref_fused_march(fns, acfg, o_b, d_b, budgets, density_only=False):
    """Oracle for kernels/fused_march.py: the chunked reference march
    (core/pipeline._march_block) over a pure-jnp FieldFns — the exact
    while_loop early-termination contract the fused kernel must keep
    (chunks_done equality is asserted, not just value closeness)."""
    from ..core import pipeline

    march = lambda a: pipeline._march_block(  # noqa: E731
        fns, acfg, *a, density_only=density_only)
    return jax.lax.map(march, (o_b, d_b, budgets))


# -------------------------------------------------------------- volume render
def ref_volume_render(sigmas, anchor_colors, deltas, group: int,
                      valid=None, white_background: bool = True):
    """Decoupled volume rendering oracle.

    sigmas (R, S); anchor_colors (R, A, 3) with A = ceil(S/group);
    deltas (R, S).  Expands anchors by lerp (paper §4.3) then composites
    Eq. (1).  Returns (rgb (R,3), acc (R,)).
    """
    S = sigmas.shape[-1]
    colors = dec.interpolate_group_colors(anchor_colors, group, S)
    rgb, acc, _ = rendering.composite(
        sigmas, colors, deltas, valid=valid, white_background=white_background
    )
    return rgb, acc
