"""Locality profiling & cache simulation — paper Figs. 4, 8, 15, 22 (§5.2.2).

The paper motivates its register-based cache and hybrid address mapping by
profiling (a) hash-address irregularity (Fig. 4), (b) color similarity of
adjacent samples (Fig. 8), (c) inter-ray / intra-ray voxel repetition
(Fig. 15), and (d) cache-size sensitivity (Fig. 22).  This module computes
each profile for our scenes/models; benchmarks/locality.py and
benchmarks/reuse_cache.py report them.

On TPU the "register cache" becomes tile-local gather dedup (DESIGN.md §2);
``dedup_window_rate`` measures exactly the win available to a tile of a
given size, which is how we size the Pallas encode kernel's block.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Sequence

import jax.numpy as jnp
import numpy as np

from . import hashgrid


def hash_address_trace(points: jnp.ndarray, cfg: hashgrid.HashGridConfig,
                       level: int) -> np.ndarray:
    """Table-row addresses of the 8 corners for consecutive points (Fig. 4).

    Returns (N, 8) int32 addresses for the given level.
    """
    res = cfg.level_resolution(level)
    scaled = points * res
    base = jnp.clip(jnp.floor(scaled).astype(jnp.int32), 0, res - 1)
    corners = base[:, None, :] + hashgrid._corner_offsets()[None, :, :]
    idx = hashgrid.level_indices(
        corners, res, cfg.level_is_dense(level), cfg.table_size
    )
    return np.asarray(idx)


def adjacent_color_cosine(colors: jnp.ndarray) -> np.ndarray:
    """Cosine similarity between colors of adjacent samples along rays.

    colors: (R, S, 3).  Returns flat array of cosines (Fig. 8: paper finds
    >95% of mass near 1).
    """
    a = np.asarray(colors[:, :-1])
    b = np.asarray(colors[:, 1:])
    num = (a * b).sum(-1)
    den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1) + 1e-9
    return (num / den).reshape(-1)


def inter_ray_repetition(points_a: jnp.ndarray, points_b: jnp.ndarray,
                         cfg: hashgrid.HashGridConfig) -> np.ndarray:
    """Fraction of ray-b samples whose voxel (per level) also appears on
    ray-a (Fig. 15a: neighboring rays share >90% of voxels at low res).

    points_*: (S, 3) samples of two neighboring rays.
    Returns (n_levels,) repetition rates.
    """
    ids_a = np.asarray(hashgrid.level_voxel_ids(points_a, cfg))
    ids_b = np.asarray(hashgrid.level_voxel_ids(points_b, cfg))
    rates = []
    for l in range(cfg.n_levels):
        rates.append(np.isin(ids_b[:, l], ids_a[:, l]).mean())
    return np.asarray(rates)


def intra_ray_max_voxel_count(points: jnp.ndarray,
                              cfg: hashgrid.HashGridConfig) -> np.ndarray:
    """Max #samples sharing one voxel, per level (Fig. 15b: 98/192 at L0)."""
    ids = np.asarray(hashgrid.level_voxel_ids(points, cfg))
    out = []
    for l in range(cfg.n_levels):
        _, counts = np.unique(ids[:, l], return_counts=True)
        out.append(counts.max())
    return np.asarray(out)


def lru_cache_hit_rate(addresses: np.ndarray, cache_items: int) -> float:
    """Simulate the paper's per-table LRU register cache (Fig. 22).

    addresses: flat int array in access order.  Returns hit rate.
    """
    if cache_items <= 0:
        return 0.0
    cache: OrderedDict = OrderedDict()
    hits = 0
    for a in addresses.reshape(-1).tolist():
        if a in cache:
            hits += 1
            cache.move_to_end(a)
        else:
            cache[a] = True
            if len(cache) > cache_items:
                cache.popitem(last=False)
    return hits / max(addresses.size, 1)


def cache_sweep(points: jnp.ndarray, cfg: hashgrid.HashGridConfig,
                sizes: Sequence[int] = (0, 2, 4, 8, 16, 32)) -> Dict[int, np.ndarray]:
    """Hit rate per (cache size, level) — reproduces Fig. 22's shape."""
    out = {}
    for s in sizes:
        rates = []
        for l in range(cfg.n_levels):
            tr = hash_address_trace(points, cfg, l)
            rates.append(lru_cache_hit_rate(tr, s))
        out[s] = np.asarray(rates)
    return out


def dedup_window_rate(points: jnp.ndarray, cfg: hashgrid.HashGridConfig,
                      window: int, level: int) -> float:
    """Fraction of corner-gathers inside a `window`-sample tile that are
    duplicates of an earlier gather in the same tile.

    This is the available win for the Pallas encode kernel's tile-local
    staging buffer (the TPU analogue of the register cache): a rate of r
    means the kernel needs only (1-r) of the naive HBM gather traffic.
    """
    tr = hash_address_trace(points, cfg, level)  # (N, 8)
    N = tr.shape[0]
    dup = 0
    total = 0
    for s in range(0, N, window):
        tile = tr[s : s + window].reshape(-1)
        total += tile.size
        dup += tile.size - np.unique(tile).size
    return dup / max(total, 1)


def gather_bytes(n_points: int, cfg: hashgrid.HashGridConfig,
                 dedup_rate: float = 0.0, bytes_per_feat: int = 4) -> float:
    """Embedding-gather traffic for n_points samples (all levels, 8 corners),
    optionally after dedup — the paper's 'data access' currency."""
    per_point = cfg.n_levels * 8 * cfg.feature_dim * bytes_per_feat
    return n_points * per_point * (1.0 - dedup_rate)
