"""Instant-NGP neural field assembly + the baseline (paper's "original") renderer.

`NGPConfig` bundles the hash-grid and MLP configs.  `paper_mlp=True` uses a
color head sized so the density:color FLOP split matches the paper's
reported 8%:92% (§3 Challenge 2); the default matches the open-source
Instant-NGP sizes (64-wide, 1+2 hidden layers, ~33%:67%).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from . import hashgrid, mlp, scene


@dataclasses.dataclass(frozen=True)
class NGPConfig:
    grid: hashgrid.HashGridConfig = hashgrid.HashGridConfig()
    net: mlp.MLPConfig = mlp.MLPConfig()

    @staticmethod
    def make(
        n_levels=16, log2_table_size=19, feature_dim=2,
        base_resolution=16, max_resolution=2048, paper_mlp=False,
    ) -> "NGPConfig":
        grid = hashgrid.HashGridConfig(
            n_levels=n_levels, log2_table_size=log2_table_size,
            feature_dim=feature_dim, base_resolution=base_resolution,
            max_resolution=max_resolution,
        )
        if paper_mlp:
            net = mlp.MLPConfig(
                encoding_dim=grid.output_dim, color_hidden=128, color_layers=3
            )
        else:
            net = mlp.MLPConfig(encoding_dim=grid.output_dim)
        return NGPConfig(grid=grid, net=net)

    @staticmethod
    def small(paper_mlp=False) -> "NGPConfig":
        """CPU-trainable config used by examples/tests (full config is used
        by the dry-run via ShapeDtypeStructs only)."""
        return NGPConfig.make(
            n_levels=8, log2_table_size=14, max_resolution=256,
            paper_mlp=paper_mlp,
        )


def init_ngp(key: jax.Array, cfg: NGPConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "grid": hashgrid.init_hashgrid(k1, cfg.grid),
        "mlps": mlp.init_mlps(k2, cfg.net),
    }


def query_density(params: Dict, cfg: NGPConfig, points: jnp.ndarray):
    """points (N,3) -> (sigma (N,), geo_feat (N, geo))  — zero outside cube."""
    enc = hashgrid.encode(points, params["grid"], cfg.grid)
    sigma, geo = mlp.density_apply(params["mlps"], enc)
    inside = jnp.all((points >= 0.0) & (points <= 1.0), axis=-1)
    return jnp.where(inside, sigma, 0.0), geo


def query_color(params: Dict, cfg: NGPConfig, geo_feat, dirs):
    return mlp.color_apply(params["mlps"], geo_feat, dirs, cfg.net.sh_degree)


def query_field(params: Dict, cfg: NGPConfig, points, dirs):
    sigma, geo = query_density(params, cfg, points)
    color = query_color(params, cfg, geo, dirs)
    return sigma, color


def render_fixed(
    params: Dict, cfg: NGPConfig, origins, dirs, n_samples: int, key=None,
    white_background: bool = True,
):
    """The paper's baseline: fixed `n_samples` per ray, full MLP per sample.

    Returns (rgb (R,3), aux dict with per-sample sigmas/colors/deltas for
    the adaptive-sampling probe pass to reuse).
    """
    from . import pipeline

    return pipeline.render_fixed_fns(
        field_fns(params, cfg), origins, dirs, n_samples, key,
        white_background=white_background,
    )


def field_fns(params: Dict, cfg: NGPConfig):
    """Bind (params, cfg) into the pipeline's FieldFns protocol."""
    from . import fields

    return fields.FieldFns(
        density=lambda pts: query_density(params, cfg, pts),
        color=lambda geo, dirs: query_color(params, cfg, geo, dirs),
    )


def render_image(params, cfg, cam, n_samples=128, chunk=4096, renderer=None):
    """Render a full image in ray chunks (host loop; memory-bounded)."""
    o, d = scene.camera_rays(cam)
    render = renderer or (
        lambda oo, dd: render_fixed(params, cfg, oo, dd, n_samples)[0]
    )
    outs = []
    for s in range(0, o.shape[0], chunk):
        outs.append(render(o[s : s + chunk], d[s : s + chunk]))
    img = jnp.concatenate(outs, axis=0)
    return img.reshape(cam.height, cam.width, 3)
