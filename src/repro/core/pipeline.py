"""The two-phase ASDR renderer (paper §5.5 dataflow, TPU-adapted).

Phase I  — probe every d-th pixel at full ``ns``; derive per-pixel sample
           counts (adaptive.py).
Phase II — sort rays into difficulty-homogeneous blocks; march each block
           in a chunked ``lax.while_loop`` running exactly
           ``ceil(block_budget / chunk)`` iterations (+ early termination
           when every ray in the block saturates).  Within a chunk, the
           color MLP runs only on every ``group``-th sample (decouple.py).

The pipeline is written against the ``FieldFns`` protocol (fields.py): the
same code renders the trained Instant-NGP network, the exact analytic
field (tests), or the Pallas fused-MLP kernel path.

Blocks are the data-parallel unit: `render_adaptive` exposes a pure
per-block function that launch/ shards over the ``data`` mesh axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import adaptive, decouple, rendering, scene
from .fields import FieldFns

LOG_EPS_T = jnp.log(rendering.EARLY_TERM_TRANSMITTANCE)


@dataclasses.dataclass(frozen=True)
class ASDRConfig:
    ns_full: int = 192
    probe_stride: int = 5            # paper's d
    delta: float = 1.0 / 2048.0      # paper's best threshold (Fig. 21a)
    candidates: Tuple[int, ...] = adaptive.DEFAULT_CANDIDATES
    group: int = 2                   # color-decoupling group size n
    block_size: int = 256            # rays per Phase-II block
    chunk: int = 16                  # samples per while_loop iteration
    early_termination: bool = True
    white_background: bool = True
    # Beyond-paper (TPU adaptation): block-level early termination only
    # fires when EVERY ray in a block saturates; sorting Phase-II rays by
    # (count, probe-interpolated opacity) groups saturating rays into the
    # same blocks so whole blocks exit early (EXPERIMENTS.md §Perf).
    sort_by_opacity: bool = False


def render_fixed_fns(
    fns: FieldFns, origins, dirs, n_samples: int, key=None,
    white_background: bool = True,
):
    """Baseline fixed-count renderer over a FieldFns (paper's "original").

    Deliberately NOT jitted here: fns closures may capture model params,
    and a static-fns jit would bake those arrays into the executable and
    recompile per FieldFns construction.  Callers with stable fns (the
    serving engine, launch cells) jit at their own boundary.
    """
    pts, deltas, ts = scene.sample_points(origins, dirs, n_samples, key)
    R, S = pts.shape[:2]
    flat = pts.reshape(-1, 3)
    dflat = jnp.repeat(dirs, S, axis=0)
    sigma, geo = fns.density(flat)
    color = fns.color(geo, dflat)
    sigma = sigma.reshape(R, S)
    color = color.reshape(R, S, 3)
    rgb, acc, weights = rendering.composite(
        sigma, color, deltas, white_background=white_background
    )
    aux = {"sigmas": sigma, "colors": color, "deltas": deltas, "ts": ts,
           "acc": acc, "weights": weights}
    return rgb, aux


def _march_block(fns: FieldFns, acfg: ASDRConfig, origins, dirs, budget):
    """March one block of rays with a traced per-block sample budget.

    origins/dirs: (B, 3); budget: traced int32 scalar.
    Returns (rgb (B,3), acc (B,), chunks_done scalar).
    """
    B = origins.shape[0]
    C = acfg.chunk
    delta_t = (scene.FAR - scene.NEAR) / budget.astype(jnp.float32)
    n_chunks = (budget + C - 1) // C

    def cond(state):
        ci, log_t, _, _ = state
        alive = jnp.any(log_t > LOG_EPS_T) if acfg.early_termination else True
        return jnp.logical_and(ci < n_chunks, alive)

    def body(state):
        ci, log_t, rgb, acc = state
        idx = ci * C + jnp.arange(C)
        valid = idx < budget
        ts = scene.NEAR + (idx.astype(jnp.float32) + 0.5) * delta_t
        pts = origins[:, None, :] + ts[None, :, None] * dirs[:, None, :]
        flat = pts.reshape(-1, 3)
        sigma, geo = fns.density(flat)
        sigma = sigma.reshape(B, C)
        sigma = jnp.where(valid[None, :], sigma, 0.0)
        geo = geo.reshape(B, C, -1)

        # color-density decoupling within the chunk
        a_idx = jnp.arange(0, C, acfg.group)
        A = a_idx.shape[0]
        geo_a = geo[:, a_idx].reshape(B * A, -1)
        dirs_a = jnp.repeat(dirs, A, axis=0)
        col_a = fns.color(geo_a, dirs_a).reshape(B, A, 3)
        colors = decouple.interpolate_group_colors(col_a, acfg.group, C)

        alphas = rendering.alphas_from_sigmas(sigma, delta_t)
        one_m = jnp.clip(1.0 - alphas, 1e-10, 1.0)
        log_steps = jnp.log(one_m)
        # transmittance inside the chunk, carried from previous chunks
        intra = jnp.cumsum(log_steps, axis=-1) - log_steps  # exclusive
        trans = jnp.exp(log_t[:, None] + intra)
        w = trans * alphas
        rgb = rgb + jnp.sum(w[..., None] * colors, axis=1)
        acc = acc + jnp.sum(w, axis=-1)
        log_t = log_t + jnp.sum(log_steps, axis=-1)
        return ci + 1, log_t, rgb, acc

    state = (
        jnp.asarray(0, jnp.int32),
        jnp.zeros((B,)),
        jnp.zeros((B, 3)),
        jnp.zeros((B,)),
    )
    ci, _, rgb, acc = jax.lax.while_loop(cond, body, state)
    if acfg.white_background:
        rgb = rgb + (1.0 - acc[:, None])
    return rgb, acc, ci


def block_sort(acfg: ASDRConfig, counts, opacity=None):
    """Sort rays into difficulty-homogeneous blocks: (order, budgets).

    Shared by render_adaptive and the render serving engine so that pooled
    serving blocks are built with exactly the single-image semantics.
    counts: (R,) int32 with R % block_size == 0.
    """
    R = counts.shape[0]
    B = acfg.block_size
    if acfg.sort_by_opacity and opacity is not None:
        # composite key: count (primary), quantized opacity (secondary)
        key = counts.astype(jnp.int32) * 1024 + jnp.clip(
            (opacity * 1023).astype(jnp.int32), 0, 1023)
        order = jnp.argsort(key).astype(jnp.int32)
        sorted_counts = counts[order]
        budgets = sorted_counts.reshape(R // B, B).max(axis=1)
        return order, budgets
    return adaptive.sort_rays_into_blocks(counts, B)


def pad_rays_to_blocks(acfg: ASDRConfig, origins, dirs, counts, opacity=None):
    """Pad rays to a block_size multiple with minimum-count dummy rays.

    Pad rays point +z from the origin corner, get the cheapest budget, and
    never reach the image: callers crop to the first R rows after unsort.
    Returns (origins, dirs, counts, opacity, pad).
    """
    R = origins.shape[0]
    pad = (-R) % acfg.block_size
    if pad:
        origins = jnp.concatenate([origins, jnp.zeros((pad, 3))], axis=0)
        dirs = jnp.concatenate(
            [dirs, jnp.tile(jnp.asarray([[0.0, 0.0, 1.0]]), (pad, 1))], axis=0
        )
        counts = jnp.concatenate(
            [counts, jnp.full((pad,), min(acfg.candidates), jnp.int32)],
            axis=0,
        )
        if opacity is not None:
            opacity = jnp.concatenate([opacity, jnp.zeros((pad,))], axis=0)
    return origins, dirs, counts, opacity, pad


def render_adaptive(fns: FieldFns, acfg: ASDRConfig, origins, dirs, counts,
                    opacity=None):
    """Phase II: sorted-block adaptive render.

    origins/dirs: (R, 3) with R % block_size == 0; counts: (R,) int32;
    opacity: optional (R,) probe-interpolated accumulated opacity used as a
    secondary sort key (see ASDRConfig.sort_by_opacity).
    Returns (rgb (R,3), acc (R,), stats).
    """
    R = origins.shape[0]
    B = acfg.block_size
    order, budgets = block_sort(acfg, counts, opacity)
    o_s = origins[order].reshape(-1, B, 3)
    d_s = dirs[order].reshape(-1, B, 3)

    march = partial(_march_block, fns, acfg)
    rgb_s, acc_s, chunks = jax.lax.map(
        lambda args: march(*args), (o_s, d_s, budgets)
    )
    # unsort
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(R, dtype=order.dtype))
    rgb = rgb_s.reshape(R, 3)[inv]
    acc = acc_s.reshape(R)[inv]
    stats = {
        "samples_processed": jnp.sum(chunks) * B * acfg.chunk,
        "baseline_samples": R * acfg.ns_full,
        "chunks_per_block": chunks,
        "budgets": budgets,
    }
    return rgb, acc, stats


def probe_phase(fns: FieldFns, acfg: ASDRConfig, cam, probe_key=None,
                return_opacity: bool = False):
    """Phase I: strided probe -> per-pixel sample-count map (H*W,).

    With return_opacity, also bilinearly interpolates the probe rays'
    accumulated opacity over the image (secondary block-sort key)."""
    H, W = cam.height, cam.width
    o, d = scene.camera_rays(cam)
    d_stride = acfg.probe_stride
    jj, ii = jnp.meshgrid(
        jnp.arange(0, H, d_stride), jnp.arange(0, W, d_stride), indexing="ij"
    )
    probe_idx = (jj * W + ii).reshape(-1)
    rgb_full, aux = render_fixed_fns(
        fns, o[probe_idx], d[probe_idx], acfg.ns_full, probe_key,
        white_background=acfg.white_background,
    )
    pcounts = adaptive.probe_counts(
        aux["sigmas"], aux["colors"], rgb_full, acfg.ns_full,
        acfg.candidates, acfg.delta,
    )
    counts = adaptive.interpolate_counts(
        pcounts, (jj.shape[0], jj.shape[1]), (H, W),
        acfg.candidates, acfg.ns_full,
    )
    probe_cost = int(probe_idx.shape[0]) * acfg.ns_full
    if not return_opacity:
        return counts, probe_cost
    # bilinear interpolation of the probe opacity map (reuse the count
    # interpolation machinery on a scaled-int representation)
    acc_q = jnp.clip((aux["acc"] * 1000).astype(jnp.int32), 0, 1000)
    opacity = adaptive.interpolate_counts(
        acc_q, (jj.shape[0], jj.shape[1]), (H, W),
        candidates=tuple(range(0, 1001, 50)), ns_full=1000,
    ).astype(jnp.float32) / 1000.0
    return counts, probe_cost, opacity


def render_asdr_image(fns: FieldFns, acfg: ASDRConfig, cam, probe_key=None):
    """Full two-phase ASDR render of a camera view.

    Returns (image (H,W,3), stats dict).
    """
    H, W = cam.height, cam.width
    o, d = scene.camera_rays(cam)

    opacity = None
    if acfg.sort_by_opacity:
        counts, probe_cost, opacity = probe_phase(
            fns, acfg, cam, probe_key, return_opacity=True)
    else:
        counts, probe_cost = probe_phase(fns, acfg, cam, probe_key)

    # ---- Phase II ----
    R = H * W
    o, d, counts, opacity, _pad = pad_rays_to_blocks(
        acfg, o, d, counts, opacity)
    rgb, acc, stats = render_adaptive(fns, acfg, o, d, counts, opacity)
    img = rgb[:R].reshape(H, W, 3)

    stats = dict(stats)
    stats.update(adaptive.compute_savings(counts[:R], acfg.ns_full))
    stats["probe_samples"] = probe_cost
    stats["phase2_fraction_of_baseline"] = (
        stats["samples_processed"] / stats["baseline_samples"]
    )
    return img, stats


# --------------------------------------------------------------------------
# Cross-frame probe reuse — the paper's §5.2.2 data reuse extended to the
# temporal axis: Phase-I count/opacity maps transfer between nearby camera
# poses, so most frames of a smooth trajectory skip the probe entirely.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProbeReuseConfig:
    """When may a frame reuse another pose's Phase-I maps?

    A cached entry matches when BOTH the FULL relative-rotation angle
    (geodesic on SO(3) — an in-plane roll counts, since it permutes every
    pixel's ray) and the eye translation to the requesting pose are under
    the thresholds, and the image geometry (HxW, focal) is identical.
    ``refresh_every = k`` forces a fresh probe after an entry has been
    reused k times, bounding count-map staleness on long trajectories;
    0 disables refreshing.
    """
    max_angle_deg: float = 4.0
    max_translation: float = 0.08
    refresh_every: int = 8
    max_entries: int = 64
    # conservative count-map dilation: scaled to the worst-case pixel shift
    # of the pose delta (adaptive.reuse_dilation_radius) so reused maps
    # never under-sample shifted content; 0 margin disables.  A pose delta
    # whose conservative radius exceeds dilate_cap is treated as a MISS
    # (re-probe) — never as a smaller-than-safe dilation.
    dilate_margin: float = 1.5
    dilate_cap: int = 8


@dataclasses.dataclass
class _ProbeEntry:
    cam: "scene.Camera"
    acfg: ASDRConfig          # config the maps were probed under
    counts: jnp.ndarray
    opacity: jnp.ndarray
    reuses_since_probe: int = 0
    last_used: int = 0


class ProbeCache:
    """Pose-keyed cache of Phase-I (counts, opacity) maps.

    Host-side bookkeeping (pure-python, one lookup per request); the maps
    themselves stay on device.  One cache per scene — poses from different
    fields must never share count maps.
    """

    def __init__(self, rcfg: ProbeReuseConfig | None = None):
        self.rcfg = rcfg or ProbeReuseConfig()
        self._entries: list[_ProbeEntry] = []
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.refreshes = 0

    def __len__(self):
        return len(self._entries)

    @property
    def reused_fraction(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def _match(self, cam, acfg):
        """Nearest usable entry: (entry, angle, translation) or None."""
        max_ang = np.deg2rad(self.rcfg.max_angle_deg)
        max_tr = self.rcfg.max_translation
        best, best_score = None, np.inf
        for e in self._entries:
            # image geometry and probe config must match exactly: the count
            # map is per-pixel and acfg-specific; a different focal (zoom)
            # changes every ray even at an identical pose.  Filtering here
            # (not post-hoc) lets entries for different configs coexist
            # instead of shadowing each other.
            if e.acfg != acfg:
                continue
            if (e.cam.height, e.cam.width) != (cam.height, cam.width):
                continue
            if abs(e.cam.focal - cam.focal) > 1e-6 * max(cam.focal, 1.0):
                continue
            ang, tr = adaptive.pose_distance(cam, e.cam)
            if ang > max_ang or tr > max_tr:
                continue
            score = ang / max(max_ang, 1e-9) + tr / max(max_tr, 1e-9)
            if score < best_score:
                best, best_score = (e, ang, tr), score
        return best

    def _store(self, cam, acfg, counts, opacity, replacing=None):
        self._clock += 1
        if replacing is not None:
            replacing.cam = cam
            replacing.acfg = acfg
            replacing.counts = counts
            replacing.opacity = opacity
            replacing.reuses_since_probe = 0
            replacing.last_used = self._clock
            return
        if len(self._entries) >= self.rcfg.max_entries:
            self._entries.remove(min(self._entries, key=lambda e: e.last_used))
        self._entries.append(_ProbeEntry(cam, acfg, counts, opacity,
                                         last_used=self._clock))


def probe_phase_cached(fns: FieldFns, acfg: ASDRConfig, cam,
                       cache: ProbeCache | None, probe_key=None):
    """Phase I with cross-frame reuse.

    Returns (counts (H*W,), probe_cost, opacity (H*W,), reused: bool).
    probe_cost is 0 on a cache hit — the whole point: a reused frame pays
    only Phase II.  Opacity is always produced so the serving engine can
    sort pooled blocks by the composite (count, opacity) key.
    """
    if cache is not None:
        match = cache._match(cam, acfg)
        if match is not None:
            entry, ang, tr = match
            radius = adaptive.reuse_dilation_radius(
                cam, ang, tr, scene.NEAR,
                margin=cache.rcfg.dilate_margin,
            ) if cache.rcfg.dilate_margin > 0 else 0
            k = cache.rcfg.refresh_every
            usable = (radius <= cache.rcfg.dilate_cap
                      and (k <= 0 or entry.reuses_since_probe < k))
            if usable:
                cache.hits += 1
                cache._clock += 1
                entry.reuses_since_probe += 1
                entry.last_used = cache._clock
                counts = adaptive.dilate_count_map(
                    entry.counts, (cam.height, cam.width), radius,
                    border_fill=acfg.ns_full)
                return counts, 0, entry.opacity, True
            # re-probe at the CURRENT pose and rebase the entry: either a
            # scheduled refresh (k-th reuse) or a pose delta whose
            # conservative dilation radius overflows dilate_cap
            counts, cost, opacity = probe_phase(
                fns, acfg, cam, probe_key, return_opacity=True)
            cache.refreshes += 1
            cache.misses += 1
            cache._store(cam, acfg, counts, opacity, replacing=entry)
            return counts, cost, opacity, False
    counts, cost, opacity = probe_phase(
        fns, acfg, cam, probe_key, return_opacity=True)
    if cache is not None:
        cache.misses += 1
        cache._store(cam, acfg, counts, opacity)
    return counts, cost, opacity, False
