"""The two-phase ASDR renderer (paper §5.5 dataflow, TPU-adapted).

Phase I  — probe every d-th pixel at full ``ns``; derive per-pixel sample
           counts (adaptive.py).
Phase II — sort rays into difficulty-homogeneous blocks; march each block
           in a chunked ``lax.while_loop`` running exactly
           ``ceil(block_budget / chunk)`` iterations (+ early termination
           when every ray in the block saturates).  Within a chunk, the
           color MLP runs only on every ``group``-th sample (decouple.py).

The pipeline is written against the ``FieldFns`` protocol (fields.py): the
same code renders the trained Instant-NGP network, the exact analytic
field (tests), or the Pallas fused-MLP kernel path.

Blocks are the data-parallel unit: `render_adaptive` exposes a pure
per-block function that launch/ shards over the ``data`` mesh axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from . import adaptive, decouple, rendering, scene
from .fields import FieldFns

LOG_EPS_T = jnp.log(rendering.EARLY_TERM_TRANSMITTANCE)


@dataclasses.dataclass(frozen=True)
class ASDRConfig:
    ns_full: int = 192
    probe_stride: int = 5            # paper's d
    delta: float = 1.0 / 2048.0      # paper's best threshold (Fig. 21a)
    candidates: Tuple[int, ...] = adaptive.DEFAULT_CANDIDATES
    group: int = 2                   # color-decoupling group size n
    block_size: int = 256            # rays per Phase-II block
    chunk: int = 16                  # samples per while_loop iteration
    early_termination: bool = True
    white_background: bool = True
    # Beyond-paper (TPU adaptation): block-level early termination only
    # fires when EVERY ray in a block saturates; sorting Phase-II rays by
    # (count, probe-interpolated opacity) groups saturating rays into the
    # same blocks so whole blocks exit early (EXPERIMENTS.md §Perf).
    sort_by_opacity: bool = False
    # Phase-II march backend: "reference" = chunked density/color calls
    # per chunk (this module), "fused" = single-kernel streaming march
    # (kernels/fused_march.py) when the FieldFns carries fused-march
    # resources (fields without them fall back to the reference march).
    march_backend: str = "reference"
    # Fused-march table supply: "auto" keeps the hash-table stack
    # VMEM-resident when it fits and streams levels through a
    # double-buffered DMA pair when it does not (the only option at
    # full-config table sizes); "resident"/"streamed" pin the choice
    # (kernels.ops._select_streaming; "resident" refuses configs that
    # exceed the VMEM budget).  Ignored by the reference backend.
    march_table_streaming: str = "auto"
    # Per-RAY early exit: rays whose transmittance saturates stop
    # contributing sample work (their sigmas are masked) instead of
    # riding until the whole block exits.  chunks_done and ray_chunks
    # are unchanged by the flag — a dead ray's log-transmittance is
    # already frozen below the threshold — and the rgb/acc deviation is
    # bounded by the EARLY_TERM_TRANSMITTANCE tail.
    per_ray_early_exit: bool = False


def render_fixed_fns(
    fns: FieldFns, origins, dirs, n_samples: int, key=None,
    white_background: bool = True,
):
    """Baseline fixed-count renderer over a FieldFns (paper's "original").

    Deliberately NOT jitted here: fns closures may capture model params,
    and a static-fns jit would bake those arrays into the executable and
    recompile per FieldFns construction.  Callers with stable fns (the
    serving engine, launch cells) jit at their own boundary.
    """
    pts, deltas, ts = scene.sample_points(origins, dirs, n_samples, key)
    R, S = pts.shape[:2]
    flat = pts.reshape(-1, 3)
    dflat = jnp.repeat(dirs, S, axis=0)
    sigma, geo = fns.density(flat)
    color = fns.color(geo, dflat)
    sigma = sigma.reshape(R, S)
    color = color.reshape(R, S, 3)
    rgb, acc, weights = rendering.composite(
        sigma, color, deltas, white_background=white_background
    )
    aux = {"sigmas": sigma, "colors": color, "deltas": deltas, "ts": ts,
           "acc": acc, "weights": weights}
    return rgb, aux


def _march_block(fns: FieldFns, acfg: ASDRConfig, origins, dirs, budget,
                 density_only: bool = False):
    """March one block of rays with a traced per-block sample budget.

    origins/dirs: (B, 3); budget: traced int32 scalar.
    Returns (rgb (B,3), acc (B,), depth (B,), chunks_done scalar,
    ray_chunks (B,) int32) — depth is the per-ray termination depth
    ``E[t] + (1 - acc) * FAR``, the full-resolution replacement for the
    probe's stride-d proxy depth (framecache warps register against it
    at depth edges); ray_chunks counts the chunks each ray entered
    still live (un-saturated), the per-RAY refinement of chunks_done
    that prices early-exit savings.

    With ``density_only`` (static) the color MLP never runs and rgb stays
    zero — the march only produces acc/depth, for rays whose radiance is
    served from the warp/radiance tiers (serve/README.md).
    """
    B = origins.shape[0]
    C = acfg.chunk
    delta_t = (scene.FAR - scene.NEAR) / budget.astype(jnp.float32)
    n_chunks = (budget + C - 1) // C

    def cond(state):
        ci, log_t = state[0], state[1]
        alive = jnp.any(log_t > LOG_EPS_T) if acfg.early_termination else True
        return jnp.logical_and(ci < n_chunks, alive)

    def body(state):
        ci, log_t, rgb, acc, dep, ray_chunks = state
        # per-ray liveness at chunk start: saturated rays stop counting
        # toward ray_chunks; with per_ray_early_exit their sigma is also
        # masked (freezing log_t), which cannot change the block-level
        # exit chunk — a dead ray's log_t is already below the threshold
        alive = log_t > LOG_EPS_T
        idx = ci * C + jnp.arange(C)
        valid = idx < budget
        ts = scene.NEAR + (idx.astype(jnp.float32) + 0.5) * delta_t
        pts = origins[:, None, :] + ts[None, :, None] * dirs[:, None, :]
        flat = pts.reshape(-1, 3)
        sigma, geo = fns.density(flat)
        sigma = sigma.reshape(B, C)
        sigma = jnp.where(valid[None, :], sigma, 0.0)
        if acfg.per_ray_early_exit:
            sigma = jnp.where(alive[:, None], sigma, 0.0)

        if not density_only:
            geo = geo.reshape(B, C, -1)
            # color-density decoupling within the chunk
            a_idx = jnp.arange(0, C, acfg.group)
            A = a_idx.shape[0]
            geo_a = geo[:, a_idx].reshape(B * A, -1)
            dirs_a = jnp.repeat(dirs, A, axis=0)
            col_a = fns.color(geo_a, dirs_a).reshape(B, A, 3)
            colors = decouple.interpolate_group_colors(col_a, acfg.group, C)

        alphas = rendering.alphas_from_sigmas(sigma, delta_t)
        one_m = jnp.clip(1.0 - alphas, 1e-10, 1.0)
        log_steps = jnp.log(one_m)
        # transmittance inside the chunk, carried from previous chunks
        intra = jnp.cumsum(log_steps, axis=-1) - log_steps  # exclusive
        trans = jnp.exp(log_t[:, None] + intra)
        w = trans * alphas
        if not density_only:
            rgb = rgb + jnp.sum(w[..., None] * colors, axis=1)
        acc = acc + jnp.sum(w, axis=-1)
        dep = dep + jnp.sum(w * ts[None, :], axis=-1)
        log_t = log_t + jnp.sum(log_steps, axis=-1)
        ray_chunks = ray_chunks + alive.astype(jnp.int32)
        return ci + 1, log_t, rgb, acc, dep, ray_chunks

    state = (
        jnp.asarray(0, jnp.int32),
        jnp.zeros((B,)),
        jnp.zeros((B, 3)),
        jnp.zeros((B,)),
        jnp.zeros((B,)),
        jnp.zeros((B,), jnp.int32),
    )
    ci, _, rgb, acc, dep, ray_chunks = jax.lax.while_loop(cond, body, state)
    # an early-terminated ray leaves a negligible transmittance tail; the
    # (1 - acc) * FAR term pins true background rays to the far plane
    depth = dep + (1.0 - acc) * scene.FAR
    if acfg.white_background and not density_only:
        rgb = rgb + (1.0 - acc[:, None])
    return rgb, acc, depth, ci, ray_chunks


def march_blocks(fns: FieldFns, acfg: ASDRConfig, o_b, d_b, budgets,
                 density_only: bool = False):
    """March a batch of blocks: o_b/d_b (N, B, 3), budgets (N,) int32 ->
    (rgb (N,B,3), acc (N,B), depth (N,B), chunks (N,), ray_chunks
    (N,B) int32).

    The backend seam for Phase II: with ``march_backend == "fused"`` and a
    FieldFns carrying fused-march resources (kernels.ops.field_fns), the
    whole batch runs as ONE streaming Pallas kernel; otherwise each block
    runs the chunked reference march above under ``lax.map``.  Both paths
    honor the same while_loop early-termination contract (identical
    chunks_done, budgets masked identically).
    """
    if acfg.march_backend == "fused" and fns.fused is not None:
        from ..kernels import ops as _kops  # lazy: core stays kernel-free
        return _kops.fused_march_blocks(
            fns.fused, acfg, o_b, d_b, budgets, density_only=density_only)
    march = partial(_march_block, fns, acfg, density_only=density_only)
    return jax.lax.map(lambda args: march(*args), (o_b, d_b, budgets))


def block_sort(acfg: ASDRConfig, counts, opacity=None):
    """Sort rays into difficulty-homogeneous blocks: (order, budgets).

    Shared by render_adaptive and the render serving engine so that pooled
    serving blocks are built with exactly the single-image semantics.
    counts: (R,) int32 with R % block_size == 0.
    """
    R = counts.shape[0]
    B = acfg.block_size
    if acfg.sort_by_opacity and opacity is not None:
        # composite key: count (primary), quantized opacity (secondary)
        key = counts.astype(jnp.int32) * 1024 + jnp.clip(
            (opacity * 1023).astype(jnp.int32), 0, 1023)
        order = jnp.argsort(key).astype(jnp.int32)
        sorted_counts = counts[order]
        budgets = sorted_counts.reshape(R // B, B).max(axis=1)
        return order, budgets
    return adaptive.sort_rays_into_blocks(counts, B)


def pad_rays_to_blocks(acfg: ASDRConfig, origins, dirs, counts, opacity=None):
    """Pad rays to a block_size multiple with minimum-count dummy rays.

    Pad rays point +z from the origin corner, get the cheapest budget, and
    never reach the image: callers crop to the first R rows after unsort.
    Returns (origins, dirs, counts, opacity, pad).
    """
    R = origins.shape[0]
    pad = (-R) % acfg.block_size
    if pad:
        origins = jnp.concatenate([origins, jnp.zeros((pad, 3))], axis=0)
        dirs = jnp.concatenate(
            [dirs, jnp.tile(jnp.asarray([[0.0, 0.0, 1.0]]), (pad, 1))], axis=0
        )
        counts = jnp.concatenate(
            [counts, jnp.full((pad,), min(acfg.candidates), jnp.int32)],
            axis=0,
        )
        if opacity is not None:
            opacity = jnp.concatenate([opacity, jnp.zeros((pad,))], axis=0)
    return origins, dirs, counts, opacity, pad


def render_adaptive(fns: FieldFns, acfg: ASDRConfig, origins, dirs, counts,
                    opacity=None):
    """Phase II: sorted-block adaptive render.

    origins/dirs: (R, 3) with R % block_size == 0; counts: (R,) int32;
    opacity: optional (R,) probe-interpolated accumulated opacity used as a
    secondary sort key (see ASDRConfig.sort_by_opacity).
    Returns (rgb (R,3), acc (R,), stats).
    """
    R = origins.shape[0]
    B = acfg.block_size
    order, budgets = block_sort(acfg, counts, opacity)
    o_s = origins[order].reshape(-1, B, 3)
    d_s = dirs[order].reshape(-1, B, 3)

    rgb_s, acc_s, depth_s, chunks, ray_chunks = march_blocks(
        fns, acfg, o_s, d_s, budgets)
    # unsort
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(R, dtype=order.dtype))
    rgb = rgb_s.reshape(R, 3)[inv]
    acc = acc_s.reshape(R)[inv]
    stats = {
        "samples_processed": jnp.sum(chunks) * B * acfg.chunk,
        "baseline_samples": R * acfg.ns_full,
        "chunks_per_block": chunks,
        # per-ray live-chunk counts (block-sorted order): the gap to
        # chunks_per_block * B is the sample work per-ray early exit
        # can skip on saturated trajectories
        "ray_chunks_per_block": ray_chunks,
        "budgets": budgets,
        # full-resolution termination depth (ROADMAP item): replaces the
        # probe's stride-d proxy depth wherever a finished frame is cached
        "term_depth": depth_s.reshape(R)[inv],
    }
    return rgb, acc, stats


def probe_phase(fns: FieldFns, acfg: ASDRConfig, cam, probe_key=None,
                return_opacity: bool = False, return_depth: bool = False):
    """Phase I: strided probe -> per-pixel sample-count map (H*W,).

    With return_opacity, also bilinearly interpolates the probe rays'
    accumulated opacity over the image (secondary block-sort key).  With
    return_depth, additionally interpolates each probe ray's expected
    termination distance (background pinned to FAR) — the proxy depth the
    framecache warp primitive reprojects per-pixel maps with."""
    H, W = cam.height, cam.width
    o, d = scene.camera_rays(cam)
    d_stride = acfg.probe_stride
    jj, ii = jnp.meshgrid(
        jnp.arange(0, H, d_stride), jnp.arange(0, W, d_stride), indexing="ij"
    )
    probe_idx = (jj * W + ii).reshape(-1)
    rgb_full, aux = render_fixed_fns(
        fns, o[probe_idx], d[probe_idx], acfg.ns_full, probe_key,
        white_background=acfg.white_background,
    )
    pcounts = adaptive.probe_counts(
        aux["sigmas"], aux["colors"], rgb_full, acfg.ns_full,
        acfg.candidates, acfg.delta,
    )
    counts = adaptive.interpolate_counts(
        pcounts, (jj.shape[0], jj.shape[1]), (H, W),
        acfg.candidates, acfg.ns_full,
    )
    probe_cost = int(probe_idx.shape[0]) * acfg.ns_full
    if not (return_opacity or return_depth):
        return counts, probe_cost
    probe_hw = (jj.shape[0], jj.shape[1])
    opacity = adaptive.interpolate_map(aux["acc"], probe_hw, (H, W))
    if not return_depth:
        return counts, probe_cost, opacity
    t_exp = rendering.expected_termination_depth(
        aux["weights"], aux["ts"], aux["acc"], scene.FAR)
    depth = adaptive.interpolate_map(t_exp, probe_hw, (H, W))
    return counts, probe_cost, opacity, depth


def render_asdr_image(fns: FieldFns, acfg: ASDRConfig, cam, probe_key=None):
    """Full two-phase ASDR render of a camera view.

    Returns (image (H,W,3), stats dict).
    """
    H, W = cam.height, cam.width
    o, d = scene.camera_rays(cam)

    opacity = None
    if acfg.sort_by_opacity:
        counts, probe_cost, opacity = probe_phase(
            fns, acfg, cam, probe_key, return_opacity=True)
    else:
        counts, probe_cost = probe_phase(fns, acfg, cam, probe_key)

    # ---- Phase II ----
    R = H * W
    o, d, counts, opacity, _pad = pad_rays_to_blocks(
        acfg, o, d, counts, opacity)
    rgb, acc, stats = render_adaptive(fns, acfg, o, d, counts, opacity)
    img = rgb[:R].reshape(H, W, 3)

    stats = dict(stats)
    stats.update(adaptive.compute_savings(counts[:R], acfg.ns_full))
    stats["probe_samples"] = probe_cost
    stats["phase2_fraction_of_baseline"] = (
        stats["samples_processed"] / stats["baseline_samples"]
    )
    return img, stats


# --------------------------------------------------------------------------
# DEPRECATED import path: cross-frame reuse moved to repro.framecache.
# ``ProbeCache`` / ``ProbeReuseConfig`` / ``probe_phase_cached`` now live in
# framecache/probe.py (rebuilt on the pose-delta warp primitive); the lazy
# module __getattr__ below keeps `from repro.core.pipeline import ProbeCache`
# working without a core -> framecache import cycle at module load.
# --------------------------------------------------------------------------
_FRAMECACHE_REEXPORTS = ("ProbeCache", "ProbeReuseConfig",
                         "probe_phase_cached")


def __getattr__(name):  # PEP 562 — lazy deprecation re-exports
    if name in _FRAMECACHE_REEXPORTS:
        from ..framecache import probe as _probe
        return getattr(_probe, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


