"""§4.2 — Adaptive sampling with rendering-difficulty awareness.

Phase-I probe: render every `d`-th pixel at the full count ``ns``; re-
composite *the same* predicted (sigma, color) samples at reduced counts
``ns_i`` (stride subsampling — no extra MLP work, exactly the paper's
"perform multiple volume renderings using different numbers of sampled
points"); pick the smallest ``ns_i`` whose difficulty ``rd_i`` (Eq. 3) is
``<= delta``; bilinearly interpolate counts for unprobed pixels.

TPU adaptation (DESIGN.md §8.3): per-pixel dynamic trip counts are illegal
under XLA's static shapes, so Phase II sorts rays by their assigned count
into homogeneous blocks and marches each block in a chunked
``lax.while_loop`` whose trip count is the block's budget — dynamic work,
static shapes.  Blocks are the data-parallel unit (shard-mappable over the
``data`` mesh axis).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import rendering

# Default candidate ladder (paper probes several ns_i; ours spans the same
# 16x range as Fig. 7's 12..192).
DEFAULT_CANDIDATES = (12, 24, 48, 96)


def subsampled_composite(
    sigmas: jnp.ndarray, colors: jnp.ndarray, ns_full: int, ns_i: int,
    white_background: bool = True,
):
    """Re-composite using every (ns_full//ns_i)-th of the existing samples.

    sigmas (R, S), colors (R, S, 3) from the full-count probe render.
    """
    stride = ns_full // ns_i
    sub_s = sigmas[:, ::stride][:, :ns_i]
    sub_c = colors[:, ::stride][:, :ns_i]
    deltas = jnp.full(sub_s.shape, (rendering_far() - rendering_near()) / ns_i)
    rgb, _, _ = rendering.composite(
        sub_s, sub_c, deltas, white_background=white_background
    )
    return rgb


def rendering_near():
    from . import scene
    return scene.NEAR


def rendering_far():
    from . import scene
    return scene.FAR


def rendering_difficulty(rgb_full: jnp.ndarray, rgb_sub: jnp.ndarray) -> jnp.ndarray:
    """Eq. (3): rd_i = max(|dr|, |dg|, |db|)  per ray. Colors in [0,1]."""
    return jnp.max(jnp.abs(rgb_full - rgb_sub), axis=-1)


def probe_counts(
    sigmas: jnp.ndarray, colors: jnp.ndarray, rgb_full: jnp.ndarray,
    ns_full: int, candidates: Sequence[int] = DEFAULT_CANDIDATES,
    delta: float = 1.0 / 2048.0,
) -> jnp.ndarray:
    """Per-probe-ray sample counts: smallest ns_i with rd_i <= delta.

    Returns int32 (R,) counts drawn from candidates + [ns_full].
    """
    counts = jnp.full(rgb_full.shape[0], ns_full, dtype=jnp.int32)
    # iterate descending so the smallest passing candidate wins
    for ns_i in sorted(candidates, reverse=True):
        rgb_i = subsampled_composite(sigmas, colors, ns_full, ns_i)
        rd = rendering_difficulty(rgb_full, rgb_i)
        counts = jnp.where(rd <= delta, ns_i, counts)
    return counts


def interpolate_map(
    probe: jnp.ndarray, probe_hw: Tuple[int, int], full_hw: Tuple[int, int],
) -> jnp.ndarray:
    """Float bilinear interpolation of a per-probe-pixel map to full res.

    probe: (ph*pw,) values on the strided probe grid.  Returns float32
    (H*W,).  Shared by count interpolation (which then snaps to the
    candidate ladder), the probe opacity/depth maps, and the framecache
    warp code — values stay exact floats, no quantization.
    """
    ph, pw = probe_hw
    H, W = full_hw
    grid = probe.reshape(ph, pw).astype(jnp.float32)
    ys = jnp.linspace(0.0, ph - 1.0, H)
    xs = jnp.linspace(0.0, pw - 1.0, W)
    y0 = jnp.clip(jnp.floor(ys).astype(jnp.int32), 0, ph - 1)
    x0 = jnp.clip(jnp.floor(xs).astype(jnp.int32), 0, pw - 1)
    y1 = jnp.clip(y0 + 1, 0, ph - 1)
    x1 = jnp.clip(x0 + 1, 0, pw - 1)
    wy = (ys - y0)[:, None]
    wx = (xs - x0)[None, :]
    v = (
        grid[y0][:, x0] * (1 - wy) * (1 - wx)
        + grid[y0][:, x1] * (1 - wy) * wx
        + grid[y1][:, x0] * wy * (1 - wx)
        + grid[y1][:, x1] * wy * wx
    )
    return v.reshape(H * W)


def interpolate_counts(
    probe: jnp.ndarray, probe_hw: Tuple[int, int], full_hw: Tuple[int, int],
    candidates: Sequence[int] = DEFAULT_CANDIDATES, ns_full: int = 192,
) -> jnp.ndarray:
    """Bilinear interpolation of the probe-count map to the full image, then
    conservative snap-UP to the candidate ladder (paper §4.2)."""
    v = interpolate_map(probe, probe_hw, full_hw)
    ladder = jnp.asarray(sorted(set(list(candidates) + [ns_full])), jnp.int32)
    # snap UP: smallest ladder value >= v
    idx = jnp.searchsorted(ladder, jnp.ceil(v).astype(jnp.int32), side="left")
    idx = jnp.clip(idx, 0, ladder.shape[0] - 1)
    return ladder[idx]


def sort_rays_into_blocks(
    counts: jnp.ndarray, block_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort ray indices by sample count; return (order, per-block budget).

    order: (R,) int32 permutation; budgets: (R//block, ) int32 = max count
    in each block (conservative).  R must be divisible by block_size (pad
    rays upstream).
    """
    order = jnp.argsort(counts)
    sorted_counts = counts[order]
    nblocks = counts.shape[0] // block_size
    budgets = sorted_counts.reshape(nblocks, block_size).max(axis=1)
    return order.astype(jnp.int32), budgets


def pose_distance(cam_a, cam_b) -> Tuple[float, float]:
    """(relative-rotation angle [rad], origin translation) between cameras.

    The cross-frame probe-reuse criterion (serve/render_engine.py): Phase-I
    maps transfer between poses whose rays nearly coincide, which is exactly
    when both the relative rotation and the eye translation are small.  The
    angle is the FULL relative-rotation angle (geodesic metric on SO(3)),
    not just the optical-axis angle — an in-plane roll permutes every
    pixel's ray and must count as distance even though the view direction
    is unchanged.  Host-side numpy — runs per request, never traced.
    """
    ra = np.asarray(cam_a.c2w_rot, np.float64)
    rb = np.asarray(cam_b.c2w_rot, np.float64)
    # rotation angle of ra^T rb: cos = (trace - 1) / 2
    cos = float(np.clip((np.trace(ra.T @ rb) - 1.0) * 0.5, -1.0, 1.0))
    angle = float(np.arccos(cos))
    trans = float(np.linalg.norm(
        np.asarray(cam_a.origin) - np.asarray(cam_b.origin)))
    return angle, trans


def dilate_count_map(counts: jnp.ndarray, hw: Tuple[int, int],
                     radius: int, border_fill: int | None = None) -> jnp.ndarray:
    """Pixelwise max-filter of a count map — the conservative margin for
    cross-frame reuse.

    A count map probed at pose A, used at nearby pose B, can under-sample
    pixels whose content shifted between the poses.  Dilating by the
    worst-case optical flow of the pose delta (see ``reuse_dilation_radius``)
    guarantees every pixel sees at least the count its content was assigned
    at probe time, without warping.  Separable max over rows then columns.

    The guarantee cannot hold for content entering the frame from
    OFF-SCREEN at the borders (the probe never saw it): with
    ``border_fill`` (typically ns_full), the radius-wide border band is
    raised to at least that count, closing the gap conservatively.
    """
    if radius <= 0:
        return counts
    H, W = hw
    g = counts.reshape(H, W)
    k = 2 * radius + 1
    gp = jnp.pad(g, ((radius, radius), (0, 0)), mode="edge")
    g = jnp.max(jnp.stack([gp[i:i + H] for i in range(k)]), axis=0)
    gp = jnp.pad(g, ((0, 0), (radius, radius)), mode="edge")
    g = jnp.max(jnp.stack([gp[:, i:i + W] for i in range(k)]), axis=0)
    if border_fill is not None:
        yy, xx = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
        border = ((yy < radius) | (yy >= H - radius)
                  | (xx < radius) | (xx >= W - radius))
        g = jnp.where(border, jnp.maximum(g, border_fill), g)
    return g.reshape(H * W)


def reuse_dilation_radius(cam, angle: float, trans: float,
                          near: float, margin: float = 1.5) -> int:
    """Worst-case pixel shift between two poses, as a dilation radius.

    A small rotation by ``angle`` displaces the projection of a pixel at
    image radius r by at most ``angle * (focal^2 + r^2) / focal`` (the
    derivative of the pinhole projection; at the principal point this is
    ``angle * focal``, growing by sec^2 toward the edges and covering
    in-plane roll at the corners).  We take r at the image corner, so the
    bound holds for EVERY pixel at any FOV.  Translation moves content at
    depth z by ``trans / z * focal`` (worst case z = near).

    Shifts under half a pixel cannot move content across a pixel boundary
    and round to radius 0 — this also absorbs the ~1e-4 rad noise float32
    ``arccos`` produces for identical poses, so zero-distance reuse is
    exactly re-probing (tests/test_render_serve.py relies on this).

    Unclamped: the caller (pipeline.probe_phase_cached) treats a radius
    above its configured cap as a cache MISS rather than silently
    dilating less than the conservative bound requires.
    """
    focal = cam.focal
    r_corner2 = (cam.width * 0.5) ** 2 + (cam.height * 0.5) ** 2
    rot_px = angle * (focal * focal + r_corner2) / max(focal, 1e-6)
    px = rot_px + (trans / max(near, 1e-6)) * focal
    return max(int(np.ceil(margin * px - 0.5)), 0)


def compute_savings(counts: jnp.ndarray, ns_full: int) -> dict:
    """Analytic work-reduction stats (paper: avg 120 vs 192 on Lego)."""
    avg = float(jnp.mean(counts))
    return {
        "avg_samples_per_ray": avg,
        "sample_reduction": ns_full / max(avg, 1e-9),
        "fraction_background": float(jnp.mean(counts <= min(DEFAULT_CANDIDATES))),
    }
