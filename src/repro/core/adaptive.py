"""§4.2 — Adaptive sampling with rendering-difficulty awareness.

Phase-I probe: render every `d`-th pixel at the full count ``ns``; re-
composite *the same* predicted (sigma, color) samples at reduced counts
``ns_i`` (stride subsampling — no extra MLP work, exactly the paper's
"perform multiple volume renderings using different numbers of sampled
points"); pick the smallest ``ns_i`` whose difficulty ``rd_i`` (Eq. 3) is
``<= delta``; bilinearly interpolate counts for unprobed pixels.

TPU adaptation (DESIGN.md §8.3): per-pixel dynamic trip counts are illegal
under XLA's static shapes, so Phase II sorts rays by their assigned count
into homogeneous blocks and marches each block in a chunked
``lax.while_loop`` whose trip count is the block's budget — dynamic work,
static shapes.  Blocks are the data-parallel unit (shard-mappable over the
``data`` mesh axis).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import rendering

# Default candidate ladder (paper probes several ns_i; ours spans the same
# 16x range as Fig. 7's 12..192).
DEFAULT_CANDIDATES = (12, 24, 48, 96)


def subsampled_composite(
    sigmas: jnp.ndarray, colors: jnp.ndarray, ns_full: int, ns_i: int,
    white_background: bool = True,
):
    """Re-composite using every (ns_full//ns_i)-th of the existing samples.

    sigmas (R, S), colors (R, S, 3) from the full-count probe render.
    """
    stride = ns_full // ns_i
    sub_s = sigmas[:, ::stride][:, :ns_i]
    sub_c = colors[:, ::stride][:, :ns_i]
    deltas = jnp.full(sub_s.shape, (rendering_far() - rendering_near()) / ns_i)
    rgb, _, _ = rendering.composite(
        sub_s, sub_c, deltas, white_background=white_background
    )
    return rgb


def rendering_near():
    from . import scene
    return scene.NEAR


def rendering_far():
    from . import scene
    return scene.FAR


def rendering_difficulty(rgb_full: jnp.ndarray, rgb_sub: jnp.ndarray) -> jnp.ndarray:
    """Eq. (3): rd_i = max(|dr|, |dg|, |db|)  per ray. Colors in [0,1]."""
    return jnp.max(jnp.abs(rgb_full - rgb_sub), axis=-1)


def probe_counts(
    sigmas: jnp.ndarray, colors: jnp.ndarray, rgb_full: jnp.ndarray,
    ns_full: int, candidates: Sequence[int] = DEFAULT_CANDIDATES,
    delta: float = 1.0 / 2048.0,
) -> jnp.ndarray:
    """Per-probe-ray sample counts: smallest ns_i with rd_i <= delta.

    Returns int32 (R,) counts drawn from candidates + [ns_full].
    """
    counts = jnp.full(rgb_full.shape[0], ns_full, dtype=jnp.int32)
    # iterate descending so the smallest passing candidate wins
    for ns_i in sorted(candidates, reverse=True):
        rgb_i = subsampled_composite(sigmas, colors, ns_full, ns_i)
        rd = rendering_difficulty(rgb_full, rgb_i)
        counts = jnp.where(rd <= delta, ns_i, counts)
    return counts


def interpolate_counts(
    probe: jnp.ndarray, probe_hw: Tuple[int, int], full_hw: Tuple[int, int],
    candidates: Sequence[int] = DEFAULT_CANDIDATES, ns_full: int = 192,
) -> jnp.ndarray:
    """Bilinear interpolation of the probe-count map to the full image, then
    conservative snap-UP to the candidate ladder (paper §4.2)."""
    ph, pw = probe_hw
    H, W = full_hw
    grid = probe.reshape(ph, pw).astype(jnp.float32)
    ys = jnp.linspace(0.0, ph - 1.0, H)
    xs = jnp.linspace(0.0, pw - 1.0, W)
    y0 = jnp.clip(jnp.floor(ys).astype(jnp.int32), 0, ph - 1)
    x0 = jnp.clip(jnp.floor(xs).astype(jnp.int32), 0, pw - 1)
    y1 = jnp.clip(y0 + 1, 0, ph - 1)
    x1 = jnp.clip(x0 + 1, 0, pw - 1)
    wy = (ys - y0)[:, None]
    wx = (xs - x0)[None, :]
    v = (
        grid[y0][:, x0] * (1 - wy) * (1 - wx)
        + grid[y0][:, x1] * (1 - wy) * wx
        + grid[y1][:, x0] * wy * (1 - wx)
        + grid[y1][:, x1] * wy * wx
    )
    ladder = jnp.asarray(sorted(set(list(candidates) + [ns_full])), jnp.int32)
    # snap UP: smallest ladder value >= v
    idx = jnp.searchsorted(ladder, jnp.ceil(v).astype(jnp.int32), side="left")
    idx = jnp.clip(idx, 0, ladder.shape[0] - 1)
    return ladder[idx].reshape(H * W)


def sort_rays_into_blocks(
    counts: jnp.ndarray, block_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort ray indices by sample count; return (order, per-block budget).

    order: (R,) int32 permutation; budgets: (R//block, ) int32 = max count
    in each block (conservative).  R must be divisible by block_size (pad
    rays upstream).
    """
    order = jnp.argsort(counts)
    sorted_counts = counts[order]
    nblocks = counts.shape[0] // block_size
    budgets = sorted_counts.reshape(nblocks, block_size).max(axis=1)
    return order.astype(jnp.int32), budgets


def compute_savings(counts: jnp.ndarray, ns_full: int) -> dict:
    """Analytic work-reduction stats (paper: avg 120 vs 192 on Lego)."""
    avg = float(jnp.mean(counts))
    return {
        "avg_samples_per_ray": avg,
        "sample_reduction": ns_full / max(avg, 1e-9),
        "fraction_background": float(jnp.mean(counts <= min(DEFAULT_CANDIDATES))),
    }
