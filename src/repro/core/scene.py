"""Procedural analytic scenes with exact ground-truth (sigma, color) fields.

The paper evaluates on Synthetic-NeRF blender scenes (Lego, Hotdog, ...)
which are not available offline.  We substitute analytic scenes: smooth
compositions of colored SDF primitives inside the unit cube, with an exact
volume-density field.  Ground-truth images are produced by finely marching
the *analytic* field (no network), so PSNR comparisons between rendering
strategies (full sampling / adaptive / decoupled / naive reduction) are
exact-reference comparisons, matching the paper's claim structure.

Scenes mimic the paper's difficulty mix: "lego"-like structured clutter,
a "hotdog"-like pair of blobs on a plate, and a mostly-empty "mic"-like
scene (many background pixels — where adaptive sampling shines).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import rendering

Field = Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]


def _sphere_sdf(p, center, radius):
    return jnp.linalg.norm(p - jnp.asarray(center), axis=-1) - radius


def _box_sdf(p, center, half):
    q = jnp.abs(p - jnp.asarray(center)) - jnp.asarray(half)
    outside = jnp.linalg.norm(jnp.maximum(q, 0.0), axis=-1)
    inside = jnp.minimum(jnp.max(q, axis=-1), 0.0)
    return outside + inside


def _primitives_to_field(prims, sharpness=60.0, density_scale=40.0) -> Field:
    """Soft-min composition: density = scale * sigmoid(-sharpness * sdf)."""

    def field(p):
        sds, cols = [], []
        for kind, args, color in prims:
            if kind == "sphere":
                sds.append(_sphere_sdf(p, *args))
            else:
                sds.append(_box_sdf(p, *args))
            cols.append(jnp.asarray(color))
        sd = jnp.stack(sds, axis=-1)  # (N, P)
        occ = jax.nn.sigmoid(-sharpness * sd)  # (N, P)
        sigma = density_scale * jnp.max(occ, axis=-1)
        w = jax.nn.softmax(-sharpness * sd, axis=-1)  # color of nearest prim
        color = w @ jnp.stack(cols, axis=0)
        return sigma, jnp.clip(color, 0.0, 1.0)

    return field


def make_scene(name: str = "lego") -> Field:
    if name == "lego":
        prims = [
            ("box", ((0.5, 0.5, 0.28), (0.26, 0.26, 0.03)), (0.85, 0.75, 0.2)),
            ("box", ((0.42, 0.5, 0.38), (0.06, 0.18, 0.07)), (0.9, 0.6, 0.1)),
            ("box", ((0.62, 0.46, 0.40), (0.05, 0.05, 0.10)), (0.8, 0.2, 0.1)),
            ("sphere", ((0.56, 0.62, 0.50), 0.07), (0.2, 0.4, 0.85)),
            ("sphere", ((0.40, 0.38, 0.52), 0.05), (0.2, 0.8, 0.3)),
            ("box", ((0.52, 0.52, 0.56), (0.03, 0.12, 0.03)), (0.7, 0.7, 0.75)),
        ]
        return _primitives_to_field(prims)
    if name == "hotdog":
        prims = [
            ("box", ((0.5, 0.5, 0.3), (0.3, 0.3, 0.02)), (0.95, 0.95, 0.92)),
            ("sphere", ((0.42, 0.5, 0.4), 0.1), (0.75, 0.45, 0.2)),
            ("sphere", ((0.58, 0.5, 0.4), 0.1), (0.75, 0.45, 0.2)),
            ("box", ((0.5, 0.5, 0.44), (0.16, 0.04, 0.03)), (0.85, 0.25, 0.1)),
        ]
        return _primitives_to_field(prims, sharpness=50.0)
    if name == "mic":  # mostly empty — background-heavy like the paper's Mic
        prims = [
            ("sphere", ((0.5, 0.5, 0.62), 0.08), (0.6, 0.6, 0.65)),
            ("box", ((0.5, 0.5, 0.42), (0.015, 0.015, 0.13)), (0.3, 0.3, 0.32)),
            ("box", ((0.5, 0.5, 0.28), (0.07, 0.07, 0.012)), (0.25, 0.25, 0.28)),
        ]
        return _primitives_to_field(prims, sharpness=80.0)
    raise ValueError(f"unknown scene {name!r}")


@dataclasses.dataclass(frozen=True)
class Camera:
    height: int
    width: int
    focal: float  # in pixels
    # camera-to-world rotation (3,3) and origin (3,)
    c2w_rot: np.ndarray
    origin: np.ndarray


def look_at_camera(
    height: int, width: int, theta: float, phi: float, radius: float = 1.2,
    center=(0.5, 0.5, 0.42), fov_deg: float = 45.0,
) -> Camera:
    center = np.asarray(center, np.float32)
    eye = center + radius * np.asarray(
        [np.cos(phi) * np.cos(theta), np.cos(phi) * np.sin(theta), np.sin(phi)],
        np.float32,
    )
    fwd = center - eye
    fwd = fwd / np.linalg.norm(fwd)
    right = np.cross(fwd, np.asarray([0.0, 0.0, 1.0], np.float32))
    right = right / np.linalg.norm(right)
    up = np.cross(right, fwd)
    rot = np.stack([right, up, fwd], axis=-1).astype(np.float32)  # cols
    focal = 0.5 * width / np.tan(0.5 * np.deg2rad(fov_deg))
    return Camera(height, width, float(focal), rot, eye.astype(np.float32))


def camera_rays(cam: Camera):
    """Returns (origins (H*W, 3), dirs (H*W, 3)) — dirs are unit vectors."""
    j, i = jnp.meshgrid(
        jnp.arange(cam.height, dtype=jnp.float32),
        jnp.arange(cam.width, dtype=jnp.float32),
        indexing="ij",
    )
    x = (i - cam.width * 0.5 + 0.5) / cam.focal
    y = -(j - cam.height * 0.5 + 0.5) / cam.focal
    d_cam = jnp.stack([x, y, jnp.ones_like(x)], axis=-1)  # (H, W, 3)
    rot = jnp.asarray(cam.c2w_rot)
    d = d_cam @ rot.T
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    o = jnp.broadcast_to(jnp.asarray(cam.origin), d.shape)
    return o.reshape(-1, 3), d.reshape(-1, 3)


# Ray-march bounds: scenes live in the unit cube; near/far fixed.
NEAR, FAR = 0.2, 2.2


def sample_points(origins, dirs, n_samples: int, key=None):
    """Stratified (if key) or midpoint sampling of n_samples along each ray.

    Returns points (R, S, 3), deltas (R, S), ts (R, S).
    """
    R = origins.shape[0]
    edges = jnp.linspace(NEAR, FAR, n_samples + 1)
    mids = 0.5 * (edges[:-1] + edges[1:])
    if key is not None:
        jitter = (jax.random.uniform(key, (R, n_samples)) - 0.5) * (
            (FAR - NEAR) / n_samples
        )
        ts = mids[None, :] + jitter
    else:
        ts = jnp.broadcast_to(mids[None, :], (R, n_samples))
    deltas = jnp.full((R, n_samples), (FAR - NEAR) / n_samples)
    pts = origins[:, None, :] + ts[..., None] * dirs[:, None, :]
    return pts, deltas, ts


@partial(jax.jit, static_argnums=(0, 3))
def render_reference(field: Field, origins, dirs, n_samples: int = 512):
    """Ground-truth render by finely marching the analytic field."""
    pts, deltas, _ = sample_points(origins, dirs, n_samples)
    flat = pts.reshape(-1, 3)
    sigma, color = field(flat)
    # points outside the unit cube contribute nothing
    inside = jnp.all((flat >= 0.0) & (flat <= 1.0), axis=-1)
    sigma = jnp.where(inside, sigma, 0.0)
    sigma = sigma.reshape(pts.shape[:2])
    color = color.reshape(pts.shape[:2] + (3,))
    rgb, acc, _ = rendering.composite(sigma, color, deltas)
    return rgb, acc
