"""Volume rendering (Eq. 1 of the paper) + early-termination accounting.

``C = sum_i T_i * alpha_i * c_i,  T_i = prod_{j<i} (1 - alpha_j),
  alpha_i = 1 - exp(-sigma_i * delta_i)``

All functions operate on per-ray sample arrays of static shape; masking
(``valid``) realizes variable sample counts with static shapes (the TPU-
legal form of the paper's per-pixel adaptivity).
"""
from __future__ import annotations

import jax.numpy as jnp

# Opacity saturation threshold for early termination (§6.6: terminate when
# accumulated opacity exceeds ~1; Instant-NGP uses T < 1e-4).
EARLY_TERM_TRANSMITTANCE = 1e-4


def alphas_from_sigmas(sigmas: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
    return 1.0 - jnp.exp(-sigmas * deltas)


def transmittance(alphas: jnp.ndarray) -> jnp.ndarray:
    """Exclusive cumulative product of (1 - alpha) along the last axis."""
    one_minus = jnp.clip(1.0 - alphas, 1e-10, 1.0)
    log_t = jnp.cumsum(jnp.log(one_minus), axis=-1)
    # exclusive: shift right, T_0 = 1
    log_t = jnp.concatenate(
        [jnp.zeros_like(log_t[..., :1]), log_t[..., :-1]], axis=-1
    )
    return jnp.exp(log_t)


def composite(
    sigmas: jnp.ndarray,
    colors: jnp.ndarray,
    deltas: jnp.ndarray,
    valid: jnp.ndarray | None = None,
    white_background: bool = True,
):
    """Volume-render rays.

    sigmas: (..., S), colors: (..., S, 3), deltas: (..., S),
    valid: optional bool (..., S) — samples beyond a ray's adaptive budget.
    Returns (rgb (..., 3), acc (...,), weights (..., S)).
    """
    if valid is not None:
        sigmas = jnp.where(valid, sigmas, 0.0)
    alphas = alphas_from_sigmas(sigmas, deltas)
    trans = transmittance(alphas)
    weights = trans * alphas
    rgb = jnp.sum(weights[..., None] * colors, axis=-2)
    acc = jnp.sum(weights, axis=-1)
    if white_background:
        rgb = rgb + (1.0 - acc[..., None])
    return rgb, acc, weights


def expected_termination_depth(
    weights: jnp.ndarray, ts: jnp.ndarray, acc: jnp.ndarray, far: float
) -> jnp.ndarray:
    """Per-ray proxy termination depth ``E[t] + (1 - acc) * far``.

    weights/ts: (..., S), acc: (...,).  Rays that hit nothing park their
    depth at the far plane, so warped background stays background.  Shared
    by the Phase-I probe (stride-d resolution) and the Phase-II march
    (full per-ray resolution) — the framecache warp primitive reprojects
    per-pixel maps with whichever is available, preferring the march's.
    """
    return jnp.sum(weights * ts, axis=-1) + (1.0 - acc) * far


def early_termination_counts(alphas: jnp.ndarray) -> jnp.ndarray:
    """Number of samples each ray *needs* before T drops below threshold.

    Used by benchmarks/early_term.py to quantify §6.6's orthogonal saving
    (the while_loop renderer realizes it block-wise; this gives the ideal
    per-ray count).
    """
    trans = transmittance(alphas)
    needed = jnp.sum(trans >= EARLY_TERM_TRANSMITTANCE, axis=-1)
    return needed


def psnr(img: jnp.ndarray, ref: jnp.ndarray) -> jnp.ndarray:
    mse = jnp.mean((img - ref) ** 2)
    return -10.0 * jnp.log10(jnp.maximum(mse, 1e-12))


def ssim(img: jnp.ndarray, ref: jnp.ndarray, window: int = 8) -> jnp.ndarray:
    """Simplified SSIM over non-overlapping windows (adequate for deltas).

    img/ref: (H, W, 3) in [0, 1].
    """
    H, W, C = img.shape
    h, w = H // window * window, W // window * window

    def blocks(x):
        x = x[:h, :w]
        x = x.reshape(h // window, window, w // window, window, C)
        return x.transpose(0, 2, 1, 3, 4).reshape(-1, window * window, C)

    a, b = blocks(img), blocks(ref)
    mu_a, mu_b = a.mean(axis=1), b.mean(axis=1)
    var_a, var_b = a.var(axis=1), b.var(axis=1)
    cov = ((a - mu_a[:, None]) * (b - mu_b[:, None])).mean(axis=1)
    c1, c2 = 0.01**2, 0.03**2
    s = ((2 * mu_a * mu_b + c1) * (2 * cov + c2)) / (
        (mu_a**2 + mu_b**2 + c1) * (var_a + var_b + c2)
    )
    return s.mean()
