"""Field abstraction — the composability seam of the renderer.

Everything downstream of sampling (decoupling, adaptive probing, the
two-phase ASDR pipeline, the Pallas volume-render kernel driver) consumes a
``FieldFns`` pair instead of a concrete model, so the same pipeline runs

  * the trained Instant-NGP network      (``model.field_fns``),
  * the exact analytic scene field       (``analytic_field_fns``) — used by
    tests to validate the *algorithm* independently of training error,
  * a Pallas-kernel-backed fused network (``kernels.ops.field_fns``).

``density(points) -> (sigma (N,), geo (N, G))`` and
``color(geo, dirs) -> rgb (N, 3)``.  For analytic fields the "geo feature"
is simply the ground-truth color and ``color`` is a projection.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp


class FieldFns(NamedTuple):
    density: Callable  # (N,3) -> (sigma (N,), geo (N,G))
    color: Callable    # (geo (N,G), dirs (N,3)) -> rgb (N,3)
    # Optional fused-march resources (kernels.ops.FusedMarchResources).
    # When present AND ASDRConfig.march_backend == "fused", Phase II runs
    # the single-kernel streaming march (kernels/fused_march.py) instead
    # of chunked density/color calls.  None everywhere else — analytic
    # and pure-jnp fields keep the reference chunked march.
    fused: object = None


def analytic_field_fns(field) -> FieldFns:
    """Wrap an analytic ``scene.Field`` (points -> (sigma, color))."""

    def density(points):
        sigma, color = field(points)
        inside = jnp.all((points >= 0.0) & (points <= 1.0), axis=-1)
        return jnp.where(inside, sigma, 0.0), color

    def color(geo, dirs):
        del dirs
        return geo

    return FieldFns(density=density, color=color)
