"""Multi-resolution hash-grid encoding (Instant-NGP) with ASDR's level-split layout.

The paper (§5.2.1) observes that *low-resolution* levels waste hash-table
space (a 16³ grid uses 1/128 of a 2^19 table) and that hashing them causes
access conflicts; it therefore stores low-res levels *de-hashed* (direct
(x,y,z)-derived addresses) and keeps hashing only for levels whose dense
size exceeds the table.  That is exactly the split we implement: a level is
"dense" when ``(res+1)^3 <= table_size`` — dense levels index directly
(perfect locality, the TPU analogue of conflict-free crossbar rows) and
high-res levels use Instant-NGP's spatial hash (Eq. 2).

All functions are pure; parameters are plain pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Instant-NGP's hash primes (Eq. 2 of the ASDR paper / Müller et al. 2022).
PRIMES = (1, 2654435761, 805459861)


@dataclasses.dataclass(frozen=True)
class HashGridConfig:
    n_levels: int = 16
    log2_table_size: int = 19
    feature_dim: int = 2
    base_resolution: int = 16
    max_resolution: int = 2048

    @property
    def table_size(self) -> int:
        return 1 << self.log2_table_size

    @property
    def growth_factor(self) -> float:
        if self.n_levels == 1:
            return 1.0
        return float(
            np.exp(
                (np.log(self.max_resolution) - np.log(self.base_resolution))
                / (self.n_levels - 1)
            )
        )

    def level_resolution(self, level: int) -> int:
        return int(np.floor(self.base_resolution * self.growth_factor**level))

    def level_resolutions(self) -> Tuple[int, ...]:
        return tuple(self.level_resolution(l) for l in range(self.n_levels))

    def level_is_dense(self, level: int) -> bool:
        res = self.level_resolution(level)
        return (res + 1) ** 3 <= self.table_size

    @property
    def output_dim(self) -> int:
        return self.n_levels * self.feature_dim


def init_hashgrid(key: jax.Array, cfg: HashGridConfig, dtype=jnp.float32):
    """Uniform(-1e-4, 1e-4) init, as in Instant-NGP.

    Returns a single stacked table ``(n_levels, table_size, feature_dim)``.
    Dense levels only use their first ``(res+1)^3`` rows; the remainder is
    the "storage headroom" the paper talks about (we report utilization in
    benchmarks/reuse_cache.py).
    """
    shape = (cfg.n_levels, cfg.table_size, cfg.feature_dim)
    return jax.random.uniform(key, shape, dtype, minval=-1e-4, maxval=1e-4)


def _corner_offsets() -> jnp.ndarray:
    """The 8 corners of a unit voxel, shape (8, 3), int32."""
    offs = np.stack(np.meshgrid([0, 1], [0, 1], [0, 1], indexing="ij"), axis=-1)
    return jnp.asarray(offs.reshape(8, 3), dtype=jnp.int32)


def level_indices(coords: jnp.ndarray, res: int, dense: bool, table_size: int) -> jnp.ndarray:
    """Map integer vertex coords (..., 3) -> table row indices (...,).

    Dense levels: direct row-major address (paper's de-hashed addressing).
    Hashed levels: Instant-NGP spatial hash (Eq. 2).
    """
    coords = coords.astype(jnp.uint32)
    if dense:
        stride = res + 1
        idx = coords[..., 0] + stride * (coords[..., 1] + stride * coords[..., 2])
        return idx.astype(jnp.int32)
    h = coords[..., 0] * np.uint32(PRIMES[0])
    h = h ^ (coords[..., 1] * np.uint32(PRIMES[1]))
    h = h ^ (coords[..., 2] * np.uint32(PRIMES[2]))
    return (h % np.uint32(table_size)).astype(jnp.int32)


def encode_level(
    points: jnp.ndarray, table: jnp.ndarray, res: int, dense: bool
) -> jnp.ndarray:
    """Encode points (N, 3) in [0,1]^3 against one level's table (T, F)."""
    scaled = points * res  # (N, 3)
    base = jnp.floor(scaled).astype(jnp.int32)
    base = jnp.clip(base, 0, res - 1)
    frac = scaled - base  # (N, 3) in [0, 1)

    corners = base[:, None, :] + _corner_offsets()[None, :, :]  # (N, 8, 3)
    idx = level_indices(corners, res, dense, table.shape[0])  # (N, 8)
    feats = table[idx]  # (N, 8, F)  -- XLA gather

    # Trilinear weights: prod over axes of (1-frac) or frac per corner bit.
    offs = _corner_offsets().astype(points.dtype)  # (8, 3)
    w = jnp.where(offs[None, :, :] == 1.0, frac[:, None, :], 1.0 - frac[:, None, :])
    w = jnp.prod(w, axis=-1)  # (N, 8)
    return jnp.sum(feats * w[..., None], axis=1)  # (N, F)


def encode(points: jnp.ndarray, tables: jnp.ndarray, cfg: HashGridConfig) -> jnp.ndarray:
    """Full multi-resolution encoding: (N, 3) -> (N, n_levels * feature_dim)."""
    outs = []
    for l in range(cfg.n_levels):
        res = cfg.level_resolution(l)
        outs.append(encode_level(points, tables[l], res, cfg.level_is_dense(l)))
    return jnp.concatenate(outs, axis=-1)


def level_voxel_ids(points: jnp.ndarray, cfg: HashGridConfig) -> jnp.ndarray:
    """Voxel id per (point, level) — used by reuse/locality profiling.

    Returns (N, n_levels) int32: the row-major id of the voxel containing
    each point at each level (NOT the hash — two points share a voxel id iff
    they actually fall in the same cube, matching the paper's Fig. 15).
    """
    ids = []
    for l in range(cfg.n_levels):
        res = cfg.level_resolution(l)
        base = jnp.clip(jnp.floor(points * res).astype(jnp.int64), 0, res - 1)
        ids.append(base[:, 0] + res * (base[:, 1] + res * base[:, 2]))
    return jnp.stack(ids, axis=-1).astype(jnp.int64)


def storage_utilization(cfg: HashGridConfig) -> dict:
    """Reproduces the paper's Fig. 13 numbers structurally.

    'naive' = every level hash-mapped into a full table (dense levels waste
    the tail). 'hybrid' = dense levels sized exactly + replicated copies to
    fill the same physical budget (paper: 85.95% -> we report the analytic
    utilization of both layouts for our config).
    """
    T = cfg.table_size
    naive_used, hybrid_used, total = 0, 0, 0
    copies = {}
    for l in range(cfg.n_levels):
        res = cfg.level_resolution(l)
        dense_size = (res + 1) ** 3
        total += T
        if dense_size <= T:
            naive_used += dense_size  # hashing a small level still only touches dense_size rows
            n_copies = max(1, T // dense_size)
            copies[l] = n_copies
            hybrid_used += n_copies * dense_size
        else:
            naive_used += T
            hybrid_used += T
            copies[l] = 1
    return {
        "naive_utilization": naive_used / total,
        "hybrid_utilization": hybrid_used / total,
        "copies_per_level": copies,
    }
