"""Instant-NGP training on analytic scenes (the substrate the paper assumes).

The paper accelerates *inference* of a trained Instant-NGP; training is the
substrate we must build ourselves (task spec: "build every substrate the
paper depends on").  We train on procedural analytic scenes (scene.py) by
photometric MSE against analytically-rendered reference rays, with AdamW
(optim/) and stratified ray-batch sampling from a pool of camera views.

``train_ngp`` is what benchmarks/ and examples/ call to obtain the model
that the ASDR pipeline then renders.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from . import model as model_lib
from . import scene as scene_lib


@dataclasses.dataclass(frozen=True)
class NGPTrainConfig:
    scene: str = "lego"
    steps: int = 300
    batch_rays: int = 1024
    n_samples: int = 48
    lr: float = 5e-3
    n_views: int = 12
    view_hw: Tuple[int, int] = (96, 96)
    seed: int = 0
    log_every: int = 50


def _make_view_rays(cfg: NGPTrainConfig, field):
    """Pre-render reference colors for rays from a ring of training views."""
    all_o, all_d, all_c = [], [], []
    rng = np.random.default_rng(cfg.seed)
    for v in range(cfg.n_views):
        theta = 2.0 * np.pi * v / cfg.n_views + rng.uniform(0, 0.1)
        phi = rng.uniform(0.35, 0.8)
        cam = scene_lib.look_at_camera(*cfg.view_hw, theta=theta, phi=phi)
        o, d = scene_lib.camera_rays(cam)
        ref, _ = scene_lib.render_reference(field, o, d)
        all_o.append(np.asarray(o))
        all_d.append(np.asarray(d))
        all_c.append(np.asarray(ref))
    return (
        jnp.asarray(np.concatenate(all_o)),
        jnp.asarray(np.concatenate(all_d)),
        jnp.asarray(np.concatenate(all_c)),
    )


def make_train_step(cfg: NGPTrainConfig, model_cfg: model_lib.NGPConfig,
                    opt_cfg: optim.AdamWConfig):
    def loss_fn(params, o, d, ref, key):
        rgb, _ = model_lib.render_fixed(
            params, model_cfg, o, d, cfg.n_samples, key
        )
        return jnp.mean((rgb - ref) ** 2)

    @jax.jit
    def step(params, opt_state, o, d, ref, key, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, o, d, ref, key)
        grads, _ = optim.clip_by_global_norm(grads, 1.0)
        params, opt_state = optim.adamw_update(
            grads, opt_state, params, opt_cfg, lr
        )
        return params, opt_state, loss

    return step


def train_ngp(cfg: NGPTrainConfig = NGPTrainConfig(),
              model_cfg: model_lib.NGPConfig | None = None,
              verbose: bool = True):
    """Train and return (params, model_cfg, field, history)."""
    model_cfg = model_cfg or model_lib.NGPConfig.small()
    field = scene_lib.make_scene(cfg.scene)
    key = jax.random.PRNGKey(cfg.seed)
    key, init_key = jax.random.split(key)
    params = model_lib.init_ngp(init_key, model_cfg)

    opt_cfg = optim.AdamWConfig(lr=cfg.lr, b2=0.99, eps=1e-15)
    opt_state = optim.adamw_init(params, opt_cfg)
    sched = optim.cosine_schedule(cfg.lr, cfg.steps)

    o, d, ref = _make_view_rays(cfg, field)
    n_rays = o.shape[0]
    step = make_train_step(cfg, model_cfg, opt_cfg)

    history = []
    t0 = time.time()
    for i in range(cfg.steps):
        key, bkey, skey = jax.random.split(key, 3)
        idx = jax.random.randint(bkey, (cfg.batch_rays,), 0, n_rays)
        params, opt_state, loss = step(
            params, opt_state, o[idx], d[idx], ref[idx],
            skey, sched(jnp.asarray(i)),
        )
        if i % cfg.log_every == 0 or i == cfg.steps - 1:
            history.append((i, float(loss)))
            if verbose:
                print(
                    f"[train_ngp {cfg.scene}] step {i:4d} "
                    f"loss {float(loss):.5f} ({time.time()-t0:.1f}s)"
                )
    return params, model_cfg, field, history
