"""§4.3 — Color-density decoupling via color-wise locality.

Every sample gets a density-MLP evaluation; only every ``n``-th sample (the
group anchor) gets a color-MLP evaluation.  Non-anchor colors are linear
interpolations between the two enclosing anchors (the paper interpolates
between c_{(i-1)n+1} and c_{in+1}; the trailing group clamps to the last
anchor).  With n=2 the paper reports ~46% MLP-compute reduction at ~0 PSNR
loss, beating naive 2x sample reduction by ~1.7 PSNR (Fig. 9) — reproduced
in benchmarks/sweeps.py and benchmarks/quality.py.
"""
from __future__ import annotations


import jax.numpy as jnp

from . import rendering, scene
from .fields import FieldFns


def interpolate_group_colors(anchor_colors: jnp.ndarray, n: int, S: int) -> jnp.ndarray:
    """Expand anchor colors (R, A, 3) to all samples (R, S, 3) by lerp.

    Anchors sit at sample indices 0, n, 2n, ...  A = ceil(S / n).
    Sample j lies in group i = j // n with offset t = (j % n) / n and is
    lerp(anchor_i, anchor_{i+1}, t) (anchor index clamped at the end).
    """
    R, A, _ = anchor_colors.shape
    j = jnp.arange(S)
    gi = j // n
    t = (j % n).astype(anchor_colors.dtype) / n
    left = anchor_colors[:, jnp.clip(gi, 0, A - 1)]
    right = anchor_colors[:, jnp.clip(gi + 1, 0, A - 1)]
    return left + (right - left) * t[None, :, None]


def render_decoupled(
    fns: FieldFns, origins, dirs, n_samples: int, group: int = 2,
    key=None, white_background: bool = True,
):
    """Decoupled renderer: density for all samples, color for anchors only.

    Returns (rgb, stats) where stats counts actual MLP evaluations.
    """
    pts, deltas, _ = scene.sample_points(origins, dirs, n_samples, key)
    R, S = pts.shape[:2]
    flat = pts.reshape(-1, 3)
    sigma, geo = fns.density(flat)
    sigma = sigma.reshape(R, S)
    geo = geo.reshape(R, S, -1)

    anchor_idx = jnp.arange(0, S, group)
    A = anchor_idx.shape[0]
    geo_anchor = geo[:, anchor_idx].reshape(R * A, -1)
    dirs_anchor = jnp.repeat(dirs, A, axis=0)
    anchor_colors = fns.color(geo_anchor, dirs_anchor)
    anchor_colors = anchor_colors.reshape(R, A, 3)

    colors = interpolate_group_colors(anchor_colors, group, S)
    rgb, acc, _ = rendering.composite(
        sigma, colors, deltas, white_background=white_background
    )
    stats = {
        "density_evals": R * S,
        "color_evals": R * A,
        "color_eval_fraction": A / S,
    }
    return rgb, stats


def render_naive_reduced(
    fns: FieldFns, origins, dirs, n_samples: int, factor: int = 2, key=None,
):
    """The paper's Fig. 9(b) strawman: just use n_samples // factor samples
    (both density AND color MLP run on the reduced set)."""
    from . import pipeline

    rgb, _ = pipeline.render_fixed_fns(
        fns, origins, dirs, n_samples // factor, key
    )
    return rgb


def mlp_flops_saved(cfg, n_samples: int, group: int) -> dict:
    """Analytic MLP-FLOP reduction from decoupling (paper: 46% at n=2 with
    the 92%-color-share MLP)."""
    from . import mlp as mlp_lib

    f = mlp_lib.flops_per_sample(cfg.net)
    full = n_samples * (f["density_flops"] + f["color_flops"])
    anchors = -(-n_samples // group)  # ceil
    dec = n_samples * f["density_flops"] + anchors * f["color_flops"]
    return {
        "full_mlp_flops": full,
        "decoupled_mlp_flops": dec,
        "reduction_fraction": 1.0 - dec / full,
    }
