"""Instant-NGP's density and color MLPs + spherical-harmonics direction encoding.

Shapes follow the paper (§4.3 / Fig. 6b): the density network maps the
32-d grid encoding to [density, 15-d geometry feature]; the color network
consumes [geometry feature, SH(dir)] and emits RGB.  The color network is
~92% of MLP FLOPs (paper §3, Challenge 2) — `flops_per_sample` below lets
benchmarks report that split exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    encoding_dim: int = 32          # n_levels * feature_dim
    density_hidden: int = 64
    density_layers: int = 1         # hidden layers
    geo_feature_dim: int = 15
    sh_degree: int = 4              # 16 SH components
    color_hidden: int = 64
    color_layers: int = 2           # hidden layers

    @property
    def sh_dim(self) -> int:
        return self.sh_degree**2

    @property
    def color_input_dim(self) -> int:
        return self.geo_feature_dim + self.sh_dim


def _dense_init(key, fan_in, fan_out, dtype):
    scale = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, (fan_in, fan_out), dtype, -scale, scale)


def init_mlps(key: jax.Array, cfg: MLPConfig, dtype=jnp.float32) -> Dict:
    keys = jax.random.split(key, 8)
    d_sizes = (
        [cfg.encoding_dim]
        + [cfg.density_hidden] * cfg.density_layers
        + [1 + cfg.geo_feature_dim]
    )
    c_sizes = (
        [cfg.color_input_dim] + [cfg.color_hidden] * cfg.color_layers + [3]
    )
    density = [
        _dense_init(keys[i], d_sizes[i], d_sizes[i + 1], dtype)
        for i in range(len(d_sizes) - 1)
    ]
    color = [
        _dense_init(keys[4 + i], c_sizes[i], c_sizes[i + 1], dtype)
        for i in range(len(c_sizes) - 1)
    ]
    return {"density": density, "color": color}


def _mlp_forward(ws, x, final_act=None):
    for i, w in enumerate(ws):
        x = x @ w
        if i < len(ws) - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act is not None else x


def trunc_exp(x):
    """Numerically-safe exp used by Instant-NGP for density activation."""
    return jnp.exp(jnp.clip(x, -15.0, 15.0))


def density_apply(params: Dict, encoding: jnp.ndarray):
    """(N, encoding_dim) -> (sigma (N,), geo_feat (N, geo_feature_dim))."""
    out = _mlp_forward(params["density"], encoding)
    sigma = trunc_exp(out[..., 0])
    return sigma, out[..., 1:]


def sh_encode(dirs: jnp.ndarray, degree: int = 4) -> jnp.ndarray:
    """Real spherical harmonics up to `degree` (degree<=4 -> 16 dims).

    dirs: (N, 3) unit vectors.
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    xx, yy, zz = x * x, y * y, z * z
    xy, yz, xz = x * y, y * z, x * z
    comps = [jnp.full_like(x, 0.28209479177387814)]
    if degree > 1:
        comps += [
            -0.48860251190291987 * y,
            0.48860251190291987 * z,
            -0.48860251190291987 * x,
        ]
    if degree > 2:
        comps += [
            1.0925484305920792 * xy,
            -1.0925484305920792 * yz,
            0.94617469575755997 * zz - 0.31539156525251999,
            -1.0925484305920792 * xz,
            0.54627421529603959 * (xx - yy),
        ]
    if degree > 3:
        comps += [
            0.59004358992664352 * y * (-3.0 * xx + yy),
            2.8906114426405538 * xy * z,
            0.45704579946446572 * y * (1.0 - 5.0 * zz),
            0.3731763325901154 * z * (5.0 * zz - 3.0),
            0.45704579946446572 * x * (1.0 - 5.0 * zz),
            1.4453057213202769 * z * (xx - yy),
            0.59004358992664352 * x * (-xx + 3.0 * yy),
        ]
    return jnp.stack(comps, axis=-1)


def color_apply(params: Dict, geo_feat: jnp.ndarray, dirs: jnp.ndarray, sh_degree: int = 4):
    """(N, geo) x (N, 3) -> rgb (N, 3) in [0, 1]."""
    sh = sh_encode(dirs, sh_degree)
    x = jnp.concatenate([geo_feat, sh], axis=-1)
    return _mlp_forward(params["color"], x, final_act=jax.nn.sigmoid)


def flops_per_sample(cfg: MLPConfig) -> Dict[str, float]:
    """2*fan_in*fan_out per matmul row — reproduces the paper's 8%/92% split."""
    d_sizes = (
        [cfg.encoding_dim]
        + [cfg.density_hidden] * cfg.density_layers
        + [1 + cfg.geo_feature_dim]
    )
    c_sizes = [cfg.color_input_dim] + [cfg.color_hidden] * cfg.color_layers + [3]
    d = sum(2 * a * b for a, b in zip(d_sizes[:-1], d_sizes[1:]))
    c = sum(2 * a * b for a, b in zip(c_sizes[:-1], c_sizes[1:]))
    return {
        "density_flops": float(d),
        "color_flops": float(c),
        "color_fraction": c / (c + d),
    }
