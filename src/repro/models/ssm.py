"""Mamba-2 SSD (state-space duality) block — chunked scan + O(1) decode.

Recurrence (per head h, state (P, N)):
    h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t),   a_t = exp(dt_t * A)
    y_t = C_t · h_t + D * x_t

Chunked algorithm (Dao & Gu 2024, §6): the sequence is split into chunks of
``ssm_chunk``; within a chunk the contribution is a masked quadratic form
(MXU-friendly), across chunks a short ``lax.scan`` carries the (H, P, N)
state.  ``ssd_scan`` (chunked) == ``ssd_reference`` (naive recurrence) is a
property test.

Projections are kept separate (z/x/B/C/dt) instead of one fused in_proj so
each gets a clean sharding rule: d_inner shards over the model axis (head
parallel), B/C/dt are small and replicated.

Decode: ``ssm_step`` advances one token in O(H*P*N) with a conv ring buffer
— this is what makes the ``long_500k`` cell O(1)-state for SSM archs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import params as pp
from .params import P


class SSMState(NamedTuple):
    h: jax.Array       # (B, H*P, N) running state (flat heads: H alone may
                       # not divide the TP axis — hymba has 50 — but H*P does)
    conv: jax.Array    # (B, conv_w, C_in) conv ring (C_in = di + 2*G*N)


def ssm_init(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = 1  # n_groups
    ks = jax.random.split(key, 8)
    return {
        "z_proj": pp.dense_init(ks[0], (d, di), ("d_model", "ssm_inner")),
        "x_proj": pp.dense_init(ks[1], (d, di), ("d_model", "ssm_inner")),
        "b_proj": pp.dense_init(ks[2], (d, G * N), ("d_model", None)),
        "c_proj": pp.dense_init(ks[3], (d, G * N), ("d_model", None)),
        "dt_proj": pp.dense_init(ks[4], (d, H), ("d_model", None)),
        "conv_w": P(
            0.1 * jax.random.normal(ks[5], (cfg.ssm_conv, di + 2 * G * N)),
            (None, "ssm_inner"),
        ),
        "A_log": P(jnp.log(jnp.linspace(1.0, 16.0, H)), (None,)),
        "D": pp.ones_init((H,), (None,)),
        "dt_bias": pp.zeros_init((H,), (None,)),
        "norm": pp.zeros_init((di,), ("ssm_inner",)),
        "out_proj": pp.dense_init(ks[6], (di, d), ("ssm_inner", "d_model")),
    }


def _causal_conv(u, w):
    """Depthwise causal conv: u (B, S, C), w (K, C) -> (B, S, C)."""
    K = w.shape[0]
    out = jnp.zeros_like(u)
    for i in range(K):
        shifted = jnp.pad(u, ((0, 0), (K - 1 - i, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[i]
    return out


def _split_bcx(p: Dict, x, cfg, return_raw: bool = False, valid_len=None):
    """Project + conv. x (B,S,D) -> xs (B,S,H,P), Bm/Cm (B,S,G,N),
    dt (B,S,H), z (B,S,di).  dt is zeroed beyond valid_len (padded
    positions then neither decay nor update the state)."""
    B_, S, _ = x.shape
    di = cfg.ssm_d_inner
    H, Pd, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, 1
    z = x @ p["z_proj"]
    xc = x @ p["x_proj"]
    bc = jnp.concatenate([x @ p["b_proj"], x @ p["c_proj"]], axis=-1)
    u_raw = jnp.concatenate([xc, bc], axis=-1)        # (B,S,di+2GN)
    u = jax.nn.silu(_causal_conv(u_raw, p["conv_w"]))
    xc, bm, cm = jnp.split(u, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(x @ p["dt_proj"] + p["dt_bias"])  # (B,S,H)
    if valid_len is not None and valid_len < S:
        mask = (jnp.arange(S) < valid_len).astype(dt.dtype)
        dt = dt * mask[None, :, None]
    xs = xc.reshape(B_, S, H, Pd)
    bm = bm.reshape(B_, S, G, N)
    cm = cm.reshape(B_, S, G, N)
    if return_raw:
        return xs, bm, cm, dt, z, u_raw
    return xs, bm, cm, dt, z


def ssd_reference(xs, bm, cm, dt, A, D):
    """Naive O(S) recurrence oracle. xs (B,S,H,P), bm/cm (B,S,G,N),
    dt (B,S,H), A (H,) negative, D (H,).  Returns y (B,S,H,P)."""
    B_, S, H, Pd = xs.shape
    N = bm.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp
        a_t = jnp.exp(dt_t * A)                        # (B,H)
        u = dt_t[..., None, None] * jnp.einsum(
            "bgn,bhp->bhpn", b_t, x_t
        )
        h = a_t[..., None, None] * h + u
        y = jnp.einsum("bhpn,bgn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B_, H, Pd, N), jnp.float32)
    xs_t = jnp.moveaxis(xs.astype(jnp.float32), 1, 0)
    bm_t = jnp.moveaxis(bm.astype(jnp.float32), 1, 0)
    cm_t = jnp.moveaxis(cm.astype(jnp.float32), 1, 0)
    dt_t = jnp.moveaxis(dt.astype(jnp.float32), 1, 0)
    _, ys = jax.lax.scan(step, h0, (xs_t, bm_t, cm_t, dt_t))
    y = jnp.moveaxis(ys, 0, 1)
    return y + xs.astype(jnp.float32) * D[:, None]


def ssd_scan(xs, bm, cm, dt, A, D, chunk: int):
    """Chunked SSD. Same contract as ssd_reference; O(S*chunk) intra work
    plus an O(S/chunk) state scan."""
    B_, S, H, Pd = xs.shape
    N = bm.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    C_ = S // chunk
    f32 = jnp.float32

    xs_c = xs.astype(f32).reshape(B_, C_, chunk, H, Pd)
    bm_c = bm.astype(f32).reshape(B_, C_, chunk, 1, N)
    cm_c = cm.astype(f32).reshape(B_, C_, chunk, 1, N)
    dt_c = dt.astype(f32).reshape(B_, C_, chunk, H)

    loga = dt_c * A                                   # (B,C,Q,H) log decay
    cum = jnp.cumsum(loga, axis=2)                    # inclusive
    # intra-chunk quadratic term
    # M[t,s] = exp(cum[t]-cum[s]) for s<=t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,C,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of masked (+large) entries would be inf and the
    # where() would leak NaN into the backward pass
    diff = jnp.where(tri[None, None, :, :, None], diff, -1e30)
    M = jnp.exp(diff)
    cb = jnp.einsum("bctgn,bcsgn->bcts", cm_c, bm_c)        # (B,C,t,s)
    G_ = cb[..., None] * M * dt_c[:, :, None, :, :]          # (B,C,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", G_, xs_c)

    # chunk-local end states and total decays
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,C,Q,H)
    u = dt_c[..., None, None] * jnp.einsum(
        "bcsgn,bcshp->bcshpn", bm_c, xs_c
    )
    h_local = jnp.einsum("bcsh,bcshpn->bchpn", dec_to_end, u)
    A_chunk = jnp.exp(cum[:, :, -1, :])                     # (B,C,H)

    # inter-chunk state scan
    def step(h, inp):
        a_c, hl = inp
        h_in = h
        h = a_c[..., None, None] * h + hl
        return h, h_in

    h0 = jnp.zeros((B_, H, Pd, N), f32)
    a_t = jnp.moveaxis(A_chunk, 1, 0)
    hl_t = jnp.moveaxis(h_local, 1, 0)
    h_final, h_prevs = jax.lax.scan(step, h0, (a_t, hl_t))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                    # (B,C,H,P,N)

    # inter-chunk contribution: C_t · (exp(cum[t]) * h_prev)
    y_inter = jnp.einsum("bctgn,bchpn->bcthp", cm_c, h_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B_, S, H, Pd)
    return y + xs.astype(f32) * D[:, None], h_final


def ssm_apply_with_state(p: Dict, x, cfg):
    """Full block: x (B,S,D) -> ((B,S,D), SSMState) via chunked SSD.

    The returned state (final h + conv tail) hands off to ``ssm_step`` for
    decode — prefill->decode equivalence is a property test.
    """
    p = pp.cast_tree(p, x.dtype)
    S = x.shape[1]
    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    xs, bm, cm, dt, z, u_raw = _split_bcx(
        p, x, cfg, return_raw=True, valid_len=S
    )
    A = -jnp.exp(p["A_log"])
    y, h_final = ssd_scan(xs, bm, cm, dt, A, p["D"], chunk)
    y = y.reshape(y.shape[0], y.shape[1], -1)               # (B,S,di)
    y = pp.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"])
    out = y.astype(x.dtype) @ p["out_proj"]
    # conv ring tail: last (conv) raw inputs, zero-padded on the left
    K = cfg.ssm_conv
    tail = u_raw[:, max(0, S - K) : S]
    if tail.shape[1] < K:
        tail = jnp.pad(tail, ((0, 0), (K - tail.shape[1], 0), (0, 0)))
    state = SSMState(
        h=h_final.reshape(h_final.shape[0], -1, h_final.shape[-1]),
        conv=tail,
    )
    return (out[:, :S] if pad else out), state


def ssm_apply(p: Dict, x, cfg):
    """x (B,S,D) -> (B,S,D); state discarded (train path)."""
    return ssm_apply_with_state(p, x, cfg)[0]


def ssm_init_state(cfg, batch, dtype=jnp.float32) -> SSMState:
    G = 1
    return SSMState(
        h=jnp.zeros((batch, cfg.ssm_heads * cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv, cfg.ssm_d_inner + 2 * G * cfg.ssm_state),
                       dtype),
    )


def ssm_step(p: Dict, x, state: SSMState, cfg) -> Tuple[jax.Array, SSMState]:
    """Single-token decode. x (B, 1, D) -> (y (B, 1, D), new state)."""
    p = pp.cast_tree(p, x.dtype)
    B_, _, D = x.shape
    di = cfg.ssm_d_inner
    H, Pd, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, 1
    xt = x[:, 0]
    z = xt @ p["z_proj"]
    u_new = jnp.concatenate(
        [xt @ p["x_proj"], xt @ p["b_proj"], xt @ p["c_proj"]], axis=-1
    )
    conv = jnp.concatenate([state.conv[:, 1:], u_new[:, None]], axis=1)
    u = jax.nn.silu(jnp.sum(conv * p["conv_w"][None], axis=1))
    xc, bm, cm = jnp.split(u, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(xt @ p["dt_proj"] + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])

    xs = xc.reshape(B_, H, Pd).astype(jnp.float32)
    bmr = bm.reshape(B_, G, N).astype(jnp.float32)
    cmr = cm.reshape(B_, G, N).astype(jnp.float32)
    a_t = jnp.exp(dt.astype(jnp.float32) * A)               # (B,H)
    upd = dt.astype(jnp.float32)[..., None, None] * jnp.einsum(
        "bgn,bhp->bhpn", bmr, xs
    )
    h_prev = state.h.reshape(B_, H, Pd, N)
    h = a_t[..., None, None] * h_prev + upd
    y = jnp.einsum("bhpn,bgn->bhp", h, cmr) + xs * p["D"][:, None]
    y = y.reshape(B_, di)
    y = pp.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"])
    out = y.astype(x.dtype) @ p["out_proj"]
    return out[:, None], SSMState(h=h.reshape(B_, H * Pd, N),
                                  conv=conv.astype(state.conv.dtype))
