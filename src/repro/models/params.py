"""Annotated parameters: every leaf carries its logical sharding axes.

Init functions build trees whose leaves are ``P(value, axes)``; ``split``
separates them into a value tree and an axes tree that stay structurally
in sync by construction (no hand-maintained parallel spec trees).

Logical axis names (resolved to mesh axes by sharding/rules.py):
  "vocab" "d_model" "d_ff" "heads" "kv_heads" "head_dim" "experts"
  "ssm_inner" "ssm_state" "layers" None
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class P(NamedTuple):
    value: Any
    axes: Tuple[Optional[str], ...]


def is_p(x) -> bool:
    return isinstance(x, P)


def split(tree):
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_p)
    return values, axes


def dense_init(key, shape, axes, scale: float = 1.0, dtype=jnp.float32) -> P:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return P(jax.random.normal(key, shape, dtype) * std, axes)


def embed_init(key, vocab, d_model, dtype=jnp.float32) -> P:
    v = jax.random.normal(key, (vocab, d_model), dtype) * 0.02
    return P(v, ("vocab", "d_model"))


def zeros_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> P:
    return P(jnp.ones(shape, dtype), axes)


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def cast_tree(tree, dtype):
    """Cast float params to the compute dtype (mixed-precision entry point:
    f32 master params, bf16 compute — XLA fuses the casts into consumers)."""
    def cast(w):
        if jnp.issubdtype(w.dtype, jnp.floating):
            return w.astype(dtype)
        return w

    return jax.tree.map(cast, tree)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
