"""Model configuration shared by every assigned architecture.

One dataclass covers the whole zoo; family-specific fields are zero/None
when unused.  ``layer_kinds()`` resolves the local/global attention pattern
(gemma2's 1:1 alternation, gemma3's 5:1, hymba's first/middle/last-global)
into a per-layer window size: ``0`` means full (global) attention, else the
sliding-window width.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024

    # attention features
    qk_norm: bool = False                 # qwen3 / gemma3
    attn_softcap: float = 0.0             # gemma2: 50.0 (0 = off)
    final_softcap: float = 0.0            # gemma2: 30.0 (0 = off)
    window: int = 0                       # sliding-window width for local layers
    local_global_pattern: str = "all_global"
    #   all_global | alternating | five_to_one | ends_global
    rope_theta: float = 10000.0
    post_norms: bool = False              # gemma2/3 sandwich norms

    # ffn
    act: str = "silu"                     # silu (gated) | geglu | gelu
    tie_embeddings: bool = True
    embed_scale: bool = False             # gemma family: x *= sqrt(d_model)

    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024            # routing-group tokens (GShard-style)

    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (hymba): parallel attention + SSM heads in each layer
    parallel_ssm: bool = False

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500               # 30 s audio @ 50 Hz post-conv (stub)

    # vlm (paligemma): image-prefix length with precomputed embeddings (stub)
    prefix_tokens: int = 0

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"               # activation/compute dtype

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the embedding's vocab dim divides any
        (model|data) mesh axis; unembed masks the padding to -inf."""
        return -(-self.vocab // 256) * 256

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = global/full attention)."""
        n, w = self.n_layers, self.window
        if self.local_global_pattern == "all_global" or w == 0:
            return tuple(0 for _ in range(n))
        if self.local_global_pattern == "alternating":      # gemma2
            return tuple(w if i % 2 == 0 else 0 for i in range(n))
        if self.local_global_pattern == "five_to_one":      # gemma3
            return tuple(0 if i % 6 == 5 else w for i in range(n))
        if self.local_global_pattern == "ends_global":      # hymba
            mid = n // 2
            return tuple(0 if i in (0, mid, n - 1) else w for i in range(n))
        raise ValueError(self.local_global_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D roofline)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "moe":
            ffn = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            ffn += d * self.n_experts  # router
        elif self.family == "ssm":
            attn = 0
            ffn = 0
        else:
            ffn = 3 * d * self.d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ns = self.ssm_d_inner, self.ssm_state
            g = 1  # n_groups
            ssm = d * (2 * di + 2 * g * ns + self.ssm_heads) + di * d \
                + self.ssm_conv * (di + 2 * g * ns) + 2 * self.ssm_heads
        per_layer = attn + ffn + ssm + 4 * d
        if self.is_encoder_decoder:
            # whisper: non-gated GELU MLPs (2 matmuls), learned positions,
            # cross-attention per decoder layer
            ffn2 = 2 * d * self.d_ff
            dec_layer = 2 * attn + ffn2 + 6 * d
            enc_layer = attn + ffn2 + 4 * d
            total = (emb + L * dec_layer
                     + self.encoder_layers * enc_layer
                     + (self.encoder_seq + 32768) * d)  # pos embeds
            return int(total)
        total = emb + L * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ffn_act = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        per_layer = attn + ffn_act + d * self.n_experts + 4 * d
        return int(emb + L * per_layer)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (shape) of the assigned grid."""
    name: str           # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
