"""Feed-forward layers: gated dense FFN and GShard-style capacity-routed MoE.

MoE design (EP over the ``model`` mesh axis):
  * tokens are routed in groups of ``moe_group_size`` (capacity is computed
    per group, keeping the dispatch/combine masks small enough to live in
    HBM at 32k sequence lengths);
  * dispatch/combine are einsums against a (G, S_g, E, C) mask — activations
    are replicated over ``model``, expert weights and the dispatched buffer
    are sharded on E, so each model shard builds its own experts' inputs
    locally and the combine ends in the same all-reduce TP already pays;
  * over-capacity tokens are dropped (their combine weight is zero), the
    standard trade for static shapes at scale;
  * top-k ranks are dispatched in priority order (rank 0 claims capacity
    first), matching GShard/Switch semantics.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import params as pp


# ------------------------------------------------------------------ dense FFN
def ffn_init(key, d_model, d_ff, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    out = {
        "wi_gate": pp.dense_init(k1, (d_model, d_ff), ("d_model", "d_ff")),
        "wo": pp.dense_init(k3, (d_ff, d_model), ("d_ff", "d_model")),
    }
    if gated:
        out["wi_up"] = pp.dense_init(k2, (d_model, d_ff), ("d_model", "d_ff"))
    return out


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def ffn_apply(p: Dict, x, act: str = "silu"):
    from ..sharding.activation import constrain

    p = pp.cast_tree(p, x.dtype)
    h = _act(x @ p["wi_gate"], act)
    h = constrain(h, ("batch", "seq", "d_ff_act"))
    if "wi_up" in p:  # gated variant
        h = h * (x @ p["wi_up"])
    return h @ p["wo"]


# ------------------------------------------------------------------------ MoE
def moe_init(key, cfg):
    """Router + stacked expert weights (+ optional shared experts)."""
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    out = {
        "router": pp.dense_init(ks[0], (d, E), ("d_model", None)),
        "wi_gate": pp.dense_init(ks[1], (E, d, f), ("experts", "d_model", "d_ff")),
        "wi_up": pp.dense_init(ks[2], (E, d, f), ("experts", "d_model", "d_ff")),
        "wo": pp.dense_init(ks[3], (E, f, d), ("experts", "d_ff", "d_model")),
    }
    if cfg.n_shared_experts:
        out["shared"] = ffn_init(ks[4], d, cfg.n_shared_experts * f)
    return out


def _route(logits, k, capacity):
    """logits (G, S, E) -> dispatch (G,S,E,C) f32, combine (G,S,E,C) f32.

    Priority dispatch: rank-0 choices claim capacity slots before rank-1,
    etc.  Over-capacity (slot >= C) choices are dropped.
    """
    G, S, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (G, S, k)

    counts = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, S, E, capacity), jnp.float32)
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    for r in range(k):
        e_r = gate_idx[:, :, r]                              # (G, S)
        onehot = jax.nn.one_hot(e_r, E, dtype=jnp.int32)     # (G, S, E)
        # position among this rank's tokens + already-claimed slots
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        counts = counts + jnp.sum(onehot, axis=1)
        slot = jnp.sum(pos * onehot, axis=-1)                # (G, S)
        keep = (slot < capacity).astype(jnp.float32)
        oh_slot = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)
        mask = (onehot.astype(jnp.float32)[..., None] * oh_slot[:, :, None, :])
        dispatch = dispatch + keep[..., None, None] * mask
        combine = combine + (keep * gate_vals[:, :, r])[..., None, None] * mask
    return dispatch, combine


def moe_apply(p: Dict, x, cfg, act: str = "silu"):
    """x (B, S, D) -> (B, S, D).  Capacity-routed top-k experts + shared."""
    p = pp.cast_tree(p, x.dtype)
    B, S, D = x.shape
    gs = min(cfg.moe_group_size, S)
    assert (B * S) % gs == 0
    G = B * S // gs
    xg = x.reshape(G, gs, D)
    k = cfg.top_k
    capacity = max(1, int(gs * k / cfg.n_experts * cfg.capacity_factor))

    logits = xg @ p["router"]                                # (G, gs, E)
    dispatch, combine = _route(logits, k, capacity)

    # dispatch: (G,gs,E,C) x (G,gs,D) -> (G,E,C,D)
    buf = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    h = _act(jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"]), act)
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wi_up"])
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), eo)
    out = out.reshape(B, S, D)

    if "shared" in p:
        out = out + ffn_apply(p["shared"], x, act)
    return out


def moe_aux_loss(logits, k):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, k)
    f = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    pbar = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return E * jnp.sum(f * pbar)
