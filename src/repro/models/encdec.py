"""Whisper-style encoder-decoder backbone.

Per the task spec the conv audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_seq, d_model) directly (30 s of
audio -> 1500 frames at 50 Hz post-conv).  The transformer backbone is
complete: bidirectional encoder, causal decoder with cross-attention,
learned positional embeddings (whisper uses absolute positions, not RoPE),
plain-GELU (non-gated) MLPs.

Serving: cross-attention K/V are computed once from the encoder output at
prefill and are static thereafter — the decode cache carries [self-KV ring
or linear] + [cross-KV static], the standard enc-dec serving layout.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding.activation import constrain
from . import attention as attn
from . import ffn as ffn_lib
from . import params as pp
from .config import ModelConfig
from .params import P


def _attn_init(key, cfg: ModelConfig):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": pp.dense_init(ks[0], (d, H, Dh), ("d_model", "heads", "head_dim")),
        "wk": pp.dense_init(ks[1], (d, KV, Dh), ("d_model", "kv_heads", "head_dim")),
        "wv": pp.dense_init(ks[2], (d, KV, Dh), ("d_model", "kv_heads", "head_dim")),
        "wo": pp.dense_init(ks[3], (H, Dh, d), ("heads", "head_dim", "d_model")),
    }


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "pre_attn_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "attn": _attn_init(ks[0], cfg),
        "pre_ffn_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "ffn": ffn_lib.ffn_init(ks[1], cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "pre_attn_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "attn": _attn_init(ks[0], cfg),
        "pre_cross_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "cross": _attn_init(ks[1], cfg),
        "pre_ffn_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "ffn": ffn_lib.ffn_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


def model_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.encoder_layers + cfg.n_layers + 4)
    tree = {
        "embed": pp.embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "enc_pos": P(
            0.02 * jax.random.normal(ks[1], (cfg.encoder_seq, cfg.d_model)),
            (None, "d_model"),
        ),
        # sized for the largest decoder context in the assigned shape grid
        # (prefill_32k / decode_32k); real whisper caps at 448 — DESIGN.md
        "dec_pos": P(
            0.02 * jax.random.normal(ks[2], (32768, cfg.d_model)),
            (None, "d_model"),
        ),
        "enc_final_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
        "final_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
    }
    top_vals, top_axes = pp.split(tree)

    def stack_layers(init_fn, keys):
        vals_list, axes = [], None
        for k in keys:
            v, axes = pp.split(init_fn(k, cfg))
            vals_list.append(v)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *vals_list)
        axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                            is_leaf=lambda x: isinstance(x, tuple))
        return stacked, axes

    enc_v, enc_a = stack_layers(_enc_layer_init, ks[4 : 4 + cfg.encoder_layers])
    dec_v, dec_a = stack_layers(
        _dec_layer_init, ks[4 + cfg.encoder_layers :]
    )
    values = {**top_vals, "encoder": enc_v, "decoder": dec_v}
    axes = {**top_axes, "encoder": enc_a, "decoder": dec_a}
    return values, axes


def abstract_params(cfg: ModelConfig):
    box = {}

    def f(k):
        vals, axes = model_init(k, cfg)
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def _mha(p, xq, k, v, q_pos, k_pos, causal: bool, cfg, chunk=1024):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(xq.dtype))
    q = constrain(q, ("batch", "seq", "heads_act", None))
    if causal:
        out = attn.attend_chunked(q, k, v, q_pos, k_pos,
                                  chunk=min(chunk, k.shape[1]))
    else:
        # bidirectional: extra_mask=all-True overrides causality
        S, K = q_pos.shape[0], k_pos.shape[0]
        out = attn.attend_chunked(
            q, k, v, q_pos, k_pos, chunk=min(chunk, k.shape[1]),
            extra_mask=jnp.ones((S, K), bool),
        )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(xq.dtype))


def _kv(p, x):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    return k, v


def encode(values, cfg: ModelConfig, frames):
    """frames (B, S_enc, D) stub embeddings -> encoder output (B, S_enc, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + values["enc_pos"][None].astype(
        jnp.dtype(cfg.dtype)
    )
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(x, layer_p):
        h = pp.rms_norm(x, layer_p["pre_attn_norm"], cfg.norm_eps)
        k, v = _kv(layer_p["attn"], h)
        x = x + _mha(layer_p["attn"], h, k, v, pos, pos, causal=False, cfg=cfg)
        h2 = pp.rms_norm(x, layer_p["pre_ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.ffn_apply(layer_p["ffn"], h2, "gelu")
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, None

    x, _ = jax.lax.scan(body, x, values["encoder"])
    return pp.rms_norm(x, values["enc_final_norm"], cfg.norm_eps)


def decode_train(values, cfg: ModelConfig, tokens, enc_out,
                 remat_policy: Optional[str] = None):
    """Teacher-forced decoder pass. Returns logits (B, S, V)."""
    B, S = tokens.shape
    x = values["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + values["dec_pos"][:S][None].astype(x.dtype)
    pos = jnp.arange(S, dtype=jnp.int32)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(x, layer_p):
        h = pp.rms_norm(x, layer_p["pre_attn_norm"], cfg.norm_eps)
        k, v = _kv(layer_p["attn"], h)
        x = x + _mha(layer_p["attn"], h, k, v, pos, pos, causal=True, cfg=cfg)
        hc = pp.rms_norm(x, layer_p["pre_cross_norm"], cfg.norm_eps)
        ck, cv = _kv(layer_p["cross"], enc_out)
        x = x + _mha(layer_p["cross"], hc, ck, cv, pos, enc_pos,
                     causal=False, cfg=cfg)
        h2 = pp.rms_norm(x, layer_p["pre_ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.ffn_apply(layer_p["ffn"], h2, "gelu")
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, None

    if remat_policy == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, values["decoder"])
    x = pp.rms_norm(x, values["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, values["embed"].T.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        lane = jnp.arange(logits.shape[-1])
        logits = jnp.where(lane < cfg.vocab, logits, -1e30)
    return constrain(logits, ("batch", "seq", "vocab_act"))


class EncDecCache(NamedTuple):
    """Flat head storage (KV*Dh trailing axis) — see attention.KVCache."""
    self_k: jax.Array    # (L, B, S_max, KV*Dh)
    self_v: jax.Array
    cross_k: jax.Array   # (L, B, S_enc, KV*Dh)
    cross_v: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> EncDecCache:
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    return EncDecCache(
        self_k=jnp.zeros((L, batch, max_seq, KV * Dh), dtype),
        self_v=jnp.zeros((L, batch, max_seq, KV * Dh), dtype),
        cross_k=jnp.zeros((L, batch, cfg.encoder_seq, KV * Dh), dtype),
        cross_v=jnp.zeros((L, batch, cfg.encoder_seq, KV * Dh), dtype),
    )


def decode_step(values, cfg: ModelConfig, cache: EncDecCache, token, pos):
    """One decoder step against self+cross caches."""
    B = token.shape[0]
    x = values["embed"][token].astype(jnp.dtype(cfg.dtype))
    x = x + jax.lax.dynamic_slice_in_dim(
        values["dec_pos"], pos, 1, axis=0
    )[None].astype(x.dtype)
    enc_pos = jnp.arange(cache.cross_k.shape[2], dtype=jnp.int32)
    new_sk, new_sv = cache.self_k, cache.self_v
    B = token.shape[0]
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    for l in range(cfg.n_layers):
        layer_p = jax.tree.map(lambda v: v[l], values["decoder"])
        h = pp.rms_norm(x, layer_p["pre_attn_norm"], cfg.norm_eps)
        k, v = _kv(layer_p["attn"], h)
        k_flat = k.reshape(B, 1, KV * Dh).astype(new_sk.dtype)
        v_flat = v.reshape(B, 1, KV * Dh).astype(new_sv.dtype)
        new_sk = jax.lax.dynamic_update_slice(
            new_sk, k_flat[None], (l, 0, pos, 0)
        )
        new_sv = jax.lax.dynamic_update_slice(
            new_sv, v_flat[None], (l, 0, pos, 0)
        )
        kv_cache = attn.KVCache(new_sk[l], new_sv[l])
        q = jnp.einsum("bsd,dhk->bshk", h, layer_p["attn"]["wq"].astype(h.dtype))
        a = attn.decode_attend(q, kv_cache, pos, ring=False, kv_heads=KV)
        x = x + jnp.einsum("bshk,hkd->bsd", a,
                           layer_p["attn"]["wo"].astype(h.dtype))
        hc = pp.rms_norm(x, layer_p["pre_cross_norm"], cfg.norm_eps)
        ck4 = cache.cross_k[l].reshape(B, -1, KV, Dh).astype(h.dtype)
        cv4 = cache.cross_v[l].reshape(B, -1, KV, Dh).astype(h.dtype)
        x = x + _mha(
            layer_p["cross"], hc, ck4, cv4,
            jnp.full((1,), pos, jnp.int32), enc_pos, causal=False, cfg=cfg,
        )
        h2 = pp.rms_norm(x, layer_p["pre_ffn_norm"], cfg.norm_eps)
        x = x + ffn_lib.ffn_apply(layer_p["ffn"], h2, "gelu")
    x = pp.rms_norm(x, values["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, values["embed"].T.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        lane = jnp.arange(logits.shape[-1])
        logits = jnp.where(lane < cfg.vocab, logits, -1e30)
    cache = EncDecCache(new_sk, new_sv, cache.cross_k, cache.cross_v)
    return logits, cache


def prefill_cross(values, cfg: ModelConfig, enc_out):
    """Static cross-attention K/V for all decoder layers (flat storage)."""
    cks, cvs = [], []
    B, S_enc, _ = enc_out.shape
    for l in range(cfg.n_layers):
        layer_p = jax.tree.map(lambda v: v[l], values["decoder"])
        ck, cv = _kv(layer_p["cross"], enc_out)
        cks.append(ck.reshape(B, S_enc, -1))
        cvs.append(cv.reshape(B, S_enc, -1))
    return jnp.stack(cks), jnp.stack(cvs)
