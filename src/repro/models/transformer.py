"""Decoder-only transformer zoo: dense / MoE / SSM / hybrid / prefix-VLM.

One layer body covers the whole family; per-layer attention windows arrive
as a scanned int32 array so gemma2's alternating and gemma3's 5:1 patterns
run under a single ``lax.scan`` (train/prefill), while serve decode unrolls
layers in Python so local layers hold O(window) ring caches and global
layers hold linear caches (heterogeneous shapes — the long_500k enabler).

Params are stacked over layers (leading "layers" dim) for scan; decode
slices layer ``l`` with a static index.  All leaves carry logical sharding
axes (models/params.py) resolved by sharding/rules.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.activation import constrain
from . import attention as attn
from . import ffn as ffn_lib
from . import params as pp
from . import ssm as ssm_lib
from .config import ModelConfig


# ------------------------------------------------------------------ layer init
def _attn_init(key, cfg: ModelConfig):
    """Projections stored 2D with combined (heads*head_dim) axes so the TP
    dim always divides the mesh (e.g. qwen3's 40 heads don't divide 16 but
    40*128 does); activations reshape to 4D after the matmul."""
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": pp.dense_init(ks[0], (d, H * Dh), ("d_model", "heads")),
        "wk": pp.dense_init(ks[1], (d, KV * Dh), ("d_model", "kv_heads")),
        "wv": pp.dense_init(ks[2], (d, KV * Dh), ("d_model", "kv_heads")),
        "wo": pp.dense_init(ks[3], (H * Dh, d), ("heads", "d_model")),
    }
    if cfg.qk_norm:
        p["q_norm"] = pp.zeros_init((Dh,), (None,))
        p["k_norm"] = pp.zeros_init((Dh,), (None,))
    return p


def layer_init(key, cfg: ModelConfig, moe: bool):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"pre_attn_norm": pp.zeros_init((d,), ("d_model",))}
    if cfg.family != "ssm":
        p["attn"] = _attn_init(ks[0], cfg)
    if cfg.family in ("ssm", "hybrid"):
        p["ssm"] = ssm_lib.ssm_init(ks[1], cfg)
        if cfg.parallel_ssm:
            p["attn_branch_norm"] = pp.zeros_init((d,), ("d_model",))
            p["ssm_branch_norm"] = pp.zeros_init((d,), ("d_model",))
    if cfg.post_norms:
        p["post_attn_norm"] = pp.zeros_init((d,), ("d_model",))
    if cfg.family != "ssm" and cfg.d_ff > 0:
        p["pre_ffn_norm"] = pp.zeros_init((d,), ("d_model",))
        if moe:
            p["moe"] = ffn_lib.moe_init(ks[2], cfg)
        else:
            p["ffn"] = ffn_lib.ffn_init(ks[2], d, cfg.d_ff)
        if cfg.post_norms:
            p["post_ffn_norm"] = pp.zeros_init((d,), ("d_model",))
    return p


def model_init(key, cfg: ModelConfig):
    """Returns (values, axes) — stacked-layer annotated params."""
    ks = jax.random.split(key, cfg.n_layers + 3)
    tree: Dict[str, Any] = {
        "embed": pp.embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": pp.zeros_init((cfg.d_model,), ("d_model",)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = pp.dense_init(
            ks[1], (cfg.d_model, cfg.padded_vocab), ("d_model", "vocab")
        )
    layer_vals, layer_axes = [], None
    for l in range(cfg.n_layers):
        vals, axes = pp.split(layer_init(ks[3 + l], cfg, moe=cfg.family == "moe"))
        layer_vals.append(vals)
        layer_axes = axes
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_vals)
    stacked_axes = jax.tree.map(
        lambda a: ("layers",) + a, layer_axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    top_vals, top_axes = pp.split(tree)
    values = {**top_vals, "layers": stacked}
    axes = {**top_axes, "layers": stacked_axes}
    return values, axes


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct values, axes) without allocating anything.

    Axes are static strings built during tracing — stashed via closure
    because eval_shape outputs must be arrays."""
    box = {}

    def f(k):
        vals, axes = model_init(k, cfg)
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


# --------------------------------------------------------------- layer forward
def _attention_block(p, x, cfg: ModelConfig, window, positions, k_pos=None,
                     kv_override=None, extra_mask=None, chunk=1024):
    """x (B,S,D) -> attn output (B,S,D).  kv_override: (k, v) for cross-like
    reuse; otherwise self-attention."""
    B, S, _ = x.shape
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    KV = cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    if kv_override is None:
        k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, KV, Dh)
        v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, KV, Dh)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = pp.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = pp.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = attn.apply_rope(q, positions[None], cfg.rope_theta)
    if kv_override is None:
        k = attn.apply_rope(k, positions[None], cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads_act", None))
    k = constrain(k, ("batch", "seq", "heads_act", None))
    kp = positions if k_pos is None else k_pos
    out = attn.attend_chunked(
        q, k, v, positions, kp, window=window,
        softcap_val=cfg.attn_softcap, chunk=min(chunk, k.shape[1]),
        extra_mask=extra_mask,
    )
    out = out.reshape(B, S, H * Dh) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def layer_apply(p, x, cfg: ModelConfig, window, positions,
                extra_mask=None, collect_kv=False):
    """One transformer layer. Returns (x, (kv or None, ssm_state or None))
    — cache material is only emitted when collect_kv (prefill)."""
    kv = None
    ssm_state = None
    h = pp.rms_norm(x, p["pre_attn_norm"], cfg.norm_eps)
    if cfg.family == "ssm":
        s_out, ssm_state = ssm_lib.ssm_apply_with_state(p["ssm"], h, cfg)
        x = x + s_out
    else:
        a_out, kv = _attention_block(
            p["attn"], h, cfg, window, positions, extra_mask=extra_mask
        )
        if cfg.parallel_ssm:
            s_out, ssm_state = ssm_lib.ssm_apply_with_state(p["ssm"], h, cfg)
            a_out = 0.5 * (
                pp.rms_norm(a_out, p["attn_branch_norm"], cfg.norm_eps)
                + pp.rms_norm(s_out, p["ssm_branch_norm"], cfg.norm_eps)
            )
        if cfg.post_norms:
            a_out = pp.rms_norm(a_out, p["post_attn_norm"], cfg.norm_eps)
        x = x + a_out
        if cfg.d_ff > 0:
            h2 = pp.rms_norm(x, p["pre_ffn_norm"], cfg.norm_eps)
            if "moe" in p:
                f_out = ffn_lib.moe_apply(p["moe"], h2, cfg, cfg.act)
            else:
                f_out = ffn_lib.ffn_apply(p["ffn"], h2, cfg.act)
            if cfg.post_norms:
                f_out = pp.rms_norm(f_out, p["post_ffn_norm"], cfg.norm_eps)
            x = x + f_out
    x = constrain(x, ("batch", "seq", "embed_act"))
    if not collect_kv:
        kv, ssm_state = None, None
    return x, (kv, ssm_state)


# -------------------------------------------------------------------- forward
def embed_tokens(values, cfg: ModelConfig, tokens):
    x = values["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x.astype(jnp.dtype(cfg.dtype))


def unembed(values, cfg: ModelConfig, x):
    x = pp.rms_norm(x, values["final_norm"], cfg.norm_eps)
    head = values.get("lm_head", None)
    if head is None:
        head = values["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = pp.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab:  # mask vocab padding to -inf
        lane = jnp.arange(logits.shape[-1])
        logits = jnp.where(lane < cfg.vocab, logits, -1e30)
    return constrain(logits, ("batch", "seq", "vocab_act"))


def _prefix_mask(prefix_len: int, S: int):
    """Bidirectional over the image prefix (paligemma), causal elsewhere.
    Returns bool (S, S) OR'd into the causal mask."""
    if not prefix_len:
        return None
    q = jnp.arange(S)[:, None]
    k = jnp.arange(S)[None, :]
    return (q < prefix_len) & (k < prefix_len)


def forward(values, cfg: ModelConfig, tokens, img_embeds=None,
            remat_policy: Optional[str] = None, collect_kv: bool = False):
    """Train/prefill forward. tokens (B, S_text); img_embeds (B, Pfx, D)
    prepended when cfg.prefix_tokens > 0.  Returns (logits, stacked_kv)."""
    x = embed_tokens(values, cfg, tokens)
    if cfg.prefix_tokens:
        assert img_embeds is not None
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    x = constrain(x, ("batch", "seq", "embed_act"))
    positions = jnp.arange(S, dtype=jnp.int32)
    extra_mask = _prefix_mask(cfg.prefix_tokens, S)
    windows = jnp.asarray(cfg.layer_kinds(), jnp.int32)

    def body(x, xs):
        layer_p, window = xs
        x, kv = layer_apply(
            layer_p, x, cfg, window, positions,
            extra_mask=extra_mask, collect_kv=collect_kv,
        )
        return x, kv

    if remat_policy == "full":
        body = jax.checkpoint(body)
    elif remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots
        )

    x, kvs = jax.lax.scan(body, x, (values["layers"], windows))
    logits = unembed(values, cfg, x)
    return logits, kvs


# ------------------------------------------------------------------- serving
class LayerCache(NamedTuple):
    kv: Optional[attn.KVCache]
    ssm: Optional[ssm_lib.SSMState]


def init_layer_caches(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> List[LayerCache]:
    """Per-layer decode caches: ring buffers for local layers, linear for
    global; SSM states for ssm/hybrid families."""
    caches = []
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    for window in cfg.layer_kinds():
        kv = None
        if cfg.family != "ssm":
            if window and window < max_seq:
                kv = attn.init_cache(batch, window, KV, Dh, dtype)
            else:
                kv = attn.init_cache(batch, max_seq, KV, Dh, dtype)
        ssm_state = None
        if cfg.family in ("ssm", "hybrid"):
            ssm_state = ssm_lib.ssm_init_state(cfg, batch, dtype)
        caches.append(LayerCache(kv=kv, ssm=ssm_state))
    return caches


def _layer_slice(values, l: int):
    return jax.tree.map(lambda v: v[l], values["layers"])


def decode_step(values, cfg: ModelConfig, caches: List[LayerCache],
                token, pos):
    """One decode step. token (B, 1) int32; pos scalar int32 (position of
    this token).  Returns (logits (B,1,V), new caches)."""
    x = embed_tokens(values, cfg, token)
    x = constrain(x, ("batch", None, "embed_act"))
    new_caches = []
    kinds = cfg.layer_kinds()
    for l in range(cfg.n_layers):
        p = _layer_slice(values, l)
        cache = caches[l]
        window = kinds[l]
        h = pp.rms_norm(x, p["pre_attn_norm"], cfg.norm_eps)
        new_kv, new_ssm = cache.kv, cache.ssm
        if cfg.family == "ssm":
            out, new_ssm = ssm_lib.ssm_step(p["ssm"], h, cache.ssm, cfg)
            x = x + out
        else:
            B = h.shape[0]
            H, Dh, KV = cfg.n_heads, cfg.resolved_head_dim, cfg.n_kv_heads
            q = (h @ p["attn"]["wq"].astype(h.dtype)).reshape(B, 1, H, Dh)
            k = (h @ p["attn"]["wk"].astype(h.dtype)).reshape(B, 1, KV, Dh)
            v = (h @ p["attn"]["wv"].astype(h.dtype)).reshape(B, 1, KV, Dh)
            if cfg.qk_norm:
                q = pp.rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
                k = pp.rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
            pos_arr = jnp.full((1, 1), pos, jnp.int32)
            q = attn.apply_rope(q, pos_arr, cfg.rope_theta)
            k = attn.apply_rope(k, pos_arr, cfg.rope_theta)
            ring = attn.is_ring(window, cache.kv.k.shape[1])
            new_kv = attn.cache_update(cache.kv, k, v, pos, ring)
            new_kv = LayerCacheConstrain(new_kv)
            a = attn.decode_attend(
                q, new_kv, pos, ring, KV, window=window,
                softcap_val=cfg.attn_softcap,
            )
            a_out = a.reshape(B, 1, H * Dh) @ p["attn"]["wo"].astype(h.dtype)
            if cfg.parallel_ssm:
                s_out, new_ssm = ssm_lib.ssm_step(p["ssm"], h, cache.ssm, cfg)
                a_out = 0.5 * (
                    pp.rms_norm(a_out, p["attn_branch_norm"], cfg.norm_eps)
                    + pp.rms_norm(s_out, p["ssm_branch_norm"], cfg.norm_eps)
                )
            if cfg.post_norms:
                a_out = pp.rms_norm(a_out, p["post_attn_norm"], cfg.norm_eps)
            x = x + a_out
            if cfg.d_ff > 0:
                h2 = pp.rms_norm(x, p["pre_ffn_norm"], cfg.norm_eps)
                if "moe" in p:
                    f = ffn_lib.moe_apply(p["moe"], h2, cfg, cfg.act)
                else:
                    f = ffn_lib.ffn_apply(p["ffn"], h2, cfg.act)
                if cfg.post_norms:
                    f = pp.rms_norm(f, p["post_ffn_norm"], cfg.norm_eps)
                x = x + f
        new_caches.append(LayerCache(kv=new_kv, ssm=new_ssm))
    logits = unembed(values, cfg, x)
    return logits, new_caches


def LayerCacheConstrain(kv: attn.KVCache) -> attn.KVCache:
    k = constrain(kv.k, ("batch", "kv_seq", "heads_act"))
    v = constrain(kv.v, ("batch", "kv_seq", "heads_act"))
    return attn.KVCache(k, v)


def prefill(values, cfg: ModelConfig, tokens, img_embeds=None,
            max_seq: Optional[int] = None):
    """Prefill forward: returns (logits, per-layer caches ready for decode).

    Local (windowed) layers convert the full-sequence K/V into the ring
    layout (slot s = latest position with pos % W == s); global layers are
    zero-padded out to ``max_seq`` slots so decode has room to append; SSM
    layers hand off their final (h, conv) state.
    """
    logits, (kvs, ssm_states) = forward(
        values, cfg, tokens, img_embeds=img_embeds, collect_kv=True
    )
    caches: List[LayerCache] = []
    kinds = cfg.layer_kinds()
    S = logits.shape[1]
    max_seq = max_seq or S
    for l, window in enumerate(kinds):
        kv = None
        if cfg.family != "ssm" and kvs is not None:
            k_l, v_l = kvs[0][l], kvs[1][l]
            k_l = k_l.reshape(k_l.shape[0], k_l.shape[1], -1)  # flat storage
            v_l = v_l.reshape(v_l.shape[0], v_l.shape[1], -1)
            if window and window < S:
                start = S - window
                rolled_k = jnp.roll(k_l[:, start:], shift=start % window, axis=1)
                rolled_v = jnp.roll(v_l[:, start:], shift=start % window, axis=1)
                kv = attn.KVCache(rolled_k, rolled_v)
            else:
                if max_seq > S:
                    pad = ((0, 0), (0, max_seq - S), (0, 0))
                    k_l = jnp.pad(k_l, pad)
                    v_l = jnp.pad(v_l, pad)
                kv = attn.KVCache(k_l, v_l)
        ssm_state = None
        if ssm_states is not None and cfg.family in ("ssm", "hybrid"):
            ssm_state = jax.tree.map(lambda s: s[l], ssm_states)
        caches.append(LayerCache(kv=kv, ssm=ssm_state))
    return logits, caches
