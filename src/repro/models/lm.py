"""Model-zoo dispatch: one uniform interface over every assigned arch.

``build(cfg)`` returns a ``ModelAPI`` with
  init(key) -> values                    (concrete params)
  abstract() -> (shapes, axes)           (dry-run: no allocation)
  loss_fn(values, batch, key) -> scalar  (next-token CE + aux)
  prefill_fn(values, batch) -> (logits, caches)
  decode_fn(values, caches, token, pos) -> (logits, caches)
  decode_cache_specs(batch, seq) -> pytree of ShapeDtypeStruct
  input_specs(shape) -> batch pytree of ShapeDtypeStruct

Batch layouts per family:
  dense/moe/ssm/hybrid : {"tokens": (B, S)}
  vlm                  : + {"img_embeds": (B, prefix, D)}   (SigLIP stub)
  encdec               : {"frames": (B, S_enc, D), "tokens": (B, S)}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeCell
from . import encdec as encdec_lib
from . import transformer as tfm


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Mean next-token CE over (B, S, V) logits vs (B, S) labels, with a
    small z-loss to keep the softmax normalizer bounded (stability at
    scale)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    abstract: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    decode_cache_specs: Callable
    decode_cache_axes: Callable
    input_specs: Callable
    input_axes: Callable


from ..sharding.rules import Axes

KV_AXES = Axes(("batch", "kv_seq", "heads_act"))
SSM_H_AXES = Axes(("batch", "heads_act", None))
SSM_CONV_AXES = Axes(("batch", None, "d_ff_act"))


def _batch_axes(cfg: ModelConfig) -> Dict[str, Any]:
    axes: Dict[str, Any] = {"tokens": ("batch", None)}
    if cfg.family == "vlm":
        axes["img_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        axes["frames"] = ("batch", None, None)
    return axes


def _token_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {"tokens": sd((B, S), jnp.int32)}
    if cfg.family == "vlm":
        specs["img_embeds"] = sd(
            (B, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        specs["frames"] = sd(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
    return specs


def build(cfg: ModelConfig, remat_policy: Optional[str] = "full") -> ModelAPI:
    if cfg.family == "encdec":
        return _build_encdec(cfg, remat_policy)

    def init(key):
        return tfm.model_init(key, cfg)[0]

    def abstract():
        return tfm.abstract_params(cfg)

    def loss_fn(values, batch, key=None):
        tokens = batch["tokens"]
        logits, _ = forward_logits(values, batch, remat_policy)
        # predict token t+1 from prefix..t; VLM prefix positions excluded
        pred = logits[:, cfg.prefix_tokens :][:, :-1]
        return cross_entropy(pred, tokens[:, 1:])

    def forward_logits(values, batch, remat=None):
        return tfm.forward(
            values, cfg, batch["tokens"],
            img_embeds=batch.get("img_embeds"), remat_policy=remat,
        )

    def prefill_fn(values, batch, max_seq=None):
        return tfm.prefill(
            values, cfg, batch["tokens"], img_embeds=batch.get("img_embeds"),
            max_seq=max_seq,
        )

    def decode_fn(values, caches, token, pos):
        return tfm.decode_step(values, cfg, caches, token, pos)

    def decode_cache_specs(batch: int, seq: int, dtype=jnp.bfloat16):
        caches = jax.eval_shape(
            lambda: tfm.init_layer_caches(cfg, batch, seq, dtype)
        )
        return caches

    def decode_cache_axes(batch: int, seq: int):
        from . import attention as A
        from . import ssm as S

        out = []
        for window in cfg.layer_kinds():
            kv = None
            if cfg.family != "ssm":
                kv = A.KVCache(KV_AXES, KV_AXES)
            ssm = None
            if cfg.family in ("ssm", "hybrid"):
                ssm = S.SSMState(SSM_H_AXES, SSM_CONV_AXES)
            out.append(tfm.LayerCache(kv=kv, ssm=ssm))
        return out

    def input_specs(shape: ShapeCell):
        return _token_specs(cfg, shape)

    def input_axes():
        return _batch_axes(cfg)

    return ModelAPI(cfg, init, abstract, loss_fn, prefill_fn, decode_fn,
                    decode_cache_specs, decode_cache_axes, input_specs,
                    input_axes)


def _build_encdec(cfg: ModelConfig, remat_policy) -> ModelAPI:
    def init(key):
        return encdec_lib.model_init(key, cfg)[0]

    def abstract():
        return encdec_lib.abstract_params(cfg)

    def loss_fn(values, batch, key=None):
        enc_out = encdec_lib.encode(values, cfg, batch["frames"])
        logits = encdec_lib.decode_train(
            values, cfg, batch["tokens"], enc_out, remat_policy
        )
        return cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])

    def prefill_fn(values, batch):
        enc_out = encdec_lib.encode(values, cfg, batch["frames"])
        logits = encdec_lib.decode_train(values, cfg, batch["tokens"], enc_out)
        ck, cv = encdec_lib.prefill_cross(values, cfg, enc_out)
        return logits, (enc_out, ck, cv)

    def decode_fn(values, cache, token, pos):
        return encdec_lib.decode_step(values, cfg, cache, token, pos)

    def decode_cache_specs(batch: int, seq: int, dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: encdec_lib.init_cache(cfg, batch, seq, dtype)
        )

    def decode_cache_axes(batch: int, seq: int):
        ax = Axes((None,) + tuple(KV_AXES))  # + stacked-layer dim
        return encdec_lib.EncDecCache(ax, ax, ax, ax)

    def input_specs(shape: ShapeCell):
        return _token_specs(cfg, shape)

    def input_axes():
        return _batch_axes(cfg)

    return ModelAPI(cfg, init, abstract, loss_fn, prefill_fn, decode_fn,
                    decode_cache_specs, decode_cache_axes, input_specs,
                    input_axes)
