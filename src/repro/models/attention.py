"""GQA attention: RoPE, qk-norm, logit softcap, sliding windows, KV caches.

Three execution paths, all numerically equivalent (tests assert it):
  * ``attend_full``    — reference O(S^2) masked attention (small S only).
  * ``attend_chunked`` — flash-style online-softmax scan over KV chunks;
    memory O(S * chunk) instead of O(S^2).  Used for train and prefill.
  * ``decode_attend``  — one query token against a (possibly ring-buffer)
    KV cache.

Sliding windows: a per-layer ``window`` (0 = global) arrives as a traced
scalar so the same compiled layer body serves gemma2's alternating and
gemma3's 5:1 local:global patterns under ``lax.scan`` over layers.

Caches: global layers use a linear cache (B, S_max, KV, Dh); local layers
use a ring buffer of ``window`` slots — decode writes slot ``pos % window``
and reconstructs absolute positions from slot ages, so a 500k-context
stream holds only O(window) state for local layers (the sub-quadratic
requirement of the ``long_500k`` cell).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10000.0):
    """x (..., S, H, Dh), positions (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mask(q_pos, k_pos, window):
    """Causal + optional sliding-window mask. window is a traced scalar
    (0 = global).  q_pos (Q,), k_pos (K,) -> bool (Q, K)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    in_window = jnp.where(
        window > 0, q_pos[:, None] - k_pos[None, :] < window, True
    )
    return causal & in_window


def _qk_scores(q, k, scale, softcap_val):
    """q (B,Q,H,Dh), k (B,K,KV,Dh) -> scores (B,H,Q,K) with GQA broadcast."""
    B, Q, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Q, KV, rep, Dh)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    return s  # (B, KV, rep, Q, K)


def _weighted_v(p, v):
    """p (B,KV,rep,Q,K), v (B,K,KV,Dh) -> (B,Q,H,Dh)."""
    B, KV, rep, Q, K = p.shape
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return out.reshape(B, Q, KV * rep, -1)


def attend_full(q, k, v, q_pos, k_pos, window=0, softcap_val: float = 0.0,
                extra_mask=None):
    """Reference masked attention (materializes S^2 scores)."""
    scale = q.shape[-1] ** -0.5
    s = _qk_scores(q, k, scale, softcap_val)
    m = _mask(q_pos, k_pos, jnp.asarray(window))
    if extra_mask is not None:
        m = m | extra_mask
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _weighted_v(p, v).astype(q.dtype)


def attend_chunked(q, k, v, q_pos, k_pos, window=0, softcap_val: float = 0.0,
                   chunk: int = 1024, extra_mask=None):
    """Flash-style online-softmax over KV chunks (memory O(S*chunk)).

    q (B,Q,H,Dh); k/v (B,K,KV,Dh); q_pos (Q,), k_pos (K,).
    extra_mask: optional bool (Q, K) OR'd into the causal/window mask
    (used for the prefix-LM bidirectional block of paligemma).
    """
    B, Q, H, Dh = q.shape
    K = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    scale = Dh ** -0.5
    nchunks = -(-K // chunk)
    pad = nchunks * chunk - K
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
        if extra_mask is not None:
            extra_mask = jnp.pad(extra_mask, ((0, 0), (0, pad)))
    kc = k.reshape(B, nchunks, chunk, KV, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, KV, Dh).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(nchunks, chunk)
    mc = (extra_mask.reshape(Q, nchunks, chunk).transpose(1, 0, 2)
          if extra_mask is not None else None)

    qg = q.reshape(B, Q, KV, rep, Dh).astype(jnp.float32)
    window = jnp.asarray(window)

    def body(carry, xs):
        m_run, d_run, acc = carry
        if mc is None:
            kb, vb, pb = xs
            em = None
        else:
            kb, vb, pb, em = xs
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb.astype(jnp.float32)) * scale
        if softcap_val:
            s = softcap_val * jnp.tanh(s / softcap_val)
        msk = _mask(q_pos, pb, window)
        if em is not None:
            msk = msk | em
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        d_run = d_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqk,bkgd->bgrqd", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, d_run, acc), None

    init = (
        jnp.full((B, KV, rep, Q), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, rep, Q), jnp.float32),
        jnp.zeros((B, KV, rep, Q, Dh), jnp.float32),
    )
    xs = (kc, vc, pc) if mc is None else (kc, vc, pc, mc)
    (m_run, d_run, acc), _ = jax.lax.scan(body, init, xs)
    out = acc / jnp.maximum(d_run[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Q, H, Dh)
    return out.astype(q.dtype)


# ------------------------------------------------------------------ caches
class KVCache(NamedTuple):
    """Storage is FLAT (B, S_slots, KV*Dh): the combined trailing axis
    always divides the model mesh axis even when KV alone doesn't (qwen3
    kv=8 on a 16-way TP axis).  Ring-ness is NOT stored (pytree purity for
    jit/ShapeDtypeStruct): a cache is a ring buffer iff its layer has
    window > 0 and exactly ``window`` slots — callers derive ``ring`` from
    (window, k.shape[1]) via ``is_ring``."""
    k: jax.Array        # (B, S_slots, KV*Dh)
    v: jax.Array


def is_ring(window: int, slots: int) -> bool:
    return bool(window) and slots <= window


def init_cache(batch, slots, kv_heads, head_dim, dtype) -> KVCache:
    shape = (batch, slots, kv_heads * head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_slot_positions(cache: KVCache, pos, ring: bool):
    """Absolute position of each cache slot given current stream pos.

    Linear cache: slot s holds position s (valid while s < pos).
    Ring cache:   slot s holds the most recent position p < pos with
                  p % window == s  ->  p = pos - 1 - ((pos - 1 - s) % W).
    """
    S = cache.k.shape[1]
    s = jnp.arange(S, dtype=jnp.int32)
    if not ring:
        return jnp.where(s < pos, s, jnp.iinfo(jnp.int32).max)
    age = jnp.mod(pos - 1 - s, S)
    p = pos - 1 - age
    return jnp.where(p >= 0, p, jnp.iinfo(jnp.int32).max)


def cache_update(cache: KVCache, k_new, v_new, pos, ring: bool) -> KVCache:
    """Insert one step (B, 1, KV, Dh) at stream position pos (scalar)."""
    S = cache.k.shape[1]
    B = k_new.shape[0]
    k_new = k_new.reshape(B, 1, -1)
    v_new = v_new.reshape(B, 1, -1)
    slot = jnp.mod(pos, S) if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, 1)
    return KVCache(k, v)


def decode_attend(q, cache: KVCache, pos, ring: bool, kv_heads: int,
                  window=0, softcap_val: float = 0.0):
    """q (B,1,H,Dh) against the (flat-stored) cache; pos = current token's
    position."""
    k_pos = cache_slot_positions(cache, pos + 1, ring)   # cache already updated
    q_pos = jnp.full((1,), pos, jnp.int32)
    B, S = cache.k.shape[:2]
    k4 = cache.k.reshape(B, S, kv_heads, -1)
    v4 = cache.v.reshape(B, S, kv_heads, -1)
    return attend_full(q, k4, v4, q_pos, k_pos,
                       window=window, softcap_val=softcap_val)
