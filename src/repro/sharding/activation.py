"""Activation sharding constraints, decoupled from model code.

Model forward passes call ``constrain(x, ("batch", "seq", "embed_act"))``
at the canonical cut points.  Outside any context this is a no-op (pure
single-device semantics, e.g. smoke tests); inside
``activation_sharding(rules, mesh)`` it applies
``jax.lax.with_sharding_constraint`` with the resolved PartitionSpec —
this is how the launcher steers GSPMD without models knowing about meshes.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from . import rules as rules_lib

_state = threading.local()


def _top():
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def activation_sharding(rules: rules_lib.Rules, mesh):
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append((rules, mesh))
    try:
        yield
    finally:
        stack.pop()


def constrain(x, axes: Tuple[Optional[str], ...]):
    ctx = _top()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = rules_lib.resolve_spec(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
