"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Params carry logical axis names (models/params.py); activations are
constrained through sharding/activation.py.  A ``Rules`` table maps each
logical name to a mesh axis (or tuple of axes, or None = replicated).

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single-pod.

TRAIN_RULES — ZeRO-3-style: every param's d_model dim shards over ``data``
(FSDP; XLA all-gathers per layer and reduce-scatters grads) while TP dims
(vocab/heads/d_ff/experts) shard over ``model``.  Optimizer state inherits
param sharding, so Adam moments are fully sharded (ZeRO-1 comes free).

SERVE_RULES — params replicated over ``data`` (no optimizer, latency wins),
TP dims over ``model``; batch shards over (pod, data).

LONG_CONTEXT_SERVE_RULES — for global_batch < |data| (the long_500k cell):
the KV cache's *sequence* dim shards over (pod, data) (sequence
parallelism); attention against the sharded cache ends in a psum that XLA
derives automatically.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Assignment = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, Assignment]


class Axes(tuple):
    """Logical-axes leaf marker.  Needed wherever an axes tuple lives
    inside a NamedTuple container (KVCache, SSMState, ...): a plain tuple
    leaf is indistinguishable from the container itself under
    ``is_leaf=isinstance(x, tuple)`` — which silently replicated every
    decode cache until this type existed (see EXPERIMENTS.md §Perf)."""


def is_axes_leaf(x) -> bool:
    return isinstance(x, Axes) or (
        isinstance(x, tuple) and not hasattr(x, "_fields")
        and all(isinstance(a, (str, type(None))) for a in x)
    )

TRAIN_RULES: Rules = {
    # params
    "vocab": "model",
    "d_model": "data",          # FSDP / ZeRO-3
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "ssm_inner": "model",
    "layers": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed_act": None,
    "heads_act": "model",
    "d_ff_act": "model",
    "vocab_act": "model",
    "experts_act": "model",
    "groups_act": ("pod", "data"),
}

SERVE_RULES: Rules = {
    **TRAIN_RULES,
    "d_model": None,            # replicate params over data for latency
    # d_ff falls back to `data` when `model` is already claimed by the
    # experts dim: dbrx-132b's 250 GB of expert weights then shard
    # (E/model x d_ff/data) = /256 instead of /16 — without this the
    # serve params alone (16.5 GB bf16/chip) overflow HBM.
    "d_ff": ("model", "data"),
}

LONG_CONTEXT_SERVE_RULES: Rules = {
    **SERVE_RULES,
    "batch": None,              # global_batch < |data|: don't shard batch
    "kv_seq": ("pod", "data"),  # sequence parallelism over the cache
    "groups_act": None,
}

# §Perf hillclimb (decode cells): shard the KV cache's SEQUENCE dim over
# the model axis instead of its heads dim.  Decode attention then runs
# fully local per seq-shard (partial softmax + tiny psums) and GSPMD never
# has to reshard the (B, S, KV*Dh) cache between heads/batch layouts —
# which is what blew decode peak memory up at baseline.
DECODE_SP_RULES: Rules = {
    **SERVE_RULES,
    "kv_seq": "model",
    "heads_act": None,
}


def resolve_spec(axes: Tuple[Optional[str], ...], rules: Rules,
                 mesh: Mesh) -> PartitionSpec:
    """Map logical axes to a PartitionSpec, dropping mesh axes that don't
    exist (single-pod mesh has no 'pod') and de-duplicating axes that would
    be assigned twice (first dim wins)."""
    mesh_axes = set(mesh.axis_names)
    used = set()
    out = []
    for ax in axes:
        assign = rules.get(ax) if ax is not None else None
        if assign is None:
            out.append(None)
            continue
        if isinstance(assign, str):
            assign = (assign,)
        picked = tuple(a for a in assign if a in mesh_axes and a not in used)
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return PartitionSpec(*out)


def param_specs(axes_tree, rules: Rules, mesh: Mesh):
    """Axes tree (from models.params.split) -> tree of NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, resolve_spec(axes, rules, mesh)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
