from .rules import (
    TRAIN_RULES, SERVE_RULES, LONG_CONTEXT_SERVE_RULES,
    resolve_spec, param_specs, Rules,
)
from .activation import activation_sharding, constrain
