"""Pluggable-executor pipeline invariants (serve/{admission,pool,executor}).

ISSUE-5 test requirements: same frame bytes and identical deterministic
counters for workers {0, 1, 4} x prefetch {0, 2} on a replay trajectory;
commit ordering preserved under an adversarial slow-probe stub (worker
completion order inverted vs admission order); the Stage-B commit section
performs NO pad/sort device work (instrumented); the framecache entry
snapshot/lock contract never shows a torn entry to an off-thread plan;
and the render_engine facade stays within its size budget.
"""
import dataclasses
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import fields, pipeline, scene
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.serve import admission, executor as executor_lib
from repro.serve import pool as pool_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)
# counters decided at commit time (engine thread, admission order) — must
# match across executors; misprepares is timing-dependent by design
from repro.serve.stats import DETERMINISTIC_COUNTERS

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16


def cam_at(theta, phi=0.5):
    return scene.look_at_camera(SIZE, SIZE, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def flds():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def serve_cfg(workers=0, prefetch=2, slots=2):
    return RenderServeConfig(
        slots=slots, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0),
        radiance=fc_radiance.RadianceReuseConfig(refresh_every=0),
        prefetch=prefetch, workers=workers)


def replay_traj(n=8):
    # poses repeat every 3 requests: laps 2+ exercise warp reuse, full
    # radiance hits, AND speculation racing the in-flight sources
    return [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7 + 0.05 * (i % 3)))
            for i in range(n)]


# ----------------------------------------------------------- determinism
def test_workers_determinism(flds):
    """Executors move WHERE Stage A runs, never WHAT commits: frames and
    all commit-determined counters must be bit-identical for
    workers {0, 1, 4} x prefetch {0, 2} on the replay trajectory."""
    runs = {}
    for workers in (0, 1, 4):
        for prefetch in (0, 2):
            eng = RenderServingEngine(flds, ACFG,
                                      serve_cfg(workers, prefetch))
            done = {r.rid: r for r in eng.render(replay_traj())}
            runs[(workers, prefetch)] = (done, eng.engine_stats())
            eng.close()
    ref_done, ref_st = runs[(0, 0)]
    for key, (done, st) in runs.items():
        for rid in ref_done:
            np.testing.assert_array_equal(
                ref_done[rid].image, done[rid].image,
                err_msg=f"frame {rid} differs at workers,prefetch={key}")
        for c in DETERMINISTIC_COUNTERS:
            assert ref_st[c] == st[c], (key, c, ref_st[c], st[c])
    # the fully synchronous run can never misprepare
    assert ref_st["misprepares"] == 0


def test_commit_ordering_under_adversarial_slow_probe(flds, monkeypatch):
    """Commits happen on the engine thread in ADMISSION order even when
    worker completion order is inverted: the earliest-submitted probe is
    stubbed slowest, so later speculations finish first — finish order,
    frames, and counters must still match the synchronous run."""
    real_execute = fc_probe.execute_probe_plan
    lock = threading.Lock()
    seen = {"n": 0}

    def slow_execute(fns, acfg, cam, plan, probe_key=None, rcfg=None):
        with lock:
            i = seen["n"]
            seen["n"] += 1
        if plan.kind in ("fresh", "refresh"):
            time.sleep(0.12 if i < 2 else 0.0)   # earliest probes slowest
        return real_execute(fns, acfg, cam, plan, probe_key=probe_key,
                            rcfg=rcfg)

    # distinct fresh poses: every admission pays a probe, all speculated
    def traj():
        return [RenderRequest(rid=i, scene="mic", cam=cam_at(0.55 + 0.1 * i))
                for i in range(6)]

    cfg = RenderServeConfig(
        slots=1, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(max_angle_deg=0.01,
                                        max_translation=1e-4),
        radiance=None, prefetch=4, workers=0)
    eng_s = RenderServingEngine(flds, ACFG, cfg)
    done_s = eng_s.render(traj())

    monkeypatch.setattr(fc_probe, "execute_probe_plan", slow_execute)
    eng_t = RenderServingEngine(flds, ACFG,
                                dataclasses.replace(cfg, workers=4))
    done_t = eng_t.render(traj())
    eng_t.close()

    assert [r.rid for r in done_t] == [r.rid for r in done_s]
    by_rid = {r.rid: r for r in done_s}
    for r in done_t:
        np.testing.assert_array_equal(r.image, by_rid[r.rid].image)
    st_s, st_t = eng_s.engine_stats(), eng_t.engine_stats()
    for c in DETERMINISTIC_COUNTERS:
        assert st_s[c] == st_t[c], (c, st_s[c], st_t[c])


# ------------------------------------------------- Stage-B instrumentation
def test_stage_b_commit_performs_no_pad_sort(flds, monkeypatch):
    """The tentpole invariant: pad/sort (and layout building generally)
    is Stage-A work — it must never execute inside the commit section,
    at any prefetch depth."""
    calls = {"pad": 0, "sort": 0, "layout": 0, "in_commit": 0}
    real_pad = pipeline.pad_rays_to_blocks
    real_sort = pipeline.block_sort
    real_layout = pool_lib.build_layout

    def pad(*a, **kw):
        calls["pad"] += 1
        calls["in_commit"] += admission.commit_active()
        return real_pad(*a, **kw)

    def sort(*a, **kw):
        calls["sort"] += 1
        calls["in_commit"] += admission.commit_active()
        return real_sort(*a, **kw)

    def layout(*a, **kw):
        calls["layout"] += 1
        calls["in_commit"] += admission.commit_active()
        return real_layout(*a, **kw)

    monkeypatch.setattr(pipeline, "pad_rays_to_blocks", pad)
    monkeypatch.setattr(pipeline, "block_sort", sort)
    monkeypatch.setattr(pool_lib, "build_layout", layout)

    for prefetch in (0, 2):
        eng = RenderServingEngine(flds, ACFG, serve_cfg(0, prefetch))
        eng.render(replay_traj(6))
    assert calls["pad"] > 0 and calls["sort"] > 0 and calls["layout"] > 0
    assert calls["in_commit"] == 0, \
        f"pad/sort ran inside the Stage-B commit section: {calls}"


# ----------------------------------------------------- snapshot integrity
def test_plan_snapshot_never_torn_under_concurrent_rebase(flds):
    """Satellite regression: a plan's entry snapshot (arrays + version)
    must be internally consistent even while the engine thread rebases
    the entry.  Entry generation g writes value g into every map — a
    torn snapshot would mix generations."""
    cache = fc_probe.ProbeCache(fc_probe.ProbeReuseConfig(refresh_every=0))
    cam = cam_at(0.7)

    def maps_of(gen):
        return fc_probe.ProbeMaps(np.full((4,), gen, np.int32),
                                  np.full((4,), gen, np.float32),
                                  np.full((4,), gen, np.float32), 0)

    with cache.lock:
        cache._store(cam, ACFG, maps_of(0))
    entry = cache._entries[0]
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            plan = fc_probe.plan_probe(cache, cam, ACFG)
            if plan.kind != "reuse":
                continue
            m = plan.src_maps
            gens = {int(m.counts[0]), int(m.opacity[0]), int(m.depth[0])}
            if len(gens) != 1:
                torn.append(gens)
            # version stamp must belong to the same generation
            if plan.basis[2] != int(m.counts[0]):
                torn.append(("version", plan.basis[2], int(m.counts[0])))

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for gen in range(1, 300):
        # engine-thread rebase: reassign fields + bump version under lock
        # (the commit path for a refresh plan)
        fc_probe.commit_probe_plan(cache, cam, ACFG,
                                   fc_probe.ProbePlan("refresh", entry),
                                   maps_of(gen))
    stop.set()
    for t in threads:
        t.join()
    assert not torn, f"observed torn entry snapshots: {torn[:5]}"
    assert entry.version == 299


# ------------------------------------------------------------- unit tests
# Factories, not instances: each contract case needs a FRESH executor
# (close() is part of the contract under test).  DeviceExecutor is
# constructed over whatever devices exist — on the default single-device
# lane that is [device 0], which exercises the identical contract; the
# fleet lane re-runs the engine-level paths on real secondary devices.
EXECUTOR_FACTORIES = {
    "sync": lambda: executor_lib.SyncExecutor(),
    "threaded": lambda: executor_lib.ThreadedExecutor(2),
    "device": lambda: executor_lib.DeviceExecutor(
        devices=list(__import__("jax").devices())),
}


@pytest.mark.parametrize("kind", sorted(EXECUTOR_FACTORIES))
def test_executor_contract(kind):
    """The hardened contract, identical across ALL backends: idempotent
    submit per key; blocking take; take of an unknown key is None; every
    submitted key drains through take (no leaks); reset and close are
    idempotent; submit after close raises."""
    make = EXECUTOR_FACTORIES[kind]
    ex = make()
    ran = []
    ex.submit("a", lambda: ran.append(1) or "r1")
    ex.submit("a", lambda: ran.append(2) or "r2")     # idempotent
    assert ex.take("a") == "r1"
    assert ran == [1]
    assert ex.take("a") is None                       # taken once
    assert ex.take("never") is None
    ex.submit("k", lambda: time.sleep(0.05) or "slow")
    assert ex.take("k") == "slow"                     # blocks until done

    # leak check: take drains every submitted key
    keys = [f"key{i}" for i in range(5)]
    for k in keys:
        ex.submit(k, lambda k=k: f"v-{k}")
    assert ex.pending() == len(keys)
    assert [ex.take(k) for k in keys] == [f"v-{k}" for k in keys]
    assert ex.pending() == 0

    # reset idempotent; pending speculation dropped
    ex.submit("r", lambda: "gone")
    ex.reset()
    ex.reset()
    assert ex.pending() == 0 and ex.take("r") is None

    # close idempotent; submit afterwards must raise
    ex.close()
    ex.close()
    with pytest.raises(RuntimeError):
        ex.submit("late", lambda: "x")
    assert ex.take("late") is None


def test_take_steals_queued_speculation():
    """Stall regression (BENCH workers_gate row): the engine must never
    block on speculation still QUEUED behind a busy worker — take()
    cancels the unstarted future and runs the closure inline.  With one
    execution slot, taking the second submission used to wait out the
    first's sleep; stolen inline it returns immediately."""
    ex = executor_lib.ThreadedExecutor(1, max_concurrent=1)
    ex.submit("slow", lambda: time.sleep(2.0) or "slow")
    ex.submit("fast", lambda: "fast")
    t0 = time.time()
    assert ex.take("fast") == "fast"
    assert time.time() - t0 < 1.0, "take() waited behind queued work"
    ex.close()


def test_make_executor_single_device_fallback():
    """devices>0 on this single-device host degrades to SyncExecutor
    (the fleet lane covers the true multi-device selection)."""
    import jax
    assert jax.device_count() >= 1
    ex = executor_lib.make_executor(0, devices=2)
    if jax.device_count() == 1:
        assert isinstance(ex, executor_lib.SyncExecutor)
    else:
        assert isinstance(ex, executor_lib.DeviceExecutor)
    ex.close()


def test_render_engine_facade_size_budget():
    """The fast tier fails if serve/render_engine.py regrows past its
    line budget (same check make lint runs via tools/check_sizes.py)."""
    tools = Path(__file__).resolve().parent.parent / "tools"
    sys.path.insert(0, str(tools))
    try:
        import check_sizes
        assert check_sizes.violations() == []
    finally:
        sys.path.remove(str(tools))
