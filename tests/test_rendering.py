"""Volume rendering Eq.(1): correctness + early-termination accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import rendering as R


def brute_force_composite(sigmas, colors, deltas):
    R_, S = sigmas.shape
    out = np.zeros((R_, 3))
    acc = np.zeros(R_)
    for r in range(R_):
        T = 1.0
        for i in range(S):
            a = 1.0 - np.exp(-sigmas[r, i] * deltas[r, i])
            out[r] += T * a * colors[r, i]
            acc[r] += T * a
            T *= 1.0 - a
    return out, acc


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_composite_matches_brute_force(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    sig = jax.random.uniform(k1, (5, 16)) * 20
    col = jax.random.uniform(k2, (5, 16, 3))
    dl = jnp.full((5, 16), 0.05)
    rgb, acc, w = R.composite(sig, col, dl, white_background=False)
    ref_rgb, ref_acc = brute_force_composite(
        np.asarray(sig), np.asarray(col), np.asarray(dl))
    np.testing.assert_allclose(np.asarray(rgb), ref_rgb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc), ref_acc, rtol=1e-4, atol=1e-5)


def test_weights_bounded_and_transmittance_monotone():
    key = jax.random.PRNGKey(3)
    sig = jax.random.uniform(key, (7, 32)) * 50
    dl = jnp.full((7, 32), 0.03)
    alphas = R.alphas_from_sigmas(sig, dl)
    trans = R.transmittance(alphas)
    t = np.asarray(trans)
    assert (np.diff(t, axis=-1) <= 1e-7).all()  # monotone nonincreasing
    assert (t[:, 0] == 1.0).all()  # exclusive product starts at 1
    _, acc, w = R.composite(sig, jnp.ones((7, 32, 3)), dl,
                            white_background=False)
    assert float(jnp.max(acc)) <= 1.0 + 1e-5
    assert float(jnp.min(w)) >= 0.0


def test_valid_mask_zeroes_contributions():
    sig = jnp.ones((2, 8)) * 10
    col = jnp.ones((2, 8, 3))
    dl = jnp.full((2, 8), 0.1)
    valid = jnp.arange(8) < 4
    rgb_m, acc_m, _ = R.composite(sig, col, dl, valid=valid[None].repeat(2, 0),
                                  white_background=False)
    rgb_4, acc_4, _ = R.composite(sig[:, :4], col[:, :4], dl[:, :4],
                                  white_background=False)
    np.testing.assert_allclose(np.asarray(rgb_m), np.asarray(rgb_4), rtol=1e-5)


def test_early_termination_counts():
    # opaque wall at sample 3 -> needed ~4 samples
    sig = jnp.zeros((1, 16)).at[0, 3].set(1e4)
    alphas = R.alphas_from_sigmas(sig, jnp.full((1, 16), 0.1))
    needed = R.early_termination_counts(alphas)
    assert int(needed[0]) <= 5


def test_psnr_ssim_sanity():
    img = jnp.zeros((16, 16, 3))
    assert float(R.psnr(img, img)) > 100
    assert abs(float(R.ssim(img + 0.5, img + 0.5)) - 1.0) < 1e-5
    noisy = img + 0.25
    assert float(R.psnr(noisy, img)) < 15
