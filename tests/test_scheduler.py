"""Request-lifecycle scheduler invariants (serve/scheduler.py) — ISSUE-9.

FifoPolicy bit-identity vs the pre-scheduler engine (same frames, same
deterministic counters) across executors x prefetch; EDF ordering
determinism under deadline ties (unit + engine level); the shed-degrade
property that a request's budget never falls below its class's shed
floor plus the engine-level accounting invariant
``requests_shed + requests_full == frames``; open-loop arrival gating;
policy resolution; budget-scaled layouts; and the executor depth gauges
the scheduler publishes.
"""
import dataclasses
import math
import time

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import fields, pipeline, scene
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.serve import executor as executor_lib
from repro.serve import pool as pool_lib
from repro.serve.render_engine import (DeadlinePolicy, FifoPolicy,
                                       RenderRequest, RenderServeConfig,
                                       RenderServingEngine, RequestClass,
                                       ShedPolicy)
from repro.serve.scheduler import Scheduler, budget_scale_for, make_policy
from repro.serve.stats import DETERMINISTIC_COUNTERS, EngineCounters

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16


def cam_at(theta, phi=0.5):
    return scene.look_at_camera(SIZE, SIZE, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def flds():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def serve_cfg(workers=0, prefetch=2, slots=2, **kw):
    return RenderServeConfig(
        slots=slots, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0),
        radiance=fc_radiance.RadianceReuseConfig(refresh_every=0),
        prefetch=prefetch, workers=workers, **kw)


def replay_traj(n=8):
    return [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7 + 0.05 * (i % 3)))
            for i in range(n)]


def _req(rid, cls=None, arrival=0.0, theta=0.7):
    kw = {} if cls is None else {"cls": cls}
    return RenderRequest(rid=rid, scene="mic", cam=cam_at(theta),
                         arrival_s=arrival, **kw)


# ----------------------------------------------------------- bit-identity
def test_fifo_policy_bit_identity(flds):
    """The scheduler seam must be invisible at the default: policy=None,
    policy='fifo', and an explicit FifoPolicy() produce the same frame
    bytes and deterministic counters as each other at every executor
    (sync / threaded / device-config) x prefetch {0, 2} combination."""
    cases = [
        ("none-sync-p0", None, dict(workers=0, prefetch=0)),
        ("none-threaded-p2", None, dict(workers=2, prefetch=2)),
        ("name-sync-p2", "fifo", dict(workers=0, prefetch=2)),
        ("inst-sync-p0", FifoPolicy(), dict(workers=0, prefetch=0)),
        ("inst-sync-p2", FifoPolicy(), dict(workers=0, prefetch=2)),
        ("inst-threaded-p0", FifoPolicy(), dict(workers=2, prefetch=0)),
        ("inst-threaded-p2", FifoPolicy(), dict(workers=2, prefetch=2)),
        # devices>0 resolves per-host (DeviceExecutor, or SyncExecutor on
        # a single-device host) — either way the frames must match
        ("inst-device-p2", FifoPolicy(), dict(workers=0, prefetch=2,
                                              devices=2)),
    ]
    runs = {}
    for label, policy, kw in cases:
        eng = RenderServingEngine(flds, ACFG,
                                  serve_cfg(policy=policy, **kw))
        done = {r.rid: r for r in eng.render(replay_traj())}
        runs[label] = (done, eng.engine_stats())
        eng.close()
    ref_done, ref_st = runs["none-sync-p0"]
    for label, (done, st) in runs.items():
        for rid in ref_done:
            np.testing.assert_array_equal(
                ref_done[rid].image, done[rid].image,
                err_msg=f"frame {rid} differs at {label}")
        for c in DETERMINISTIC_COUNTERS:
            assert ref_st[c] == st[c], (label, c, ref_st[c], st[c])
    # the default class never sheds: all runs served full budget
    assert ref_st["requests_shed"] == 0
    assert ref_st["requests_full"] == ref_st["frames"]


# ------------------------------------------------------------ EDF ordering
def test_edf_select_deadline_order_and_ties():
    """Unit-level determinism: earliest absolute deadline wins; equal
    deadlines (including the no-deadline default class) resolve to the
    lowest queue position; un-arrived requests are invisible."""
    pol = DeadlinePolicy()
    rt50 = RequestClass("rt50", deadline_ms=50.0)
    rt10 = RequestClass("rt10", deadline_ms=10.0)
    q = [_req(0), _req(1, rt50), _req(2, rt10), _req(3, rt50)]
    assert pol.select(q, now_rel=0.0) == 2
    assert [r.rid for r in pol.prefetch_order(q, 0.0)] == [2, 1, 3, 0]
    # ties -> queue position, for any mix of equal keys
    q_tie = [_req(0, rt50), _req(1, rt50), _req(2, rt50)]
    assert pol.select(q_tie, 0.0) == 0
    assert [r.rid for r in pol.prefetch_order(q_tie, 0.0)] == [0, 1, 2]
    # a deadline that would win is invisible until it ARRIVES (absolute
    # deadline 0.02 + 10 ms = 0.03 beats rid 1's 0.05 — but only once
    # now_rel passes 0.02); a LATE arrival's absolute deadline can also
    # fall past an earlier peer's, so arriving never jumps the line
    q_fut = [_req(0, rt10, arrival=0.02), _req(1, rt50)]
    assert pol.select(q_fut, 0.0) == 1
    assert pol.select(q_fut, 0.025) == 0
    q_late = [_req(0, rt10, arrival=5.0), _req(1, rt50)]
    assert pol.select(q_late, 6.0) == 1
    assert pol.select([_req(0, arrival=1.0)], 0.0) is None


def test_edf_engine_admission_order(flds):
    """Engine-level EDF with slots=1 drains strictly by (deadline, queue
    position) — and reordering admissions never changes frame bytes
    (caches off: each request renders from its own pose alone)."""
    rt20 = RequestClass("rt20", deadline_ms=20.0)
    rt5 = RequestClass("rt5", deadline_ms=5.0)

    def traj():
        return [_req(0, theta=0.55), _req(1, rt20, theta=0.65),
                _req(2, rt20, theta=0.75), _req(3, rt5, theta=0.85)]

    cfg = RenderServeConfig(slots=1, blocks_per_batch=4, reuse=None,
                            radiance=None, prefetch=0)
    eng = RenderServingEngine(flds, ACFG,
                              dataclasses.replace(cfg, policy="edf"))
    done = eng.render(traj())
    eng.close()
    assert [r.rid for r in done] == [3, 1, 2, 0]

    eng_f = RenderServingEngine(flds, ACFG, cfg)
    ref = {r.rid: r for r in eng_f.render(traj())}
    eng_f.close()
    for r in done:
        np.testing.assert_array_equal(r.image, ref[r.rid].image)


# ------------------------------------------------------------ shed property
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=3),
       st.floats(min_value=0.0, max_value=0.2),
       st.floats(min_value=1.0, max_value=400.0),
       st.floats(min_value=0.0, max_value=0.5))
def test_shed_never_degrades_past_floor(floor, ewma, deadline_ms, waited):
    """Property (ISSUE-9): whatever the projected service time, realized
    wait, and deadline, ``_maybe_shed`` never takes a request's tier
    past its class's shed floor — the degraded budget scale stays at or
    above the floor tier's scale — and every step is accounted."""
    cls = RequestClass("rt", deadline_ms=deadline_ms,
                       tiers=(1.0, 0.5, 0.25, 0.125), shed_floor=floor)
    counters = EngineCounters()
    sched = Scheduler("shed", counters)
    sched.ewma_service_s = ewma
    req = RenderRequest(rid=0, scene="mic", cam=None, cls=cls)
    assert req.tier == 0
    sched._maybe_shed(req, waited)
    assert 0 <= req.tier <= cls.shed_floor
    assert budget_scale_for(req) >= cls.tiers[cls.shed_floor]
    assert req.degrades == req.tier
    assert counters.shed_degrades == req.degrades
    # no projection basis, or no deadline -> never sheds
    for quiet_cls in (cls, dataclasses.replace(cls, deadline_ms=math.inf)):
        quiet = RenderRequest(rid=1, scene="mic", cam=None, cls=quiet_cls)
        cold = Scheduler("shed", EngineCounters())
        cold.ewma_service_s = 0.0 if quiet_cls is cls else ewma
        cold._maybe_shed(quiet, waited)
        assert quiet.tier == 0 and quiet.degrades == 0


def test_shed_accounting_and_class_stats(flds):
    """Engine-level accounting: a warmed EWMA plus an impossible 5 ms
    deadline sheds every rt request exactly to the floor tier, and
    ``requests_shed + requests_full == frames`` with per-class ledgers
    splitting the traffic (floored requests may still miss — they are
    counted, never dropped)."""
    warm = RequestClass("warm", deadline_ms=1.0)   # earliest deadline:
    rt = RequestClass("rt", deadline_ms=5.0,       # admitted first, sheds
                      tiers=(1.0, 0.5, 0.25), shed_floor=2)   # nothing

    def traj():
        return [_req(0, warm, theta=0.55)] + [
            _req(i, rt, theta=0.55 + 0.1 * i) for i in range(1, 6)]

    cfg = RenderServeConfig(slots=1, blocks_per_batch=4, reuse=None,
                            radiance=None, prefetch=0, policy="shed")
    eng = RenderServingEngine(flds, ACFG, cfg)
    done = eng.render(traj())
    st_out = eng.engine_stats()
    eng.close()
    assert st_out["frames"] == 6
    assert st_out["requests_shed"] + st_out["requests_full"] \
        == st_out["frames"]
    # rid 0 admits on a cold EWMA (never sheds) and warms it; every rt
    # request then projects >> 5 ms slack and degrades to the floor
    assert st_out["requests_shed"] == 5
    assert st_out["shed_degrades"] == 10
    for r in done:
        if r.cls.name == "rt":
            assert r.tier == r.cls.shed_floor
            assert budget_scale_for(r) == r.cls.tiers[r.cls.shed_floor]
        else:
            assert r.tier == 0 and r.degrades == 0
    assert set(st_out["class_stats"]) == {"rt", "warm"}
    led = st_out["class_stats"]["rt"]
    assert led["frames"] == 5 and led["shed"] == 5
    assert st_out["deadline_misses"] >= led["deadline_misses"]


# -------------------------------------------------------- open-loop traffic
def test_open_loop_arrival_gating(flds):
    """A queued request is invisible until its ``arrival_s`` passes: the
    engine idles through the gap, and the latency clock starts at the
    ARRIVAL, not at enqueue."""
    cfg = RenderServeConfig(slots=2, blocks_per_batch=4, reuse=None,
                            radiance=None, prefetch=0)
    eng = RenderServingEngine(flds, ACFG, cfg)
    eng.render([_req(0)])               # absorb compile time
    t0 = time.time()
    done = eng.render([_req(1), _req(2, arrival=0.4, theta=0.75)])
    wall = time.time() - t0
    eng.close()
    assert [r.rid for r in done] == [1, 2]
    assert wall >= 0.4                  # rid 2 never admitted early
    # rid 2's latency excludes the 0.4 s it had not yet arrived
    assert done[1].latency_s <= wall - 0.35


# ----------------------------------------------------------------- plumbing
def test_make_policy_resolution():
    assert type(make_policy(None)) is FifoPolicy
    assert not make_policy(None).shed
    assert type(make_policy("fifo")) is FifoPolicy
    assert type(make_policy("edf")) is DeadlinePolicy
    pol = make_policy("shed")
    assert type(pol) is ShedPolicy and pol.shed and pol.headroom == 1.0
    mine = ShedPolicy(headroom=2.0)
    assert make_policy(mine) is mine
    with pytest.raises(ValueError):
        make_policy("lifo")


def test_budget_scaled_counts_floor_and_identity():
    """Layout degrade point: scaled counts round UP, never below one
    sample per ray; scale 1.0 is the identity (the bit-identity path
    skips the scaling ops entirely)."""
    counts = jnp.array([0, 1, 2, 7, 48], jnp.int32)
    out = np.asarray(pool_lib._scale_counts(counts, 0.25))
    np.testing.assert_array_equal(out, [1, 1, 1, 2, 12])
    # scale 1.0 is identity on real (positive) counts; build_layout
    # additionally skips the call entirely at 1.0 (the bit-identity path)
    np.testing.assert_array_equal(
        np.asarray(pool_lib._scale_counts(counts[1:], 1.0)),
        np.asarray(counts[1:]))


def test_executor_depth_gauges(flds):
    """Satellite: every executor reports queue depth, and the scheduler
    publishes it through the metrics registry during admission."""
    ex = executor_lib.SyncExecutor()
    assert ex.depth() == {"pending": 0, "inflight": 0}
    ex.submit("k", lambda: 1)
    assert ex.depth()["pending"] == 1
    ex.take("k")
    assert ex.depth()["pending"] == 0
    ex.close()

    ex = executor_lib.ThreadedExecutor(2)
    ex.submit("a", lambda: time.sleep(0.02) or 1)
    d = ex.depth()
    assert d["pending"] >= 1 and d["inflight"] >= 0
    assert ex.take("a") == 1
    assert ex.depth()["pending"] == 0
    ex.close()

    eng = RenderServingEngine(flds, ACFG, serve_cfg(0, 2))
    eng.render(replay_traj(3))
    snap = eng.metrics.snapshot()
    eng.close()
    assert "executor_pending_depth" in snap
    assert "executor_inflight_depth" in snap
