"""Scene-space block reuse invariants (repro.scenecache).

ISSUE-3 test requirements: view-bucket quantization boundary behavior,
byte budget never exceeded under arbitrary insert sequences (property
test), deterministic (coverage-aware) eviction, and engine bit-identity
with scenecache=None — plus the framecache satellite (ordered tie-break
eviction, resident_bytes on both pose caches).
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import framecache, scenecache
from repro.core import fields, pipeline, scene
from repro.framecache import base as fc_base
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.scenecache import (SceneBlockCache, SceneCacheConfig,
                              ShardedSceneCache, block_keys,
                              render_adaptive_cached, shard_of)
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16
CFG = SceneCacheConfig()


def cam_at(theta, phi=0.5, size=SIZE):
    return scene.look_at_camera(size, size, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def setup():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def _block(rng, B=8):
    o = rng.uniform(0.2, 0.8, size=(1, B, 3)).astype(np.float32)
    d = rng.normal(size=(1, B, 3)).astype(np.float32)
    d /= np.linalg.norm(d, axis=-1, keepdims=True)
    return o, d


# ------------------------------------------------------------------- keys
def test_block_key_identity_and_sensitivity():
    rng = np.random.default_rng(0)
    o, d = _block(rng)
    (k1, c1), = block_keys(CFG, "mic", ACFG, o, d, np.asarray([32]))
    (k2, c2), = block_keys(CFG, "mic", ACFG, o.copy(), d.copy(),
                           np.asarray([32]))
    assert k1 == k2 and c1 == c2          # pure function of the inputs
    (k3, _), = block_keys(CFG, "hotdog", ACFG, o, d, np.asarray([32]))
    (k4, _), = block_keys(CFG, "mic", ACFG, o, d, np.asarray([48]))
    loose = dataclasses.replace(ACFG, delta=0.1)
    (k5, _), = block_keys(CFG, "mic", loose, o, d, np.asarray([32]))
    (k6, _), = block_keys(CFG, "mic", ACFG, o, -d, np.asarray([32]))
    assert len({k1, k3, k4, k5, k6}) == 5  # scene/budget/acfg/view all key


def test_view_bucket_quantization_boundary():
    """A direction nudge that stays inside its view bucket (and inside its
    voxel cells) keeps the key; a nudge of the same size across the bucket
    boundary changes it."""
    cfg = SceneCacheConfig(voxel_res=4, view_buckets=64)
    B = 4
    o = np.full((1, B, 3), 0.375, np.float32)      # voxel-cell centers
    d = np.tile(np.asarray([0.0, 0.0, 1.0], np.float32), (1, B, 1))
    # x-component bucket boundary sits at dx=0 (floor((0.5)*64) = 32):
    # +eps stays in bucket 32, -eps lands in bucket 31.  eps shifts the
    # chord endpoints by <= FAR*eps ~ 2e-4 << the 1/4-unit voxel cells.
    eps = 1e-4
    d_in = d.copy()
    d_in[..., 0] = eps
    d_out = d.copy()
    d_out[..., 0] = -eps
    (k0, _), = block_keys(cfg, "s", ACFG, o, d, np.asarray([32]))
    (ki, _), = block_keys(cfg, "s", ACFG, o, d_in, np.asarray([32]))
    (ko, _), = block_keys(cfg, "s", ACFG, o, d_out, np.asarray([32]))
    assert k0 == ki          # same bucket, same voxels -> shared key
    assert k0 != ko          # crossed the bucket boundary -> distinct key


# ------------------------------------------------------------------ store
def _mk_out(rng, B):
    return (rng.uniform(size=(B, 3)).astype(np.float32),
            rng.uniform(size=(B,)).astype(np.float32),
            rng.uniform(scene.NEAR, scene.FAR, size=(B,)).astype(np.float32))


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_byte_budget_never_exceeded(seed):
    """Property: after EVERY operation of an arbitrary store/lookup
    sequence, resident_bytes() <= byte_budget and matches the entries."""
    rng = np.random.default_rng(seed)
    B = 16
    one = _mk_out(rng, B)
    entry_bytes = scenecache.BlockOutput(*one, 0).nbytes
    budget = int(entry_bytes * 3.5)       # room for 3 entries, not 4
    cache = SceneBlockCache(SceneCacheConfig(byte_budget=budget))
    keys = [bytes([i]) * 8 for i in range(8)]
    for _ in range(60):
        op = rng.integers(0, 3)
        k = keys[rng.integers(0, len(keys))]
        if op == 2:
            cache.lookup(k)
        else:
            cell = ("s", int(rng.integers(0, 2)))
            rgb, acc, dep = _mk_out(rng, B)
            cache.store(k, cell, rgb, acc, dep, int(rng.integers(1, 4)))
        assert cache.resident_bytes() <= budget
        assert cache.resident_bytes() == sum(
            e.out.nbytes for e in cache._entries.values())
        assert len(cache) <= 3
    # an entry bigger than the whole budget is rejected, not admitted
    big = _mk_out(rng, 4096)
    assert not cache.store(b"big", ("s", 9), *big, 1)
    assert cache.rejected == 1 and cache.resident_bytes() <= budget


def test_store_lookup_roundtrip_and_lru():
    rng = np.random.default_rng(1)
    B = 8
    cache = SceneBlockCache(SceneCacheConfig(byte_budget=1 << 20))
    rgb, acc, dep = _mk_out(rng, B)
    assert cache.lookup(b"k1") is None and cache.misses == 1
    cache.store(b"k1", ("s", 0), jnp.asarray(rgb), acc, dep, 3)
    out = cache.lookup(b"k1")
    assert out is not None and out.chunks == 3
    np.testing.assert_array_equal(out.rgb, rgb)
    np.testing.assert_array_equal(out.acc, acc)
    np.testing.assert_array_equal(out.depth, dep)
    assert cache.hits == 1 and cache.stats()["hit_rate"] == 0.5


def test_eviction_deterministic_and_coverage_aware():
    """Coverage-aware LRU total order: redundant-cell entries evict first
    (LRU within the group, insertion order on exact ties), sole covers of
    a cell survive; two caches fed the same sequence agree exactly."""
    rng = np.random.default_rng(2)
    B = 16
    one = _mk_out(rng, B)
    entry_bytes = scenecache.BlockOutput(*one, 0).nbytes
    budget = int(entry_bytes * 3.5)

    def build():
        c = SceneBlockCache(SceneCacheConfig(byte_budget=budget))
        c.store(b"a", ("cell1",), *_mk_out(rng, B), 1)   # redundant pair...
        c.store(b"b", ("cell1",), *_mk_out(rng, B), 1)
        c.store(b"c", ("cell2",), *_mk_out(rng, B), 1)   # sole cover
        c.lookup(b"a")               # make "a" the RECENT redundant entry
        c.store(b"d", ("cell3",), *_mk_out(rng, B), 1)   # forces eviction
        return c

    c1, c2 = build(), build()
    assert set(c1._entries) == set(c2._entries) == {b"a", b"c", b"d"}
    assert c1.evictions == 1          # "b": LRU of the redundant cell1 pair
    # exact-recency tie inside one cell: insertion order (oldest) decides
    c3 = SceneBlockCache(SceneCacheConfig(byte_budget=budget))
    for k in (b"x", b"y", b"z"):
        c3.store(k, ("cell",), *_mk_out(rng, B), 1)
    for e in c3._entries.values():
        e.last_used = 7
    c3.store(b"w", ("cell",), *_mk_out(rng, B), 1)
    assert b"x" not in c3._entries and set(c3._entries) == {b"y", b"z", b"w"}


# ------------------------------------------------- framecache (satellite)
def test_framecache_eviction_tie_breaks_by_insertion_order():
    class _E:
        def __init__(self):
            self.last_used = 0

    cache = fc_base.PoseKeyedCache(
        fc_probe.ProbeReuseConfig(max_entries=2))
    e1, e2, e3 = _E(), _E(), _E()
    cache._append_with_eviction(e1)
    cache._append_with_eviction(e2)
    e1.last_used = e2.last_used = 5        # exact recency tie
    cache._append_with_eviction(e3)
    assert e1 not in cache._entries and e2 in cache._entries
    assert [e.seq for e in cache._entries] == [1, 2]


def test_framecache_resident_bytes():
    R = SIZE * SIZE
    cam = cam_at(0.7)
    probe = fc_probe.ProbeCache(fc_probe.ProbeReuseConfig())
    rad = fc_radiance.RadianceCache(fc_radiance.RadianceReuseConfig())
    assert probe.resident_bytes() == 0 and rad.resident_bytes() == 0
    counts = jnp.full((R,), 16, jnp.int32)
    opac = jnp.zeros((R,), jnp.float32)
    depth = jnp.full((R,), 1.0, jnp.float32)
    probe._store(cam, ACFG, fc_probe.ProbeMaps(counts, opac, depth, 0))
    # counts (int32) + opacity + depth (float32), all (R,)
    assert probe.resident_bytes() == 3 * 4 * R
    probe._store(cam_at(0.9), ACFG,
                 fc_probe.ProbeMaps(counts, opac, None, 0))  # depth-less
    assert probe.resident_bytes() == 3 * 4 * R + 2 * 4 * R
    rad.store(cam, ACFG, jnp.zeros((R, 3)), opac, depth)
    # rgb (R,3) + acc + depth, float32
    assert rad.resident_bytes() == (3 + 1 + 1) * 4 * R


# ----------------------------------------------------------- single image
def test_single_image_all_miss_matches_plain_pipeline(setup):
    """First (all-miss) cached call must be bit-identical to the plain
    pipeline; the replayed call hits every block and stays bit-identical."""
    fns = setup["mic"]
    cache = SceneBlockCache(SceneCacheConfig(byte_budget=8 << 20))
    fc = framecache.FrameCache(scene=cache, scene_id="mic")
    img1, st1 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    ref, _ = pipeline.render_asdr_image(fns, ACFG, cam_at(0.7))
    np.testing.assert_array_equal(img1, np.asarray(ref))
    assert st1["scene_block_hits"] == 0 and st1["scene_block_misses"] == 4
    img2, st2 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert st2["scene_block_hits"] == 4 and st2["scene_block_misses"] == 0
    np.testing.assert_array_equal(img1, img2)
    assert cache.resident_bytes() > 0


def test_make_frame_cache_shared_store_requires_scene_id():
    """Block keys disambiguate scenes only by scene_id: a shared store
    under the default id would serve scenes each other's blocks, so the
    constructor refuses it."""
    store = SceneBlockCache(SceneCacheConfig())
    with pytest.raises(ValueError, match="scene_id"):
        framecache.make_frame_cache(scene_cache=store)
    fc = framecache.make_frame_cache(scene_cache=store, scene_id="mic")
    assert fc.scene is store and fc.scene_id == "mic"


def test_render_adaptive_cached_none_is_render_adaptive(setup):
    fns = setup["mic"]
    o, d = scene.camera_rays(cam_at(0.7))
    counts = jnp.full((SIZE * SIZE,), 16, jnp.int32)
    rgb_a, acc_a, st_a = pipeline.render_adaptive(fns, ACFG, o, d, counts)
    rgb_b, acc_b, st_b = render_adaptive_cached(fns, ACFG, o, d, counts)
    np.testing.assert_array_equal(np.asarray(rgb_a), np.asarray(rgb_b))
    np.testing.assert_array_equal(np.asarray(acc_a), np.asarray(acc_b))
    assert st_b["scene_block_hits"] == 0
    np.testing.assert_array_equal(np.asarray(st_a["term_depth"]),
                                  np.asarray(st_b["term_depth"]))


# ----------------------------------------------------------------- engine
def test_engine_scenecache_none_is_bit_identical(setup):
    """The identity requirement: scenecache=None leaves the pooled-march
    engine bit-identical to render_asdr_image."""
    eng = RenderServingEngine(setup, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None, scenecache=None))
    done = eng.render([RenderRequest(rid=0, scene="mic", cam=cam_at(0.7))])
    ref, _ = pipeline.render_asdr_image(setup["mic"], ACFG, cam_at(0.7))
    np.testing.assert_array_equal(done[0].image, np.asarray(ref))
    assert "scenecache" not in eng.engine_stats()


def test_engine_cross_client_block_reuse_bit_identical(setup):
    """Two clients at the same pose: the second's blocks come from the
    shared store (zero extra marches) and the frames match bit-exactly —
    including a third client served by a SECOND engine sharing the store."""
    store = SceneBlockCache(SceneCacheConfig(byte_budget=8 << 20))
    eng = RenderServingEngine(setup, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None), scenecache=store)
    first = eng.render([RenderRequest(rid=0, scene="mic", cam=cam_at(0.7))])
    marched = eng.blocks_marched
    second = eng.render([RenderRequest(rid=1, scene="mic", cam=cam_at(0.7))])
    assert eng.blocks_marched == marched          # zero new marches
    assert eng.scene_blocks_hit == 4
    # compute honesty: a fully cache-served frame spent zero samples
    assert second[0].stats["scene_block_hits"] == 4
    assert second[0].stats["samples_processed"] == 0
    assert (second[0].stats["samples_reused"]
            == first[0].stats["samples_processed"])
    np.testing.assert_array_equal(first[0].image, second[0].image)
    ref, _ = pipeline.render_asdr_image(setup["mic"], ACFG, cam_at(0.7))
    np.testing.assert_array_equal(second[0].image, np.asarray(ref))
    eng2 = RenderServingEngine(setup, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None), scenecache=store)
    third = eng2.render([RenderRequest(rid=2, scene="mic", cam=cam_at(0.7))])
    assert eng2.blocks_marched == 0 and eng2.scene_blocks_hit == 4
    np.testing.assert_array_equal(third[0].image, np.asarray(ref))


def test_engine_same_round_duplicate_blocks_dedup(setup):
    """Identical requests admitted in the same scheduling round: in-batch
    dedup + the pool sweep mean the engine marches each distinct block
    once and both frames complete identically."""
    eng = RenderServingEngine(setup, ACFG, RenderServeConfig(
        slots=4, blocks_per_batch=4, reuse=None,
        scenecache=SceneCacheConfig(byte_budget=8 << 20)))
    reqs = [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
            for i in range(3)]
    done = {r.rid: r for r in eng.render(reqs)}
    assert eng.blocks_marched == 4                # one frame's worth
    assert eng.scene_blocks_hit == 8              # the other two frames'
    for rid in (1, 2):
        np.testing.assert_array_equal(done[0].image, done[rid].image)
    # cache-level counters are FIRST-TOUCH lookup stats: every block
    # records exactly one admission miss (all 3 frames admit before any
    # march), sweep deliveries count hits, in-batch dedup followers never
    # look up, and the multi-round pool re-sweeps add NO further misses
    sc = eng.engine_stats()["scenecache"]
    assert sc["misses"] == 12 and sc["hits"] == 4


def test_engine_stats_expose_scenecache(setup):
    eng = RenderServingEngine(setup, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None,
        scenecache=SceneCacheConfig(byte_budget=1 << 20)))
    eng.render([RenderRequest(rid=0, scene="mic", cam=cam_at(0.7))])
    st = eng.engine_stats()
    sc = st["scenecache"]
    assert sc["entries"] == 4 and sc["resident_bytes"] > 0
    assert sc["resident_bytes"] <= sc["byte_budget"]
    assert st["scene_block_hit_rate"] == 0.0


# ---------------------------------------------------------- serialization
def test_key_bytes_round_trip():
    """key_to_bytes/from_bytes must reproduce (digest, cell) exactly —
    the wire format an external/sharded store exchanges."""
    rng = np.random.default_rng(3)
    o, d = _block(rng)
    (key, cell), = block_keys(CFG, "mic", ACFG, o, d, np.asarray([32]))
    buf = scenecache.key_to_bytes(key, cell)
    key2, cell2 = scenecache.key_from_bytes(buf)
    assert key2 == key and cell2 == cell
    assert isinstance(buf, bytes)
    # byte layout is stable: same inputs, same bytes (no process state)
    assert scenecache.key_to_bytes(key, cell) == buf


def test_entry_bytes_round_trip_and_store_load():
    """A dumped resident entry reloads bit-exactly into another store,
    through the normal byte-budgeted store path."""
    rng = np.random.default_rng(4)
    B = ACFG.block_size
    src = SceneBlockCache(SceneCacheConfig(byte_budget=1 << 20))
    o, d = _block(rng, B=B)
    (key, cell), = block_keys(src.cfg, "mic", ACFG, o, d, np.asarray([24]))
    rgb, acc, depth = (rng.uniform(size=(B, 3)).astype(np.float32),
                       rng.uniform(size=(B,)).astype(np.float32),
                       rng.uniform(size=(B,)).astype(np.float32))
    src.store(key, cell, rgb, acc, depth, 3)
    data = src.dump_entry(key)
    assert data is not None and src.dump_entry(b"absent") is None

    # an entry that can never fit is REJECTED, not silently "loaded"
    tiny = SceneBlockCache(SceneCacheConfig(byte_budget=64))
    assert tiny.load_entry(data) is None and len(tiny) == 0

    dst = SceneBlockCache(SceneCacheConfig(byte_budget=1 << 20))
    assert dst.load_entry(data) == key
    out = dst.lookup(key)
    np.testing.assert_array_equal(out.rgb, rgb)
    np.testing.assert_array_equal(out.acc, acc)
    np.testing.assert_array_equal(out.depth, depth)
    assert out.chunks == 3
    assert dst.resident_bytes() <= dst.cfg.byte_budget
    # round-trip at the record level too
    k2, c2, o2 = scenecache.entry_from_bytes(data)
    assert k2 == key and c2 == cell
    np.testing.assert_array_equal(o2.depth, depth)


def test_serial_rejects_foreign_and_truncated_records():
    rng = np.random.default_rng(5)
    o, d = _block(rng)
    (key, cell), = block_keys(CFG, "mic", ACFG, o, d, np.asarray([8]))
    buf = scenecache.key_to_bytes(key, cell)
    with pytest.raises(ValueError):
        scenecache.key_from_bytes(b"XXXX" + buf[4:])
    with pytest.raises(ValueError):
        scenecache.entry_from_bytes(buf)           # key record, not entry
    with pytest.raises(ValueError):
        scenecache.key_from_bytes(buf + b"\x00")   # trailing garbage
    # truncation anywhere must surface as the documented ValueError,
    # never a bare struct.error
    ent = scenecache.entry_to_bytes(key, cell,
                                    scenecache.BlockOutput(
                                        np.zeros((4, 3), np.float32),
                                        np.zeros((4,), np.float32),
                                        np.zeros((4,), np.float32), 1))
    for cut in (5, len(buf) // 2, len(buf) - 3):
        with pytest.raises(ValueError):
            scenecache.key_from_bytes(buf[:cut])
    for cut in (5, len(buf) // 2, len(ent) // 2, len(ent) - 7):
        with pytest.raises(ValueError):
            scenecache.entry_from_bytes(ent[:cut])


# ---------------------------------------------------------- sharded store
def test_shard_routing_pure_and_stable():
    """Routing is a pure function of the key bytes — stable across
    instances, processes, and hosts.  The golden literals pin the exact
    mapping (int.from_bytes(key[:8], 'little') % n): a routing change
    would silently strand every replicated entry on the wrong shard."""
    golden = [  # blake2b-16 digests of b"block-a/b/c"
        (bytes.fromhex("ff4ae11015502c538ed2bf412a48081f"), 3, 6),
        (bytes.fromhex("23cb8a0909dc5440836dec32520bad9c"), 3, 2),
        (bytes.fromhex("6ec6f77cafee3e64332257d68a63d412"), 2, 1),
    ]
    for key, at4, at7 in golden:
        assert shard_of(key, 4) == at4
        assert shard_of(key, 7) == at7
        assert shard_of(key, 1) == 0
    # only the first 8 bytes route: the digest tail never moves an entry
    k = golden[0][0]
    assert shard_of(k, 4) == shard_of(k[:8] + b"\xff" * 8, 4)
    # two independent caches agree on placement for arbitrary keys
    a = ShardedSceneCache(SceneCacheConfig(byte_budget=1 << 20), shards=4)
    b = ShardedSceneCache(SceneCacheConfig(byte_budget=1 << 20), shards=4)
    rng = np.random.default_rng(7)
    for _ in range(32):
        key = rng.bytes(16)
        assert a._shard(key) == b._shard(key) == shard_of(key, 4)
        assert 0 <= shard_of(key, 4) < 4
    a.close(), b.close()


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_sharded_per_shard_budget_never_exceeded(seed):
    """Property: after EVERY operation of an arbitrary store/lookup
    sequence, each shard holds resident_bytes() <= byte_budget // n —
    the per-shard bound, not just the global one."""
    rng = np.random.default_rng(seed)
    B = 16
    entry_bytes = scenecache.BlockOutput(*_mk_out(rng, B), 0).nbytes
    budget = int(entry_bytes * 3.5) * 2          # ~1.75 entries per shard
    cache = ShardedSceneCache(SceneCacheConfig(byte_budget=budget), shards=2)
    per = budget // 2
    keys = [rng.bytes(16) for _ in range(10)]
    for _ in range(60):
        op = rng.integers(0, 3)
        k = keys[rng.integers(0, len(keys))]
        if op == 2:
            cache.lookup(k)
        else:
            cache.store(k, ("s", int(rng.integers(0, 2))),
                        *_mk_out(rng, B), int(rng.integers(1, 4)))
        st_ = cache.stats()
        assert all(b <= per for b in st_["per_shard_resident_bytes"])
        assert cache.resident_bytes() <= budget
        assert st_["per_shard_budget"] == per
    cache.close()


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_sharded_n1_union_equals_plain_semantics(seed):
    """Property: at shards=1 the sharded store is observationally equal
    to a plain SceneBlockCache — same lookup results, same stats on
    every common key — for an arbitrary op sequence."""
    rng = np.random.default_rng(seed)
    B = 8
    cfg = SceneCacheConfig(byte_budget=1 << 16)
    plain = SceneBlockCache(cfg)
    shard = ShardedSceneCache(cfg, shards=1)
    keys = [rng.bytes(16) for _ in range(6)]
    for _ in range(50):
        op = rng.integers(0, 3)
        k = keys[rng.integers(0, len(keys))]
        if op == 2:
            got_p = plain.lookup(k)
            got_s = shard.lookup(k)
            assert (got_p is None) == (got_s is None)
            if got_p is not None:
                np.testing.assert_array_equal(got_p.rgb, got_s.rgb)
        else:
            cell = ("s", int(rng.integers(0, 2)))
            out = _mk_out(rng, B)
            chunks = int(rng.integers(1, 4))
            assert (plain.store(k, cell, *out, chunks)
                    == shard.store(k, cell, *out, chunks))
        sp, ss = plain.stats(), shard.stats()
        for key in sp:
            assert sp[key] == ss[key], (key, sp[key], ss[key])
        assert len(plain) == len(shard)
        assert plain.resident_bytes() == shard.resident_bytes()
    # replication routes through the same wire format
    for k in keys:
        dp, ds = plain.dump_entry(k), shard.dump_entry(k)
        assert (dp is None) == (ds is None)
        if dp is not None:
            assert dp == ds
            fresh = ShardedSceneCache(cfg, shards=4)
            assert fresh.load_entry(dp) == k
            assert fresh.shards[shard_of(k, 4)].lookup(k) is not None
            fresh.close()
    shard.close()


def test_sweep_delivers_fast_shards_before_slow_join():
    """Regression for the sweep join point: the old code gathered every
    future IN ORDER before delivering anything, so one slow shard
    stalled all deliveries.  The as-completed join must deliver the
    completed prefix while the slow fetch is still in flight — and
    delivery order must remain exactly the submission order."""
    import concurrent.futures
    import threading
    import time

    from repro.serve import pool as pool_lib
    from repro.serve import stats as stats_lib

    release = threading.Event()
    delivered = []

    class Slot:                       # minimal pool-item delivery surface
        def deliver(self, bi, rgb, acc, depth, chunks, cached):
            delivered.append(bi)

    out = scenecache.BlockOutput(*_mk_out(np.random.default_rng(0), 8), 1)
    ex = concurrent.futures.ThreadPoolExecutor(1)

    class Store:                      # one slow shard, the rest instant
        def fetch_async(self, key, count_miss=True):
            if key == b"slow":
                def blocked():
                    assert release.wait(10.0), "test released the shard"
                    return None
                return ex.submit(blocked)
            f: concurrent.futures.Future = concurrent.futures.Future()
            f.set_result(out)
            return f

    counters = stats_lib.EngineCounters()
    pool = pool_lib.BlockPool(pipeline.ASDRConfig(), 4, Store(), counters)
    slot, z = Slot(), np.zeros((8, 3), np.float32)
    pool.items = [(slot, bi, z, z, 16, key, ("s", 0), False)
                  for bi, key in enumerate([b"fast-a", b"slow", b"fast-b"])]
    t = threading.Thread(target=pool.sweep)
    t.start()
    try:
        # the fast shard AHEAD of the slow one delivers while the slow
        # fetch is still blocked (the gather-all join could not do this)
        deadline = time.time() + 5.0
        while delivered != [0] and time.time() < deadline:
            time.sleep(0.002)
        assert delivered == [0] and not release.is_set()
    finally:
        release.set()
        t.join(10.0)
    assert not t.is_alive()
    # fast-b queued BEHIND the slow shard still delivered — after it, in
    # submission order; the slow miss stays pooled for the round's march
    assert delivered == [0, 2]
    assert [it[5] for it in pool.items] == [b"slow"]
    assert counters.scene_blocks_hit == 2
    ex.shutdown()
