"""Per-arch smoke tests (reduced configs): forward/train step + decode.

One test per assigned architecture (task requirement): instantiate the
REDUCED same-family config, run one forward/train step on CPU, assert
output shapes and no NaNs; plus a decode-vs-forward consistency check.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm, transformer as tfm

ARCHS = configs.list_archs()


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.get_smoke(arch)
    api = lm.build(cfg, remat_policy=None)
    key = jax.random.PRNGKey(0)
    values = api.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(api.loss_fn)(values, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), \
        f"{arch}: non-finite grads"
    # loss near ln(vocab) at init (sanity of the CE plumbing)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.slow
@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-medium"])
def test_smoke_decode_consistency(arch):
    """prefill(S-1) + decode(1) == full forward's last-position logits.

    MoE uses a large capacity factor here: with capacity drops the prefill
    (token competition within a group) and decode (single token, always
    fits) semantics legitimately differ — drop behaviour is covered in
    test_moe.py; this test checks the cache/decode mechanism.
    """
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32",
                              capacity_factor=8.0)
    api = lm.build(cfg, remat_policy=None)
    key = jax.random.PRNGKey(0)
    values = api.init(key)
    B, S = 2, 24
    batch = _batch(cfg, key, B, S)
    if cfg.family == "vlm":
        batch["img_embeds"] = batch["img_embeds"].astype(jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    pfx = cfg.prefix_tokens or 0
    _, caches = api.prefill_fn(values, pre, max_seq=S + pfx)
    lg, _ = api.decode_fn(values, caches, batch["tokens"][:, -1:],
                          jnp.asarray(S - 1 + pfx))
    full, _ = tfm.forward(values, cfg, batch["tokens"],
                          img_embeds=batch.get("img_embeds"))
    tol = 1e-3 if cfg.family == "moe" else 1e-4
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, -1]))) < tol


@pytest.mark.slow
def test_whisper_decode_consistency():
    from repro.models import encdec as E
    cfg = dataclasses.replace(configs.get_smoke("whisper-medium"),
                              dtype="float32")
    api = lm.build(cfg, remat_policy=None)
    key = jax.random.PRNGKey(0)
    values = api.init(key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    batch["frames"] = batch["frames"].astype(jnp.float32)
    enc_out = E.encode(values, cfg, batch["frames"])
    full = E.decode_train(values, cfg, batch["tokens"], enc_out)[:, -1]
    cache = E.init_cache(cfg, B, S, jnp.float32)
    ck, cv = E.prefill_cross(values, cfg, enc_out)
    cache = cache._replace(cross_k=ck.astype(jnp.float32),
                           cross_v=cv.astype(jnp.float32))
    for t in range(S):
        lg, cache = api.decode_fn(values, cache,
                                  batch["tokens"][:, t:t+1], jnp.asarray(t))
    assert float(jnp.max(jnp.abs(lg[:, 0] - full))) < 1e-4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """FULL configs must build abstract (ShapeDtypeStruct) params — no
    allocation — and match the analytic param count within 2%."""
    cfg = configs.get(arch)
    api = lm.build(cfg)
    shapes, axes = api.abstract()
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expect = cfg.param_count()
    # padded vocab + conv/meta params make small deviations
    assert abs(total - expect) / expect < 0.05, (total, expect)
    # axes tree matches the value tree structure exactly
    jax.tree.map(lambda s, a: None, shapes,
                 jax.tree.map(lambda a: a, axes,
                              is_leaf=lambda x: isinstance(x, tuple)))


def test_layer_kind_patterns():
    g2 = configs.get("gemma2-27b")
    kinds = g2.layer_kinds()
    assert kinds[0] == 4096 and kinds[1] == 0  # alternating, local first
    g3 = configs.get("gemma3-12b")
    kinds3 = g3.layer_kinds()
    assert kinds3[:6].count(0) == 1 and kinds3[5] == 0  # 5 local : 1 global
    hy = configs.get("hymba-1.5b")
    kh = hy.layer_kinds()
    assert kh[0] == 0 and kh[len(kh) // 2] == 0 and kh[-1] == 0
