"""Attention: chunked == full, GQA, windows, softcap, caches, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import attention as A


def _qkv(key, B=2, S=32, H=4, KV=2, Dh=16, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, Dh), dtype)
    k = jax.random.normal(k2, (B, S, KV, Dh), dtype)
    v = jax.random.normal(k3, (B, S, KV, Dh), dtype)
    return q, k, v


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([0, 8]),
       st.sampled_from([0.0, 50.0]), st.sampled_from([4, 8, 32]))
def test_chunked_equals_full(seed, window, cap, chunk):
    key = jax.random.PRNGKey(seed)
    q, k, v = _qkv(key)
    pos = jnp.arange(32, dtype=jnp.int32)
    full = A.attend_full(q, k, v, pos, pos, window=window, softcap_val=cap)
    ch = A.attend_chunked(q, k, v, pos, pos, window=window, softcap_val=cap,
                          chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ch),
                               rtol=2e-4, atol=2e-5)


def test_chunked_with_prefix_mask():
    key = jax.random.PRNGKey(7)
    q, k, v = _qkv(key, S=24)
    pos = jnp.arange(24, dtype=jnp.int32)
    em = (pos[:, None] < 8) & (pos[None, :] < 8)  # bidirectional prefix
    full = A.attend_full(q, k, v, pos, pos, extra_mask=em)
    ch = A.attend_chunked(q, k, v, pos, pos, chunk=8, extra_mask=em)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ch),
                               rtol=2e-4, atol=2e-5)
    # prefix token 0 must differ from pure-causal (it can see tokens 1..7)
    causal = A.attend_full(q, k, v, pos, pos)
    assert float(jnp.max(jnp.abs(full[:, 0] - causal[:, 0]))) > 1e-4


def test_sliding_window_masks_far_keys():
    key = jax.random.PRNGKey(1)
    q, k, v = _qkv(key, S=16)
    pos = jnp.arange(16, dtype=jnp.int32)
    out_w = A.attend_full(q, k, v, pos, pos, window=4)
    # last query attends only to keys 12..15; check equality with truncation
    out_trunc = A.attend_full(q[:, -1:], k[:, -4:], v[:, -4:],
                              pos[-1:], pos[-4:])
    np.testing.assert_allclose(np.asarray(out_w[:, -1:]),
                               np.asarray(out_trunc), rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    r = A.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> independent of p
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
    def dot_at(p, d):
        rq = A.apply_rope(q, jnp.asarray([[p]]))
        rk = A.apply_rope(k, jnp.asarray([[p + d]]))
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(0, 3) - dot_at(11, 3)) < 1e-3


def test_ring_cache_slot_positions():
    cache = A.init_cache(1, 4, 2, 8, jnp.float32)  # window-4 ring
    # stream pos = 6 -> slots hold positions [4, 5, 2, 3] (slot = pos % 4)
    got = np.asarray(A.cache_slot_positions(cache, 6, ring=True))
    np.testing.assert_array_equal(got, [4, 5, 2, 3])
    # linear cache at pos 2: [0, 1, INTMAX, INTMAX]
    got = np.asarray(A.cache_slot_positions(cache, 2, ring=False))
    assert got[0] == 0 and got[1] == 1 and got[2] > 1e9


def test_decode_matches_full_attention_stepwise():
    """Feeding tokens one by one through the ring cache == windowed attn."""
    key = jax.random.PRNGKey(4)
    B, S, H, KV, Dh, W = 1, 12, 2, 2, 8, 4
    q, k, v = _qkv(key, B=B, S=S, H=H, KV=KV, Dh=Dh)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = A.attend_full(q, k, v, pos, pos, window=W)
    cache = A.init_cache(B, W, KV, Dh, jnp.float32)
    for t in range(S):
        cache = A.cache_update(cache, k[:, t:t+1], v[:, t:t+1],
                               jnp.asarray(t), ring=True)
        out = A.decode_attend(q[:, t:t+1], cache, jnp.asarray(t), True, KV,
                              window=W)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(ref[:, t]),
                                   rtol=1e-4, atol=1e-5)
