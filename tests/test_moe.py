"""MoE routing: conservation, capacity drops, dense equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ffn
from repro.models.config import ModelConfig
from repro.models.params import split

# LM-zoo routing math — exercised nightly via `pytest -m ""`; the fast
# ASDR tier keeps the render/serve/kernel surface
pytestmark = pytest.mark.slow


CFG = ModelConfig(
    name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=48, vocab=64,
    n_experts=4, top_k=2, moe_d_ff=48, moe_group_size=16,
    capacity_factor=1.25, dtype="float32",
)


def test_route_dispatch_combine_properties():
    key = jax.random.PRNGKey(0)
    G, S_, E, k, C = 2, 16, 4, 2, 10
    logits = jax.random.normal(key, (G, S_, E))
    dispatch, combine = ffn._route(logits, k, C)
    # each (token, rank) occupies <= 1 slot; dispatch is 0/1
    assert set(np.unique(np.asarray(dispatch))) <= {0.0, 1.0}
    per_token = np.asarray(dispatch).sum(axis=(2, 3))
    assert per_token.max() <= k
    # no slot is claimed twice
    per_slot = np.asarray(dispatch).sum(axis=1)  # (G, E, C)
    assert per_slot.max() <= 1.0
    # combine weights only where dispatched, and <= softmax prob
    cw = np.asarray(combine)
    assert ((cw > 0) <= (np.asarray(dispatch) > 0)).all()
    probs = np.asarray(jax.nn.softmax(logits, -1))
    got_w = cw.sum(axis=3)  # (G, S, E)
    assert (got_w <= probs + 1e-5).all()


def test_capacity_drops_overflow_tokens():
    # all tokens pick expert 0 at rank 0 -> only C fit
    G, S_, E, k, C = 1, 16, 4, 1, 4
    logits = jnp.zeros((G, S_, E)).at[..., 0].set(10.0)
    dispatch, combine = ffn._route(logits, k, C)
    kept = float(np.asarray(dispatch)[..., 0, :].sum())
    assert kept == C  # exactly capacity survive


def test_moe_matches_dense_sum_at_high_capacity():
    """With capacity_factor high enough to avoid drops, the MoE output must
    equal the explicit per-token top-k expert sum."""
    import dataclasses
    cfg = dataclasses.replace(CFG, capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    p, _ = split(ffn.moe_init(key, cfg))
    B, S_ = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S_, cfg.d_model)) * 0.3
    out = ffn.moe_apply(p, x, cfg, "silu")

    # reference: dense per-token computation
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["wi_gate"][e]) * (x @ p["wi_up"][e])
        y = h @ p["wo"][e]
        gate = jnp.sum(jnp.where(idx == e, w, 0.0), -1)
        ref = ref + gate[..., None].astype(x.dtype) * y
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_shared_experts_added():
    import dataclasses
    cfg = dataclasses.replace(CFG, n_shared_experts=2)
    p, _ = split(ffn.moe_init(jax.random.PRNGKey(3), cfg))
    assert "shared" in p
    x = jnp.zeros((1, 16, cfg.d_model))
    out = ffn.moe_apply(p, x, cfg, "silu")
    assert out.shape == x.shape


def test_aux_loss_balanced_router_is_minimal():
    key = jax.random.PRNGKey(4)
    uniform = jnp.zeros((2, 64, 4))
    skew = jnp.zeros((2, 64, 4)).at[..., 0].set(5.0)
    assert float(ffn.moe_aux_loss(skew, 2)) > float(ffn.moe_aux_loss(uniform, 2))
