"""Locality profiling (paper Figs. 4/8/15/22) behaves as the paper found."""
import jax.numpy as jnp
import numpy as np

from repro.core import fields, pipeline, reuse, scene
from repro.core.hashgrid import HashGridConfig


CFG = HashGridConfig(n_levels=8, log2_table_size=14, max_resolution=256)


def _two_neighbor_rays(S=96):
    # fine camera so adjacent pixels are adjacent rays (paper: 800x800)
    cam = scene.look_at_camera(128, 128, theta=0.5, phi=0.5)
    o, d = scene.camera_rays(cam)
    mid = 64 * 128 + 64
    pts_a, _, _ = scene.sample_points(o[mid:mid+1], d[mid:mid+1], S)
    pts_b, _, _ = scene.sample_points(o[mid+1:mid+2], d[mid+1:mid+2], S)
    return pts_a[0], pts_b[0]


def test_inter_ray_repetition_high_at_low_res():
    """Paper Fig. 15a: neighboring rays share >90% of voxels at low res,
    decreasing with resolution."""
    a, b = _two_neighbor_rays()
    rates = reuse.inter_ray_repetition(a, b, CFG)
    assert rates[0] > 0.85
    assert rates[0] >= rates[-1]


def test_intra_ray_concentration():
    """Paper Fig. 15b: many samples of one ray land in the same voxel at
    low res; fewer at high res."""
    a, _ = _two_neighbor_rays()
    counts = reuse.intra_ray_max_voxel_count(a, CFG)
    assert counts[0] > counts[-1]
    assert counts[0] >= 6


def test_color_cosine_similarity_near_one():
    """Paper Fig. 8: >95% of adjacent-sample color cosines ~ 1."""
    field = scene.make_scene("lego")
    fns = fields.analytic_field_fns(field)
    cam = scene.look_at_camera(12, 12, theta=0.9, phi=0.5)
    o, d = scene.camera_rays(cam)
    _, aux = pipeline.render_fixed_fns(fns, o, d, 64)
    cos = reuse.adjacent_color_cosine(aux["colors"])
    assert (cos > 0.95).mean() > 0.9


def test_lru_cache_hit_rate_monotone_in_size():
    """Paper Fig. 22 shape: bigger register cache -> higher hit rate, with
    diminishing returns; level-0 traces hit hard even at 8 entries."""
    a, _ = _two_neighbor_rays()
    sweep = reuse.cache_sweep(a, CFG, sizes=(0, 2, 8, 32))
    assert (sweep[0] == 0).all()
    assert (sweep[8] >= sweep[2] - 1e-9).all()
    assert (sweep[32] >= sweep[8] - 1e-9).all()
    assert sweep[8][0] > 0.5


def test_dedup_window_rate_and_gather_bytes():
    a, _ = _two_neighbor_rays()
    r0 = reuse.dedup_window_rate(a, CFG, window=32, level=0)
    r_hi = reuse.dedup_window_rate(a, CFG, window=32, level=CFG.n_levels - 1)
    assert r0 > r_hi            # low-res tiles dedup far more
    assert 0.0 <= r_hi <= 1.0
    full = reuse.gather_bytes(1000, CFG)
    deduped = reuse.gather_bytes(1000, CFG, dedup_rate=r0)
    assert deduped < full


def test_lru_hit_rate_zero_size_and_monotone_synthetic():
    """lru_cache_hit_rate == 0 at size 0 and is monotone in cache size on
    an arbitrary trace (not just camera-ray traces)."""
    rng = np.random.default_rng(3)
    trace = rng.integers(0, 50, size=2000)
    assert reuse.lru_cache_hit_rate(trace, 0) == 0.0
    assert reuse.lru_cache_hit_rate(trace, -1) == 0.0
    rates = [reuse.lru_cache_hit_rate(trace, s) for s in (1, 2, 4, 8, 16,
                                                          32, 64)]
    assert all(b >= a - 1e-12 for a, b in zip(rates, rates[1:]))
    # a cache holding every address hits on all but cold misses
    full = reuse.lru_cache_hit_rate(trace, 50)
    assert full >= 1.0 - 50 / trace.size - 1e-12


def test_dedup_window_rate_bounds_and_window_monotone():
    """On a straight-ray trace: dedup rate lies in [0, 1) and grows with
    the window size (bigger tiles can only find more duplicates)."""
    o = jnp.asarray([[0.05, 0.5, 0.5]])
    d = jnp.asarray([[1.0, 0.0, 0.0]])            # axis-aligned straight ray
    pts, _, _ = scene.sample_points(o, d, 192)
    pts = pts[0]
    rates = [reuse.dedup_window_rate(pts, CFG, window=w, level=0)
             for w in (4, 16, 64, 192)]
    for r in rates:
        assert 0.0 <= r < 1.0
    assert all(b >= a - 1e-12 for a, b in zip(rates, rates[1:]))
    assert rates[-1] > rates[0]                    # strictly more reuse


def test_hash_trace_irregularity():
    """Paper Fig. 4: hashed addresses jump; dense addresses are local."""
    a, _ = _two_neighbor_rays()
    tr_dense = reuse.hash_address_trace(a, CFG, 0)
    tr_hash = reuse.hash_address_trace(a, CFG, CFG.n_levels - 1)
    jump_d = np.abs(np.diff(tr_dense[:, 0])).mean()
    jump_h = np.abs(np.diff(tr_hash[:, 0])).mean()
    assert jump_h > 10 * jump_d
