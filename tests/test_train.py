"""Train-step factory: microbatch equivalence, convergence, restarts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full LM train steps — heavy compile

import repro.configs as configs
from repro.data import TokenPipeline
from repro.models import lm
from repro.train.step import TrainConfig, make_loss_and_grads, make_train_step


@pytest.fixture(scope="module")
def tiny():
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("minitron-8b"),
                              dtype="float32")
    api = lm.build(cfg, remat_policy=None)
    values = api.init(jax.random.PRNGKey(0))
    return cfg, api, values


def test_microbatch_gradient_equivalence(tiny):
    """Accumulated grads over 4 microbatches == single-batch grads."""
    cfg, api, values = tiny
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    g1 = make_loss_and_grads(api.loss_fn, 1)
    g4 = make_loss_and_grads(api.loss_fn, 4)
    l1, grads1 = g1(values, batch)
    l4, grads4 = g4(values, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=2e-3)
    flat1 = jax.tree.leaves(grads1)
    flat4 = jax.tree.leaves(grads4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-2, atol=3e-4)


def test_loss_decreases_on_structured_data(tiny):
    cfg, api, values = tiny
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    step_fn, opt_init = make_train_step(api.loss_fn, tcfg)
    step_fn = jax.jit(step_fn)
    opt = opt_init(values)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq_len=64)
    losses = []
    for i in range(30):
        batch = {"tokens": pipe.batch_at(i)}
        values, opt, m = step_fn(values, opt, batch, jnp.asarray(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_grad_norm_metric_and_clipping(tiny):
    cfg, api, values = tiny
    tcfg = TrainConfig(max_grad_norm=1e-9)  # everything clipped
    step_fn, opt_init = make_train_step(api.loss_fn, tcfg)
    opt = opt_init(values)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                          cfg.vocab)}
    new_values, _, m = step_fn(values, opt, batch, jnp.asarray(0))
    # with clip ~0 params barely move
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(new_values),
                            jax.tree.leaves(values)))
    assert d < 1e-5
    assert float(m["grad_norm"]) > 0


def test_train_loop_restart_from_checkpoint(tmp_path):
    """Injected failure -> restart from last checkpoint -> same final state
    as an uninterrupted run (deterministic-by-step data)."""
    from repro.launch.train import train_loop

    cfg = configs.get_smoke("minitron-8b")
    api = lm.build(cfg, remat_policy=None)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=12)

    _, _, losses_fail = train_loop(
        api, tcfg, steps=12, batch=4, seq=32,
        ckpt_dir=tmp_path / "a", ckpt_every=4,
        max_restarts=1, fail_at_step=9, verbose=False,
    )
    _, _, losses_ok = train_loop(
        api, tcfg, steps=12, batch=4, seq=32,
        ckpt_dir=tmp_path / "b", ckpt_every=4, verbose=False,
    )
    # the restarted run replays steps 9..11 identically
    d_fail = dict(losses_fail)
    d_ok = dict(losses_ok)
    for s in (10, 11):
        np.testing.assert_allclose(d_fail[s], d_ok[s], rtol=1e-4)
