"""§4.2 adaptive sampling: Eq.(3) metric, count selection, interpolation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive, fields, pipeline, scene


@pytest.fixture(scope="module")
def probe_data():
    field = scene.make_scene("mic")
    fns = fields.analytic_field_fns(field)
    cam = scene.look_at_camera(8, 8, theta=0.3, phi=0.5)
    o, d = scene.camera_rays(cam)
    rgb, aux = pipeline.render_fixed_fns(fns, o, d, 64)
    return rgb, aux


def test_rendering_difficulty_eq3():
    a = jnp.asarray([[0.1, 0.5, 0.9]])
    b = jnp.asarray([[0.2, 0.2, 0.85]])
    rd = adaptive.rendering_difficulty(a, b)
    np.testing.assert_allclose(float(rd[0]), 0.3, rtol=1e-6)


def test_probe_counts_monotone_in_delta(probe_data):
    rgb, aux = probe_data
    cands = (8, 16, 32)
    loose = adaptive.probe_counts(aux["sigmas"], aux["colors"], rgb, 64,
                                  cands, delta=0.1)
    tight = adaptive.probe_counts(aux["sigmas"], aux["colors"], rgb, 64,
                                  cands, delta=1e-5)
    assert float(jnp.mean(loose)) <= float(jnp.mean(tight))
    ladder = set(cands) | {64}
    assert set(np.asarray(loose).tolist()) <= ladder


def test_delta_zero_is_lossless_selection(probe_data):
    """rd_i = 0 required -> chosen count must reproduce the full render."""
    rgb, aux = probe_data
    counts = adaptive.probe_counts(aux["sigmas"], aux["colors"], rgb, 64,
                                   (8, 16, 32), delta=0.0)
    for r in range(min(rgb.shape[0], 24)):  # spot-check bounds the runtime
        c = int(counts[r])
        if c < 64:
            sub = adaptive.subsampled_composite(
                aux["sigmas"][r:r+1], aux["colors"][r:r+1], 64, c)
            rd = adaptive.rendering_difficulty(rgb[r:r+1], sub)
            assert float(rd[0]) <= 1e-6


def test_interpolate_counts_snaps_up_to_ladder():
    probe = jnp.asarray([8, 8, 64, 64], jnp.int32)
    full = adaptive.interpolate_counts(probe, (2, 2), (8, 8),
                                       candidates=(8, 16, 32), ns_full=64)
    vals = set(np.asarray(full).tolist())
    assert vals <= {8, 16, 32, 64}
    # corners keep their probe values
    grid = np.asarray(full).reshape(8, 8)
    assert grid[0, 0] == 8 and grid[-1, -1] == 64


def test_sort_rays_into_blocks():
    counts = jnp.asarray([64, 8, 32, 8, 64, 8, 16, 8], jnp.int32)
    order, budgets = adaptive.sort_rays_into_blocks(counts, 4)
    sorted_counts = np.asarray(counts)[np.asarray(order)]
    assert (np.diff(sorted_counts) >= 0).all()
    assert budgets.shape == (2,)
    # block budget = max in block (conservative)
    assert int(budgets[0]) == sorted_counts[:4].max()
    assert int(budgets[1]) == sorted_counts[4:].max()


def test_compute_savings_matches_paper_shape():
    counts = jnp.full((100,), 120, jnp.int32)
    s = adaptive.compute_savings(counts, 192)
    np.testing.assert_allclose(s["sample_reduction"], 1.6, rtol=1e-6)
