"""Two-phase ASDR pipeline on the exact analytic field."""
import jax.numpy as jnp
import pytest

from repro.core import fields, pipeline, rendering, scene


@pytest.fixture(scope="module")
def setup():
    field = scene.make_scene("mic")
    fns = fields.analytic_field_fns(field)
    cam = scene.look_at_camera(24, 24, theta=0.7, phi=0.5)
    o, d = scene.camera_rays(cam)
    full, _ = pipeline.render_fixed_fns(fns, o, d, 96)
    return field, fns, cam, o, d, full


def test_asdr_near_lossless_with_fewer_samples(setup):
    field, fns, cam, o, d, full = setup
    acfg = pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, block_size=96, chunk=16,
        candidates=(12, 24, 48), delta=1.0 / 2048.0,
    )
    img, stats = pipeline.render_asdr_image(fns, acfg, cam)
    p = float(rendering.psnr(img, full.reshape(24, 24, 3)))
    assert p > 35.0                       # near-lossless vs fixed-96
    assert stats["avg_samples_per_ray"] < 96   # fewer samples used
    assert stats["phase2_fraction_of_baseline"] < 0.8


def test_background_gets_fewest_samples(setup):
    """mic scene is background-heavy — paper: ~40% of pixels can drop to
    the minimum count."""
    field, fns, cam, o, d, full = setup
    acfg = pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, block_size=96, chunk=16,
        candidates=(12, 24, 48),
    )
    counts, _ = pipeline.probe_phase(fns, acfg, cam)
    frac_min = float(jnp.mean(counts == 12))
    assert frac_min > 0.3


@pytest.mark.slow
def test_early_termination_reduces_chunks(setup):
    field, fns, cam, o, d, full = setup
    kw = dict(ns_full=96, probe_stride=4, block_size=96, chunk=16,
              candidates=(12, 24, 48))
    on = pipeline.ASDRConfig(early_termination=True, **kw)
    off = pipeline.ASDRConfig(early_termination=False, **kw)
    _, s_on = pipeline.render_asdr_image(fns, on, cam)
    _, s_off = pipeline.render_asdr_image(fns, off, cam)
    assert float(s_on["samples_processed"]) <= float(s_off["samples_processed"])
    # ET must not change the image materially (paper §6.6: lossless)
    img_on, _ = pipeline.render_asdr_image(fns, on, cam)
    img_off, _ = pipeline.render_asdr_image(fns, off, cam)
    assert float(rendering.psnr(img_on, img_off)) > 45.0


def test_block_unsort_roundtrip(setup):
    """render_adaptive must return rays in the original order."""
    field, fns, cam, o, d, full = setup
    R = o.shape[0]
    counts = jnp.full((R,), 24, jnp.int32)
    acfg = pipeline.ASDRConfig(ns_full=96, block_size=96, chunk=8,
                               group=1, early_termination=False)
    rgb, acc, _ = pipeline.render_adaptive(fns, acfg, o, d, counts)
    ref, _ = pipeline.render_fixed_fns(fns, o, d, 24)
    # same per-ray sample count, same order -> close colors per ray
    err = float(jnp.max(jnp.abs(rgb - ref)))
    assert err < 1e-3  # same sampling grid, same order
