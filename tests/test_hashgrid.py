"""Hash-grid encoding: dense/hash split, interpolation, utilization."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hashgrid as hg


CFG = hg.HashGridConfig(n_levels=8, log2_table_size=14, max_resolution=256)


def test_level_split_matches_paper_rule():
    # dense iff (res+1)^3 fits the table — the paper's de-hash criterion
    for l in range(CFG.n_levels):
        res = CFG.level_resolution(l)
        assert CFG.level_is_dense(l) == ((res + 1) ** 3 <= CFG.table_size)
    # low levels dense, high levels hashed for this config
    assert CFG.level_is_dense(0)
    assert not CFG.level_is_dense(CFG.n_levels - 1)


def test_dense_indices_are_unique_and_in_range():
    res = CFG.level_resolution(0)
    coords = jnp.stack(jnp.meshgrid(
        *[jnp.arange(res + 1)] * 3, indexing="ij"), -1).reshape(-1, 3)
    idx = hg.level_indices(coords, res, True, CFG.table_size)
    assert int(idx.max()) < CFG.table_size
    assert len(np.unique(np.asarray(idx))) == (res + 1) ** 3


def test_hash_indices_in_range():
    res = CFG.level_resolution(CFG.n_levels - 1)
    key = jax.random.PRNGKey(0)
    coords = jax.random.randint(key, (500, 3), 0, res + 1)
    idx = hg.level_indices(coords, res, False, CFG.table_size)
    assert int(idx.min()) >= 0 and int(idx.max()) < CFG.table_size


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_trilinear_weights_sum_to_one(seed):
    """Property: encode of a constant table equals that constant
    (trilinear weights form a partition of unity)."""
    key = jax.random.PRNGKey(seed)
    pts = jax.random.uniform(key, (17, 3))
    table = jnp.full((CFG.table_size, 2), 3.25)
    for l in [0, CFG.n_levels - 1]:
        res = CFG.level_resolution(l)
        enc = hg.encode_level(pts, table, res, CFG.level_is_dense(l))
        np.testing.assert_allclose(np.asarray(enc), 3.25, rtol=1e-5)


def test_encode_at_vertex_returns_table_row():
    """At an exact grid vertex the encoding equals that vertex's entry."""
    l = 0
    res = CFG.level_resolution(l)
    key = jax.random.PRNGKey(1)
    table = jax.random.normal(key, (CFG.table_size, 2))
    v = jnp.asarray([[1, 2, 3]], jnp.float32)
    pts = v / res
    enc = hg.encode_level(pts, table, res, True)
    row = hg.level_indices(v.astype(jnp.int32), res, True, CFG.table_size)
    np.testing.assert_allclose(
        np.asarray(enc[0]), np.asarray(table[row[0]]), rtol=1e-4, atol=1e-6
    )


def test_full_encoding_shape_and_grad():
    # 4-level sub-config keeps the grad graph small (still dense + hashed)
    cfg = hg.HashGridConfig(n_levels=4, log2_table_size=12,
                            max_resolution=64)
    key = jax.random.PRNGKey(0)
    tables = hg.init_hashgrid(key, cfg)
    pts = jax.random.uniform(key, (33, 3))
    enc = hg.encode(pts, tables, cfg)
    assert enc.shape == (33, cfg.output_dim)
    g = jax.grad(lambda t: jnp.sum(hg.encode(pts, t, cfg) ** 2))(tables)
    assert not bool(jnp.any(jnp.isnan(g)))


def test_storage_utilization_improves_like_paper():
    """Paper Fig. 13: hybrid (de-hash + replicate) utilization >> naive."""
    cfg = hg.HashGridConfig()  # paper-scale 16 levels, 2^19
    u = hg.storage_utilization(cfg)
    assert u["hybrid_utilization"] > u["naive_utilization"]
    assert u["hybrid_utilization"] > 0.80  # paper reports 85.95%
    # copies only exist for dense (low-res) levels
    for l, c in u["copies_per_level"].items():
        if cfg.level_is_dense(l):
            assert c >= 1
        else:
            assert c == 1
