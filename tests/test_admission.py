"""Radiance-first, double-buffered admission pipeline invariants.

The ISSUE-4 test requirements: a full radiance hit skips Phase I
bit-identically to the always-probe path, rendered frames and counters
are deterministic across prefetch depths 0/1/2, the admission counters
satisfy probes + skips == admissions, and the probe-skip path never ages
probe entries (the staleness-bookkeeping regression) — plus the
zero-march samples split and end-to-end latency coverage.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import fields, pipeline, scene
from repro import framecache
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16
R = SIZE * SIZE


def cam_at(theta, phi=0.5):
    return scene.look_at_camera(SIZE, SIZE, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def flds():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def reuse_config(prefetch=0, probe_refresh=0, radiance_refresh=0):
    return RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=probe_refresh),
        radiance=fc_radiance.RadianceReuseConfig(
            refresh_every=radiance_refresh),
        prefetch=prefetch)


# ------------------------------------------------------- full-hit skip-probe
def test_full_hit_skips_probe_bit_identity(flds):
    """A replayed pose is a full radiance hit: it must skip Phase I
    entirely (zero probe samples) yet deliver the always-probe engine's
    frame bit-exactly."""
    eng = RenderServingEngine(flds, ACFG, dataclasses.replace(
        reuse_config(), slots=1))
    done = {r.rid: r for r in eng.render(
        [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
         for i in range(3)])}
    always = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None, radiance=None))
    ref = always.render([RenderRequest(rid=9, scene="mic",
                                       cam=cam_at(0.7))])[0]
    assert not done[0].stats["probe_skipped"]
    for rid in (1, 2):
        st = done[rid].stats
        assert st["probe_skipped"] and not st["probe_reused"]
        assert st["probe_samples"] == 0 and st["rays_marched"] == 0
        np.testing.assert_array_equal(done[rid].image, ref.image)
    st = eng.engine_stats()
    assert st["probe_skips"] == 2 and st["full_radiance_hits"] == 2
    cache = eng.probe_caches["mic"]
    assert cache.skips == 2 and cache.hits == 0 and cache.misses == 1


def test_counter_invariant_probes_plus_skips_equal_admissions(flds):
    """Every admission either probed (miss/refresh), reused maps (hit),
    or skipped Phase I behind a full warp hit — the three ledgers must
    sum to admissions exactly, at any prefetch depth."""
    for prefetch in (0, 2):
        eng = RenderServingEngine(flds, ACFG, reuse_config(prefetch))
        reqs = [RenderRequest(rid=i, scene="mic",
                              cam=cam_at(0.7 + 0.05 * (i % 3)))
                for i in range(7)]
        eng.render(reqs)
        st = eng.engine_stats()
        assert (st["probe_hits"] + st["probe_misses"] + st["probe_skips"]
                == st["admissions"] == len(reqs))
        cache = eng.probe_caches["mic"]
        assert cache.skips == st["probe_skips"]
        assert (cache.no_probe_fraction
                == pytest.approx(st["reused_probe_fraction"]))


# ------------------------------------------------------------- determinism
def test_determinism_across_prefetch_depths(flds):
    """Prefetch only moves Stage-A device work earlier: frames AND all
    admission counters must be bit-identical at depths 0/1/2 — including
    requests whose radiance source finishes between their speculation
    and their admission (the revalidation path)."""
    # poses repeat after 3 requests with slots=2, so laps 2+ requests are
    # speculated while their lap-1 sources are still marching
    def traj():
        return [RenderRequest(rid=i, scene="mic",
                              cam=cam_at(0.7 + 0.05 * (i % 3)))
                for i in range(9)]

    runs = {}
    for prefetch in (0, 1, 2):
        eng = RenderServingEngine(flds, ACFG, reuse_config(prefetch))
        done = {r.rid: r for r in eng.render(traj())}
        runs[prefetch] = (done, eng.engine_stats())
    done0, st0 = runs[0]
    for prefetch in (1, 2):
        done_p, st_p = runs[prefetch]
        for rid in done0:
            np.testing.assert_array_equal(done0[rid].image, done_p[rid].image)
            assert (done0[rid].stats["probe_skipped"]
                    == done_p[rid].stats["probe_skipped"])
            assert (done0[rid].stats["rays_marched"]
                    == done_p[rid].stats["rays_marched"])
        for key in ("admissions", "probe_hits", "probe_misses", "probe_skips",
                    "full_radiance_hits", "rays_marched", "samples_processed",
                    "samples_reused", "probe_refreshes"):
            assert st0[key] == st_p[key], (key, st0[key], st_p[key])
    # the synchronous run never speculates, so it can never misprepare
    assert st0["misprepares"] == 0


def test_prefetch_speculation_is_used_on_fresh_trajectories(flds):
    """On a trajectory of distinct fresh poses the speculated probes must
    survive revalidation (fresh plans share the ("probe",) basis), not be
    recomputed at admission."""
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(max_angle_deg=0.01,
                                        max_translation=1e-4),
        radiance=None, prefetch=2))
    reqs = [RenderRequest(rid=i, scene="mic", cam=cam_at(0.6 + 0.1 * i))
            for i in range(6)]
    done = eng.render(reqs)
    assert len(done) == 6
    assert eng.engine_stats()["misprepares"] == 0


def test_no_probe_cache_does_not_fake_reuse_fraction(flds):
    """With probe reuse DISABLED but radiance on, every miss frame pays a
    full fresh probe — reused_probe_fraction must read 0.0 (the probe
    ledger is the caches' own, and there is no cache), not 1.0 off
    engine-side skip counts; full_radiance_hits still records the skips."""
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=1, blocks_per_batch=4, reuse=None,
        radiance=fc_radiance.RadianceReuseConfig(refresh_every=0)))
    done = {r.rid: r for r in eng.render(
        [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
         for i in range(3)])}
    st = eng.engine_stats()
    assert st["probe_hits"] == st["probe_misses"] == st["probe_skips"] == 0
    assert st["reused_probe_fraction"] == 0.0
    assert st["full_radiance_hits"] == 2
    assert done[1].stats["probe_skipped"] and done[2].stats["probe_skipped"]


# ------------------------------------------------- skip-aware staleness
def test_probe_skips_do_not_age_entries_or_force_refreshes(flds):
    """Regression: full-radiance-hit frames used to count as probe-cache
    hits, aging the entry and periodically paying a FULL refresh probe
    for maps nobody reads.  Skips must leave refreshes and entry age
    untouched."""
    eng = RenderServingEngine(flds, ACFG, dataclasses.replace(
        reuse_config(probe_refresh=2), slots=1))
    eng.render([RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
                for i in range(6)])
    cache = eng.probe_caches["mic"]
    # rid 0: fresh probe; rids 1-5: full radiance hits -> skips only
    assert cache.misses == 1 and cache.skips == 5 and cache.hits == 0
    assert cache.refreshes == 0, "skip path paid a refresh probe"
    assert cache._entries[0].reuses_since_probe == 0, \
        "skip path aged the probe entry"


def test_staleness_still_enforced_on_consumed_reuses(flds):
    """Skips must not weaken the real bound: once maps ARE consumed
    (partial hits), refresh_every still forces a re-probe on schedule."""
    fns = flds["mic"]
    cache = fc_probe.ProbeCache(fc_probe.ProbeReuseConfig(refresh_every=2))
    fc_probe.cached_probe_maps(fns, ACFG, cam_at(0.7), cache)   # miss
    cache.note_skip()                                           # full hit
    cache.note_skip()
    assert cache._entries[0].reuses_since_probe == 0
    for _ in range(2):                                          # consumed
        _, reused = fc_probe.cached_probe_maps(fns, ACFG, cam_at(0.7), cache)
        assert reused
    _, reused = fc_probe.cached_probe_maps(fns, ACFG, cam_at(0.7), cache)
    assert not reused and cache.refreshes == 1                  # k-th reuse


def test_single_image_path_skips_probe_on_full_hit(flds):
    """framecache.render_asdr_image_cached gets the same radiance-first
    ordering as the engine."""
    fns = flds["mic"]
    fc = framecache.make_frame_cache(
        probe_cfg=fc_probe.ProbeReuseConfig(refresh_every=2),
        radiance_cfg=fc_radiance.RadianceReuseConfig(refresh_every=0))
    img1, st1 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    img2, st2 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert not st1["probe_skipped"] and st2["probe_skipped"]
    assert st2["probe_samples"] == 0 and st2["rays_marched"] == 0
    assert st2["samples_reused"] == R * ACFG.ns_full
    np.testing.assert_array_equal(img1, img2)
    assert fc.probe.skips == 1 and fc.probe.hits == 0
    assert fc.probe._entries[0].reuses_since_probe == 0


# ----------------------------------------------------- stats and latency
def test_zero_march_frames_report_samples_reused(flds):
    """Satellite: a full-radiance-hit frame spends nothing and reuses
    everything — samples_processed 0, samples_reused at the baseline
    rate — and engine_stats aggregates the split."""
    eng = RenderServingEngine(flds, ACFG, dataclasses.replace(
        reuse_config(), slots=1))
    done = {r.rid: r for r in eng.render(
        [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
         for i in range(2)])}
    st0, st1 = done[0].stats, done[1].stats
    assert st0["samples_reused"] == 0 and st0["samples_processed"] > 0
    assert st1["samples_processed"] == 0
    assert st1["samples_reused"] == R * ACFG.ns_full
    agg = eng.engine_stats()
    assert agg["samples_processed"] == st0["samples_processed"]
    assert agg["samples_reused"] == st1["samples_reused"]


def test_latency_covers_queue_wait_and_admission(flds):
    """latency_s must run from render() entry (queue wait included): with
    one slot, the second request's latency strictly contains the first
    request's march."""
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=1, blocks_per_batch=4, reuse=None, radiance=None))
    # warm the march cache so latency is march time, not compile time
    eng.render([RenderRequest(rid=9, scene="mic", cam=cam_at(0.9))])
    done = {r.rid: r for r in eng.render(
        [RenderRequest(rid=0, scene="mic", cam=cam_at(0.7)),
         RenderRequest(rid=1, scene="mic", cam=cam_at(0.8))])}
    assert done[1].latency_s > done[0].latency_s
    for r in done.values():
        assert r.latency_s >= r.stats["admission_s"] >= 0.0
        assert r.stats["admission_s"] >= r.stats["admit_stall_s"] >= 0.0
