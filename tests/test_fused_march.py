"""Fused single-kernel Phase-II march vs the chunked reference march.

The oracle is ``ref.ref_fused_march`` — core.pipeline's chunked
while_loop march over the PURE-JNP model FieldFns — so every assertion
here pins the fused kernel (kernels/fused_march.py) against numerics
that never touch Pallas.  ``chunks_done`` is asserted EXACTLY equal:
the early-termination contract is part of the backend seam, not a
tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fields, pipeline, scene
from repro.core.model import NGPConfig, init_ngp
from repro.core.model import field_fns as jnp_field_fns
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def model():
    cfg = NGPConfig.small()
    params = init_ngp(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def both_fns(model):
    """(kernel-backed FieldFns with fused resources, pure-jnp FieldFns)."""
    cfg, params = model
    return ops.field_fns(params, cfg), jnp_field_fns(params, cfg)


def _blocked_rays(n_blocks, block_size, theta=0.6, phi=0.4):
    cam = scene.look_at_camera(n_blocks * block_size // 8, 8,
                               theta=theta, phi=phi)
    o, d = scene.camera_rays(cam)
    return (o.reshape(n_blocks, block_size, 3),
            d.reshape(n_blocks, block_size, 3))


def _acfg(**kw):
    base = dict(block_size=32, chunk=16, group=2, march_backend="fused")
    base.update(kw)
    return pipeline.ASDRConfig(**base)


def _assert_march_equal(got, want, atol=1e-5):
    """(rgb, acc, depth, chunks, ray_chunks) parity; the two chunk
    counters are exactly equal — early termination (block- and per-ray
    granular) is part of the backend contract, not a tolerance."""
    for g, w, name in [(got[0], want[0], "rgb"), (got[1], want[1], "acc"),
                       (got[2], want[2], "depth")]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=atol, err_msg=name)
    assert np.array_equal(np.asarray(got[3]), np.asarray(want[3])), (
        f"chunks_done mismatch: {got[3]} vs {want[3]}")
    assert np.array_equal(np.asarray(got[4]), np.asarray(want[4])), (
        "per-ray chunks mismatch")


# ----------------------------------------------------------------- parity
def test_fused_march_matches_reference(both_fns):
    """Budgets cover budget < chunk (16), multi-chunk (48), and a budget
    not divisible by chunk (33 -> 3 chunks, last partially masked)."""
    fns_k, fns_j = both_fns
    acfg = _acfg()
    o_b, d_b = _blocked_rays(3, acfg.block_size)
    budgets = jnp.asarray([16, 48, 33], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)
    # budget 16 == one chunk; 48 -> 3; 33 -> ceil(33/16) = 3
    assert np.asarray(got[3]).tolist() == [1, 3, 3]


def test_fused_march_budget_below_chunk(both_fns):
    fns_k, fns_j = both_fns
    acfg = _acfg()
    o_b, d_b = _blocked_rays(1, acfg.block_size)
    budgets = jnp.asarray([7], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)
    assert int(got[3][0]) == 1


def test_fused_march_group_not_dividing_chunk(both_fns):
    """group=3 with chunk=16: the last anchor covers a short tail and the
    lerp right-neighbour clamps — decouple.interpolate_group_colors
    semantics must hold inside the kernel."""
    fns_k, fns_j = both_fns
    acfg = _acfg(group=3)
    o_b, d_b = _blocked_rays(2, acfg.block_size)
    budgets = jnp.asarray([32, 21], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)


def test_fused_march_early_termination_saturated_block(model):
    """A block whose rays ALL saturate early must stop the while_loop at
    the same chunk on both backends (chunks_done < ceil(budget/chunk))."""
    cfg, params = model
    # saturate the field: non-negative features + amplified non-negative
    # density weights drive sigma to trunc_exp's clip everywhere inside
    # the cube, so transmittance collapses within the first occupied chunk
    hot = dict(params)
    hot["grid"] = jnp.abs(params["grid"]) + 0.5
    hot["mlps"] = dict(params["mlps"])
    hot["mlps"]["density"] = [jnp.abs(w) * 4.0
                              for w in params["mlps"]["density"]]
    fns_k = ops.field_fns(hot, cfg)
    fns_j = jnp_field_fns(hot, cfg)
    acfg = _acfg(block_size=8)
    # rays enter the cube at t = 0.3 (sample index ~9 of 192): saturation
    # is guaranteed inside chunk 0, termination by the next chunk check
    o = jnp.tile(jnp.asarray([0.45, 0.45, -0.3]), (8, 1))
    o = o + jnp.linspace(0.0, 0.1, 8)[:, None] * jnp.asarray([1.0, 1.0, 0.0])
    d = jnp.tile(jnp.asarray([0.0, 0.0, 1.0]), (8, 1))
    o_b, d_b = o[None], d[None]
    budgets = jnp.asarray([192], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)
    assert int(got[3][0]) < 192 // acfg.chunk, "early termination never fired"
    np.testing.assert_allclose(np.asarray(got[1]), 1.0, atol=1e-4)


def test_fused_march_early_termination_off(both_fns):
    """With early_termination=False the loop must run every chunk."""
    fns_k, fns_j = both_fns
    acfg = _acfg(early_termination=False)
    o_b, d_b = _blocked_rays(1, acfg.block_size)
    budgets = jnp.asarray([48], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)
    assert int(got[3][0]) == 3


def test_fused_march_pad_blocks(both_fns):
    """Serve-layer pad blocks: budget=1, straight-up rays that never enter
    the cube — the fused kernel must keep the same background output."""
    fns_k, fns_j = both_fns
    acfg = _acfg(block_size=8)
    o = jnp.zeros((1, 8, 3), jnp.float32)
    d = jnp.tile(jnp.asarray([0.0, 0.0, -1.0]), (1, 8, 1))
    budgets = jnp.asarray([1], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o, d, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o, d, budgets)
    _assert_march_equal(got, want)
    np.testing.assert_allclose(np.asarray(got[1]), 0.0, atol=1e-6)  # acc
    np.testing.assert_allclose(np.asarray(got[0]), 1.0, atol=1e-6)  # white


def test_fused_march_density_only(both_fns):
    """Density-only marches (serve's warp refresh path) skip the color
    chain entirely; acc/depth/chunks must still match the reference."""
    fns_k, fns_j = both_fns
    acfg = _acfg()
    o_b, d_b = _blocked_rays(2, acfg.block_size)
    budgets = jnp.asarray([48, 33], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets,
                                density_only=True)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets,
                               density_only=True)
    for g, w, name in [(got[1], want[1], "acc"), (got[2], want[2], "depth")]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    assert np.array_equal(np.asarray(got[3]), np.asarray(want[3]))
    # and density-only vs full march agree on acc/depth too
    full = pipeline.march_blocks(both_fns[0], acfg, o_b, d_b, budgets)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(full[1]),
                               rtol=1e-4, atol=1e-5)


def test_fused_backend_falls_back_without_resources(both_fns):
    """march_backend='fused' on a FieldFns with no fused resources (e.g.
    analytic fields) must take the reference path bit-identically."""
    field = scene.make_scene("mic")
    fns = fields.analytic_field_fns(field)
    assert fns.fused is None
    o_b, d_b = _blocked_rays(2, 32)
    budgets = jnp.asarray([48, 16], jnp.int32)
    got = pipeline.march_blocks(fns, _acfg(), o_b, d_b, budgets)
    want = pipeline.march_blocks(fns, _acfg(march_backend="reference"),
                                 o_b, d_b, budgets)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------- table streaming (production path)
@pytest.fixture(scope="module")
def full_shape_model():
    """Scaled-down FULL-config shapes: the full config's 16 levels (same
    dense/hash level mix, same streaming loop trip count) at a table size
    interpret mode can march on CPU."""
    cfg = NGPConfig.make(n_levels=16, log2_table_size=10, max_resolution=512)
    params = init_ngp(jax.random.PRNGKey(3), cfg)
    return cfg, params


def test_streamed_parity_over_vmem_budget(full_shape_model, monkeypatch):
    """The tentpole contract: a 16-level stack whose RESIDENT footprint
    exceeds the (simulated) VMEM budget must auto-select the streamed
    path and keep full reference parity — rgb/acc/depth allclose, chunks
    AND per-ray chunks exactly equal."""
    cfg, params = full_shape_model
    fns_k = ops.field_fns(params, cfg)
    fns_j = jnp_field_fns(params, cfg)
    acfg = _acfg()
    assert acfg.march_table_streaming == "auto"
    resident = ops.fused_march_vmem_bytes(acfg, fns_k.fused, streamed=False)
    streamed = ops.fused_march_vmem_bytes(acfg, fns_k.fused, streamed=True)
    assert streamed < resident
    monkeypatch.setattr(ops, "FUSED_MARCH_VMEM_LIMIT",
                        (resident + streamed) // 2)
    assert ops._select_streaming(acfg, fns_k.fused) is True
    o_b, d_b = _blocked_rays(2, acfg.block_size)
    budgets = jnp.asarray([48, 33], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)


def test_streamed_resident_bit_identity(both_fns):
    """Where both table supplies run, every output array is BYTE-equal:
    residency is a supply strategy, never a numerics change."""
    fns_k, _ = both_fns
    o_b, d_b = _blocked_rays(3, 32)
    budgets = jnp.asarray([16, 48, 33], jnp.int32)
    got_r = pipeline.march_blocks(
        fns_k, _acfg(march_table_streaming="resident"), o_b, d_b, budgets)
    got_s = pipeline.march_blocks(
        fns_k, _acfg(march_table_streaming="streamed"), o_b, d_b, budgets)
    for i, (r, s) in enumerate(zip(got_r, got_s)):
        assert np.array_equal(np.asarray(r), np.asarray(s)), (
            f"streamed != resident at tuple element {i}")


def test_streamed_odd_level_count():
    """L=5: the double-buffer ping/pong wraps on an ODD level count (the
    last level's slot collides with level 0's next-chunk slot only if the
    two-apart reuse invariant breaks)."""
    cfg = NGPConfig.make(n_levels=5, log2_table_size=10, max_resolution=256)
    params = init_ngp(jax.random.PRNGKey(4), cfg)
    fns_k = ops.field_fns(params, cfg)
    fns_j = jnp_field_fns(params, cfg)
    acfg = _acfg(march_table_streaming="streamed")
    o_b, d_b = _blocked_rays(2, acfg.block_size)
    budgets = jnp.asarray([48, 21], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets)
    _assert_march_equal(got, want)
    got_r = pipeline.march_blocks(
        fns_k, _acfg(march_table_streaming="resident"), o_b, d_b, budgets)
    for r, s in zip(got_r, got):
        assert np.array_equal(np.asarray(r), np.asarray(s))


def test_streamed_density_only(full_shape_model, monkeypatch):
    """The serve layer's density-only refresh marches must stream too:
    acc/depth/chunks parity with the color chain skipped."""
    cfg, params = full_shape_model
    fns_k = ops.field_fns(params, cfg)
    fns_j = jnp_field_fns(params, cfg)
    acfg = _acfg()
    monkeypatch.setattr(ops, "FUSED_MARCH_VMEM_LIMIT", 1)  # force streamed
    assert ops._select_streaming(acfg, fns_k.fused) is True
    o_b, d_b = _blocked_rays(2, acfg.block_size)
    budgets = jnp.asarray([48, 33], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o_b, d_b, budgets,
                                density_only=True)
    want = ref.ref_fused_march(fns_j, acfg, o_b, d_b, budgets,
                               density_only=True)
    for g, w, name in [(got[1], want[1], "acc"), (got[2], want[2], "depth")]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    assert np.array_equal(np.asarray(got[3]), np.asarray(want[3]))
    assert np.array_equal(np.asarray(got[4]), np.asarray(want[4]))


def test_auto_select_matrix(both_fns, monkeypatch):
    """Small config under the real 16 MB budget stays resident; an
    explicit resident pin on an over-budget config REFUSES instead of
    silently overflowing VMEM."""
    fns_k, _ = both_fns
    acfg = _acfg()
    assert ops._select_streaming(acfg, fns_k.fused) is False
    monkeypatch.setattr(ops, "FUSED_MARCH_VMEM_LIMIT", 1)
    assert ops._select_streaming(acfg, fns_k.fused) is True
    with pytest.raises(ValueError, match="resident fused march"):
        ops._select_streaming(_acfg(march_table_streaming="resident"),
                              fns_k.fused)
    with pytest.raises(ValueError, match="march_table_streaming"):
        ops._select_streaming(_acfg(march_table_streaming="bogus"),
                              fns_k.fused)


# ------------------------------------------------------- per-ray early exit
def _saturating_mixed_block(model):
    """Hot-field params + a block mixing rays through the dense cube
    (saturate within a few chunks) with near-graze rays that keep the
    BLOCK alive to its full budget — per-ray exit has work to skip."""
    cfg, params = model
    hot = dict(params)
    hot["grid"] = jnp.abs(params["grid"]) + 0.5
    hot["mlps"] = dict(params["mlps"])
    hot["mlps"]["density"] = [jnp.abs(w) * 4.0
                              for w in params["mlps"]["density"]]
    o_hit = jnp.tile(jnp.asarray([0.45, 0.45, -0.3]), (4, 1))
    o_hit = o_hit + jnp.linspace(0.0, 0.1, 4)[:, None] * jnp.asarray(
        [1.0, 1.0, 0.0])
    o_miss = jnp.tile(jnp.asarray([0.5, 0.5, -2.0]), (4, 1))  # cube far away
    o = jnp.concatenate([o_hit, o_miss])[None]
    d = jnp.tile(jnp.asarray([0.0, 0.0, 1.0]), (1, 8, 1))
    return hot, cfg, o, d


def test_per_ray_early_exit_parity(model):
    """Flag ON vs OFF on a mixed saturated/background block: chunk
    counters stay EXACTLY equal (a dead ray's transmittance is already
    frozen below the exit threshold, so masking its sigma cannot move
    the block's exit chunk), outputs stay within the early-termination
    tail, and the saturated rays demonstrably exited before the block."""
    hot, cfg, o, d = _saturating_mixed_block(model)
    fns_k = ops.field_fns(hot, cfg)
    budgets = jnp.asarray([192], jnp.int32)
    acfg = _acfg(block_size=8)
    off = pipeline.march_blocks(fns_k, acfg, o, d, budgets)
    on = pipeline.march_blocks(
        fns_k, _acfg(block_size=8, per_ray_early_exit=True),
        o, d, budgets)
    assert np.array_equal(np.asarray(off[3]), np.asarray(on[3]))
    assert np.array_equal(np.asarray(off[4]), np.asarray(on[4]))
    # saturated rays stopped counting chunks before the block did
    rc = np.asarray(on[4])[0]
    block_chunks = int(np.asarray(on[3])[0])
    assert (rc[:4] < block_chunks).all(), "no per-ray exit headroom"
    assert (rc[4:] == block_chunks).all(), "background rays must ride out"
    # the skipped tail perturbs outputs by at most the termination eps
    for a, b, name in [(off[0], on[0], "rgb"), (off[1], on[1], "acc"),
                       (off[2], on[2], "depth")]:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, err_msg=name)


def test_per_ray_early_exit_reference_parity(model):
    """Flag ON: fused (streamed) and chunked reference still agree —
    the masking semantics live in BOTH backends."""
    hot, cfg, o, d = _saturating_mixed_block(model)
    fns_k = ops.field_fns(hot, cfg)
    fns_j = jnp_field_fns(hot, cfg)
    acfg = _acfg(block_size=8, per_ray_early_exit=True,
                 march_table_streaming="streamed")
    budgets = jnp.asarray([192], jnp.int32)
    got = pipeline.march_blocks(fns_k, acfg, o, d, budgets)
    want = ref.ref_fused_march(fns_j, acfg, o, d, budgets)
    _assert_march_equal(got, want, atol=1e-4)


# --------------------------------------------------- weight-pack memoization
def test_weight_packing_memoized(model):
    cfg, params = model
    ops.packed_weights(params["mlps"], cfg.net)   # warm (may hit or miss)
    s1 = ops.pack_cache_stats()
    wd1, wc1 = ops.packed_weights(params["mlps"], cfg.net)
    s2 = ops.pack_cache_stats()
    assert s2["hits"] == s1["hits"] + 1 and s2["misses"] == s1["misses"]
    # same objects back (memoized, not re-traced)
    wd2, wc2 = ops.packed_weights(params["mlps"], cfg.net)
    assert wd2 is wd1 and wc2 is wc1
    # distinct params are a distinct entry
    other = init_ngp(jax.random.PRNGKey(1), cfg)
    ops.packed_weights(other["mlps"], cfg.net)
    s3 = ops.pack_cache_stats()
    assert s3["misses"] == s2["misses"] + 1
    assert s3["size"] >= 2


def test_field_fns_share_packed_weights(model):
    """Constructing FieldFns twice for the same params must not re-pack."""
    cfg, params = model
    ops.field_fns(params, cfg)
    s1 = ops.pack_cache_stats()
    ops.field_fns(params, cfg)
    s2 = ops.pack_cache_stats()
    assert s2["misses"] == s1["misses"]
