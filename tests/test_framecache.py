"""Cross-frame reuse subsystem invariants (repro.framecache).

Covers the three ISSUE-2 test requirements: warped count maps stay
conservative (exact at zero pose delta), the disocclusion mask is correct
under translation, and the serving engine remains bit-identical to the
single-image pipeline with radiance reuse disabled — plus the framecache
safety invariants (no warp chaining, low-valid miss, refresh bounds).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import adaptive, fields, pipeline, scene
from repro import framecache
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.framecache import warp as fc_warp
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16


def cam_at(theta, phi=0.5, size=SIZE):
    return scene.look_at_camera(size, size, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def setup():
    fns = fields.analytic_field_fns(scene.make_scene("mic"))
    maps, _ = fc_probe.cached_probe_maps(fns, ACFG, cam_at(0.7), None)
    return fns, maps


# ------------------------------------------------------------------ warp
def test_forward_warp_self_is_identity(setup):
    """Projecting a frame's own lifted points back into it must hit every
    pixel exactly — the zero-delta shortcut and replay gates rely on it."""
    _, maps = setup
    cam = cam_at(0.7)
    tgt, ok, dist = fc_warp.forward_warp(cam, cam, maps.depth)
    np.testing.assert_array_equal(np.asarray(tgt), np.arange(SIZE * SIZE))
    assert np.asarray(ok).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(maps.depth),
                               rtol=1e-5)


def test_warp_image_self_is_identity(setup):
    fns, maps = setup
    cam = cam_at(0.7)
    rgb = jnp.asarray(np.random.default_rng(0).uniform(
        size=(SIZE * SIZE, 3)).astype(np.float32))
    acc = jnp.asarray(np.random.default_rng(1).uniform(
        size=(SIZE * SIZE,)).astype(np.float32))
    rgb_w, acc_w, depth_w, valid = fc_warp.warp_image(
        rgb, acc, maps.depth, cam, cam)
    assert np.asarray(valid).all()
    np.testing.assert_array_equal(np.asarray(rgb_w), np.asarray(rgb))
    np.testing.assert_array_equal(np.asarray(acc_w), np.asarray(acc))


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([0.0, 0.01, 0.02, 0.04]))
def test_warped_counts_conservative(setup, jitter):
    """Property: a warped count map never under-samples — on valid pixels
    the reused count >= the fresh-probe count at the new pose (within the
    one-pixel warp margin); invalid pixels carry ns_full.  At zero pose
    delta this is exact equality."""
    fns, maps = setup
    cam = cam_at(0.7)
    cam_b = cam_at(0.7 + jitter)
    warped, valid = fc_warp.warp_count_map(
        maps.counts, maps.depth, cam, cam_b, ACFG.ns_full, margin=1)
    fresh, _ = fc_probe.cached_probe_maps(fns, ACFG, cam_b, None)
    w, f = np.asarray(warped), np.asarray(fresh.counts)
    v = np.asarray(valid)
    assert (w[~v] == ACFG.ns_full).all()
    cons = (w >= f)[v].mean() if v.any() else 1.0
    assert cons >= 0.98, f"warped counts under-sample: {cons:.3f} at {jitter}"
    if jitter == 0.0:
        # self-warp is the identity permutation: with the rounding margin
        # off, the warped map IS the fresh map, bit-exactly (the cached
        # path shortcuts the warp entirely in this case — see probe.py)
        exact, v0 = fc_warp.warp_count_map(
            maps.counts, maps.depth, cam, cam_b, ACFG.ns_full, margin=0)
        np.testing.assert_array_equal(np.asarray(exact), f)
        assert np.asarray(v0).all()


def test_disocclusion_mask_on_translation(setup):
    """A translated pose reveals content the source never saw: the warp
    must flag it invalid, and the invalid band must sit on the side the
    new content enters from."""
    _, maps = setup
    cam = cam_at(0.7)
    # slide the eye along the camera's right axis; keep the rotation
    right = np.asarray(cam.c2w_rot)[:, 0]
    cam_t = scene.Camera(cam.height, cam.width, cam.focal, cam.c2w_rot,
                         np.asarray(cam.origin) + 0.12 * right)
    tgt, ok, dist = fc_warp.forward_warp(cam, cam_t, maps.depth)
    _src, valid = fc_warp.nearest_source(tgt, ok, dist, SIZE * SIZE)
    v = np.asarray(valid).reshape(SIZE, SIZE)
    assert 0.3 < v.mean() < 1.0
    # content shifts left in the image when the eye moves right: the
    # revealed (invalid) band is on the right edge
    assert v[:, : SIZE // 4].mean() > v[:, -SIZE // 4:].mean()


def test_warp_zbuffer_prefers_near_surface():
    """Two source pixels landing on one target pixel: the nearer wins."""
    cam = cam_at(0.7)
    n = cam.height * cam.width
    tgt = jnp.zeros((4,), jnp.int32)          # all collide on pixel 0
    ok = jnp.asarray([True, True, True, False])
    dist = jnp.asarray([2.0, 0.5, 1.0, 0.1])  # entry 3 is invalid
    src, valid = fc_warp.nearest_source(tgt, ok, dist, n)
    assert bool(valid[0]) and int(src[0]) == 1
    assert not np.asarray(valid[1:]).any()


# ----------------------------------------------------------------- probe
def test_probe_cache_warp_mode_sustains_beyond_dilate_cap(setup):
    """A pose delta whose conservative dilation radius overflows the cap
    (a PR-1 miss) must still be a HIT in warp mode."""
    fns, _ = setup
    rcfg = dict(max_angle_deg=6.0, max_translation=0.12, refresh_every=0)
    cam, cam_far = cam_at(0.7), cam_at(0.79)
    ang, tr = adaptive.pose_distance(cam, cam_far)
    radius = adaptive.reuse_dilation_radius(cam, ang, tr, scene.NEAR,
                                            margin=1.5)
    assert radius > 8, "test needs a delta past the dilation cap"

    warp_cache = fc_probe.ProbeCache(
        fc_probe.ProbeReuseConfig(warp=True, **rcfg))
    dil_cache = fc_probe.ProbeCache(
        fc_probe.ProbeReuseConfig(warp=False, dilate_cap=8, **rcfg))
    for cache in (warp_cache, dil_cache):
        fc_probe.cached_probe_maps(fns, ACFG, cam, cache)
    _, reused_w = fc_probe.cached_probe_maps(fns, ACFG, cam_far, warp_cache)
    _, reused_d = fc_probe.cached_probe_maps(fns, ACFG, cam_far, dil_cache)
    assert reused_w and not reused_d


def test_dilation_mode_reuse_frames_cache_under_march_depth(setup):
    """warp=False reuse at a nonzero delta transfers depth unwarped-able —
    ProbeMaps.depth must be None — but the frame is still radiance-
    cacheable: the store keeps the MARCH's own termination depth, which is
    pose-aligned by construction (the probe proxy it replaced was not)."""
    fns, _ = setup
    fc = framecache.FrameCache(
        probe=fc_probe.ProbeCache(fc_probe.ProbeReuseConfig(
            warp=False, dilate_cap=64, refresh_every=0)),
        radiance=fc_radiance.RadianceCache(
            fc_radiance.RadianceReuseConfig(refresh_every=0)))
    framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert len(fc.radiance) == 1
    # 0.75 sits OUTSIDE the radiance radius (2 deg / 0.04) but INSIDE the
    # probe radius (4 deg / 0.08): probe dilation-reuses, radiance misses
    maps, reused = fc_probe.cached_probe_maps(fns, ACFG, cam_at(0.75),
                                              fc.probe)
    assert reused and maps.depth is None
    _, st = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.75), fc)
    assert st["probe_reused"] and not st["radiance_reused"]
    # fully-marched frame stored despite maps.depth=None, with a sane
    # per-ray depth; replaying the pose now reuses it bit-exactly
    assert len(fc.radiance) == 2
    d = np.asarray(fc.radiance._entries[-1].depth)
    assert (d >= scene.NEAR).all() and (d <= scene.FAR + 1e-4).all()
    _, st2 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.75), fc)
    assert st2["radiance_reused"] and st2["rays_marched"] == 0


def test_probe_maps_include_depth(setup):
    fns, maps = setup
    d = np.asarray(maps.depth)
    assert d.shape == (SIZE * SIZE,)
    assert (d >= scene.NEAR).all() and (d <= scene.FAR + 1e-5).all()


def test_march_termination_depth_sharper_than_probe_proxy(setup):
    """The Phase-II march's per-ray termination depth (ROADMAP item) must
    be in-range, pin background rays to FAR, and register depth edges
    better than the probe's stride-d interpolated proxy — the reason the
    radiance store switched to it."""
    fns, maps = setup
    cam = cam_at(0.7)
    o, d = scene.camera_rays(cam)
    counts = jnp.full((SIZE * SIZE,), ACFG.ns_full, jnp.int32)
    _, acc, stats = pipeline.render_adaptive(fns, ACFG, o, d, counts)
    march_d = np.asarray(stats["term_depth"])
    acc = np.asarray(acc)
    assert march_d.shape == (SIZE * SIZE,)
    assert (march_d >= scene.NEAR - 1e-5).all()
    assert (march_d <= scene.FAR + 1e-4).all()
    bg = acc < 1e-3
    assert bg.any() and np.allclose(march_d[bg], scene.FAR, atol=2e-3)
    # reference: densely-sampled expected termination depth per ray
    from repro.core import rendering
    pts, deltas, ts = scene.sample_points(o, d, 256)
    fld = scene.make_scene("mic")
    sigma, _ = fld(pts.reshape(-1, 3))
    inside = np.all((np.asarray(pts.reshape(-1, 3)) >= 0.0)
                    & (np.asarray(pts.reshape(-1, 3)) <= 1.0), axis=-1)
    sigma = jnp.where(jnp.asarray(inside), sigma, 0.0).reshape(
        SIZE * SIZE, 256)
    _, ref_acc, w = rendering.composite(
        sigma, jnp.zeros(sigma.shape + (3,)), deltas)
    ref_d = np.asarray(rendering.expected_termination_depth(
        w, ts, ref_acc, scene.FAR))
    err_march = np.abs(march_d - ref_d).mean()
    err_probe = np.abs(np.asarray(maps.depth) - ref_d).mean()
    assert err_march <= err_probe + 1e-3, (err_march, err_probe)


# -------------------------------------------------------------- radiance
def test_radiance_zero_delta_identity(setup):
    """Replaying a pose returns the cached frame bit-exactly, marching
    zero rays."""
    fns, _ = setup
    fc = framecache.make_frame_cache(
        radiance_cfg=fc_radiance.RadianceReuseConfig(refresh_every=0))
    img1, st1 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    img2, st2 = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert not st1["radiance_reused"] and st2["radiance_reused"]
    assert st2["rays_marched"] == 0 and st1["rays_marched"] == SIZE * SIZE
    np.testing.assert_array_equal(img1, img2)
    # and it matches the plain pipeline exactly
    ref, _ = pipeline.render_asdr_image(fns, ACFG, cam_at(0.7))
    np.testing.assert_array_equal(img1, np.asarray(ref))


def test_radiance_low_valid_fraction_is_miss(setup):
    """A warp that would leave most of the frame disoccluded must fall
    back to a full render, not serve a mostly-hole frame."""
    fns, _ = setup
    cache = fc_radiance.RadianceCache(fc_radiance.RadianceReuseConfig(
        max_angle_deg=90.0, max_translation=10.0, min_valid_fraction=0.95))
    cam = cam_at(0.7)
    img, stats = framecache.render_asdr_image_cached(
        fns, ACFG, cam, framecache.FrameCache(radiance=cache))
    # a big sideways translation reveals a wide band -> valid < 0.95
    right = np.asarray(cam.c2w_rot)[:, 0]
    cam_t = scene.Camera(cam.height, cam.width, cam.focal, cam.c2w_rot,
                         np.asarray(cam.origin) + 0.3 * right)
    assert cache.lookup(cam_t, ACFG) is None
    assert cache.low_valid_misses == 1


def test_radiance_warped_frames_are_not_recached(setup):
    """Safety invariant: only fully-rendered frames enter the cache, so
    warps never chain."""
    fns, _ = setup
    fc = framecache.make_frame_cache(
        radiance_cfg=fc_radiance.RadianceReuseConfig(refresh_every=0))
    framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert len(fc.radiance) == 1
    _, st = framecache.render_asdr_image_cached(fns, ACFG, cam_at(0.7), fc)
    assert st["radiance_reused"] and len(fc.radiance) == 1
    entry = fc.radiance._entries[0]
    assert entry.reuses_since_render == 1


def test_radiance_refresh_every_forces_full_render(setup):
    fns, _ = setup
    fc = framecache.make_frame_cache(
        radiance_cfg=fc_radiance.RadianceReuseConfig(refresh_every=2))
    cam = cam_at(0.7)
    stats = [framecache.render_asdr_image_cached(fns, ACFG, cam, fc)[1]
             for _ in range(4)]
    assert [s["radiance_reused"] for s in stats] == [False, True, True, False]
    assert fc.radiance.refreshes == 1


# ---------------------------------------------------------------- engine
def test_engine_matches_pipeline_with_radiance_disabled(setup):
    """ISSUE-2 identity requirement: radiance=None keeps the engine
    bit-identical to render_asdr_image even while probe reuse is on."""
    fns, _ = setup
    flds = {"mic": fns}
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0), radiance=None))
    reqs = [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7))
            for i in range(3)]
    done = {r.rid: r for r in eng.render(reqs)}
    ref, _ = pipeline.render_asdr_image(fns, ACFG, cam_at(0.7))
    for rid in done:
        assert not done[rid].stats["radiance_reused"]
        assert done[rid].stats["rays_marched"] == SIZE * SIZE
        np.testing.assert_array_equal(done[rid].image, np.asarray(ref))


def test_engine_radiance_replay_marches_zero_rays(setup):
    fns, _ = setup
    flds = {"mic": fns}
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0),
        radiance=fc_radiance.RadianceReuseConfig(refresh_every=0)))
    reqs = [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7 + 0.05 * (i % 2)))
            for i in range(4)]
    done = {r.rid: r for r in eng.render(reqs)}
    for rid in (2, 3):
        assert done[rid].stats["radiance_reused"]
        assert done[rid].stats["rays_marched"] == 0
        np.testing.assert_array_equal(done[rid].image, done[rid - 2].image)
    st = eng.engine_stats()
    assert st["rays_marched_fraction"] == 0.5
    assert st["reused_radiance_fraction"] == 0.5


def test_engine_radiance_composites_marched_rays(setup):
    """A near-pose frame assembled from warp + marched disocclusions must
    stay close to the fully-rendered frame at that pose."""
    fns, _ = setup
    flds = {"mic": fns}
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0),
        radiance=fc_radiance.RadianceReuseConfig(
            max_angle_deg=4.0, max_translation=0.08, refresh_every=0,
            min_valid_fraction=0.2)))
    # sequential render() calls: the radiance lookup happens at admission,
    # so frame 0 must have FINISHED before frame 1 can warp it
    first = eng.render([RenderRequest(rid=0, scene="mic", cam=cam_at(0.7))])
    done = {r.rid: r for r in eng.render(
        [RenderRequest(rid=1, scene="mic", cam=cam_at(0.73))])}
    done[0] = first[0]
    assert done[1].stats["radiance_reused"]
    ref, _ = pipeline.render_asdr_image(fns, ACFG, cam_at(0.73))
    from repro.core import rendering
    assert float(rendering.psnr(done[1].image, np.asarray(ref))) > 30.0


# ------------------------------------------------------- compat + interp
def test_pipeline_reexports_are_framecache():
    assert pipeline.ProbeCache is fc_probe.ProbeCache
    assert pipeline.ProbeReuseConfig is fc_probe.ProbeReuseConfig
    assert pipeline.probe_phase_cached is fc_probe.probe_phase_cached
    with pytest.raises(AttributeError):
        pipeline.no_such_symbol


def test_interpolate_map_is_exact_float_bilinear():
    # constant maps are fixed points at any scale
    const = jnp.full((16,), 0.37, jnp.float32)
    out = adaptive.interpolate_map(const, (4, 4), (12, 12))
    np.testing.assert_allclose(np.asarray(out), 0.37, rtol=1e-6)
    # interpolation never leaves the data range, and hits corners exactly
    rng = np.random.default_rng(3)
    probe = jnp.asarray(rng.uniform(size=(16,)).astype(np.float32))
    out = np.asarray(adaptive.interpolate_map(probe, (4, 4), (8, 8)))
    p = np.asarray(probe).reshape(4, 4)
    assert out.min() >= p.min() - 1e-6 and out.max() <= p.max() + 1e-6
    grid = out.reshape(8, 8)
    assert abs(grid[0, 0] - p[0, 0]) < 1e-6
    assert abs(grid[-1, -1] - p[-1, -1]) < 1e-6


def test_probe_opacity_is_unquantized(setup):
    """The 50-step int ladder hack is gone: probe opacity is float
    bilinear of the probe acc, not snapped to multiples of 0.05."""
    fns, _ = setup
    _, _, opacity = pipeline.probe_phase(fns, ACFG, cam_at(0.7),
                                         return_opacity=True)
    op = np.asarray(opacity)
    assert op.min() >= 0.0 and op.max() <= 1.0 + 1e-6
    frac = np.abs(op * 20 - np.round(op * 20))
    assert (frac > 1e-4).any(), "opacity still quantized to the 0.05 ladder"
