"""Optimizer: AdamW vs numpy reference, schedules, int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def numpy_adamw(params, grads, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * grads
    v = b2 * v + (1 - b2) * grads**2
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    new_p = params - lr * (mhat / (np.sqrt(vhat) + eps) + wd * params)
    return new_p, m, v


def test_adamw_matches_numpy_reference():
    cfg = optim.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.01)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    state = optim.adamw_init(p, cfg)
    np_p, np_m, np_v = np.asarray(p["w"]), np.zeros((2, 2)), np.zeros((2, 2))
    for t in range(1, 6):
        g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]]) * t}
        p, state = optim.adamw_update(g, state, p, cfg)
        np_p, np_m, np_v = numpy_adamw(
            np_p, np.asarray(g["w"]), np_m, np_v, t,
            cfg.lr, cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay)
        np.testing.assert_allclose(np.asarray(p["w"]), np_p, rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    new_norm = float(optim.global_norm(clipped))
    np.testing.assert_allclose(new_norm, 1.0, rtol=1e-5)


def test_schedules():
    s = optim.linear_warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(5))), 0.5, rtol=1e-6)
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.asarray(110))) <= 0.11


def test_int8_compression_roundtrip_error():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,)) * 0.01
    q, s, pad = optim.int8_compress(x)
    y = optim.int8_decompress(q, s, pad, x.shape)
    rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert rel < 1.0 / 100  # 127-level quantization ~ <1% of max


@pytest.mark.slow
def test_compressed_psum_under_shard_map():
    """int8 psum == f32 psum within quantization error (needs >=2 devices:
    run in a subprocess with forced host device count)."""
    import subprocess, sys, os
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
import numpy as np
from repro import optim

mesh = Mesh(np.asarray(jax.devices()[:4]), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 256)) * 0.1

def f(xs):
    return optim.compressed_psum(xs[0], "pod")

got = shard_map(f, mesh=mesh, in_specs=(P("pod"),), out_specs=P())(x)
want = jnp.sum(x, axis=0)
err = float(jnp.max(jnp.abs(got - want)))
scale = float(jnp.max(jnp.abs(want)))
assert err < 0.05 * scale + 1e-3, (err, scale)
print("OK", err)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_error_feedback_reduces_bias():
    """With error feedback, repeated compressed sums track the true sum
    (residual re-injection)."""
    x = jnp.asarray([1e-4, 5e-4, -2e-4] * 10 + [1.0])  # tiny values + outlier
    total_plain = jnp.zeros_like(x)
    total_ef = jnp.zeros_like(x)
    resid = jnp.zeros_like(x)
    for _ in range(50):
        q, s, pad = optim.int8_compress(x)
        total_plain = total_plain + optim.int8_decompress(q, s, pad, x.shape)
        corr = x + resid
        q, s, pad = optim.int8_compress(corr)
        deq = optim.int8_decompress(q, s, pad, x.shape)
        resid = corr - deq
        total_ef = total_ef + deq
    want = 50 * x
    err_plain = float(jnp.linalg.norm(total_plain - want))
    err_ef = float(jnp.linalg.norm(total_ef - want))
    assert err_ef < err_plain * 0.5
