"""Pytest config.

XLA_FLAGS: the device COUNT is deliberately left alone (smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses that set
their own count) — but the fast tier DOES append
``--xla_backend_optimization_level=0`` below, and child processes inherit
it unless they overwrite XLA_FLAGS (the subprocess tests do).

When `hypothesis` is unavailable (it is not baked into the container), a
minimal deterministic stand-in is installed into ``sys.modules`` before
collection so the property tests still run: ``@given`` sweeps a small
evenly-spaced subset of the strategy product instead of random sampling.
Install the real package via requirements-dev.txt for full randomized runs.
"""
import os
import sys
import types


# Cheap XLA backend codegen for the fast tier (~20% less compile time on
# CPU; numerics unchanged — the full suite passes either way).  Device
# count is deliberately untouched (see module docstring).  Opt out with
# REPRO_FAST_TESTS=0.  Must run before the first jax import, which is why
# it lives at conftest import time and not in a fixture.
if os.environ.get("REPRO_FAST_TESTS", "1") != "0":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_backend_optimization_level" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_backend_optimization_level=0").strip()


def _install_hypothesis_fallback():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import functools
    import itertools

    class _Strategy:
        def __init__(self, examples):
            self._examples = list(examples)

        def examples(self):
            return self._examples

    def integers(min_value=0, max_value=100):
        span = max_value - min_value
        vals = {min_value, max_value, min_value + span // 2,
                min_value + span // 3, min_value + (2 * span) // 3}
        return _Strategy(sorted(vals))

    def sampled_from(seq):
        return _Strategy(seq)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        mid = 0.5 * (min_value + max_value)
        return _Strategy([min_value, mid, max_value])

    def booleans():
        return _Strategy([False, True])

    def just(value):
        return _Strategy([value])

    class settings:  # noqa: N801 — mirrors hypothesis' API
        def __init__(self, max_examples=10, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._hyp_max_examples = self.max_examples
            return fn

    def given(*strategies):
        def deco(fn):
            import inspect

            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # like hypothesis, positional strategies fill params from the
            # right; anything left of them stays a pytest fixture
            bound = names[len(names) - len(strategies):]
            free = [sig.parameters[p] for p in names[:len(names) - len(strategies)]]

            def wrapper(*args, **kw):
                combos = list(itertools.product(
                    *[s.examples() for s in strategies]))
                # the fallback is a deterministic sweep, not a randomized
                # search — 5 spread examples bound the fast tier's runtime
                n = min(getattr(wrapper, "_hyp_max_examples", 10), 5)
                if len(combos) > n:  # even subsample, endpoints included
                    step = (len(combos) - 1) / (n - 1) if n > 1 else 0
                    combos = [combos[round(i * step)] for i in range(n)]
                for combo in combos:
                    fn(*args, **kw, **dict(zip(bound, combo)))

            functools.update_wrapper(wrapper, fn)
            del wrapper.__wrapped__  # keep pytest from seeing fn's params
            wrapper.__signature__ = sig.replace(parameters=free)
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [("integers", integers), ("sampled_from", sampled_from),
                      ("floats", floats), ("booleans", booleans),
                      ("just", just)]:
        setattr(st_mod, name, obj)
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_fallback()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
