"""Pytest config — deliberately does NOT set XLA_FLAGS: smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses."""
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
