"""Serving engine: batched generation, greedy rollout correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm, transformer as tfm
from repro.serve.engine import Request, ServeConfig, ServingEngine

# Full LM prefill+decode rollouts — heavy compile; the fast tier covers
# serving via tests/test_render_serve.py (same slot/pool machinery).
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(configs.get_smoke("minitron-8b"),
                              dtype="float32")
    api = lm.build(cfg, remat_policy=None)
    values = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(api, values, ServeConfig(max_seq=64, slots=2))
    return cfg, api, values, eng


def test_batched_generation_completes(engine):
    cfg, api, values, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=6) for i in range(5)]
    done = eng.generate(reqs)
    assert len(done) == 5
    for r in done:
        assert r.out is not None and r.out.shape == (6,)
        assert (r.out >= 0).all() and (r.out < cfg.vocab).all()


def test_greedy_decode_matches_forward_rollout(engine):
    """Engine's greedy generation must equal argmax rollout through the
    full forward pass (teacher-forcing the generated tokens)."""
    cfg, api, values, eng = engine
    prompt = np.asarray([5, 9, 2, 7], dtype=np.int32)
    req = Request(rid=0, prompt=prompt, max_new=5)
    eng.generate([req])

    toks = list(prompt)
    for _ in range(5):
        logits, _ = tfm.forward(values, cfg,
                                jnp.asarray([toks], dtype=jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = np.asarray(toks[len(prompt):])
    np.testing.assert_array_equal(req.out, want)
