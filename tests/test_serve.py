"""Serving engine: batched generation, greedy rollout correctness.

Two tiers (ROADMAP item — rejoin the fast tier):

  * fast (default run) — a micro LM config compiled in a few seconds
    exercises the full slot/prefill/decode machinery on every push;
  * slow (nightly ``make test-full``) — the same assertions against the
    minitron smoke config, whose heavier prefill+decode compile is what
    exiled this file from the fast tier in the first place.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm, transformer as tfm
from repro.models.config import ModelConfig
from repro.serve.engine import Request, ServeConfig, ServingEngine

# small enough to compile prefill + per-length forward rollouts in
# seconds on CPU, big enough to have real heads/GQA/gating
MICRO = ModelConfig(
    name="serve-micro", family="dense",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    d_ff=64, vocab=64,
    act="silu", tie_embeddings=False, dtype="float32",
)


def _build_engine(cfg):
    api = lm.build(cfg, remat_policy=None)
    values = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(api, values, ServeConfig(max_seq=64, slots=2))
    return cfg, api, values, eng


@pytest.fixture(scope="module")
def engine():
    return _build_engine(MICRO)


@pytest.fixture(scope="module")
def engine_smoke():
    return _build_engine(dataclasses.replace(
        configs.get_smoke("minitron-8b"), dtype="float32"))


def check_batched_generation_completes(built):
    cfg, api, values, eng = built
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=6) for i in range(5)]
    done = eng.generate(reqs)
    assert len(done) == 5
    for r in done:
        assert r.out is not None and r.out.shape == (6,)
        assert (r.out >= 0).all() and (r.out < cfg.vocab).all()


def check_greedy_decode_matches_forward_rollout(built):
    """Engine's greedy generation must equal argmax rollout through the
    full forward pass (teacher-forcing the generated tokens)."""
    cfg, api, values, eng = built
    prompt = np.asarray([5, 9, 2, 7], dtype=np.int32)
    req = Request(rid=0, prompt=prompt, max_new=5)
    eng.generate([req])

    toks = list(prompt)
    for _ in range(5):
        logits, _ = tfm.forward(values, cfg,
                                jnp.asarray([toks], dtype=jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = np.asarray(toks[len(prompt):])
    np.testing.assert_array_equal(req.out, want)


# ------------------------------------------------------------- fast tier
def test_batched_generation_completes(engine):
    check_batched_generation_completes(engine)


def test_greedy_decode_matches_forward_rollout(engine):
    check_greedy_decode_matches_forward_rollout(engine)


# ---------------------------------------------------- nightly (test-full)
@pytest.mark.slow
def test_batched_generation_completes_smoke_config(engine_smoke):
    check_batched_generation_completes(engine_smoke)


@pytest.mark.slow
def test_greedy_decode_matches_forward_rollout_smoke_config(engine_smoke):
    check_greedy_decode_matches_forward_rollout(engine_smoke)
