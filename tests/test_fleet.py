"""Multi-device fleet lane (run via ``make test-fleet``).

These tests exercise the DeviceExecutor and the sharded scene cache on a
REAL multi-device jax runtime, made cheap on CPU-only CI by
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the HomebrewNLP
trick from SNIPPETS.md).  They carry their own pytest marker (``fleet``)
and a dedicated Makefile / CI invocation, because the device count is
locked at jax init — the default fast tier must keep seeing one device.

Covered here (ISSUE-6):
  * DeviceExecutor-vs-SyncExecutor bit-identity (frames + deterministic
    counters) for devices {1, 2, 4} x prefetch {0, 2};
  * commit ordering under an adversarial slow-probe DEVICE (the
    earliest-submitted speculation finishes last);
  * graceful fallback to SyncExecutor when only one device exists;
  * Stage-A placement actually lands on secondary devices, round-robin,
    while the march owns device 0;
  * a two-replica fleet over one ShardedSceneCache matches the plain
    single sync engine bit-exactly while sharing blocks cross-replica.
"""
import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import fields, pipeline, scene
from repro.framecache import probe as fc_probe
from repro.framecache import radiance as fc_radiance
from repro.scenecache import SceneCacheConfig, ShardedSceneCache
from repro.serve import executor as executor_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)
from repro.serve.stats import DETERMINISTIC_COUNTERS

pytestmark = [
    pytest.mark.fleet,
    pytest.mark.skipif(
        jax.device_count() < 4,
        reason="fleet lane needs 4 host devices — run via make test-fleet "
               "(XLA_FLAGS=--xla_force_host_platform_device_count=4)"),
]

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)
SIZE = 16


def cam_at(theta, phi=0.5):
    return scene.look_at_camera(SIZE, SIZE, theta=theta, phi=phi)


@pytest.fixture(scope="module")
def flds():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def serve_cfg(devices=0, prefetch=2, slots=2):
    return RenderServeConfig(
        slots=slots, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(refresh_every=0),
        radiance=fc_radiance.RadianceReuseConfig(refresh_every=0),
        prefetch=prefetch, devices=devices)


def replay_traj(n=8, offset=0):
    # poses repeat every 3 requests: laps 2+ exercise warp reuse, full
    # radiance hits, AND speculation racing the in-flight sources
    return [RenderRequest(rid=offset + i, scene="mic",
                          cam=cam_at(0.7 + 0.05 * (i % 3)))
            for i in range(n)]


# ----------------------------------------------------------- determinism
def test_device_executor_bit_identity(flds):
    """Placement moves WHERE Stage A runs, never WHAT commits: frames
    and all commit-determined counters must be bit-identical to the
    synchronous single-device run for devices {1, 2, 4} x prefetch
    {0, 2} — devices=4 clamps to the 3 available secondaries."""
    eng0 = RenderServingEngine(flds, ACFG, serve_cfg(0, 0))
    ref = {r.rid: r for r in eng0.render(replay_traj())}
    st0 = eng0.engine_stats()
    eng0.close()
    for devices in (1, 2, 4):
        for prefetch in (0, 2):
            eng = RenderServingEngine(flds, ACFG,
                                      serve_cfg(devices, prefetch))
            assert isinstance(eng.executor, executor_lib.DeviceExecutor)
            assert len(eng.executor.devices) == min(devices, 3)
            done = {r.rid: r for r in eng.render(replay_traj())}
            st = eng.engine_stats()
            eng.close()
            for rid in ref:
                np.testing.assert_array_equal(
                    ref[rid].image, done[rid].image,
                    err_msg=f"frame {rid} differs at devices={devices}, "
                            f"prefetch={prefetch}")
            for c in DETERMINISTIC_COUNTERS:
                assert st0[c] == st[c], (devices, prefetch, c, st0[c], st[c])


def test_commit_ordering_under_adversarial_slow_device(flds, monkeypatch):
    """Commits happen on the engine thread in ADMISSION order even when
    per-device completion order is inverted: the earliest-submitted
    probes are stubbed slowest (a stalled device), so later speculations
    on other devices finish first — finish order, frames, and counters
    must still match the synchronous run."""
    real_execute = fc_probe.execute_probe_plan
    lock = threading.Lock()
    seen = {"n": 0}

    def slow_execute(fns, acfg, cam, plan, probe_key=None, rcfg=None):
        with lock:
            i = seen["n"]
            seen["n"] += 1
        if plan.kind in ("fresh", "refresh"):
            time.sleep(0.12 if i < 2 else 0.0)   # earliest probes slowest
        return real_execute(fns, acfg, cam, plan, probe_key=probe_key,
                            rcfg=rcfg)

    # distinct fresh poses: every admission pays a probe, all speculated
    def traj():
        return [RenderRequest(rid=i, scene="mic", cam=cam_at(0.55 + 0.1 * i))
                for i in range(6)]

    cfg = RenderServeConfig(
        slots=1, blocks_per_batch=4,
        reuse=fc_probe.ProbeReuseConfig(max_angle_deg=0.01,
                                        max_translation=1e-4),
        radiance=None, prefetch=4, devices=0)
    eng_s = RenderServingEngine(flds, ACFG, cfg)
    done_s = eng_s.render(traj())

    monkeypatch.setattr(fc_probe, "execute_probe_plan", slow_execute)
    eng_d = RenderServingEngine(flds, ACFG,
                                dataclasses.replace(cfg, devices=4))
    assert isinstance(eng_d.executor, executor_lib.DeviceExecutor)
    done_d = eng_d.render(traj())
    eng_d.close()

    assert [r.rid for r in done_d] == [r.rid for r in done_s]
    by_rid = {r.rid: r for r in done_s}
    for r in done_d:
        np.testing.assert_array_equal(r.image, by_rid[r.rid].image)
    st_s, st_d = eng_s.engine_stats(), eng_d.engine_stats()
    for c in DETERMINISTIC_COUNTERS:
        assert st_s[c] == st_d[c], (c, st_s[c], st_d[c])


# -------------------------------------------------------------- placement
def test_stage_a_lands_on_secondary_devices():
    """The placement rule itself: submissions round-robin over the
    secondary devices; device 0 (the march's device) never executes
    speculation; results are consumable on device 0."""
    import jax.numpy as jnp
    ex = executor_lib.DeviceExecutor()
    assert [d.id for d in ex.devices] == [d.id for d in jax.devices()[1:]]
    f = jax.jit(lambda x: x * 2.0 + 1.0)
    n = 2 * len(ex.devices)
    for i in range(n):
        ex.submit(i, lambda: f(jnp.full((4,), 3.0)))
    placed = []
    for i in range(n):
        out = ex.take(i)
        (dev,) = out.devices()
        placed.append(dev.id)
        np.testing.assert_array_equal(np.asarray(out), np.full((4,), 7.0))
    ex.close()
    assert 0 not in placed
    expected = [d.id for d in jax.devices()[1:]]
    assert placed == expected * 2, f"round-robin broken: {placed}"


def test_single_device_fallback(flds, monkeypatch):
    """A devices>0 config on a single-device host degrades to the
    bit-identical SyncExecutor instead of failing (the same engine
    binary serves a laptop and a fleet host)."""
    monkeypatch.setattr(executor_lib, "_available_devices",
                        lambda: [jax.devices()[0]])
    ex = executor_lib.make_executor(0, devices=2)
    assert isinstance(ex, executor_lib.SyncExecutor)
    eng = RenderServingEngine(flds, ACFG, serve_cfg(devices=2))
    assert isinstance(eng.executor, executor_lib.SyncExecutor)
    done = eng.render(replay_traj(4))
    assert len(done) == 4 and all(r.image is not None for r in done)
    eng.close()


# -------------------------------------------------------------------- obs
def test_device_executor_tracing_bit_identity(flds, tmp_path):
    """The tracing on/off bit-identity gate's DEVICE-executor leg (the
    sync/threaded legs live in tests/test_obs.py, which only sees one
    device): frames + deterministic counters identical with the tracer
    on, Stage-A placement spans land on the serve-dev* lanes with their
    device attr, and the exported trace passes the format validator."""
    import sys
    from pathlib import Path as _P

    from repro.obs import TraceConfig

    sys.path.insert(0, str(_P(__file__).resolve().parent.parent / "tools"))
    import check_trace

    for prefetch in (0, 2):
        ref_eng = RenderServingEngine(flds, ACFG, serve_cfg(2, prefetch))
        ref = {r.rid: r for r in ref_eng.render(replay_traj())}
        st_ref = ref_eng.engine_stats()
        ref_eng.close()

        path = tmp_path / f"fleet_trace_{prefetch}.json"
        cfg = dataclasses.replace(
            serve_cfg(2, prefetch), trace=TraceConfig(path=str(path)))
        eng = RenderServingEngine(flds, ACFG, cfg)
        assert isinstance(eng.executor, executor_lib.DeviceExecutor)
        done = {r.rid: r for r in eng.render(replay_traj())}
        st = eng.engine_stats()
        spans = list(eng.tracer.spans)
        eng.close()

        for rid in ref:
            np.testing.assert_array_equal(ref[rid].image, done[rid].image)
        for c in DETERMINISTIC_COUNTERS:
            assert st_ref[c] == st[c], (prefetch, c)
        if prefetch > 0:
            runs = [s for s in spans if s.name == "executor.run"]
            assert runs, "no placement spans with prefetch on"
            assert all(s.lane.startswith("serve-dev") for s in runs)
            assert all(s.attrs["backend"] == "device" and "device" in s.attrs
                       for s in runs)
        assert check_trace.check_file(path) == []


# ------------------------------------------------------------------ fleet
def test_two_replica_fleet_sharded_cache_identity(flds):
    """Two engine replicas (device executors) over one ShardedSceneCache
    replay the same pose set: every frame bit-identical to a plain
    single sync engine, cross-replica block hits > 0, and every shard
    within its byte budget."""
    plain = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None, radiance=None))
    ref = {r.rid: r for r in plain.render(replay_traj(6))}

    shared = ShardedSceneCache(SceneCacheConfig(byte_budget=8 << 20),
                               shards=4)
    cfg = RenderServeConfig(slots=2, blocks_per_batch=4, reuse=None,
                            radiance=None, devices=2)
    engines = [RenderServingEngine(flds, ACFG, cfg, scenecache=shared)
               for _ in range(2)]
    done = [engines[0].render(replay_traj(6)),
            engines[1].render(replay_traj(6, offset=100))]
    for frames in done:
        for r in frames:
            np.testing.assert_array_equal(r.image, ref[r.rid % 100].image)
    # replica 1 replayed replica 0's poses: its blocks came from the store
    assert engines[1].engine_stats()["scene_block_hits"] > 0
    st = shared.stats()
    assert all(b <= st["per_shard_budget"]
               for b in st["per_shard_resident_bytes"])
    for eng in engines:
        eng.close()
    shared.close()
