"""Instant-NGP training substrate: converges on the analytic scene."""
import jax.numpy as jnp
import pytest

from repro.core import train as T


@pytest.mark.slow
def test_ngp_training_reduces_loss():
    cfg = T.NGPTrainConfig(steps=60, batch_rays=512, n_samples=32,
                           n_views=4, view_hw=(48, 48), log_every=30)
    params, mcfg, field, hist = T.train_ngp(cfg, verbose=False)
    first, last = hist[0][1], hist[-1][1]
    assert last < first * 0.4, hist
    leaves = jnp.concatenate([x.reshape(-1) for x in
                              [params["grid"].reshape(-1)]])
    assert bool(jnp.all(jnp.isfinite(leaves)))
