"""Checkpointing: atomicity, keep-k, restart, elastic reshard."""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.ckpt.manager import available_steps


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (4, 8)),
            "b": {"x": jnp.arange(6, dtype=jnp.float32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    got, step = restore_checkpoint(tmp_path, t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_tmp_ignored(tmp_path):
    """A crashed writer leaves .tmp — restore must skip it."""
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate crash: tmp dir with partial payload, no manifest
    bad = tmp_path / "step_000000002.tmp"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"garbage")
    got, step = restore_checkpoint(tmp_path, t)
    assert step == 1
    assert available_steps(tmp_path) == [1]


def test_manifest_written_last_guards_partial_rename(tmp_path):
    """A dir without manifest.json is not a valid checkpoint."""
    d = tmp_path / "step_000000005"
    d.mkdir()
    np.save(d / "leaf_00000.npy", np.zeros(3))
    assert available_steps(tmp_path) == []


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree()
    for s in range(5):
        mgr.save(s, t)
    assert available_steps(tmp_path) == [3, 4]


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    t = _tree()
    mgr.save(7, t)
    mgr.wait()
    assert mgr.latest_step() == 7
    got, _ = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_leaf_count_mismatch_fails_loudly(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"only": jnp.zeros(3)})


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 4-device mesh sharding, restore re-sharded to 2 devices
    (the elastic resume path: checkpoint written at N chips, resumed at
    N/2)."""
    import os
    import subprocess
    import sys

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.ckpt import save_checkpoint, restore_checkpoint

root = {str(tmp_path)!r}
mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
sh4 = NamedSharding(mesh4, P("data"))
x = jax.device_put(jnp.arange(32, dtype=jnp.float32).reshape(8, 4), sh4)
save_checkpoint(root, 11, {{"x": x}})

mesh2 = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
sh2 = NamedSharding(mesh2, P("data"))
got, step = restore_checkpoint(root, {{"x": x}}, shardings={{"x": sh2}})
assert step == 11
assert got["x"].sharding == sh2, got["x"].sharding
np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(x))
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=Path(__file__).resolve().parent.parent)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
