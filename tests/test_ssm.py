"""Mamba-2 SSD: chunked == naive recurrence; decode == prefill handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssm as S
from repro.models.config import ModelConfig


CFG = ModelConfig(
    name="ssm-test", family="ssm", n_layers=1, d_model=32, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=64,
    ssm_state=8, ssm_head_dim=8, ssm_expand=2, ssm_chunk=8,
    dtype="float32",
)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 999), st.sampled_from([8, 32]),
       st.sampled_from([4, 8]))
def test_ssd_scan_equals_reference(seed, s_len, chunk):
    key = jax.random.PRNGKey(seed)
    B, H, P, N = 2, 4, 8, 8
    ks = jax.random.split(key, 4)
    xs = jax.random.normal(ks[0], (B, s_len, H, P))
    bm = jax.random.normal(ks[1], (B, s_len, 1, N)) * 0.5
    cm = jax.random.normal(ks[2], (B, s_len, 1, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, s_len, H)))
    A = -jnp.exp(jnp.linspace(-1.0, 1.0, H))
    D = jnp.ones((H,))
    ref = S.ssd_reference(xs, bm, cm, dt, A, D)
    got, h_final = S.ssd_scan(xs, bm, cm, dt, A, D, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_final_state_matches_reference_recurrence():
    key = jax.random.PRNGKey(5)
    B, s_len, H, P, N = 1, 16, 2, 8, 8
    ks = jax.random.split(key, 4)
    xs = jax.random.normal(ks[0], (B, s_len, H, P))
    bm = jax.random.normal(ks[1], (B, s_len, 1, N)) * 0.5
    cm = jax.random.normal(ks[2], (B, s_len, 1, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, s_len, H)))
    A = -jnp.exp(jnp.linspace(-1.0, 0.0, H))
    D = jnp.zeros((H,))
    _, h_final = S.ssd_scan(xs, bm, cm, dt, A, D, 8)
    # replay reference recurrence manually
    h = np.zeros((B, H, P, N))
    for t in range(s_len):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A))
        u = np.asarray(dt[:, t])[..., None, None] * np.einsum(
            "bgn,bhp->bhpn", np.asarray(bm[:, t]), np.asarray(xs[:, t]))
        h = a[..., None, None] * h + u
    np.testing.assert_allclose(np.asarray(h_final), h, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_block_prefill_decode_equivalence():
    """ssm_apply_with_state -> ssm_step chain == one long ssm_apply."""
    key = jax.random.PRNGKey(0)
    p, _ = (lambda t: (jax.tree.map(lambda q: q.value, t,
                                    is_leaf=lambda x: hasattr(x, "axes")),
                       None))(S.ssm_init(key, CFG))
    from repro.models.params import split
    p, _ = split(S.ssm_init(key, CFG))
    B, s_len = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, s_len, CFG.d_model)) * 0.5
    full = S.ssm_apply(p, x, CFG)
    out_pre, state = S.ssm_apply_with_state(p, x[:, :16], CFG)
    outs = [out_pre]
    for t in range(16, s_len):
        o, state = S.ssm_step(p, x[:, t:t+1], state, CFG)
        outs.append(o)
    stitched = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_state_is_o1():
    """State size independent of sequence length (long_500k enabler)."""
    st8 = S.ssm_init_state(CFG, batch=1)
    assert st8.h.shape == (1, CFG.ssm_heads * CFG.ssm_head_dim, CFG.ssm_state)
    assert st8.conv.shape[1] == CFG.ssm_conv
