"""Flash-attention Pallas kernel vs the attend_full oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import attention as A

# interpret-mode Pallas sweeps are compile-heavy; nightly via `pytest -m ""`
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("B,S,H,KV,Dh", [
    (2, 256, 4, 2, 64),    # GQA
    (1, 128, 4, 4, 32),    # MHA
    (1, 512, 8, 1, 64),    # MQA
])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (128, 0.0), (0, 50.0)])
def test_flash_matches_reference(B, S, H, KV, Dh, window, cap):
    key = jax.random.PRNGKey(S + H + window)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, KV, Dh))
    v = jax.random.normal(ks[2], (B, S, KV, Dh))
    pos = jnp.arange(S, dtype=jnp.int32)
    want = A.attend_full(q, k, v, pos, pos, window=window, softcap_val=cap)
    got = flash_attention(q, k, v, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_bf16_io():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.bfloat16)
    pos = jnp.arange(128, dtype=jnp.int32)
    want = A.attend_full(q, k, v, pos, pos)
    got = flash_attention(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
