"""Observability layer: tracing contract, metrics primitives, overhead.

Four property groups (ISSUE-8):

  * metrics — nearest-rank percentile (small-n off-by-one regression),
    bounded Series/Counter ledgers (>10k-round growth regression),
    registry exposition (Prometheus text + JSONL snapshots);
  * tracing — span nesting/lineage reconstruction, per-thread buffers
    draining without loss under concurrent writers, flight recorder
    firing exactly once per breach, Perfetto JSON schema round-trip
    (validated by tools/check_trace.py itself);
  * zero overhead when off — ``span()`` with no tracer installed is the
    shared NULL_SPAN singleton and adds no RETAINED allocations beyond
    a constant;
  * engine integration — frames + DETERMINISTIC_COUNTERS bit-identical
    with tracing on/off across executors {sync, threaded} x prefetch
    {0, 2} (the device executor case lives in tests/test_fleet.py,
    which owns the forced multi-device runtime), and an exported trace
    reconstructs a frame's full stage lineage with matching req/batch
    ids.
"""
import json
import sys
import threading
import tracemalloc
from pathlib import Path

import numpy as np
import pytest

from repro.core import fields, pipeline, scene
from repro.obs import (NULL_SPAN, Registry, TraceConfig, Tracer, export,
                       metrics as obs_metrics, percentile)
from repro.obs import trace as trace_lib
from repro.serve import stats as stats_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)
from repro.serve.stats import DETERMINISTIC_COUNTERS

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import check_trace  # noqa: E402

ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)


@pytest.fixture(scope="module")
def flds():
    return {"mic": fields.analytic_field_fns(scene.make_scene("mic"))}


def cam_at(theta):
    return scene.look_at_camera(16, 16, theta=theta, phi=0.5)


def traj(n=6):
    # poses repeat so laps 2+ exercise probe/radiance reuse under trace
    return [RenderRequest(rid=i, scene="mic", cam=cam_at(0.7 + 0.05 * (i % 3)))
            for i in range(n)]


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    assert trace_lib.active() is None
    yield
    assert trace_lib.active() is None, "a test leaked an installed tracer"


# ------------------------------------------------------------- percentile
def test_percentile_nearest_rank_small_n():
    """The PR-7 regression: int(n*q/100) made p50 of 2 samples the MAX.
    Nearest-rank is rank ceil(q/100 * n) clamped to [1, n]."""
    assert percentile([1.0, 2.0], 50.0) == 1.0
    assert percentile([2.0, 1.0], 50.0) == 1.0          # sorts internally
    assert percentile([1.0, 2.0], 99.0) == 2.0
    assert percentile([7.0], 50.0) == 7.0
    assert percentile([], 50.0) == 0.0
    assert percentile([1.0, 2.0, 3.0], 50.0) == 2.0
    assert percentile(range(1, 101), 99.0) == 99.0
    assert percentile(range(1, 101), 100.0) == 100.0
    assert percentile(range(1, 101), 0.0) == 1.0


def test_stats_percentile_is_the_shared_one():
    """serve.stats and benchmarks/common both re-export obs.metrics'."""
    assert stats_lib._percentile is percentile


# ------------------------------------------------- bounded engine ledgers
def test_counters_bounded_after_10k_rounds():
    """The unbounded march_ms/batches_per_round list leak, regressed:
    >10k simulated rounds must keep both ledgers at O(capacity) while
    march_rounds and the batches_per_round histogram stay exact."""
    c = stats_lib.EngineCounters()
    n = 12_000
    for i in range(n):
        c.note_round(0.001 * (1 + i % 7), 1 + i % 3)
        c.note_finalized({"rays_marched": 1, "rays_total": 2,
                          "samples_processed": 3, "samples_reused": 1,
                          "admit_stall_s": 0.001}, latency_s=0.01)
    assert len(c.march_ms.window()) == stats_lib.SERIES_CAPACITY
    assert len(c.latency_ms.window()) == stats_lib.SERIES_CAPACITY
    assert c.march_ms.count == n                 # all-time count survives
    assert len(c.batches_per_round) == 3         # keys = distinct counts
    st = stats_lib.engine_stats(c, {}, {}, None)
    assert st["march_rounds"] == n
    assert sum(st["batches_per_round"].values()) == n
    assert sum(k * v for k, v in st["batches_per_round"].items()) == \
        sum(1 + i % 3 for i in range(n))
    assert st["march_ms_p50"] > 0 and st["march_ms_p99"] >= st["march_ms_p50"]
    assert st["latency_ms_p50"] == pytest.approx(10.0)
    assert st["admit_stall_ms_p50"] == pytest.approx(1.0)


def test_histogram_merge_and_registry():
    h1 = obs_metrics.Histogram()
    h2 = obs_metrics.Histogram()
    for v in (0.5, 1.0, 2.0):
        h1.observe(v)
    for v in (4.0, 8.0):
        h2.observe(v)
    h1.merge(h2)
    assert h1.count == 5
    assert h1.percentile(99.0) >= 4.0

    reg = Registry()
    reg.counter("frames").inc(3)
    reg.gauge("fps").set(12.5)
    reg.histogram("span_ms_admission.wait").observe(2.0)
    text = reg.prometheus()
    assert "frames 3" in text
    assert "fps 12.5" in text
    assert "span_ms_admission_wait" in text      # prom-sanitized name
    snap = reg.snapshot()
    assert snap["frames"] == 3


def test_registry_jsonl_snapshot(tmp_path):
    reg = Registry()
    reg.counter("frames").inc(2)
    p = tmp_path / "metrics.jsonl"
    reg.jsonl_snapshot(p, extra={"round": 1})
    reg.counter("frames").inc(1)
    reg.jsonl_snapshot(p, extra={"round": 2})
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert [ln["round"] for ln in lines] == [1, 2]
    assert [ln["metrics"]["frames"] for ln in lines] == [2, 3]
    assert all("ts" in ln for ln in lines)


# ----------------------------------------------------------- span tracing
def test_span_lineage_reconstruction():
    """Nested spans record parent = the innermost open span on their
    thread; a frame's stage chain reconstructs from parent edges."""
    tr = Tracer(TraceConfig())
    trace_lib.install(tr)
    try:
        with trace_lib.span("admission.wait", req=7):
            with trace_lib.span("stage_a.prepare", req=7):
                with trace_lib.span("probe.plan"):
                    pass
            with trace_lib.span("commit", req=7):
                pass
        tr.drain()
    finally:
        trace_lib.uninstall(tr)
    by_name = {s.name: s for s in tr.spans}
    assert len(tr.spans) == 4
    root = by_name["admission.wait"]
    assert root.parent == 0 and root.attrs["req"] == 7
    assert by_name["stage_a.prepare"].parent == root.sid
    assert by_name["probe.plan"].parent == by_name["stage_a.prepare"].sid
    assert by_name["commit"].parent == root.sid
    # sids are unique and t0 <= t1 everywhere
    assert len({s.sid for s in tr.spans}) == 4
    assert all(s.t0 <= s.t1 for s in tr.spans)


def test_threaded_buffers_drain_without_loss():
    """4 writer threads x 500 spans each, engine draining concurrently:
    every span arrives exactly once, none dropped."""
    tr = Tracer(TraceConfig())
    trace_lib.install(tr)
    stop = threading.Event()

    def writer(k):
        for i in range(500):
            with trace_lib.span("executor.run", worker=k, i=i):
                pass

    def drainer():
        while not stop.is_set():
            tr.drain()

    try:
        threads = [threading.Thread(target=writer, args=(k,),
                                    name=f"serve-stage-a_{k}")
                   for k in range(4)]
        d = threading.Thread(target=drainer, name="drain")
        d.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        d.join()
        tr.drain()
    finally:
        trace_lib.uninstall(tr)
    assert tr.dropped == 0
    assert len(tr.spans) == 2000
    seen = {(s.attrs["worker"], s.attrs["i"]) for s in tr.spans}
    assert len(seen) == 2000                     # exactly once each


def test_buffer_cap_drops_are_counted():
    tr = Tracer(TraceConfig(buffer_cap=10))
    trace_lib.install(tr)
    try:
        for i in range(25):
            with trace_lib.span("x", i=i):
                pass
        tr.drain()
    finally:
        trace_lib.uninstall(tr)
    assert len(tr.spans) == 10
    assert tr.dropped == 15


def test_flight_recorder_fires_exactly_once(tmp_path):
    """One dump per breach episode: the first breaching span writes the
    ring and disarms; later breaches are silent until rearm()."""
    rec = export.FlightRecorder(capacity=8)
    path = tmp_path / "flight.json"
    trig = rec.dump_on(export.stall_trigger(10.0), path)

    def span_ms(name, ms, sid):
        return trace_lib.Span(name, sid, 0, "engine", 0.0, ms * 1e-3, {})

    rec.record([span_ms("admission.wait", 1.0, 1)])
    assert trig.fired == 0 and not path.exists()
    fired = rec.record([span_ms("admission.wait", 50.0, 2),
                        span_ms("admission.wait", 99.0, 3)])
    assert fired == 1 and trig.fired == 1 and trig.fired_on == 2
    first = path.read_text()
    rec.record([span_ms("admission.wait", 75.0, 4)])
    assert trig.fired == 1                      # still disarmed
    assert path.read_text() == first
    rec.rearm()
    rec.record([span_ms("admission.wait", 80.0, 5)])
    assert trig.fired == 2 and trig.fired_on == 5
    # the dumped ring is itself a valid trace
    assert check_trace.check_file(path) == []


def test_rate_trigger_burst_detection_and_rearm(tmp_path):
    """ISSUE-9 satellite: a burst trigger (shed storm) fires on the
    count-th matching span inside the window — spread-out spans never
    fire — and stays one-shot until rearm(); the window state freezes
    while disarmed and resumes after."""
    rec = export.FlightRecorder(capacity=16)
    path = tmp_path / "fl_shed_burst.json"
    trig = rec.dump_on(export.shed_burst_trigger(3, 100.0), path)

    def shed(t0, sid):
        return trace_lib.Span("scheduler.shed", sid, 0, "engine",
                              t0, t0 + 1e-4, {})

    def other(t0, sid):
        return trace_lib.Span("pool.march", sid, 0, "engine",
                              t0, t0 + 1e-4, {})

    # three sheds spread over 310 ms (> window), with unrelated spans
    # interleaved: no fire
    rec.record([shed(0.00, 1), other(0.01, 2), shed(0.30, 3),
                shed(0.31, 4)])
    assert trig.fired == 0 and not path.exists()
    # the 4th shed closes a (0.30, 0.31, 0.32) window: fire once
    fired = rec.record([shed(0.32, 5), shed(0.33, 6)])
    assert fired == 1 and trig.fired == 1 and trig.fired_on == 5
    first = path.read_text()
    rec.record([shed(0.34, 7), shed(0.35, 8), shed(0.36, 9)])
    assert trig.fired == 1 and path.read_text() == first   # disarmed
    rec.rearm()
    rec.record([shed(0.37, 10)])                # resumes the frozen window
    assert trig.fired == 2 and trig.fired_on == 10
    assert check_trace.check_file(path) == []
    # the evict-storm twin watches scenecache.evict spans
    storm = export.evict_storm_trigger(2, 50.0)
    ev = lambda t0, sid: trace_lib.Span("scenecache.evict", sid, 0,
                                        "engine", t0, t0 + 1e-4, {})
    assert not storm(ev(0.0, 1))
    assert storm(ev(0.02, 2))


def test_replica_pid_export_and_fleet_merge(tmp_path):
    """ISSUE-9 satellite: TraceConfig.replica stamps every exported
    event's Chrome pid (one process group per replica) and a
    process_name metadata row; distinct-replica exports merge into one
    valid fleet timeline, duplicate pids are rejected."""
    paths = []
    for rep in (1, 2):
        tr = Tracer(TraceConfig())
        trace_lib.install(tr)
        try:
            with trace_lib.span("admission.wait", req=rep, scene="mic"):
                pass
            tr.drain()
        finally:
            trace_lib.uninstall(tr)
        path = tmp_path / f"trace_r{rep}.json"
        tr.cfg = TraceConfig(path=str(path), replica=rep)
        tr.finish()
        assert check_trace.check_file(path) == []
        data = json.loads(path.read_text())
        assert all(e["pid"] == rep for e in data["traceEvents"])
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   and e["args"]["name"] == f"replica-{rep}"
                   for e in data["traceEvents"])
        paths.append(path)
    merged = export.merge_chrome_traces(paths)
    assert merged["otherData"]["replicas"] == [1, 2]
    assert check_trace.validate(merged) == []
    out = tmp_path / "fleet.json"
    out.write_text(json.dumps(merged))
    assert check_trace.check_file(out) == []
    with pytest.raises(ValueError):
        export.merge_chrome_traces([paths[0], paths[0]])


def test_epoch_rebases_export_origin():
    """A shared epoch earlier than this tracer's wall start shifts its
    exported timestamps LATER by the same offset — per-replica exports
    land on one fleet clock."""
    tr = Tracer(TraceConfig())
    assert tr.export_origin() == tr.t_origin
    tr.cfg = TraceConfig(epoch=tr.wall_origin - 2.0)
    assert tr.export_origin() == pytest.approx(tr.t_origin - 2.0)


def test_chrome_trace_schema_roundtrip(tmp_path):
    """Exported Perfetto JSON round-trips through the schema validator
    (balanced spans, monotonic timestamps, known lanes)."""
    tr = Tracer(TraceConfig())
    trace_lib.install(tr)
    try:
        with trace_lib.span("admission.wait", req=0, scene="mic"):
            with trace_lib.span("stage_a.prepare", req=0):
                pass
        t = threading.Thread(
            target=lambda: trace_lib.span("executor.run",
                                          backend="threaded").__enter__()
            .__exit__(None, None, None),
            name="serve-stage-a_0")
        t.start()
        t.join()
        path = tmp_path / "trace.json"
        tr.cfg = TraceConfig(path=str(path))
        tr.finish()
    finally:
        trace_lib.uninstall(tr)
    assert check_trace.check_file(path) == []
    data = json.loads(path.read_text())
    evs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in evs} == {"admission.wait", "stage_a.prepare",
                                        "executor.run"}
    lanes = {e["args"]["name"] for e in data["traceEvents"]
             if e["ph"] == "M"}
    assert "serve-stage-a_0" in lanes
    # and the validator actually rejects a broken trace
    bad = dict(data)
    bad["traceEvents"] = data["traceEvents"] + [
        {"name": "orphan", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": 1.0, "args": {"sid": 999, "parent": 555}}]
    assert check_trace.validate(bad)


# --------------------------------------------------- zero overhead when off
def test_disabled_mode_null_span_singleton():
    assert trace_lib.active() is None
    s1 = trace_lib.span("admission.wait", req=1, scene="mic")
    s2 = trace_lib.span("pool.dispatch", batch=2)
    assert s1 is NULL_SPAN and s2 is NULL_SPAN
    with s1:
        pass                                    # enter/exit are no-ops
    trace_lib.instant("scenecache.hit")          # returns immediately


def test_disabled_mode_constant_retained_allocations():
    """No tracer installed: 10k instrumented call sites must retain no
    memory beyond a small constant (the kwargs dicts are transient)."""
    def admission_like(i):
        with trace_lib.span("admission.wait", req=i, scene="mic"):
            with trace_lib.span("stage_a.prepare", req=i):
                pass

    admission_like(0)                            # warm any lazy state
    tracemalloc.start()
    try:
        base, _ = tracemalloc.get_traced_memory()
        for i in range(10_000):
            admission_like(i)
        now, _ = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    assert now - base < 64 << 10, \
        f"disabled tracing retained {now - base} bytes over 10k admissions"


# ------------------------------------------------------ engine integration
def render_pair(flds, rcfg, n=6):
    eng = RenderServingEngine(flds, ACFG, rcfg)
    done = {r.rid: r for r in eng.render(traj(n))}
    st = eng.engine_stats()
    tr = eng.tracer
    eng.close()
    return done, st, tr


def test_trace_off_by_default(flds):
    assert RenderServeConfig().trace is None
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4))
    assert eng.tracer is None
    eng.close()


@pytest.mark.parametrize("workers,prefetch", [(0, 0), (0, 2), (2, 0), (2, 2)])
def test_bit_identity_tracing_on_off(flds, workers, prefetch, tmp_path):
    """Frames and every deterministic counter identical with tracing on
    vs off, for sync and threaded executors x prefetch {0, 2}.  (The
    device executor runs in tests/test_fleet.py's forced 4-device
    lane.)"""
    from repro.framecache import ProbeReuseConfig, RadianceReuseConfig
    base = RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=ProbeReuseConfig(refresh_every=0),
        radiance=RadianceReuseConfig(refresh_every=0),
        workers=workers, prefetch=prefetch)
    import dataclasses
    traced = dataclasses.replace(base, trace=TraceConfig(
        path=str(tmp_path / "t.json"), flight=True, stall_dump_ms=1e9))
    d_off, st_off, _ = render_pair(flds, base)
    d_on, st_on, tr = render_pair(flds, traced)
    assert d_off.keys() == d_on.keys()
    for rid in d_off:
        np.testing.assert_array_equal(d_off[rid].image, d_on[rid].image)
    for k in DETERMINISTIC_COUNTERS:
        assert st_off[k] == st_on[k], k
    assert tr is None or len(tr.spans) > 0
    assert check_trace.check_file(tmp_path / "t.json") == []


def test_engine_trace_reconstructs_lineage(flds, tmp_path):
    """A replayed frame's trace chains admission -> dispatch -> collect
    -> commit with matching req/batch ids (the acceptance lineage)."""
    from repro.framecache import ProbeReuseConfig
    from repro.scenecache import SceneCacheConfig
    path = tmp_path / "trace.json"
    rcfg = RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=ProbeReuseConfig(refresh_every=0),
        scenecache=SceneCacheConfig(byte_budget=4 << 20),
        prefetch=2, trace=TraceConfig(path=str(path)))
    eng = RenderServingEngine(flds, ACFG, rcfg)
    reqs = traj(6)
    done = eng.render(reqs)
    assert len(done) == len(reqs)
    spans = list(eng.tracer.spans)
    eng.close()

    names = {s.name for s in spans}
    for required in ("admission.wait", "stage_a.prepare", "stage_b.admit",
                     "commit", "pool.sweep", "pool.dispatch_round",
                     "pool.dispatch", "pool.collect", "probe.plan",
                     "probe.execute", "probe.commit"):
        assert required in names, f"missing span {required}"

    # every admitted request has an admission.wait span with its rid
    waits = [s for s in spans if s.name == "admission.wait"]
    assert {s.attrs["req"] for s in waits} == {r.rid for r in reqs}
    # stage_b.admit + commit nest under admission.wait with the same req
    by_sid = {s.sid: s for s in spans}
    for s in spans:
        if s.name == "stage_b.admit":
            parent = by_sid[s.parent]
            assert parent.name == "admission.wait"
            assert parent.attrs["req"] == s.attrs["req"]
    # batch ids pair dispatch with its collect, and reqs line up
    dispatches = {s.attrs["batch"]: s for s in spans
                  if s.name == "pool.dispatch"}
    collects = {s.attrs["batch"]: s for s in spans
                if s.name == "pool.collect"}
    assert dispatches and set(collects) == set(dispatches)
    for bid, d in dispatches.items():
        assert collects[bid].attrs["reqs"] == d.attrs["reqs"]
        assert d.attrs["scene"] == "mic"
        # collect stamps launch->arrays-ready device time back onto the
        # dispatch span, splitting its host time into queue vs device
        assert d.attrs["device_ms"] > 0.0
    # the exported file passes the validator too
    assert check_trace.check_file(path) == []


def test_engine_stats_is_registry_read(flds):
    """engine_stats() keys survive the registry round-trip exactly, and
    the same numbers appear in the Prometheus exposition."""
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4))
    eng.render(traj(4))
    st = eng.engine_stats()
    for k in ("frames", "latency_ms_p50", "latency_ms_p99",
              "admit_stall_ms_p50", "admit_stall_ms_p99",
              "march_ms_p50", "march_ms_p99", "march_rounds",
              "batches_per_round"):
        assert k in st, k
    assert st["frames"] == 4
    assert st["latency_ms_p99"] >= st["latency_ms_p50"] > 0
    text = eng.metrics.prometheus()
    assert f"frames {st['frames']}" in text
    assert max(st["batches_per_round"]) >= 1     # dict keyed by n_batches
    eng.close()
