"""§4.3 color-density decoupling: interpolation exactness + savings."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decouple, fields, pipeline, scene
from repro.core.model import NGPConfig


def test_group_1_is_identity():
    key = jax.random.PRNGKey(0)
    anchors = jax.random.uniform(key, (4, 16, 3))
    out = decouple.interpolate_group_colors(anchors, 1, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(anchors), rtol=1e-6)


def test_interpolation_exact_on_linear_colors():
    """Colors linear in sample index are reconstructed exactly (interior)."""
    S, n = 16, 4
    j = jnp.arange(0, S, n)
    anchors = jnp.stack([j, 2 * j, 3 * j], -1).astype(jnp.float32)[None]
    out = decouple.interpolate_group_colors(anchors, n, S)
    expect = jnp.stack([jnp.arange(S), 2 * jnp.arange(S), 3 * jnp.arange(S)],
                       -1).astype(jnp.float32)
    # last group clamps to final anchor (paper's trailing behaviour)
    interior = S - n
    np.testing.assert_allclose(np.asarray(out[0, :interior]),
                               np.asarray(expect[:interior]), rtol=1e-5)


def test_decoupled_render_close_to_full():
    field = scene.make_scene("lego")
    fns = fields.analytic_field_fns(field)
    cam = scene.look_at_camera(10, 10, theta=0.8, phi=0.5)
    o, d = scene.camera_rays(cam)
    full, _ = pipeline.render_fixed_fns(fns, o, d, 48)
    dec, stats = decouple.render_decoupled(fns, o, d, 48, group=2)
    naive = decouple.render_naive_reduced(fns, o, d, 48, factor=2)
    from repro.core.rendering import psnr
    p_dec = float(psnr(dec, full))
    p_naive = float(psnr(naive, full))
    # paper Fig. 9: decoupling beats naive half-sampling
    assert p_dec > p_naive
    assert stats["color_evals"] == o.shape[0] * 24
    assert stats["density_evals"] == o.shape[0] * 48


def test_mlp_flops_saved_matches_paper():
    """Paper: color MLP ~92% of FLOPs; n=2 cuts total MLP compute ~46%."""
    cfg = NGPConfig.make(paper_mlp=True)
    from repro.core.mlp import flops_per_sample
    f = flops_per_sample(cfg.net)
    assert 0.88 < f["color_fraction"] < 0.96
    s = decouple.mlp_flops_saved(cfg, 192, 2)
    assert 0.40 < s["reduction_fraction"] < 0.50
