"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashgrid, mlp as mlp_lib
from repro.core.model import NGPConfig, init_ngp
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def model():
    cfg = NGPConfig.small()
    params = init_ngp(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("n", [1, 7, 256,
                               pytest.param(533, marks=pytest.mark.slow)])
def test_hash_encode_matches_reference(model, n):
    cfg, params = model
    pts = jax.random.uniform(jax.random.PRNGKey(n), (n, 3))
    got = ops.hash_encode(pts, params["grid"], cfg.grid)
    want = hashgrid.encode(pts, params["grid"], cfg.grid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


def test_hash_encode_matches_reference_at_boundaries(model):
    """Clamp-at-boundary corners: points at/beyond the cube faces must hit
    the same clamped voxel rows in the kernel and the reference, on both
    dense and hashed levels."""
    cfg, params = model
    grid = cfg.grid
    dense_levels = [l for l in range(grid.n_levels) if grid.level_is_dense(l)]
    hashed = [l for l in range(grid.n_levels) if not grid.level_is_dense(l)]
    assert dense_levels and hashed, "config must exercise both level kinds"
    eps = np.float32(1e-6)
    corners = np.stack(np.meshgrid([0.0, 1.0], [0.0, 1.0], [0.0, 1.0],
                                   indexing="ij"), -1).reshape(-1, 3)
    pts = np.concatenate([
        corners,                                    # exact cube corners
        corners * (1 - eps) + eps / 2,              # just inside
        np.asarray([[1.0 - eps, 0.5, 0.5], [0.5, 1.0 - eps, 1.0 - eps],
                    [0.0, 0.0, 1.0], [1.0, 1.0, 1.0]], np.float32),
    ]).astype(np.float32)
    got = ops.hash_encode(jnp.asarray(pts), params["grid"], cfg.grid)
    want = hashgrid.encode(jnp.asarray(pts), params["grid"], cfg.grid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-7)
    # per-level-kind slices agree too (feature layout is [level, feat])
    F = grid.feature_dim
    for l in dense_levels[:1] + hashed[-1:]:
        np.testing.assert_allclose(
            np.asarray(got[:, l * F:(l + 1) * F]),
            np.asarray(want[:, l * F:(l + 1) * F]), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("n", [3, 128, 300])
@pytest.mark.parametrize("paper_mlp", [False, True])
def test_fused_mlp_matches_reference(n, paper_mlp):
    cfg = NGPConfig.small(paper_mlp=paper_mlp)
    params = init_ngp(jax.random.PRNGKey(1), cfg)
    key = jax.random.PRNGKey(n)
    enc = jax.random.normal(key, (n, cfg.net.encoding_dim)) * 0.3
    dirs = jax.random.normal(key, (n, 3))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    sig_k, rgb_k, geo_k = ops.fused_field(enc, dirs, params["mlps"], cfg.net)
    sig_r, geo_r = mlp_lib.density_apply(params["mlps"], enc)
    rgb_r = mlp_lib.color_apply(params["mlps"], geo_r, dirs, cfg.net.sh_degree)
    np.testing.assert_allclose(np.asarray(sig_k), np.asarray(sig_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rgb_k), np.asarray(rgb_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(geo_k), np.asarray(geo_r),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n", [5, 260])
def test_density_and_color_kernels_match(model, n):
    cfg, params = model
    key = jax.random.PRNGKey(n + 9)
    enc = jax.random.normal(key, (n, cfg.net.encoding_dim)) * 0.3
    dirs = jax.random.normal(key, (n, 3))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    sig_k, geo_k = ops.density_mlp(enc, params["mlps"], cfg.net)
    sig_r, geo_r = mlp_lib.density_apply(params["mlps"], enc)
    np.testing.assert_allclose(np.asarray(sig_k), np.asarray(sig_r),
                               rtol=1e-4, atol=1e-6)
    col_k = ops.color_mlp(geo_r, dirs, params["mlps"], cfg.net)
    col_r = mlp_lib.color_apply(params["mlps"], geo_r, dirs,
                                cfg.net.sh_degree)
    np.testing.assert_allclose(np.asarray(col_k), np.asarray(col_r),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("R,S,group", [
    (4, 32, 2),
    pytest.param(37, 48, 4, marks=pytest.mark.slow),
    pytest.param(130, 192, 2, marks=pytest.mark.slow),
    (8, 64, 1)])
def test_volume_render_kernel_matches(R, S, group):
    key = jax.random.PRNGKey(R * S)
    A = -(-S // group)
    sig = jax.random.uniform(key, (R, S)) * 8
    anch = jax.random.uniform(jax.random.PRNGKey(1), (R, A, 3))
    dl = jnp.full((R, S), 0.02)
    rgb_k, acc_k = ops.volume_render(sig, anch, dl, group)
    rgb_r, acc_r = ref.ref_volume_render(sig, anch, dl, group)
    np.testing.assert_allclose(np.asarray(rgb_k), np.asarray(rgb_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc_k), np.asarray(acc_r),
                               rtol=1e-4, atol=1e-5)


def test_volume_render_valid_mask():
    R, S, g = 6, 32, 2
    sig = jnp.ones((R, S)) * 5
    anch = jnp.ones((R, S // g, 3)) * 0.5
    dl = jnp.full((R, S), 0.05)
    valid = (jnp.arange(S) < 16)[None].repeat(R, 0)
    rgb_m, acc_m = ops.volume_render(sig, anch, dl, g, valid=valid)
    rgb_r, acc_r = ref.ref_volume_render(sig, anch, dl, g, valid=valid)
    np.testing.assert_allclose(np.asarray(rgb_m), np.asarray(rgb_r),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_kernel_field_fns_drive_full_pipeline(model):
    """The kernel-backed FieldFns must agree with the model-backed path."""
    cfg, params = model
    from repro.core import model as model_lib
    pts = jax.random.uniform(jax.random.PRNGKey(5), (97, 3)) * 1.2 - 0.1
    dirs = jax.random.normal(jax.random.PRNGKey(6), (97, 3))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    kf = ops.field_fns(params, cfg)
    mf = model_lib.field_fns(params, cfg)
    sk, gk = kf.density(pts)
    sm, gm = mf.density(pts)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sm),
                               rtol=1e-4, atol=1e-6)
    ck = kf.color(gk, dirs)
    cm = mf.color(gm, dirs)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cm),
                               rtol=1e-4, atol=1e-6)
