"""Sharding rules: logical->physical resolution, dedup, mesh dropping."""
import subprocess
import sys
import os
from pathlib import Path

import pytest

from repro.sharding import rules as R


class FakeMesh:
    def __init__(self, names):
        self.axis_names = names


def test_resolve_basic():
    mesh = FakeMesh(("data", "model"))
    spec = R.resolve_spec(("d_model", "heads"), R.TRAIN_RULES, mesh)
    assert tuple(spec) == ("data", "model")


def test_resolve_drops_missing_pod_axis():
    mesh = FakeMesh(("data", "model"))
    spec = R.resolve_spec(("batch", None), R.TRAIN_RULES, mesh)
    assert tuple(spec) == ("data", None)  # ('pod','data') -> data only


def test_resolve_keeps_pod_axis_when_present():
    mesh = FakeMesh(("pod", "data", "model"))
    spec = R.resolve_spec(("batch", None), R.TRAIN_RULES, mesh)
    assert tuple(spec) == (("pod", "data"), None)


def test_resolve_deduplicates_conflicting_axes():
    """experts and d_ff both map to model — first dim wins, second drops
    (a mesh axis may appear at most once in a PartitionSpec)."""
    mesh = FakeMesh(("data", "model"))
    spec = R.resolve_spec(("experts", "d_model", "d_ff"), R.TRAIN_RULES, mesh)
    assert tuple(spec) == ("model", "data", None)


def test_serve_rules_replicate_d_model():
    mesh = FakeMesh(("data", "model"))
    spec = R.resolve_spec(("d_model", "vocab"), R.SERVE_RULES, mesh)
    assert tuple(spec) == (None, "model")


def test_long_context_rules_shard_kv_seq():
    mesh = FakeMesh(("pod", "data", "model"))
    spec = R.resolve_spec(("batch", "kv_seq", "heads_act"),
                          R.LONG_CONTEXT_SERVE_RULES, mesh)
    assert tuple(spec) == (None, ("pod", "data"), "model")


@pytest.mark.slow
def test_small_mesh_end_to_end_subprocess():
    """Tiny config train_step lowers+compiles on a real (2,2) mesh with all
    the production sharding machinery (8 forced host devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec
import repro.configs as configs
from repro.models import lm
from repro.sharding import rules as rules_lib
from repro.train.step import TrainConfig, make_train_step

mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
cfg = configs.get_smoke("minitron-8b")
api = lm.build(cfg, remat_policy="full")
vals, axes = api.abstract()
rules = rules_lib.TRAIN_RULES
p_sh = jax.tree.map(
    lambda a: NamedSharding(mesh, rules_lib.resolve_spec(a, rules, mesh)),
    axes, is_leaf=lambda x: isinstance(x, tuple))
tcfg = TrainConfig(microbatches=2)
step, opt_init = make_train_step(api.loss_fn, tcfg, rules, mesh)
opt_abs = jax.eval_shape(opt_init, vals)
scalar = NamedSharding(mesh, PartitionSpec())
opt_sh = {"m": p_sh, "v": p_sh, "count": scalar}
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
b_sh = {"tokens": NamedSharding(mesh, PartitionSpec("data", None))}
jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh, scalar),
                 out_shardings=(p_sh, opt_sh, None))
compiled = jitted.lower(vals, opt_abs, batch,
                        jax.ShapeDtypeStruct((), jnp.int32)).compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0

# ALSO run concretely: loss finite on the real 4-device mesh
values = api.init(jax.random.PRNGKey(0))
values = jax.device_put(values, p_sh)
opt = jax.device_put(opt_init(values), opt_sh)
tok = jax.device_put(
    jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
    b_sh["tokens"])
v2, o2, m = jitted(values, opt, {"tokens": tok}, jnp.asarray(0, jnp.int32))
assert bool(jnp.isfinite(m["loss"]))
print("OK", float(m["loss"]))
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=Path(__file__).resolve().parent.parent)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
