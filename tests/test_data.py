"""Data pipelines: determinism-by-step, structure, replay."""
import numpy as np

from repro.data import TokenPipeline


def test_batches_deterministic_by_step():
    p1 = TokenPipeline(vocab=512, batch=4, seq_len=64, seed=3)
    p2 = TokenPipeline(vocab=512, batch=4, seq_len=64, seed=3)
    np.testing.assert_array_equal(np.asarray(p1.batch_at(17)),
                                  np.asarray(p2.batch_at(17)))
    # different steps differ
    assert not np.array_equal(np.asarray(p1.batch_at(17)),
                              np.asarray(p1.batch_at(18)))


def test_tokens_in_range_and_zipfian():
    p = TokenPipeline(vocab=1000, batch=16, seq_len=256, seed=0)
    t = np.asarray(p.batch_at(0))
    assert t.min() >= 0 and t.max() < 1000
    # zipf: low ids much more frequent than high ids
    low = (t < 10).mean()
    high = (t >= 500).mean()
    assert low > 5 * high


def test_phrase_structure_is_learnable():
    """Each phrase repeats its first half — bigram structure exists."""
    p = TokenPipeline(vocab=512, batch=2, seq_len=64, seed=1, phrase_len=8)
    t = np.asarray(p.batch_at(5))
    ph = t[:, :64].reshape(2, -1, 8)
    np.testing.assert_array_equal(ph[:, :, :4], ph[:, :, 4:])


def test_iterator_matches_batch_at():
    p = TokenPipeline(vocab=128, batch=2, seq_len=16, seed=9)
    it = iter(p)
    for step in range(3):
        np.testing.assert_array_equal(np.asarray(next(it)),
                                      np.asarray(p.batch_at(step)))
