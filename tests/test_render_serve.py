"""Render serving engine + cross-frame probe reuse invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import adaptive, fields, pipeline, scene
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)


ACFG = pipeline.ASDRConfig(ns_full=48, probe_stride=4, candidates=(8, 16, 32),
                           block_size=64, chunk=16, sort_by_opacity=False)


@pytest.fixture(scope="module")
def setup():
    flds = {"mic": fields.analytic_field_fns(scene.make_scene("mic")),
            "hotdog": fields.analytic_field_fns(scene.make_scene("hotdog"))}
    cam = scene.look_at_camera(16, 16, theta=0.7, phi=0.5)
    return flds, cam


def test_engine_matches_single_image_pipeline(setup):
    """Pooled multi-request serving must be bit-identical to rendering each
    view alone through render_asdr_image (fresh probes, stable sort)."""
    flds, cam = setup
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None))
    reqs = [RenderRequest(rid=0, scene="mic", cam=cam),
            RenderRequest(rid=1, scene="hotdog", cam=cam)]
    done = {r.rid: r for r in eng.render(reqs)}
    for rid, sc in [(0, "mic"), (1, "hotdog")]:
        ref, _ = pipeline.render_asdr_image(flds[sc], ACFG, cam)
        np.testing.assert_array_equal(done[rid].image, np.asarray(ref))


def test_probe_reuse_zero_distance_is_identical(setup):
    """At zero pose distance the reuse path must equal re-probing exactly:
    same count map (dilation radius 0), same rendered image."""
    flds, cam = setup
    fns = flds["mic"]
    cache = pipeline.ProbeCache(pipeline.ProbeReuseConfig())
    c1, cost1, o1, r1 = pipeline.probe_phase_cached(fns, ACFG, cam, cache)
    # a newly constructed but identical camera
    cam_b = scene.look_at_camera(16, 16, theta=0.7, phi=0.5)
    c2, cost2, o2, r2 = pipeline.probe_phase_cached(fns, ACFG, cam_b, cache)
    assert (not r1) and r2 and cost1 > 0 and cost2 == 0
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    fresh, _, _ = pipeline.probe_phase(fns, ACFG, cam_b, return_opacity=True)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(fresh))


def test_engine_reuse_frames_identical_on_replay(setup):
    """Serving the same trajectory twice: lap-2 frames reuse lap-1 probes
    and must render bit-identically to an always-probe engine."""
    flds, _ = setup
    def traj():
        return [RenderRequest(rid=i, scene="mic",
                              cam=scene.look_at_camera(
                                  16, 16, theta=0.7 + 0.1 * (i % 2), phi=0.5))
                for i in range(4)]
    reuse = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4,
        reuse=pipeline.ProbeReuseConfig(max_angle_deg=1.0,
                                        max_translation=0.02)))
    probe = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None))
    dr = {r.rid: r for r in reuse.render(traj())}
    dp = {r.rid: r for r in probe.render(traj())}
    assert dr[2].stats["probe_reused"] and dr[3].stats["probe_reused"]
    assert reuse.engine_stats()["reused_probe_fraction"] == 0.5
    for rid in dr:
        np.testing.assert_array_equal(dr[rid].image, dp[rid].image)


def test_probe_cache_refresh_every_k(setup):
    flds, cam = setup
    fns = flds["mic"]
    cache = pipeline.ProbeCache(pipeline.ProbeReuseConfig(refresh_every=2))
    pipeline.probe_phase_cached(fns, ACFG, cam, cache)      # miss
    for i in range(2):                                       # 2 hits
        *_ , reused = pipeline.probe_phase_cached(fns, ACFG, cam, cache)
        assert reused
    *_, reused = pipeline.probe_phase_cached(fns, ACFG, cam, cache)
    assert not reused                                        # forced refresh
    assert cache.refreshes == 1 and cache.hits == 2


def test_padding_rays_do_not_leak(setup):
    """Image rows must be independent of the pad rays' content."""
    flds, cam = setup
    fns = flds["mic"]
    o, d = scene.camera_rays(cam)                 # R = 256, block 96 -> pad
    acfg = pipeline.ASDRConfig(ns_full=48, candidates=(8, 16, 32),
                               block_size=96, chunk=16)
    R = o.shape[0]
    counts = jnp.asarray(np.random.default_rng(0).choice(
        [8, 16, 32], size=(R,)), jnp.int32)
    op, dp_, cp, _, pad = pipeline.pad_rays_to_blocks(acfg, o, d, counts)
    assert pad == (-R) % 96 and pad > 0
    rgb_a, _, _ = pipeline.render_adaptive(fns, acfg, op, dp_, cp)
    # replace pad rays with rays that stare straight into the scene
    op2 = op.at[R:].set(jnp.asarray([0.5, 0.5, -0.5]))
    dp2 = dp_.at[R:].set(jnp.asarray([0.0, 0.0, 1.0]))
    rgb_b, _, _ = pipeline.render_adaptive(fns, acfg, op2, dp2, cp)
    np.testing.assert_array_equal(np.asarray(rgb_a[:R]),
                                  np.asarray(rgb_b[:R]))


@pytest.mark.parametrize("by_opacity", [False, True])
def test_block_sort_is_permutation_inverse(by_opacity):
    """block_sort order must be an exact permutation; the unsort used by
    render_adaptive must be its exact inverse."""
    rng = np.random.default_rng(1)
    R = 512
    acfg = pipeline.ASDRConfig(candidates=(8, 16, 32), block_size=64,
                               sort_by_opacity=by_opacity)
    counts = jnp.asarray(rng.choice([8, 16, 32, 96], size=(R,)), jnp.int32)
    opacity = jnp.asarray(rng.uniform(size=(R,)), jnp.float32)
    order, budgets = pipeline.block_sort(acfg, counts, opacity)
    order_np = np.asarray(order)
    assert sorted(order_np.tolist()) == list(range(R))     # permutation
    inv = np.zeros(R, np.int64)
    inv[order_np] = np.arange(R)
    np.testing.assert_array_equal(order_np[inv], np.arange(R))
    np.testing.assert_array_equal(inv[order_np], np.arange(R))
    # budgets conservative: every ray's count <= its block budget
    sorted_counts = np.asarray(counts)[order_np].reshape(-1, 64)
    assert (sorted_counts.max(axis=1) == np.asarray(budgets)).all()


def test_pose_distance_and_dilation_radius():
    cam_a = scene.look_at_camera(16, 16, theta=0.7, phi=0.5)
    cam_b = scene.look_at_camera(16, 16, theta=0.75, phi=0.5)
    ang, tr = adaptive.pose_distance(cam_a, cam_a)
    assert ang == 0.0 and tr == 0.0
    ang_ab, tr_ab = adaptive.pose_distance(cam_a, cam_b)
    assert ang_ab > 0.0 and tr_ab > 0.0
    assert adaptive.reuse_dilation_radius(cam_a, 0.0, 0.0, scene.NEAR) == 0
    r_small = adaptive.reuse_dilation_radius(cam_a, 1e-4, 0.0, scene.NEAR)
    assert r_small == 0                      # sub-half-pixel noise
    r_big = adaptive.reuse_dilation_radius(cam_a, ang_ab, tr_ab, scene.NEAR)
    assert r_big >= 1
    # wide-FOV camera: the corner term must grow the bound, never shrink it
    wide = scene.look_at_camera(16, 16, theta=0.7, phi=0.5, fov_deg=90.0)
    assert (adaptive.reuse_dilation_radius(wide, 0.05, 0.0, scene.NEAR)
            >= adaptive.reuse_dilation_radius(cam_a, 0.05, 0.0, scene.NEAR))
    # an in-plane roll keeps the view direction but permutes every pixel:
    # the full-rotation metric must see it as a large distance
    rolled = scene.Camera(
        cam_a.height, cam_a.width, cam_a.focal,
        np.stack([cam_a.c2w_rot[:, 1], -cam_a.c2w_rot[:, 0],
                  cam_a.c2w_rot[:, 2]], axis=-1),
        cam_a.origin)
    ang_roll, tr_roll = adaptive.pose_distance(cam_a, rolled)
    assert ang_roll > np.deg2rad(45) and tr_roll == 0.0


def test_probe_cache_rejects_different_focal(setup):
    """Same pose, different zoom: every ray differs — must re-probe."""
    flds, cam = setup
    fns = flds["mic"]
    cache = pipeline.ProbeCache(pipeline.ProbeReuseConfig())
    pipeline.probe_phase_cached(fns, ACFG, cam, cache)
    zoomed = scene.Camera(cam.height, cam.width, cam.focal * 1.5,
                          cam.c2w_rot, cam.origin)
    *_, reused = pipeline.probe_phase_cached(fns, ACFG, zoomed, cache)
    assert not reused


def test_dilate_count_map_is_conservative():
    counts = jnp.asarray(np.random.default_rng(2).choice(
        [8, 16, 32], size=(64,)), jnp.int32)
    out = adaptive.dilate_count_map(counts, (8, 8), 1)
    assert (np.asarray(out) >= np.asarray(counts)).all()    # max filter
    np.testing.assert_array_equal(
        np.asarray(adaptive.dilate_count_map(counts, (8, 8), 0)),
        np.asarray(counts))
    # a uniform map is a fixed point at any radius
    uni = jnp.full((64,), 16, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(adaptive.dilate_count_map(uni, (8, 8), 2)), np.asarray(uni))
    # border_fill covers content entering from off-screen: the radius-wide
    # band rises to at least the fill, the interior is untouched
    bf = np.asarray(adaptive.dilate_count_map(uni, (8, 8), 1,
                                              border_fill=96)).reshape(8, 8)
    assert (bf[0] == 96).all() and (bf[-1] == 96).all()
    assert (bf[:, 0] == 96).all() and (bf[:, -1] == 96).all()
    assert (bf[1:-1, 1:-1] == 16).all()


def test_probe_cache_rejects_different_acfg(setup):
    """Count maps are acfg-specific: a changed delta/candidates must not
    serve the stale maps."""
    flds, cam = setup
    fns = flds["mic"]
    cache = pipeline.ProbeCache(pipeline.ProbeReuseConfig())
    pipeline.probe_phase_cached(fns, ACFG, cam, cache)
    import dataclasses
    loose = dataclasses.replace(ACFG, delta=0.1)
    *_, reused = pipeline.probe_phase_cached(fns, loose, cam, cache)
    assert not reused
    # same acfg still hits
    *_, reused = pipeline.probe_phase_cached(fns, loose, cam, cache)
    assert reused


def test_streaming_dispatch_bit_identical(setup):
    """inflight_batches > 1 changes only WHEN batches launch, never what
    they compute: frames and deterministic counters must match the
    one-batch-per-round engine exactly, while the streaming engine's
    rounds actually carry multiple batches."""
    from repro.serve import stats as stats_lib
    flds, cam = setup
    reqs = lambda: [RenderRequest(rid=i, scene=s, cam=cam)
                    for i, s in enumerate(["mic", "hotdog", "mic",
                                           "hotdog"])]
    mk = lambda n: RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=4, blocks_per_batch=2, reuse=None, inflight_batches=n))
    one, many = mk(1), mk(3)
    d1 = {r.rid: r for r in one.render(reqs())}
    dn = {r.rid: r for r in many.render(reqs())}
    for rid in d1:
        np.testing.assert_array_equal(d1[rid].image, dn[rid].image)
    s1, sn = one.engine_stats(), many.engine_stats()
    for k in stats_lib.DETERMINISTIC_COUNTERS:
        assert s1[k] == sn[k], k
    # the streaming engine really ran multi-batch rounds
    assert max(sn["batches_per_round"]) > 1
    assert max(s1["batches_per_round"]) == 1


def test_march_round_observability(setup):
    """engine_stats() must expose the round ledger: wall-time percentiles
    and a batches-per-round histogram whose mass equals the batch count."""
    flds, cam = setup
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=2, blocks_per_batch=4, reuse=None, inflight_batches=2))
    eng.render([RenderRequest(rid=0, scene="mic", cam=cam),
                RenderRequest(rid=1, scene="hotdog", cam=cam)])
    st = eng.engine_stats()
    assert st["march_rounds"] > 0
    assert st["march_ms_p50"] > 0.0 and st["march_ms_p99"] > 0.0
    hist = st["batches_per_round"]
    assert hist and sum(k * v for k, v in hist.items()) == st["batches"]
    assert sum(hist.values()) == st["march_rounds"]


def test_engine_stats_expose_pack_cache(setup):
    """engine_stats() surfaces the kernels weight-pack memoization
    ledger (a process-wide LRU) and tracks its hit/miss accounting."""
    from repro.core.model import NGPConfig, init_ngp
    from repro.kernels import ops
    import jax
    flds, cam = setup
    eng = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=1, blocks_per_batch=2, reuse=None))
    st0 = eng.engine_stats()
    for k in ("pack_cache_hits", "pack_cache_misses", "pack_cache_size"):
        assert k in st0, k
    direct = ops.pack_cache_stats()
    assert st0["pack_cache_hits"] == direct["hits"]
    assert st0["pack_cache_misses"] == direct["misses"]
    # a fresh params dict is a miss, re-packing it is a hit — both must
    # show up in the engine's ledger exactly
    cfg = NGPConfig.small()
    params = init_ngp(jax.random.PRNGKey(42), cfg)
    ops.packed_weights(params["mlps"], cfg.net)
    ops.packed_weights(params["mlps"], cfg.net)
    st1 = eng.engine_stats()
    assert st1["pack_cache_misses"] == st0["pack_cache_misses"] + 1
    assert st1["pack_cache_hits"] == st0["pack_cache_hits"] + 1
    assert st1["pack_cache_size"] >= 1


def test_ray_exit_skip_counter(setup):
    """pool.collect prices per-ray early exit: with the flag on, the
    gap between each block's chunk count and its rays' live-chunk
    counts lands in ``ray_exit_samples_skipped`` (chunk samples per
    skipped ray-chunk); with the flag off the counter stays zero."""
    import dataclasses
    import time as time_lib
    from repro.serve import pool as pool_lib, stats as stats_lib

    class _FakeReq:
        rid, scene = 0, "mic"

    class _FakeSlot:
        req = _FakeReq()

        def deliver(self, bi, rgb, acc, depth, chunks, cached=False):
            pass

    B = 4
    acfg = dataclasses.replace(ACFG, block_size=B, per_ray_early_exit=True)
    counters = stats_lib.EngineCounters()
    pool = pool_lib.BlockPool(acfg, 2, None, counters)
    slot = _FakeSlot()
    batch = [(slot, 0, None, None, 64, None, None, False)]
    out = (np.zeros((2, B, 3)), np.zeros((2, B)), np.zeros((2, B)),
           np.asarray([4, 1]),                      # block chunks (1 pad)
           np.asarray([[4, 2, 1, 4], [1, 1, 1, 1]]))  # per-ray chunks
    pool.collect((batch, [], 1, out, 1, None, time_lib.perf_counter()))
    # real block: (4-4)+(4-2)+(4-1)+(4-4) = 5 skipped ray-chunks; the
    # pad block's gap must NOT count
    assert counters.ray_exit_samples_skipped == 5 * acfg.chunk
    # flag off: identical collect books nothing
    counters2 = stats_lib.EngineCounters()
    pool2 = pool_lib.BlockPool(ACFG, 2, None, counters2)
    pool2.collect((batch, [], 1, out, 2, None, time_lib.perf_counter()))
    assert counters2.ray_exit_samples_skipped == 0
    assert "ray_exit_samples_skipped" in stats_lib.engine_stats(
        counters, {}, {}, None)


def test_density_refresh_enables_radiance_chaining(setup):
    """Opt-in density refresh: partially-warped frames re-march their
    warp-valid rays color-free, recovering marched acc/depth — so they
    enter the radiance cache and later frames can warp FROM them.
    Without it, warps never chain (each hit must reach a fully-marched
    frame) and the later hits become misses."""
    flds, _ = setup
    from repro.serve.render_engine import RadianceReuseConfig
    def traj():
        return [RenderRequest(rid=i, scene="mic",
                              cam=scene.look_at_camera(
                                  32, 32, theta=0.7 + 0.025 * i, phi=0.5))
                for i in range(4)]
    mk = lambda refresh: RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=1, blocks_per_batch=4, prefetch=0,
        reuse=pipeline.ProbeReuseConfig(),
        radiance=RadianceReuseConfig(),
        density_refresh=refresh))
    base, refr = mk(False), mk(True)
    db = {r.rid: r for r in base.render(traj())}
    dr = {r.rid: r for r in refr.render(traj())}
    sb, sr = base.engine_stats(), refr.engine_stats()
    # chaining: the refreshed engine converts later misses into hits
    assert sr["radiance_hits"] > sb["radiance_hits"]
    assert any(r.stats.get("density_rays", 0) > 0 for r in dr.values())
    # refreshed frames march FEWER color rays overall, not more quality
    # loss: every frame stays close to the never-reuse render
    full = RenderServingEngine(flds, ACFG, RenderServeConfig(
        slots=1, blocks_per_batch=4, reuse=None))
    df = {r.rid: r for r in full.render(traj())}
    from repro.core import rendering
    for rid in dr:
        p = float(rendering.psnr(jnp.asarray(dr[rid].image),
                                 jnp.asarray(df[rid].image)))
        assert p > 30.0, (rid, p)
