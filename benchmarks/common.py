"""Shared benchmark setup: train-once NGP cache + standard cameras.

Every benchmark renders through the same trained model so numbers are
comparable across tables.  Training is cached on disk (first run ~2 min on
this CPU); `--quick` uses fewer steps.
"""
from __future__ import annotations

import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib, pipeline, scene
from repro.core import train as train_lib
# the repo's ONE percentile implementation (nearest-rank, obs/metrics.py)
# — benches import it from here instead of keeping per-bench copies
from repro.obs.metrics import percentile  # noqa: F401

CACHE = Path(__file__).resolve().parent / "_cache"
CACHE.mkdir(exist_ok=True)

OUT_DIR = Path(__file__).resolve().parent.parent / "out" / "bench"

SCENES = ("lego", "hotdog", "mic")
EVAL_CAM = dict(theta=0.9, phi=0.55)
IMG_HW = (64, 64)
NS_FULL = 96
CANDIDATES = (12, 24, 48)


def emit_rows(stem: str, rows):
    """Append rows to out/bench/<stem>.json (a flat list across runs).

    Shared by the serving benchmarks (render_serve.py, scene_cache.py) so
    the JSON-append semantics — tolerate a corrupt file, extend, rewrite —
    stay identical everywhere.
    """
    import json
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{stem}.json"
    existing = []
    if path.exists():
        try:
            existing = json.loads(path.read_text())
        except json.JSONDecodeError:
            existing = []
    existing.extend(rows)
    path.write_text(json.dumps(existing, indent=1))
    print(f"  [json] {len(rows)} rows -> {path} ({len(existing)} total)")


def serve_bench_acfg(block: int = 128) -> "pipeline.ASDRConfig":
    """The serving benchmarks' shared render config.

    sort_by_opacity off: argsort(counts) is stable, so identical count
    maps give bit-identical block layouts — zero-distance reuse frames
    then match the always-probe baseline exactly (both the replay and
    the scene-cache benchmarks gate on this).
    """
    return pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, candidates=(12, 24, 48),
        block_size=block, chunk=16, sort_by_opacity=False)


def trained_model(scene_name: str, quick: bool = False):
    """Returns (params, cfg). Cached on disk keyed by scene+settings."""
    steps = 80 if quick else 300
    key = f"{scene_name}_s{steps}"
    path = CACHE / f"ngp_{key}.pkl"
    if path.exists():
        with open(path, "rb") as f:
            params, cfg = pickle.load(f)
        params = jax.tree.map(jnp.asarray, params)
        return params, cfg
    tcfg = train_lib.NGPTrainConfig(
        scene=scene_name, steps=steps, batch_rays=1024, n_samples=48,
        n_views=8, view_hw=(72, 72), log_every=100,
    )
    params, cfg, _, _ = train_lib.train_ngp(tcfg, verbose=True)
    host = jax.tree.map(lambda x: np.asarray(x), params)
    with open(path, "wb") as f:
        pickle.dump((host, cfg), f)
    return params, cfg


def eval_setup(scene_name: str, quick: bool = False):
    """(fns, cfg, cam, reference image) for the eval view."""
    params, cfg = trained_model(scene_name, quick)
    fns = model_lib.field_fns(params, cfg)
    field = scene.make_scene(scene_name)
    cam = scene.look_at_camera(*IMG_HW, **EVAL_CAM)
    o, d = scene.camera_rays(cam)
    ref, _ = scene.render_reference(field, o, d)
    ref_img = ref.reshape(*IMG_HW, 3)
    return fns, cfg, cam, ref_img


def baseline_image(fns, cam, ns=NS_FULL):
    o, d = scene.camera_rays(cam)
    rgb, _ = pipeline.render_fixed_fns(fns, o, d, ns)
    return rgb.reshape(cam.height, cam.width, 3)


def timer(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warm up / compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.time() - t0) / repeats
