"""Fig. 18 — per-phase (encoding / MLP) work before vs after ASDR.

The paper reports larger speedups in encoding than MLP because data
mapping/reuse attacks gather traffic; we report the same split in work
units: embedding-gather bytes (encoding) and MLP FLOPs.
"""
from __future__ import annotations


from repro.core import pipeline, reuse, scene
from repro.core.mlp import flops_per_sample

from . import common


def run(quick: bool = False):
    fns, cfg, cam, _ = common.eval_setup("lego", quick)
    o, d = scene.camera_rays(cam)
    R = o.shape[0]
    ns = common.NS_FULL

    acfg = pipeline.ASDRConfig(ns_full=ns, probe_stride=4,
                               candidates=common.CANDIDATES,
                               block_size=256, chunk=16)
    _, stats = pipeline.render_asdr_image(fns, acfg, cam)

    base_samples = R * ns
    asdr_samples = float(stats["samples_processed"]) + stats["probe_samples"]

    # encoding phase: gather bytes, with and without tile-dedup (register
    # cache analogue, §5.2.2)
    pts, _, _ = scene.sample_points(o[:64], d[:64], ns)
    dedup = reuse.dedup_window_rate(
        pts.reshape(-1, 3), cfg.grid, window=32, level=0)
    enc_base = reuse.gather_bytes(base_samples, cfg.grid)
    enc_asdr = reuse.gather_bytes(asdr_samples, cfg.grid, dedup_rate=dedup)

    f = flops_per_sample(cfg.net)
    mlp_base = base_samples * (f["density_flops"] + f["color_flops"])
    mlp_asdr = (asdr_samples * f["density_flops"]
                + asdr_samples / acfg.group * f["color_flops"])
    return {
        "encoding_bytes_baseline": enc_base,
        "encoding_bytes_asdr": enc_asdr,
        "encoding_speedup": enc_base / enc_asdr,
        "mlp_flops_baseline": mlp_base,
        "mlp_flops_asdr": mlp_asdr,
        "mlp_speedup": mlp_base / mlp_asdr,
        "tile_dedup_rate_L0": dedup,
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v:.4g}")
    return r
