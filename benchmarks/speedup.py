"""Fig. 17 / Fig. 24 analogue — speedup without CIM hardware.

The paper's silicon speedups (9.55x/69.75x) need ReRAM; its
software-only GPU figure (Fig. 24: AS = 1.84x, AS+RA = 2.75x) is the
reproducible claim.  We report (a) algorithmic work reduction (samples
marched, color-MLP evals, embedding gathers) and (b) measured CPU
wall-clock of the jitted renderers.
"""
from __future__ import annotations

import jax

from repro.core import pipeline, scene

from . import common


def run(quick: bool = False):
    sc = "lego"
    fns, cfg, cam, ref = common.eval_setup(sc, quick)
    o, d = scene.camera_rays(cam)
    R = o.shape[0]
    ns = common.NS_FULL

    acfg = pipeline.ASDRConfig(
        ns_full=ns, probe_stride=4, candidates=common.CANDIDATES,
        block_size=256, chunk=16,
    )
    img, stats = pipeline.render_asdr_image(fns, acfg, cam)

    # ---- work accounting ----
    base_samples = R * ns
    asdr_samples = float(stats["samples_processed"]) + stats["probe_samples"]
    sample_speedup = base_samples / asdr_samples
    # color-MLP evals: baseline = every sample; ASDR = anchors only
    base_color = base_samples
    asdr_color = asdr_samples / acfg.group + stats["probe_samples"]
    from repro.core.mlp import flops_per_sample
    f = flops_per_sample(cfg.net)
    base_flops = base_samples * (f["density_flops"] + f["color_flops"])
    asdr_flops = (asdr_samples * f["density_flops"]
                  + asdr_color * f["color_flops"])

    # ---- wall clock (jitted, CPU) ----
    fixed = jax.jit(lambda oo, dd: pipeline.render_fixed_fns(fns, oo, dd, ns)[0])
    t_base = common.timer(fixed, o, d)
    t_asdr = common.timer(
        lambda: pipeline.render_asdr_image(fns, acfg, cam)[0], repeats=2)

    return {
        "sample_reduction": sample_speedup,
        "mlp_flop_reduction": base_flops / asdr_flops,
        "color_eval_reduction": base_color / asdr_color,
        "wallclock_baseline_s": t_base,
        "wallclock_asdr_s": t_asdr,
        "wallclock_speedup": t_base / t_asdr,
        "paper_sw_only_AS": 1.84,
        "paper_sw_only_AS_RA": 2.75,
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v:.3f}" if isinstance(v, float) else f"{k},{v}")
    return r
