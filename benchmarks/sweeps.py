"""Fig. 21 — design-space sweeps: adaptive threshold delta, group size n."""
from __future__ import annotations


from repro.core import decouple, pipeline, rendering, scene

from . import common


def run(quick: bool = False):
    fns, cfg, cam, ref = common.eval_setup("lego", quick)
    o, d = scene.camera_rays(cam)
    base = common.baseline_image(fns, cam)
    p_base = float(rendering.psnr(base, ref))

    deltas = [1.0 / 512, 1.0 / 1024, 1.0 / 2048, 1.0 / 4096, 0.0]
    delta_rows = []
    for dl in deltas:
        acfg = pipeline.ASDRConfig(
            ns_full=common.NS_FULL, probe_stride=4, delta=dl,
            candidates=common.CANDIDATES, block_size=256, chunk=16,
        )
        img, stats = pipeline.render_asdr_image(fns, acfg, cam)
        delta_rows.append({
            "delta": dl,
            "avg_samples": float(stats["avg_samples_per_ray"]),
            "sample_reduction": float(stats["sample_reduction"]),
            "psnr": float(rendering.psnr(img, ref)),
            "psnr_drop_vs_base": p_base - float(rendering.psnr(img, ref)),
        })

    group_rows = []
    for n in (1, 2, 4, 8):
        img, stats = decouple.render_decoupled(
            fns, o, d, common.NS_FULL, group=n)
        img = img.reshape(*common.IMG_HW, 3)
        group_rows.append({
            "group": n,
            "color_eval_fraction": stats["color_eval_fraction"],
            "psnr": float(rendering.psnr(img, ref)),
            "psnr_drop_vs_base": p_base - float(rendering.psnr(img, ref)),
            "mlp_reduction": decouple.mlp_flops_saved(
                cfg, common.NS_FULL, n)["reduction_fraction"],
        })
    return {"delta_sweep": delta_rows, "group_sweep": group_rows,
            "psnr_baseline": p_base}


def main(quick: bool = False):
    r = run(quick)
    print("## delta sweep (Fig 21a)")
    print("delta,avg_samples,reduction,psnr,psnr_drop")
    for row in r["delta_sweep"]:
        print(f"{row['delta']:.6f},{row['avg_samples']:.1f},"
              f"{row['sample_reduction']:.2f},{row['psnr']:.2f},"
              f"{row['psnr_drop_vs_base']:.3f}")
    print("## group-size sweep (Fig 21b)")
    print("n,color_frac,psnr,psnr_drop,mlp_reduction")
    for row in r["group_sweep"]:
        print(f"{row['group']},{row['color_eval_fraction']:.3f},"
              f"{row['psnr']:.2f},{row['psnr_drop_vs_base']:.3f},"
              f"{row['mlp_reduction']:.3f}")
    return r
