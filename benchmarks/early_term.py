"""Fig. 23 — adaptive sampling x early termination (orthogonal savings)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pipeline, rendering, scene

from . import common


def _samples(fns, cam, adaptive: bool, early: bool):
    acfg = pipeline.ASDRConfig(
        ns_full=common.NS_FULL, probe_stride=4,
        candidates=common.CANDIDATES if adaptive else (common.NS_FULL,),
        delta=(1.0 / 2048.0 if adaptive else -1.0),  # delta<0: nothing passes
        block_size=256, chunk=16, early_termination=early,
    )
    img, stats = pipeline.render_asdr_image(fns, acfg, cam)
    total = float(stats["samples_processed"]) + stats["probe_samples"]
    return img, total


def run(quick: bool = False):
    fns, cfg, cam, ref = common.eval_setup("lego", quick)
    img_straw, straw = _samples(fns, cam, adaptive=False, early=False)
    img_et, et = _samples(fns, cam, adaptive=False, early=True)
    img_as, asamp = _samples(fns, cam, adaptive=True, early=False)
    img_both, both = _samples(fns, cam, adaptive=True, early=True)

    # ideal per-ray ET accounting (GPU/CIM granularity, paper's setting) —
    # how much a per-ray exit would save on this scene
    o, d = scene.camera_rays(cam)
    _, aux = pipeline.render_fixed_fns(fns, o, d, common.NS_FULL)
    al = rendering.alphas_from_sigmas(aux["sigmas"], aux["deltas"])
    needed = rendering.early_termination_counts(al)
    ideal_et = common.NS_FULL / float(jnp.mean(needed))
    frac_saturating = float(jnp.mean((1.0 - aux["acc"]) < 1e-4))

    return {
        "strawman_samples": straw,
        "et_speedup": straw / et,
        "as_speedup": straw / asamp,
        "as_et_speedup": straw / both,
        "ideal_per_ray_et_speedup": ideal_et,
        "frac_rays_saturating": frac_saturating,
        "psnr_strawman": float(rendering.psnr(img_straw, ref)),
        "psnr_combined": float(rendering.psnr(img_both, ref)),
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value  # paper Fig23: ET 3.67x, AS 4.4x, AS+ET 11.07x")
    for k, v in r.items():
        print(f"{k},{v:.3f}")
    return r
