"""Render-serve benchmark: trajectory throughput + probe-reuse quality.

  PYTHONPATH=src python benchmarks/render_serve.py [--poses 12] [--size 48]

Serves an orbit trajectory of ``--poses`` unique poses replayed for
``--laps`` laps (an orbit playback / several users watching the same path —
the Cicero-style cross-view reuse workload) through the batched render
serving engine twice — once with cross-frame probe reuse, once always
probing — and reports:

  * frames/sec for each path (reuse removes Phase-I from most frames),
  * the reused-probe fraction (acceptance: > 0.5),
  * per-frame PSNR vs the exact analytic reference for both paths and the
    worst-case delta between them (acceptance: within 0.1 dB).

Lap 1 probes each pose; later laps hit the cache at zero pose distance,
where reuse returns the identical count map (dilation radius 0) and the
stable count sort gives a bit-identical block layout — so reused frames
match the always-probe baseline exactly, not just within tolerance.
``--dtheta-jitter`` offsets each lap's poses to exercise the near-pose
path instead (conservative dilated count maps; PSNR deltas become nonzero
and are reported, not gated).

The analytic field makes the PSNR comparison exact-reference (no training
error in the way), matching the repo's claim structure.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import fields, pipeline, rendering, scene
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)


def trajectory_requests(scene_name, poses, laps, size, dtheta, jitter=0.0):
    reqs = []
    for lap in range(laps):
        for i in range(poses):
            theta = 0.55 + dtheta * i + jitter * lap
            reqs.append(RenderRequest(
                rid=lap * poses + i, scene=scene_name,
                cam=scene.look_at_camera(size, size, theta=theta, phi=0.5)))
    return reqs


def run_engine(flds, acfg, rcfg, reqs):
    # warm-up engine compiles the march; the shared module-level march
    # cache keeps the timed engine's clock free of compile time
    RenderServingEngine(flds, acfg, rcfg).render([reqs[0]])
    eng = RenderServingEngine(flds, acfg, rcfg)
    t0 = time.time()
    done = eng.render(list(reqs))
    dt = time.time() - t0
    return done, dt, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="mic")
    ap.add_argument("--poses", type=int, default=8,
                    help="unique poses per lap")
    ap.add_argument("--laps", type=int, default=3)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--dtheta", type=float, default=0.04,
                    help="orbit step in radians (~2.3 deg)")
    ap.add_argument("--dtheta-jitter", type=float, default=0.0,
                    help="per-lap pose offset (rad): >0 exercises the "
                         "near-pose dilated-reuse path")
    args = ap.parse_args()
    assert args.poses >= 8, "acceptance: trajectory must have >= 8 poses"

    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    # sort_by_opacity off: argsort(counts) is stable, so identical count
    # maps give bit-identical block layouts — zero-distance reuse frames
    # then match the always-probe baseline exactly
    acfg = pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, candidates=(12, 24, 48),
        block_size=128, chunk=16, sort_by_opacity=False)

    def traj():
        return trajectory_requests(args.scene, args.poses, args.laps,
                                   args.size, args.dtheta,
                                   args.dtheta_jitter)

    reuse_cfg = RenderServeConfig(
        slots=4, blocks_per_batch=16,
        reuse=pipeline.ProbeReuseConfig(max_angle_deg=1.0,
                                        max_translation=0.02,
                                        refresh_every=0))
    probe_cfg = RenderServeConfig(slots=4, blocks_per_batch=16, reuse=None)

    reqs = traj()
    done_r, dt_r, eng_r = run_engine(flds, acfg, reuse_cfg, reqs)
    done_p, dt_p, _ = run_engine(flds, acfg, probe_cfg, traj())

    # exact analytic reference per pose
    by_rid_r = {r.rid: r for r in done_r}
    by_rid_p = {r.rid: r for r in done_p}
    deltas, psnrs_r, psnrs_p = [], [], []
    for rq in reqs:
        o, d = scene.camera_rays(rq.cam)
        ref, _ = scene.render_reference(field, o, d)
        ref = np.asarray(ref).reshape(args.size, args.size, 3)
        pr = float(rendering.psnr(by_rid_r[rq.rid].image, ref))
        pp = float(rendering.psnr(by_rid_p[rq.rid].image, ref))
        psnrs_r.append(pr)
        psnrs_p.append(pp)
        deltas.append(abs(pr - pp))

    st = eng_r.engine_stats()
    frac = st["reused_probe_fraction"]
    max_delta = max(deltas)
    print(f"== render_serve bench: {args.poses}-pose orbit x {args.laps} "
          f"laps = {len(reqs)} frames, {args.size}x{args.size}, "
          f"scene={args.scene} ==")
    print(f"  fps   reuse        : {len(done_r)/dt_r:6.2f}  ({dt_r:.2f}s)")
    print(f"  fps   always-probe : {len(done_p)/dt_p:6.2f}  ({dt_p:.2f}s)")
    print(f"  reused-probe fraction: {frac:.3f} "
          f"({st['probe_hits']} hits, {st['probe_misses']} probes, "
          f"{st['probe_refreshes']} refreshes)")
    print(f"  PSNR vs reference (reuse)        : "
          f"mean {np.mean(psnrs_r):.2f} dB  min {min(psnrs_r):.2f} dB")
    print(f"  PSNR vs reference (always-probe) : "
          f"mean {np.mean(psnrs_p):.2f} dB  min {min(psnrs_p):.2f} dB")
    print(f"  per-frame |PSNR delta|: mean {np.mean(deltas):.4f} dB  "
          f"max {max_delta:.4f} dB")
    if args.dtheta_jitter > 0:
        # near-pose mode: dilated maps oversample, so reuse PSNR sits AT OR
        # ABOVE the baseline; the exact-delta gate applies to replay only
        worse = min(pr - pp for pr, pp in zip(psnrs_r, psnrs_p))
        ok = frac > 0.5 and worse > -0.1
        print(f"  near-pose acceptance (fraction>0.5, reuse no more than "
              f"0.1 dB below baseline): {'OK' if ok else 'FAIL'}")
    else:
        ok = frac > 0.5 and max_delta < 0.1
        print(f"  acceptance (fraction>0.5, max delta<0.1 dB): "
              f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
