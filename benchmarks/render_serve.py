"""Render-serve benchmark: cross-frame reuse throughput + quality gates.

  PYTHONPATH=src python benchmarks/render_serve.py            # replay gate
  PYTHONPATH=src python benchmarks/render_serve.py --sweep    # radius sweep
  PYTHONPATH=src python benchmarks/render_serve.py --latency  # p50/p99 vs slots

Default (replay) mode — the Cicero-style cross-view reuse workload: an
orbit of ``--poses`` unique poses replayed for ``--laps`` laps through the
batched serving engine with BOTH framecache tiers on (warped probe maps +
warped radiance), against an always-probe/no-reuse run.  Gates:

  * Phase-II rays-marched fraction < 0.5 of the no-reuse run (laps 2+
    warp the cached frames and march only disoccluded rays — on an exact
    replay that is zero rays),
  * per-frame |PSNR delta| vs the no-reuse run <= 0.1 dB,
  * reused-probe fraction > 0.5 (hits + SKIPS over admissions — a full
    radiance hit pays no probe at all under radiance-first admission),
  * every full-radiance-hit frame ran ZERO probe rays (probe_samples 0,
    Phase I skipped) and probes + skips == admissions,
  * per-frame admission stall p99 with the double-buffered pipeline
    (prefetch=2, default) no worse than a synchronous prefetch=0 run —
    whose frames must also match bit-exactly (prefetch determinism).

--sweep — reuse-radius sweep (ROADMAP item): per-lap pose jitter steps
through increasing pose deltas; three probe-transfer modes run the same
trajectory (warped / dilation-only / always-probe) and each (jitter, mode)
emits a JSON row with the reused fraction and PSNR delta.  Gate: the
warped path must sustain reuse (lap-2 reuse >= 0.9 at worst signed delta
>= -0.1 dB) at a pose radius >= 2x the dilation-only path's — the PR that
introduced warping exists to beat the ~4-degree dilation cap.

--latency — multi-client latency distribution (ROADMAP item): interleaved
two-scene request streams at several slot counts; emits p50/p99/mean
per-frame latency JSON rows.

--workers — threaded-executor gate + stall sweep (ROADMAP item): the
replay trajectory runs under the synchronous executor (workers=0) and a
4-worker ThreadedExecutor.  Gates: frames bit-identical (so the PSNR
delta is exactly 0.0 dB), every deterministic counter identical, and
the threaded admission-stall p99 no worse than the synchronous baseline.
A workers x prefetch sweep emits admission-stall percentile rows.

All modes append rows to out/bench/render_serve_<mode>.json.  The analytic
field makes PSNR comparisons exact-reference, matching the repo's claim
structure.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from common import emit_rows as _emit_rows, percentile, serve_bench_acfg
from repro.core import adaptive, fields, rendering, scene
from repro.framecache import ProbeReuseConfig, RadianceReuseConfig
from repro.obs import TraceConfig
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine, RequestClass)
from repro.serve.stats import DETERMINISTIC_COUNTERS


def emit_rows(name: str, rows):
    _emit_rows(f"render_serve_{name}", rows)


def trajectory_requests(scene_name, poses, laps, size, dtheta, jitter=0.0):
    reqs = []
    for lap in range(laps):
        for i in range(poses):
            theta = 0.55 + dtheta * i + jitter * lap
            reqs.append(RenderRequest(
                rid=lap * poses + i, scene=scene_name,
                cam=scene.look_at_camera(size, size, theta=theta, phi=0.5)))
    return reqs


def run_engine(flds, acfg, rcfg, reqs):
    # warm-up engine compiles the march; the shared module-level march
    # cache keeps the timed engine's clock free of compile time (closed:
    # a threaded config would otherwise leak its worker pool)
    warm = RenderServingEngine(flds, acfg, rcfg)
    warm.render([reqs[0]])
    warm.close()
    eng = RenderServingEngine(flds, acfg, rcfg)
    t0 = time.time()
    done = eng.render(list(reqs))
    dt = time.time() - t0
    return done, dt, eng


def reference_frames(field, reqs, size):
    """Exact 512-sample analytic reference per pose — computed ONCE per
    trajectory and shared across the modes that replay it (the reference
    march dominates non-engine bench cost)."""
    refs = {}
    for rq in reqs:
        o, d = scene.camera_rays(rq.cam)
        ref, _ = scene.render_reference(field, o, d)
        refs[rq.rid] = np.asarray(ref).reshape(size, size, 3)
    return refs


def psnr_per_frame(refs, done, reqs):
    by_rid = {r.rid: r for r in done}
    return [float(rendering.psnr(by_rid[rq.rid].image, refs[rq.rid]))
            for rq in reqs]


make_acfg = serve_bench_acfg


# ---------------------------------------------------------------- replay
def run_replay(args):
    assert args.poses >= 8, "acceptance: trajectory must have >= 8 poses"
    # with L laps a perfect run marches exactly 1/L of the no-reuse rays
    # and reuses (L-1)/L of the probes: L=2 sits ON both gate boundaries
    # (0.5 vs strict < / >), so the gates are only meaningful from 3 laps
    assert args.laps >= 3, "acceptance gates need --laps >= 3"
    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    acfg = make_acfg()

    def traj():
        return trajectory_requests(args.scene, args.poses, args.laps,
                                   args.size, args.dtheta)

    reuse_cfg = RenderServeConfig(
        slots=4, blocks_per_batch=16,
        reuse=ProbeReuseConfig(max_angle_deg=1.0, max_translation=0.02,
                               refresh_every=0),
        radiance=RadianceReuseConfig(max_angle_deg=1.0, max_translation=0.02,
                                     refresh_every=0))
    none_cfg = RenderServeConfig(slots=4, blocks_per_batch=16, reuse=None)

    reqs = traj()
    done_r, dt_r, eng_r = run_engine(flds, acfg, reuse_cfg, reqs)
    done_p, dt_p, eng_p = run_engine(flds, acfg, none_cfg, traj())
    # synchronous-admission baseline: same reuse config, prefetch off —
    # frames must match the double-buffered run bit-exactly, and the
    # double-buffered admission stall must not regress past it
    sync_cfg = dataclasses.replace(reuse_cfg, prefetch=0)
    done_s, _dt_s, _eng_s = run_engine(flds, acfg, sync_cfg, traj())

    refs = reference_frames(field, reqs, args.size)
    psnrs_r = psnr_per_frame(refs, done_r, reqs)
    psnrs_p = psnr_per_frame(refs, done_p, reqs)
    deltas = [abs(a - b) for a, b in zip(psnrs_r, psnrs_p)]

    st_r, st_p = eng_r.engine_stats(), eng_p.engine_stats()
    ray_frac = (st_r["rays_marched_fraction"]
                / max(st_p["rays_marched_fraction"], 1e-9))
    probe_frac = st_r["reused_probe_fraction"]
    max_delta = max(deltas)

    # radiance-first admission gates
    by_rid_s = {r.rid: r for r in done_s}
    prefetch_identical = all(
        np.array_equal(r.image, by_rid_s[r.rid].image) for r in done_r)
    full_hits = [r for r in done_r
                 if r.stats["radiance_reused"]
                 and r.stats["rays_marched"] == 0]
    full_hit_zero_probe = bool(full_hits) and all(
        r.stats["probe_samples"] == 0 and r.stats["probe_skipped"]
        for r in full_hits)
    counters_ok = (st_r["probe_hits"] + st_r["probe_misses"]
                   + st_r["probe_skips"] == st_r["admissions"])
    # timing gate over best-of-3 repetitions per side, like the workers
    # gate: p99 over a short replay IS the max frame stall, and both
    # configs only stall on lap-1 fresh probes (25-35 ms here), so a
    # single-run comparison is one sample of a noisy extreme — the
    # size-32 ok:false row in out/bench was exactly such an outlier
    # (misprepares 0, both sides statistically identical across reps)
    def stall_p99(done):
        return percentile(
            [r.stats["admit_stall_s"] * 1e3 for r in done], 99)

    p99s_r, p99s_s = [stall_p99(done_r)], [stall_p99(done_s)]
    for _ in range(2):
        d, _, e = run_engine(flds, acfg, reuse_cfg, traj())
        p99s_r.append(stall_p99(d))
        e.close()
        d, _, e = run_engine(flds, acfg, sync_cfg, traj())
        p99s_s.append(stall_p99(d))
        e.close()
    p99_r, p99_s = min(p99s_r), min(p99s_s)
    # "no worse" with a small epsilon + 10% headroom for timer noise
    admission_ok = p99_r <= p99_s * 1.10 + 0.5
    print(f"== render_serve replay: {args.poses}-pose orbit x {args.laps} "
          f"laps = {len(reqs)} frames, {args.size}x{args.size}, "
          f"scene={args.scene} ==")
    print(f"  fps   reuse    : {len(done_r)/dt_r:6.2f}  ({dt_r:.2f}s)")
    print(f"  fps   no-reuse : {len(done_p)/dt_p:6.2f}  ({dt_p:.2f}s)")
    print(f"  reused-probe fraction   : {probe_frac:.3f} "
          f"({st_r['probe_hits']} hits, {st_r['probe_skips']} skips, "
          f"{st_r['probe_misses']} probes)")
    print(f"  full-radiance-hit frames: {len(full_hits)} "
          f"(zero probe rays: {'yes' if full_hit_zero_probe else 'NO'})")
    print(f"  admission stall p99     : {p99_r:.2f} ms double-buffered vs "
          f"{p99_s:.2f} ms synchronous "
          f"(identical frames: {'yes' if prefetch_identical else 'NO'})")
    print(f"  reused-radiance fraction: "
          f"{st_r['reused_radiance_fraction']:.3f} "
          f"({st_r['radiance_hits']} hits)")
    print(f"  phase-II rays marched   : {st_r['rays_marched']} vs "
          f"{st_p['rays_marched']} no-reuse -> fraction {ray_frac:.3f}")
    print(f"  PSNR (reuse)    : mean {np.mean(psnrs_r):.2f} dB  "
          f"min {min(psnrs_r):.2f} dB")
    print(f"  PSNR (no-reuse) : mean {np.mean(psnrs_p):.2f} dB  "
          f"min {min(psnrs_p):.2f} dB")
    print(f"  per-frame |PSNR delta|: mean {np.mean(deltas):.4f} dB  "
          f"max {max_delta:.4f} dB")
    ok = (ray_frac < 0.5 and max_delta <= 0.1 and probe_frac > 0.5
          and full_hit_zero_probe and counters_ok and admission_ok
          and prefetch_identical)
    print(f"  acceptance (ray fraction<0.5, max delta<=0.1 dB, "
          f"probe fraction>0.5, full hits skip probe, "
          f"probes+skips==admissions, admission p99 no worse than sync): "
          f"{'OK' if ok else 'FAIL'}")
    emit_rows("replay", [{
        "bench": "replay", "scene": args.scene, "size": args.size,
        "poses": args.poses, "laps": args.laps,
        "fps_reuse": len(done_r) / dt_r, "fps_no_reuse": len(done_p) / dt_p,
        "reused_probe_fraction": probe_frac,
        "reused_radiance_fraction": st_r["reused_radiance_fraction"],
        "rays_marched_fraction_of_no_reuse": ray_frac,
        "mean_psnr_reuse": float(np.mean(psnrs_r)),
        "mean_psnr_no_reuse": float(np.mean(psnrs_p)),
        "max_abs_psnr_delta": max_delta, "ok": ok,
    }, {
        "bench": "replay_admission", "scene": args.scene, "size": args.size,
        "poses": args.poses, "laps": args.laps,
        "full_hit_frames": len(full_hits),
        "full_hit_zero_probe": full_hit_zero_probe,
        "probe_hits": st_r["probe_hits"],
        "probe_misses": st_r["probe_misses"],
        "probe_skips": st_r["probe_skips"],
        "admissions": st_r["admissions"],
        "counters_ok": counters_ok,
        "misprepares": st_r["misprepares"],
        "admission_stall_p99_ms_prefetch": p99_r,
        "admission_stall_p99_ms_sync": p99_s,
        "stall_gate_note": "best-of-3 p99 per side; p99 over a short "
                           "replay equals the max frame stall (lap-1 "
                           "fresh probes on both sides), so single-run "
                           "comparison is timer-noise dominated",
        "admission_ok": admission_ok,
        "prefetch_identical": prefetch_identical,
        "ok": (full_hit_zero_probe and counters_ok and admission_ok
               and prefetch_identical),
    }])
    return ok


# ----------------------------------------------------------------- sweep
SWEEP_JITTERS = (0.01, 0.02, 0.04, 0.06)   # per-lap pose offset, radians


def run_sweep(args):
    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    acfg = make_acfg()
    poses, laps = max(args.poses // 2, 4), 2
    # thresholds sit between the largest sweep jitter and the within-lap
    # pose spacing, so lap-2 frames can only reuse their own lap-1 pose
    dtheta = 0.08
    probe_cfg = dict(max_angle_deg=4.0, max_translation=0.07,
                     refresh_every=0)
    modes = {
        "warped": ProbeReuseConfig(warp=True, **probe_cfg),
        "dilated": ProbeReuseConfig(warp=False, **probe_cfg),
        "always": None,
    }

    rows = []
    sustained = {"warped": 0.0, "dilated": 0.0}
    print(f"== reuse-radius sweep: {poses} poses x {laps} laps, "
          f"{args.size}x{args.size}, modes warped/dilated/always ==")
    for jitter in SWEEP_JITTERS:
        # measured pose delta between a lap-1 pose and its lap-2 twin
        cam_a = scene.look_at_camera(args.size, args.size, theta=0.55,
                                     phi=0.5)
        cam_b = scene.look_at_camera(args.size, args.size,
                                     theta=0.55 + jitter, phi=0.5)
        ang, tr = adaptive.pose_distance(cam_a, cam_b)
        ang_deg = float(np.rad2deg(ang))

        reqs = trajectory_requests(args.scene, poses, laps, args.size,
                                   dtheta, jitter)
        refs = reference_frames(field, reqs, args.size)
        results = {}
        for mode, reuse in modes.items():
            rcfg = RenderServeConfig(slots=4, blocks_per_batch=16,
                                     reuse=reuse)
            done, dt, eng = run_engine(flds, acfg, rcfg,
                                       trajectory_requests(
                                           args.scene, poses, laps,
                                           args.size, dtheta, jitter))
            psnrs = psnr_per_frame(refs, done, reqs)
            lap2 = [r for r in done if r.rid >= poses]
            lap2_reused = (np.mean([r.stats["probe_reused"] for r in lap2])
                           if lap2 else 0.0)
            results[mode] = (psnrs, float(lap2_reused), dt, eng)
        base = results["always"][0]
        for mode in ("warped", "dilated", "always"):
            psnrs, lap2_reused, dt, eng = results[mode]
            worst = min(p - b for p, b in zip(psnrs, base))
            row = {
                "bench": "reuse_radius_sweep", "scene": args.scene,
                "size": args.size, "jitter_rad": jitter,
                "pose_delta_deg": ang_deg, "pose_delta_translation": tr,
                "mode": mode,
                "lap2_reused_fraction": lap2_reused,
                "reused_probe_fraction":
                    eng.engine_stats()["reused_probe_fraction"],
                "mean_psnr": float(np.mean(psnrs)),
                "worst_signed_delta_db": float(worst),
                "fps": len(reqs) / dt,
            }
            rows.append(row)
            if mode in sustained and lap2_reused >= 0.9 and worst >= -0.1:
                sustained[mode] = max(sustained[mode], ang_deg)
            print(f"  jitter {jitter:.3f} rad ({ang_deg:4.2f} deg) "
                  f"{mode:>8}: lap2 reuse {lap2_reused:.2f}  "
                  f"worst delta {worst:+.4f} dB  fps {len(reqs)/dt:5.2f}")

    ok = (sustained["warped"] >= 2.0 * sustained["dilated"]
          and sustained["dilated"] > 0.0)
    print(f"  sustained radius: warped {sustained['warped']:.2f} deg vs "
          f"dilated {sustained['dilated']:.2f} deg "
          f"(gate: warped >= 2x dilated): {'OK' if ok else 'FAIL'}")
    rows.append({"bench": "reuse_radius_gate",
                 "warped_radius_deg": sustained["warped"],
                 "dilated_radius_deg": sustained["dilated"], "ok": ok})
    emit_rows("sweep", rows)
    return ok


# --------------------------------------------------------------- workers
def run_workers(args):
    """Threaded-vs-sync executor gate + admission-stall sweep."""
    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    acfg = make_acfg()

    def traj():
        return trajectory_requests(args.scene, args.poses, args.laps,
                                   args.size, args.dtheta)

    base_cfg = RenderServeConfig(
        slots=4, blocks_per_batch=16,
        reuse=ProbeReuseConfig(max_angle_deg=1.0, max_translation=0.02,
                               refresh_every=0),
        radiance=RadianceReuseConfig(max_angle_deg=1.0, max_translation=0.02,
                                     refresh_every=0),
        prefetch=2)
    thr_cfg = dataclasses.replace(base_cfg, workers=4)
    # the stall comparator is the PR-4 SYNCHRONOUS baseline (no prefetch,
    # no workers: every admission pays probe+warp+layout inline) — the
    # threaded executor must never regress past it.  On this container
    # (2 cores, no parallel device streams) worker threads COMPETE with
    # the march for the same ALUs instead of overlapping it, so beating
    # the already-prefetched sync run is a hardware property, not a
    # correctness one; the workers-x-prefetch sweep below records where
    # the crossover sits on the current machine.
    sync_cfg = dataclasses.replace(base_cfg, prefetch=0)

    def stall_p99(done):
        return percentile(
            [r.stats["admit_stall_s"] * 1e3 for r in done], 99)

    reqs = traj()
    done_s, dt_s, eng_s = run_engine(flds, acfg, base_cfg, reqs)
    done_t, dt_t, eng_t = run_engine(flds, acfg, thr_cfg, traj())
    eng_t.close()

    by_rid_s = {r.rid: r for r in done_s}
    identical = all(np.array_equal(r.image, by_rid_s[r.rid].image)
                    for r in done_t)
    st_s, st_t = eng_s.engine_stats(), eng_t.engine_stats()
    counter_diffs = [k for k in DETERMINISTIC_COUNTERS
                     if st_s[k] != st_t[k]]
    # timing gate over best-of-3 repetitions per config — SAME count on
    # both sides (single-run p99 on a CPU container is max-dominated
    # timer noise; an asymmetric best-of would bias the gate)
    p99s_b, p99s_t = [], [stall_p99(done_t)]
    for _ in range(3):
        d, _, _e = run_engine(flds, acfg, sync_cfg, traj())
        p99s_b.append(stall_p99(d))
    for _ in range(2):
        d, _, e = run_engine(flds, acfg, thr_cfg, traj())
        p99s_t.append(stall_p99(d))
        e.close()
    p99_s, p99_t = min(p99s_b), min(p99s_t)
    # "no worse" with 10% headroom + epsilon for timer noise
    stall_ok = p99_t <= p99_s * 1.10 + 0.5
    ok = identical and not counter_diffs and stall_ok
    print(f"== render_serve workers: {len(reqs)} frames "
          f"{args.size}x{args.size}, scene={args.scene}, "
          f"sync vs 4-worker threaded executor ==")
    print(f"  frames bit-identical    : {'yes (PSNR delta exactly 0.0 dB)' if identical else 'NO'}")
    print(f"  deterministic counters  : "
          f"{'all equal' if not counter_diffs else counter_diffs}")
    print(f"  admission stall p99     : {p99_t:.2f} ms threaded vs "
          f"{p99_s:.2f} ms synchronous baseline (prefetch=0) "
          f"({'OK' if stall_ok else 'FAIL'})")
    print(f"  fps                     : {len(done_t)/dt_t:.2f} threaded vs "
          f"{len(done_s)/dt_s:.2f} sync")
    rows = [{
        "bench": "workers_gate", "scene": args.scene, "size": args.size,
        "poses": args.poses, "laps": args.laps, "workers": 4,
        "frames_identical": identical,
        "counter_diffs": counter_diffs,
        "admission_stall_p99_ms_threaded": p99_t,
        "admission_stall_p99_ms_sync": p99_s,
        "fps_threaded": len(done_t) / dt_t, "fps_sync": len(done_s) / dt_s,
        "misprepares_threaded": st_t["misprepares"],
        "misprepares_sync": st_s["misprepares"], "ok": ok,
    }]
    print("  stall sweep (workers x prefetch):")
    for workers in (0, 1, 2, 4):
        for prefetch in (0, 2):
            cfg = dataclasses.replace(base_cfg, workers=workers,
                                      prefetch=prefetch)
            done, dt, eng = run_engine(flds, acfg, cfg, traj())
            eng.close()
            stall = [r.stats["admit_stall_s"] * 1e3 for r in done]
            row = {
                "bench": "workers_stall_sweep", "scene": args.scene,
                "size": args.size, "workers": workers, "prefetch": prefetch,
                "admission_stall_p50_ms": percentile(stall, 50),
                "admission_stall_p99_ms": percentile(stall, 99),
                "fps": len(done) / dt,
            }
            rows.append(row)
            print(f"    workers {workers} prefetch {prefetch}: "
                  f"admit p50 {row['admission_stall_p50_ms']:6.1f} ms  "
                  f"p99 {row['admission_stall_p99_ms']:6.1f} ms  "
                  f"fps {row['fps']:5.2f}")
    print(f"  acceptance (bit-identical frames, identical counters, "
          f"threaded p99 no worse than sync): {'OK' if ok else 'FAIL'}")
    emit_rows("workers", rows)
    return ok


# ------------------------------------------------------------------- obs
def run_obs(args):
    """Tracing-overhead gate (make bench-obs): replay the orbit with the
    tracer OFF vs ON (in-memory collection + flight recorder — the
    always-on production shape) and gate

      * frames bit-identical (PSNR delta exactly 0.0 dB), and
      * tracing-on fps >= 95% of tracing-off fps (<= 5% overhead),

    best-of-3 per side so one noisy rep can't fail the gate on a shared
    CPU container.  Deterministic counters must match exactly."""
    flds = {args.scene: fields.analytic_field_fns(
        scene.make_scene(args.scene))}
    acfg = make_acfg()

    def traj():
        return trajectory_requests(args.scene, args.poses, args.laps,
                                   args.size, args.dtheta)

    off_cfg = RenderServeConfig(
        slots=4, blocks_per_batch=16,
        reuse=ProbeReuseConfig(max_angle_deg=1.0, max_translation=0.02,
                               refresh_every=0),
        prefetch=2)
    on_cfg = dataclasses.replace(off_cfg, trace=TraceConfig(flight=True))

    fps_off, fps_on = [], []
    done_off = done_on = st_off = st_on = None
    n_spans = 0
    for _ in range(3):
        d, dt, e = run_engine(flds, acfg, off_cfg, traj())
        fps_off.append(len(d) / dt)
        done_off, st_off = d, e.engine_stats()
        e.close()
        d, dt, e = run_engine(flds, acfg, on_cfg, traj())
        fps_on.append(len(d) / dt)
        done_on, st_on = d, e.engine_stats()
        n_spans = len(e.tracer.spans)
        e.close()

    by_rid = {r.rid: r for r in done_off}
    identical = all(np.array_equal(r.image, by_rid[r.rid].image)
                    for r in done_on)
    delta_db = 0.0 if identical else float("inf")
    counter_diffs = [k for k in DETERMINISTIC_COUNTERS
                     if st_off[k] != st_on[k]]
    best_off, best_on = max(fps_off), max(fps_on)
    overhead = 1.0 - best_on / best_off
    overhead_ok = best_on >= 0.95 * best_off
    ok = identical and not counter_diffs and overhead_ok
    print(f"== render_serve obs overhead: {args.poses * args.laps} frames "
          f"{args.size}x{args.size}, scene={args.scene} ==")
    print(f"  frames (trace on vs off): "
          f"{'bit-identical (delta 0.0 dB)' if identical else 'DIFFER'}")
    print(f"  deterministic counters  : "
          f"{'all equal' if not counter_diffs else counter_diffs}")
    print(f"  fps                     : {best_on:.2f} traced vs "
          f"{best_off:.2f} untraced "
          f"(overhead {100 * overhead:+.1f}%, gate <= 5%: "
          f"{'OK' if overhead_ok else 'FAIL'}; {n_spans} spans/run)")
    print(f"  acceptance (0.0 dB delta, counters equal, <= 5% overhead): "
          f"{'OK' if ok else 'FAIL'}")
    emit_rows("obs", [{
        "bench": "obs_overhead", "scene": args.scene, "size": args.size,
        "poses": args.poses, "laps": args.laps,
        "fps_traced": best_on, "fps_untraced": best_off,
        "overhead_fraction": overhead, "delta_db": delta_db,
        "frames_identical": identical, "counter_diffs": counter_diffs,
        "spans_per_run": n_spans, "ok": ok,
    }])
    return ok


# --------------------------------------------------------------- latency
def run_latency(args):
    """p50/p99 per-frame latency vs slot count and prefetch depth.

    latency_s is END-TO-END under the double-buffered admission path:
    queue wait + admission (probe/warp) + march, clocked from render()
    entry — so deeper queues legitimately show longer tails.  The
    admission-stall percentiles isolate the blocking Stage-B commit the
    prefetch is meant to shrink.
    """
    flds = {s: fields.analytic_field_fns(scene.make_scene(s))
            for s in ("mic", "hotdog")}
    acfg = make_acfg()
    frames = max(args.poses, 8) * 2
    rows = []
    print(f"== multi-client latency: {frames} frames "
          f"(2 scenes interleaved), {args.size}x{args.size} ==")
    for slots in (1, 2, 4, 8):
        for prefetch in (0, 2):
            rcfg = RenderServeConfig(slots=slots, blocks_per_batch=16,
                                     reuse=ProbeReuseConfig(refresh_every=0),
                                     prefetch=prefetch)
            reqs = [RenderRequest(
                rid=i, scene=("mic", "hotdog")[i % 2],
                cam=scene.look_at_camera(args.size, args.size,
                                         theta=0.6 + 0.01 * (i // 2),
                                         phi=0.5))
                for i in range(frames)]
            done, dt, eng = run_engine(flds, acfg, rcfg, reqs)
            # first-class engine ledgers: the p50/p99 come straight from
            # engine_stats() (stats.py Series) instead of re-aggregating
            # RenderRequest fields by hand
            st = eng.engine_stats()
            lat_ms = [r.latency_s * 1e3 for r in done]
            row = {
                "bench": "latency_vs_slots", "size": args.size,
                "frames": frames, "slots": slots, "prefetch": prefetch,
                "p50_ms": st["latency_ms_p50"],
                "p99_ms": st["latency_ms_p99"],
                "mean_ms": float(np.mean(lat_ms)),
                "admission_stall_p50_ms": st["admit_stall_ms_p50"],
                "admission_stall_p99_ms": st["admit_stall_ms_p99"],
                "fps": len(done) / dt,
            }
            rows.append(row)
            print(f"  slots {slots} prefetch {prefetch}: "
                  f"p50 {row['p50_ms']:7.1f} ms  "
                  f"p99 {row['p99_ms']:7.1f} ms  "
                  f"admit p99 {row['admission_stall_p99_ms']:6.1f} ms  "
                  f"fps {row['fps']:5.2f}")
    emit_rows("latency", rows)
    return True


# ------------------------------------------------------------------- slo
def run_slo(args):
    """SLO-aware admission under open-loop Poisson traffic (ROADMAP item).

    Heterogeneous clients: an ``rt`` class (tight deadline, a 3-rung
    budget ladder the scheduler may shed down, small frames) mixed with
    a ``bulk`` class (no deadline, full budget, 1.5x resolution).
    Arrivals are open-loop Poisson at a rate swept as a multiple of the
    engine's measured closed-loop capacity; at every offered load the
    SAME arrival sequence runs once under FifoPolicy and once under
    ShedPolicy (EDF + budget shedding).

    Gate (the acceptance row): at the DEEPEST overload factor the shed
    policy must hold the rt class's p99 latency below the FIFO baseline
    at equal offered load, must actually shed (requests_shed > 0 —
    degrade instead of queueing), and must not miss meaningfully more
    rt deadlines than FIFO (tolerance: 10% of rt frames — at deep
    overload BOTH policies miss nearly every deadline, so the saturated
    miss counts differ only by noise; the p99 spread is the signal).
    Every lighter factor is gated only for NON-regression (shed p99 <=
    1.15x fifo p99): capacity is calibrated per run on a loaded
    machine, so a nominal 1.5x factor may carry no real deadline
    pressure and its p99 comparison is then coin-flip noise — only the
    deepest factor reliably queues.
    """
    flds = {args.scene: fields.analytic_field_fns(scene.make_scene(args.scene))}
    acfg = make_acfg()
    # frame sizes where the march (what shedding scales) is a real
    # fraction of service time — smaller frames are admission-dominated
    # and shedding has nothing to cut
    size = args.size
    size_bulk = size * 2
    n = 18 if args.smoke else 36
    factors = (2.5,) if args.smoke else (0.7, 1.5, 2.5)
    rng = np.random.default_rng(7)
    is_bulk = rng.random(n) < 0.25       # ~1 in 4 requests is bulk
    # fixed pose set shared by every run: same work, same caches (off)
    thetas = 0.55 + 0.04 * rng.integers(0, 12, n)

    def requests(rt_cls, arrivals):
        # fresh objects each run: the scheduler mutates request tiers
        return [RenderRequest(
            rid=i, scene=args.scene,
            cam=scene.look_at_camera(size_bulk if is_bulk[i] else size,
                                     size_bulk if is_bulk[i] else size,
                                     theta=float(thetas[i]), phi=0.5),
            cls=RequestClass("bulk") if is_bulk[i] else rt_cls,
            arrival_s=float(arrivals[i]))
            for i in range(n)]

    def rcfg_for(policy):
        return RenderServeConfig(slots=2, blocks_per_batch=8, reuse=None,
                                 prefetch=2, policy=policy)

    # ---- calibration: closed-loop FIFO capacity (also the jit warm-up
    # for both frame shapes)
    calib = requests(RequestClass("rt"), np.zeros(n))
    warm = RenderServingEngine(flds, acfg, rcfg_for(None))
    warm.render([calib[int(np.argmax(is_bulk))], calib[int(np.argmin(is_bulk))]])
    warm.close()
    done, dt, eng = run_engine(flds, acfg, rcfg_for(None), calib)
    eng.close()
    capacity = len(done) / dt
    # rt deadline: ~3 mean service times — generous with slack, eaten
    # quickly once an overload queue forms
    deadline_ms = 3e3 / capacity
    rt_cls = RequestClass("rt", deadline_ms=deadline_ms,
                          tiers=(1.0, 0.5, 0.25), shed_floor=2)
    print(f"== render_serve SLO sweep: {n} reqs/run "
          f"(rt {size}x{size} + bulk {size_bulk}x{size_bulk}), "
          f"capacity {capacity:.1f} fps, rt deadline "
          f"{deadline_ms:.0f} ms ==")

    rows, ok = [], True
    for factor in factors:
        rate = capacity * factor
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
        per_policy = {}
        # best-of-N per side, like the workers/replay gates: a single
        # open-loop run's p99 is one order statistic of a short run and
        # max-dominated by timer noise
        for policy in ("fifo", "shed"):
            best = None
            for _ in range(2 if args.smoke else 3):
                done, dt, eng = run_engine(flds, acfg, rcfg_for(policy),
                                           requests(rt_cls, arrivals))
                st = eng.engine_stats()
                eng.close()
                if (best is None
                        or st["class_stats"]["rt"]["latency_ms_p99"]
                        < best["class_stats"]["rt"]["latency_ms_p99"]):
                    best = st
            per_policy[policy] = best
        f_rt = per_policy["fifo"]["class_stats"]["rt"]
        s_rt = per_policy["shed"]["class_stats"]["rt"]
        shed_st = per_policy["shed"]
        decisive = factor == max(factors)
        if decisive:
            miss_tol = max(2, int(0.1 * f_rt["frames"]))
            row_ok = (s_rt["latency_ms_p99"] < f_rt["latency_ms_p99"]
                      and shed_st["requests_shed"] > 0
                      and s_rt["deadline_misses"]
                      <= f_rt["deadline_misses"] + miss_tol)
        else:
            # lighter factors: non-regression only (see docstring)
            row_ok = (s_rt["latency_ms_p99"]
                      <= 1.15 * f_rt["latency_ms_p99"])
        ok = ok and row_ok
        rows.append({
            "bench": "slo_overload", "scene": args.scene, "frames": n,
            "size_rt": size, "size_bulk": size_bulk,
            "offered_factor": factor, "offered_rate_fps": rate,
            "capacity_fps": capacity, "deadline_ms": deadline_ms,
            "fifo_rt_p99_ms": f_rt["latency_ms_p99"],
            "shed_rt_p99_ms": s_rt["latency_ms_p99"],
            "fifo_rt_deadline_misses": f_rt["deadline_misses"],
            "shed_rt_deadline_misses": s_rt["deadline_misses"],
            "shed_requests_shed": shed_st["requests_shed"],
            "shed_degrades": shed_st["shed_degrades"],
            "shed_reprepares": shed_st["shed_reprepares"],
            "class_stats_shed": shed_st["class_stats"],
            "gate": "decisive" if decisive else "non_regression",
            "ok": row_ok,
        })
        print(f"  x{factor:<4} rt p99: fifo {f_rt['latency_ms_p99']:7.1f} "
              f"ms vs shed {s_rt['latency_ms_p99']:7.1f} ms | misses "
              f"{f_rt['deadline_misses']}/{s_rt['deadline_misses']} | "
              f"shed {shed_st['requests_shed']} frames "
              f"({shed_st['shed_degrades']} degrades) "
              f"{'OK' if row_ok else 'FAIL'}"
              f"{'' if decisive else ' [non-regression gate]'}")
    print(f"  acceptance (deepest factor: shed rt p99 < fifo, sheds > 0, "
          f"misses <= fifo + 10% rt frames; lighter: p99 <= 1.15x fifo): "
          f"{'OK' if ok else 'FAIL'}")
    emit_rows("slo", rows)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="mic")
    ap.add_argument("--poses", type=int, default=8,
                    help="unique poses per lap")
    ap.add_argument("--laps", type=int, default=3)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--dtheta", type=float, default=0.04,
                    help="orbit step in radians (~2.3 deg)")
    ap.add_argument("--sweep", action="store_true",
                    help="reuse-radius sweep (warped vs dilated vs always)")
    ap.add_argument("--latency", action="store_true",
                    help="latency distribution vs slot count")
    ap.add_argument("--workers", action="store_true",
                    help="threaded-executor gate + workers/prefetch "
                         "stall sweep")
    ap.add_argument("--obs", action="store_true",
                    help="tracing-overhead gate: <= 5%% fps overhead at "
                         "0.0 dB delta with the tracer on")
    ap.add_argument("--slo", action="store_true",
                    help="open-loop Poisson overload sweep: ShedPolicy "
                         "p99-per-class gate vs the FIFO baseline")
    ap.add_argument("--smoke", action="store_true",
                    help="(--slo) smaller/faster sweep for CI: one "
                         "overload factor, smaller frames")
    args = ap.parse_args()

    if args.sweep:
        ok = run_sweep(args)
    elif args.latency:
        ok = run_latency(args)
    elif args.workers:
        ok = run_workers(args)
    elif args.obs:
        ok = run_obs(args)
    elif args.slo:
        ok = run_slo(args)
    else:
        ok = run_replay(args)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
