"""Fig. 22 — register-cache size sweep (LRU hit rates per level) + the
paper's Fig. 13 storage-utilization numbers."""
from __future__ import annotations

import numpy as np

from repro.core import reuse, scene
from repro.core.hashgrid import HashGridConfig, storage_utilization

from . import common


def run(quick: bool = False):
    _, cfg, cam, _ = common.eval_setup("lego", quick)
    o, d = scene.camera_rays(cam)
    pts, _, _ = scene.sample_points(o[:32], d[:32], common.NS_FULL)
    pts = pts.reshape(-1, 3)

    sweep = reuse.cache_sweep(pts, cfg.grid, sizes=(0, 2, 4, 8, 16, 32))
    util_paper_scale = storage_utilization(HashGridConfig())  # 16 x 2^19
    return {
        "cache_sweep": {s: r.tolist() for s, r in sweep.items()},
        "mean_hit_rate": {s: float(np.mean(r)) for s, r in sweep.items()},
        "naive_utilization": util_paper_scale["naive_utilization"],
        "hybrid_utilization": util_paper_scale["hybrid_utilization"],
    }


def main(quick: bool = False):
    r = run(quick)
    print("cache_items,mean_hit_rate,level0_hit,levelmax_hit")
    for s, rates in r["cache_sweep"].items():
        print(f"{s},{r['mean_hit_rate'][s]:.3f},{rates[0]:.3f},{rates[-1]:.3f}")
    print(f"storage_utilization_naive,{r['naive_utilization']:.4f}")
    print(f"storage_utilization_hybrid,{r['hybrid_utilization']:.4f}  "
          f"# paper: 0.8595")
    return r
