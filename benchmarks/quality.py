"""Fig. 16 / Table 3 — rendering quality: ASDR vs baseline vs naive.

Paper claims reproduced (structure, on analytic scenes):
  * ASDR PSNR within ~0.1–0.3 of the fixed-192 baseline,
  * naive sample halving loses >1 PSNR more than decoupling (Fig. 9),
  * SSIM deltas ~0.002.
"""
from __future__ import annotations


from repro.core import decouple, pipeline, rendering, scene

from . import common


def run(quick: bool = False):
    rows = []
    for sc in common.SCENES:
        fns, cfg, cam, ref = common.eval_setup(sc, quick)
        o, d = scene.camera_rays(cam)
        base = common.baseline_image(fns, cam)

        acfg = pipeline.ASDRConfig(
            ns_full=common.NS_FULL, probe_stride=4,
            candidates=common.CANDIDATES, block_size=256, chunk=16,
        )
        asdr_img, stats = pipeline.render_asdr_image(fns, acfg, cam)

        naive, _ = pipeline.render_fixed_fns(fns, o, d, common.NS_FULL // 2)
        naive = naive.reshape(*common.IMG_HW, 3)
        dec, _ = decouple.render_decoupled(fns, o, d, common.NS_FULL, group=2)
        dec = dec.reshape(*common.IMG_HW, 3)

        def m(img):
            return (float(rendering.psnr(img, ref)),
                    float(rendering.ssim(img, ref)))

        p_base, s_base = m(base)
        p_asdr, s_asdr = m(asdr_img)
        p_naive, _ = m(naive)
        p_dec, _ = m(dec)
        rows.append({
            "scene": sc,
            "psnr_baseline": p_base, "psnr_asdr": p_asdr,
            "psnr_naive_half": p_naive, "psnr_decoupled": p_dec,
            "ssim_baseline": s_base, "ssim_asdr": s_asdr,
            "psnr_drop_asdr": p_base - p_asdr,
            "decouple_vs_naive_gain": p_dec - p_naive,
            "avg_samples": stats["avg_samples_per_ray"],
        })
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("scene,psnr_base,psnr_asdr,drop,psnr_naive/2,psnr_dec,"
          "dec-naive,ssim_base,ssim_asdr,avg_samples")
    for r in rows:
        print(f"{r['scene']},{r['psnr_baseline']:.2f},{r['psnr_asdr']:.2f},"
              f"{r['psnr_drop_asdr']:.2f},{r['psnr_naive_half']:.2f},"
              f"{r['psnr_decoupled']:.2f},{r['decouple_vs_naive_gain']:.2f},"
              f"{r['ssim_baseline']:.4f},{r['ssim_asdr']:.4f},"
              f"{r['avg_samples']:.1f}")
    return rows
