"""Scene-space block reuse benchmark: multi-user sweep + byte-budget sweep.

  PYTHONPATH=src python benchmarks/scene_cache.py            # client sweep
  PYTHONPATH=src python benchmarks/scene_cache.py --budgets  # budget sweep

Default (clients) mode — the workload the scenecache tier exists for:
``--clients`` concurrent users of ONE scene request the same pose set
(spectators of a shared scene: a venue, a product page, a game replay),
interleaved so their frames are live in the engine together.  Per client
count, an engine with the shared block store runs against a no-cache
engine on the identical request stream.  Gates:

  * cross-client sharing: block hit rate > 0 for clients >= 2 (one
    client's marches satisfy the others' identical blocks);
  * bounded memory: resident bytes <= the configured byte budget after
    every run;
  * fidelity: per-frame |PSNR delta| vs the no-cache engine <= 0.1 dB
    (hits replay outputs of an identical march, so the delta is 0.0).

--budgets — byte-budget sweep at a fixed client count: hit rate, resident
MB, and evictions vs budget, showing the coverage-aware LRU degrading
gradually (smaller budgets trade hit rate for memory, never correctness).

All modes append JSON rows to out/bench/scene_cache_<mode>.json.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from common import emit_rows as _emit_rows, serve_bench_acfg as make_acfg
from repro.core import fields, rendering, scene
from repro.scenecache import SceneCacheConfig
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)


def emit_rows(name: str, rows):
    _emit_rows(f"scene_cache_{name}", rows)


def multi_client_requests(scene_name, clients, poses, size, dtheta):
    """Interleaved streams: every client requests the same pose set."""
    reqs = []
    for i in range(poses):
        for c in range(clients):
            reqs.append(RenderRequest(
                rid=c * poses + i, scene=scene_name,
                cam=scene.look_at_camera(size, size,
                                         theta=0.55 + dtheta * i, phi=0.5)))
    return reqs


def frame_psnr_delta(done_c, done_p, refs):
    """max per-frame |PSNR delta| of cached vs plain against references."""
    deltas = []
    for rid, rp in done_p.items():
        p_c = float(rendering.psnr(done_c[rid].image, refs[rid]))
        p_p = float(rendering.psnr(rp.image, refs[rid]))
        deltas.append(abs(p_c - p_p))
    return max(deltas)


def run_clients(args):
    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    acfg = make_acfg()
    budget = int(args.budget_mb * (1 << 20))
    rows, all_ok = [], True
    # exact analytic reference per pose — the pose set is shared by every
    # client AND every clients-count iteration, so render each pose once
    pose_ref = {}
    for rq in multi_client_requests(args.scene, 1, args.poses, args.size,
                                    args.dtheta):
        o, d = scene.camera_rays(rq.cam)
        ref, _ = scene.render_reference(field, o, d)
        pose_ref[rq.rid] = np.asarray(ref).reshape(args.size, args.size, 3)
    print(f"== scene-cache client sweep: {args.poses} shared poses, "
          f"{args.size}x{args.size}, scene={args.scene}, "
          f"budget {args.budget_mb:.1f} MB ==")
    for clients in (1, 2, 4):
        def reqs_fn(c=clients):
            return multi_client_requests(args.scene, c, args.poses,
                                         args.size, args.dtheta)
        cfg_kw = dict(slots=4, blocks_per_batch=16, reuse=None, radiance=None)
        # warm-up compile outside the timed runs
        RenderServingEngine(flds, acfg, RenderServeConfig(**cfg_kw)).render(
            [reqs_fn()[0]])
        eng_c = RenderServingEngine(flds, acfg, RenderServeConfig(
            scenecache=SceneCacheConfig(byte_budget=budget), **cfg_kw))
        t0 = time.time()
        done_c = {r.rid: r for r in eng_c.render(reqs_fn())}
        dt_c = time.time() - t0
        eng_p = RenderServingEngine(flds, acfg, RenderServeConfig(**cfg_kw))
        t0 = time.time()
        done_p = {r.rid: r for r in eng_p.render(reqs_fn())}
        dt_p = time.time() - t0

        # per-frame PSNR vs the exact analytic reference for both engines;
        # the gate is on the DELTA (cached hits replay identical marches,
        # so this is 0.0 unless the cache corrupts a block)
        refs = {rq.rid: pose_ref[rq.rid % args.poses] for rq in reqs_fn()}
        max_delta = frame_psnr_delta(done_c, done_p, refs)

        st = eng_c.engine_stats()
        sc = st["scenecache"]
        hit_rate = st["scene_block_hit_rate"]
        resident_ok = sc["resident_bytes"] <= sc["byte_budget"]
        ok = (resident_ok and max_delta <= 0.1
              and (hit_rate > 0.0 if clients >= 2 else True))
        all_ok = all_ok and ok
        rows.append({
            "bench": "scene_cache_clients", "scene": args.scene,
            "size": args.size, "poses": args.poses, "clients": clients,
            "byte_budget": budget,
            "block_hit_rate": hit_rate,
            "blocks_marched": st["blocks_marched"],
            "blocks_hit": st["scene_block_hits"],
            "resident_mb": sc["resident_bytes"] / (1 << 20),
            "evictions": sc["evictions"],
            "fps_cached": len(done_c) / dt_c,
            "fps_plain": len(done_p) / dt_p,
            "max_abs_psnr_delta": max_delta, "ok": ok,
        })
        print(f"  clients {clients}: hit rate {hit_rate:.3f} "
              f"({st['scene_block_hits']} hits / "
              f"{st['blocks_marched']} marched)  resident "
              f"{sc['resident_bytes'] / (1 << 20):.2f} MB  "
              f"delta {max_delta:.4f} dB  "
              f"fps {len(done_c) / dt_c:.2f} vs {len(done_p) / dt_p:.2f}  "
              f"{'OK' if ok else 'FAIL'}")
    print(f"  acceptance (cross-client hits > 0, resident <= budget, "
          f"delta <= 0.1 dB): {'OK' if all_ok else 'FAIL'}")
    emit_rows("clients", rows)
    return all_ok


def run_budgets(args):
    flds = {args.scene: fields.analytic_field_fns(scene.make_scene(args.scene))}
    acfg = make_acfg()
    clients = 4
    rows, all_ok = [], True
    budgets = [int(m * (1 << 20)) for m in (0.125, 0.5, 2.0, 8.0)]
    print(f"== scene-cache budget sweep: {clients} clients x {args.poses} "
          f"poses, {args.size}x{args.size} ==")
    cfg_kw = dict(slots=4, blocks_per_batch=16, reuse=None, radiance=None)
    RenderServingEngine(flds, acfg, RenderServeConfig(**cfg_kw)).render(
        [multi_client_requests(args.scene, 1, 1, args.size, args.dtheta)[0]])
    for budget in budgets:
        eng = RenderServingEngine(flds, acfg, RenderServeConfig(
            scenecache=SceneCacheConfig(byte_budget=budget), **cfg_kw))
        t0 = time.time()
        done = eng.render(multi_client_requests(
            args.scene, clients, args.poses, args.size, args.dtheta))
        dt = time.time() - t0
        st = eng.engine_stats()
        sc = st["scenecache"]
        ok = sc["resident_bytes"] <= sc["byte_budget"]
        all_ok = all_ok and ok
        rows.append({
            "bench": "scene_cache_budgets", "scene": args.scene,
            "size": args.size, "poses": args.poses, "clients": clients,
            "byte_budget": budget,
            "block_hit_rate": st["scene_block_hit_rate"],
            "blocks_marched": st["blocks_marched"],
            "resident_mb": sc["resident_bytes"] / (1 << 20),
            "evictions": sc["evictions"],
            "fps": len(done) / dt, "ok": ok,
        })
        print(f"  budget {budget / (1 << 20):6.3f} MB: hit rate "
              f"{st['scene_block_hit_rate']:.3f}  resident "
              f"{sc['resident_bytes'] / (1 << 20):6.3f} MB  "
              f"evictions {sc['evictions']:4d}  fps {len(done) / dt:.2f}")
    print(f"  acceptance (resident <= budget at every point): "
          f"{'OK' if all_ok else 'FAIL'}")
    emit_rows("budgets", rows)
    return all_ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="mic")
    ap.add_argument("--poses", type=int, default=6,
                    help="shared poses per client")
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--dtheta", type=float, default=0.04)
    ap.add_argument("--budget-mb", type=float, default=8.0)
    ap.add_argument("--budgets", action="store_true",
                    help="byte-budget sweep instead of the client sweep")
    args = ap.parse_args()
    ok = run_budgets(args) if args.budgets else run_clients(args)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
