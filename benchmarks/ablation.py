"""Fig. 20 — contribution breakdown: strawman / +SW / +HW-analogue / full.

Hardware contributions (CIM weight residency, register cache) map to
work-unit reductions on TPU (DESIGN.md §2): tile-dedup of gathers and
fused-kernel weight residency.  Software = adaptive sampling + decoupling.
"""
from __future__ import annotations


from repro.core import pipeline, reuse, scene
from repro.core.mlp import flops_per_sample

from . import common


def run(quick: bool = False):
    fns, cfg, cam, _ = common.eval_setup("lego", quick)
    o, d = scene.camera_rays(cam)
    R = o.shape[0]
    ns = common.NS_FULL
    f = flops_per_sample(cfg.net)
    per_sample_flops = f["density_flops"] + f["color_flops"]

    pts, _, _ = scene.sample_points(o[:64], d[:64], ns)
    dedup = reuse.dedup_window_rate(pts.reshape(-1, 3), cfg.grid, 32, 0)

    acfg = pipeline.ASDRConfig(ns_full=ns, probe_stride=4,
                               candidates=common.CANDIDATES,
                               block_size=256, chunk=16)
    _, stats = pipeline.render_asdr_image(fns, acfg, cam)
    asdr_samples = float(stats["samples_processed"]) + stats["probe_samples"]

    base_samples = R * ns

    def work(samples, sw_decouple, hw_dedup):
        color = samples / (acfg.group if sw_decouple else 1)
        flops = samples * f["density_flops"] + color * f["color_flops"]
        gathers = reuse.gather_bytes(samples, cfg.grid,
                                     dedup_rate=dedup if hw_dedup else 0.0)
        # normalize to a single "work" unit: flops + bytes*4 (1 B ~ 4 flops
        # at v5e compute/bandwidth ratio 197T/819G)
        return flops + gathers * (197e12 / 819e9) / 64

    straw = work(base_samples, False, False)
    sw = work(asdr_samples, True, False)
    hw = work(base_samples, False, True)
    full = work(asdr_samples, True, True)
    return {
        "strawman_work": straw,
        "sw_only_speedup": straw / sw,
        "hw_only_speedup": straw / hw,
        "full_speedup": straw / full,
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value  # paper Fig20: HW 11.23x, SW 21.52x, full 53.90x"
          " (vs Xavier NX incl. CIM)")
    for k, v in r.items():
        print(f"{k},{v:.3f}")
    return r
