"""Roofline report — reads results/dryrun/*.json, emits the per-cell table
(EXPERIMENTS.md §Roofline is generated from this)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_records(mesh: str = "single"):
    recs = []
    for p in sorted(RESULTS.glob(f"*_{mesh}.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def table(mesh: str = "single"):
    rows = []
    for r in load_records(mesh):
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": True})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"],
            "roofline_fraction": t["roofline_fraction_compute"],
            "useful_ratio": r["useful_flops_ratio"],
            "peak_gb": r["memory"]["temp_bytes"] / 1e9,
            "compile_s": r["compile_s"],
        })
    return rows


def main(quick: bool = False):
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if not rows:
            print(f"# no dry-run records for mesh={mesh} "
                  "(run python -m repro.launch.dryrun --all)")
            continue
        print(f"## mesh={mesh}")
        print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
              "roofline_frac,useful_ratio,peak_GB,compile_s")
        for r in rows:
            if r.get("skipped"):
                print(f"{r['arch']},{r['shape']},SKIP(long_500k "
                      "needs sub-quadratic attention)")
                continue
            print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
                  f"{r['bottleneck']},{r['roofline_fraction']:.3f},"
                  f"{r['useful_ratio']:.3f},{r['peak_gb']:.2f},"
                  f"{r['compile_s']:.1f}")
    return table("single")
