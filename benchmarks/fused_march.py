"""Fused-march benchmark: single-kernel Phase II vs the chunked reference.

  PYTHONPATH=src python benchmarks/fused_march.py [--quick] [--smoke]

Four sections, appending JSON rows to out/bench/fused_march.json and
(full runs) writing the canonical summary to BENCH_fused_march.json at
the repo root:

  * replay — a short trained-NGP trajectory marches its Phase-II blocks
    through BOTH backends (the serving pool's jitted batched march, so
    this times exactly what the engine launches).  Gates:
      - per-frame |PSNR(ref) - PSNR(fused)| vs the fixed-96 baseline
        <= 0.1 dB (the backend-seam quality contract),
      - chunks_done identical on every frame (early-termination parity),
      - fused speedup >= 1.0x on the marched wall time.
  * full-config — the production table stack (16 x 2^19 x 2 = 64 MB)
    under the STREAMED fused backend, which the resident path cannot
    run (its VMEM ask is gated and the resident pin must refuse).
    Gates: resident refused, streamed speedup >= 2x over the chunked
    reference, psnr delta <= 0.1 dB vs a dense-budget baseline, chunks
    AND per-ray chunks exactly equal.
  * per-ray-exit — a saturating block through pool.collect with
    ``per_ray_early_exit`` on: the gated ``ray_exit_samples_skipped``
    counter must show skipped sample work at unchanged chunk counters.
  * engine — a >=8-slot serving run with the fused backend and
    inflight_batches >= 2.  Gate: some round launched > 1 batch
    (the streaming scheduler actually fills idle dispatch slots).

``--smoke`` (nightly CI) runs only the replay gates at one small frame:
chunks parity + the 0.1 dB ceiling, no root summary rewrite.

The trained model (not the analytic field) exercises the real kernel
path: hash tables + padded MLP stacks in the fused kernel; the
full-config section uses random-init weights (training the 64 MB grid
is out of scope on CPU — the streaming contract is table-SIZE-driven).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from common import baseline_image, emit_rows, serve_bench_acfg, trained_model
from repro.core import model as model_lib, pipeline, rendering, scene
from repro.kernels import ops
from repro.serve import pool as pool_lib, stats as stats_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)

MAX_PSNR_DELTA_DB = 0.1
BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fused_march.json"


def _frame_blocks(fns, acfg, cam):
    """One pose's Phase-II block tensors (o_b, d_b, budgets, order, R)."""
    o, d = scene.camera_rays(cam)
    counts, _ = pipeline.probe_phase(fns, acfg, cam)
    o, d, counts, _, _ = pipeline.pad_rays_to_blocks(acfg, o, d, counts)
    order, budgets = pipeline.block_sort(acfg, counts)
    B = acfg.block_size
    return (o[order].reshape(-1, B, 3), d[order].reshape(-1, B, 3),
            budgets, order, cam.height * cam.width)


def _image(rgb_s, order, R, hw):
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype))
    return np.asarray(rgb_s.reshape(-1, 3)[inv][:R].reshape(*hw, 3))


def replay_section(args):
    params, cfg = trained_model("lego", quick=args.quick)
    fns = ops.field_fns(params, cfg)
    acfg_r = serve_bench_acfg(block=args.block)
    acfg_f = dataclasses.replace(acfg_r, march_backend="fused")
    cams = [scene.look_at_camera(args.size, args.size,
                                 theta=0.9 + 0.08 * i, phi=0.55)
            for i in range(args.frames)]

    march_r = pool_lib.batched_march(fns, acfg_r)
    march_f = pool_lib.batched_march(fns, acfg_f)
    rows, t_ref = [], {"reference": 0.0, "fused": 0.0}
    worst = 0.0
    for fi, cam in enumerate(cams):
        o_b, d_b, budgets, order, R = _frame_blocks(fns, acfg_r, cam)
        outs, times = {}, {}
        for name, march in [("reference", march_r), ("fused", march_f)]:
            jax.block_until_ready(march(o_b, d_b, budgets))  # compile warm
            t0 = time.time()
            outs[name] = jax.block_until_ready(march(o_b, d_b, budgets))
            times[name] = (time.time() - t0) * 1e3
            t_ref[name] += times[name]
        assert np.array_equal(np.asarray(outs["reference"][3]),
                              np.asarray(outs["fused"][3])), (
            f"frame {fi}: chunks_done diverged")
        hw = (cam.height, cam.width)
        base = jnp.asarray(baseline_image(fns, cam))
        img_r = _image(outs["reference"][0], order, R, hw)
        img_f = _image(outs["fused"][0], order, R, hw)
        p_r = float(rendering.psnr(jnp.asarray(img_r), base))
        p_f = float(rendering.psnr(jnp.asarray(img_f), base))
        worst = max(worst, abs(p_r - p_f))
        print(f"  frame {fi}: ref {times['reference']:7.1f}ms "
              f"fused {times['fused']:7.1f}ms  "
              f"psnr {p_r:.2f}/{p_f:.2f} dB (|d|={abs(p_r - p_f):.4f})")
        rows.append(dict(bench="fused_march", mode="replay", frame=fi,
                         ref_ms=times["reference"], fused_ms=times["fused"],
                         psnr_ref_db=p_r, psnr_fused_db=p_f,
                         n_blocks=int(o_b.shape[0])))
    speedup = t_ref["reference"] / max(t_ref["fused"], 1e-9)
    print(f"  total: ref {t_ref['reference']:.0f}ms fused "
          f"{t_ref['fused']:.0f}ms -> {speedup:.2f}x, "
          f"worst |psnr delta| {worst:.4f} dB")
    assert worst <= MAX_PSNR_DELTA_DB, (
        f"GATE: fused psnr delta {worst:.4f} dB > {MAX_PSNR_DELTA_DB}")
    assert speedup >= 1.0, f"GATE: fused speedup {speedup:.2f}x < 1.0x"
    rows.append(dict(bench="fused_march", mode="replay_summary",
                     speedup=speedup, worst_psnr_delta_db=worst,
                     gate_ok=True))
    return rows, fns


def full_config_section(args):
    """The tentpole gate: the FULL 16 x 2^19 x 2 table stack (64 MB)
    marches under the streamed fused backend at >= 2x the chunked
    reference; the resident path must REFUSE the config outright."""
    cfg = model_lib.NGPConfig.make()          # production sizes
    params = model_lib.init_ngp(jax.random.PRNGKey(7), cfg)
    fns = ops.field_fns(params, cfg)
    res = fns.fused
    acfg_r = serve_bench_acfg(block=128)
    acfg_f = dataclasses.replace(acfg_r, march_backend="fused")
    vmem = dict(
        resident=ops.fused_march_vmem_bytes(acfg_f, res, streamed=False),
        streamed=ops.fused_march_vmem_bytes(acfg_f, res, streamed=True),
        limit=ops.FUSED_MARCH_VMEM_LIMIT)
    assert vmem["resident"] > vmem["limit"] >= vmem["streamed"], vmem
    assert ops._select_streaming(acfg_f, res)  # auto resolves to streamed
    try:
        ops._select_streaming(dataclasses.replace(
            acfg_f, march_table_streaming="resident"), res)
        resident_refused = False
    except ValueError:
        resident_refused = True
    assert resident_refused, "resident pin accepted a 64 MB stack"
    print(f"  vmem: resident {vmem['resident'] / 2**20:.0f} MB > limit "
          f"{vmem['limit'] / 2**20:.0f} MB >= streamed "
          f"{vmem['streamed'] / 2**20:.0f} MB (resident REFUSED)")

    cam = scene.look_at_camera(16, 16, theta=0.9, phi=0.55)
    o, d = scene.camera_rays(cam)
    B = acfg_f.block_size
    o_b = o.reshape(-1, B, 3)
    d_b = d.reshape(-1, B, 3)
    budgets = jnp.asarray([48, 32], jnp.int32)

    march_r = pool_lib.batched_march(fns, acfg_r)
    march_f = pool_lib.batched_march(fns, acfg_f)
    outs, times = {}, {}
    for name, march in [("reference", march_r), ("fused", march_f)]:
        jax.block_until_ready(march(o_b, d_b, budgets))    # compile warm
        t0 = time.time()
        outs[name] = jax.block_until_ready(march(o_b, d_b, budgets))
        times[name] = (time.time() - t0) * 1e3
    assert np.array_equal(np.asarray(outs["reference"][3]),
                          np.asarray(outs["fused"][3])), "chunks diverged"
    assert np.array_equal(np.asarray(outs["reference"][4]),
                          np.asarray(outs["fused"][4])), (
        "per-ray chunks diverged")
    # quality vs a dense-budget reference march (the dB contract): both
    # adaptive backends scored against the same budget-96 render
    base = jax.block_until_ready(
        march_r(o_b, d_b, jnp.full((2,), 96, jnp.int32)))
    base_rgb = jnp.asarray(np.asarray(base[0]))
    p_r = float(rendering.psnr(outs["reference"][0], base_rgb))
    p_f = float(rendering.psnr(outs["fused"][0], base_rgb))
    delta = abs(p_r - p_f)
    speedup = times["reference"] / max(times["fused"], 1e-9)
    print(f"  full config: ref {times['reference']:.0f}ms streamed-fused "
          f"{times['fused']:.0f}ms -> {speedup:.2f}x, psnr "
          f"{p_r:.2f}/{p_f:.2f} dB (|d|={delta:.4f})")
    assert delta <= MAX_PSNR_DELTA_DB, f"GATE: {delta:.4f} dB"
    assert speedup >= 2.0, (
        f"GATE: full-config streamed speedup {speedup:.2f}x < 2.0x")
    row = dict(bench="fused_march", mode="full_config", backend="streamed",
               config=f"{cfg.grid.n_levels}x2^{cfg.grid.log2_table_size}"
                      f"x{cfg.grid.feature_dim}",
               table_mb=round(int(np.prod(res.tables.shape)) * 4 / 2**20),
               ref_ms=times["reference"], fused_ms=times["fused"],
               speedup=speedup, psnr_delta_db=delta, chunks_parity=True,
               ray_chunks_parity=True, resident_refused=resident_refused,
               fused_march_vmem_bytes=vmem, gate_ok=True)
    return [row]


def per_ray_exit_section(args):
    """Saturating block through the REAL pool.collect path: the gated
    ``ray_exit_samples_skipped`` counter must price skipped sample work
    while both chunk counters stay exactly equal to the flag-off run."""
    cfg = model_lib.NGPConfig.small()
    params = model_lib.init_ngp(jax.random.PRNGKey(0), cfg)
    hot = dict(params)
    hot["grid"] = jnp.abs(params["grid"]) + 0.5
    hot["mlps"] = dict(params["mlps"])
    hot["mlps"]["density"] = [jnp.abs(w) * 4.0
                              for w in params["mlps"]["density"]]
    fns = ops.field_fns(hot, cfg)
    B = 64
    # half the rays bore into the saturating cube, half graze past it —
    # the block rides its full budget while the hot rays exit early
    o_hit = jnp.tile(jnp.asarray([0.45, 0.45, -0.3]), (B // 2, 1))
    o_hit = o_hit + jnp.linspace(0.0, 0.1, B // 2)[:, None] * jnp.asarray(
        [1.0, 1.0, 0.0])
    o_miss = jnp.tile(jnp.asarray([0.5, 0.5, -2.0]), (B // 2, 1))
    o_b = jnp.concatenate([o_hit, o_miss])[None]
    d_b = jnp.tile(jnp.asarray([0.0, 0.0, 1.0]), (1, B, 1))
    budgets = jnp.asarray([192], jnp.int32)

    base = dataclasses.replace(serve_bench_acfg(block=B),
                               march_backend="fused")
    acfg_on = dataclasses.replace(base, per_ray_early_exit=True)
    out_off = ops.fused_march_blocks(fns.fused, base, o_b, d_b, budgets)
    out_on = ops.fused_march_blocks(fns.fused, acfg_on, o_b, d_b, budgets)
    assert np.array_equal(np.asarray(out_off[3]), np.asarray(out_on[3]))
    assert np.array_equal(np.asarray(out_off[4]), np.asarray(out_on[4]))

    class _Req:
        rid, scene = 0, "bench"

    class _Slot:
        req = _Req()

        def deliver(self, *a, **kw):
            pass

    for name, acfg in [("off", base), ("on", acfg_on)]:
        counters = stats_lib.EngineCounters()
        pool = pool_lib.BlockPool(acfg, 1, None, counters)
        out = ops.fused_march_blocks(fns.fused, acfg, o_b, d_b, budgets)
        pool.collect(([(_Slot(), 0, None, None, 192, None, None, False)],
                      [], 0, out, 1, None, time.time()))
        skipped = counters.ray_exit_samples_skipped
        if name == "off":
            assert skipped == 0, "counter must stay gated off"
        else:
            assert skipped > 0, "no sample work skipped on saturation"
    chunks = int(np.asarray(out_on[3])[0])
    total = chunks * B * base.chunk
    print(f"  per-ray exit: {skipped}/{total} samples skipped "
          f"({skipped / total:.0%}) at exact chunk parity")
    return [dict(bench="fused_march", mode="per_ray_exit",
                 samples_skipped=int(skipped), block_samples=total,
                 skipped_fraction=skipped / total, chunks_parity=True,
                 gate_ok=True)]


def engine_section(args, fns):
    acfg = dataclasses.replace(serve_bench_acfg(block=64),
                               march_backend="fused")
    eng = RenderServingEngine({"lego": fns}, acfg, RenderServeConfig(
        slots=max(args.slots, 8), blocks_per_batch=4, reuse=None,
        inflight_batches=max(args.inflight, 2)))
    reqs = [RenderRequest(rid=i, scene="lego",
                          cam=scene.look_at_camera(
                              32, 32, theta=0.9 + 0.05 * i, phi=0.55))
            for i in range(max(args.slots, 8))]
    t0 = time.time()
    eng.render(reqs)
    wall = time.time() - t0
    st = eng.engine_stats()
    hist = st["batches_per_round"]
    print(f"  engine: {len(reqs)} frames in {wall:.2f}s, "
          f"march p50 {st['march_ms_p50']:.1f}ms, "
          f"batches/round {hist}")
    assert hist and max(hist) > 1, (
        f"GATE: no multi-batch rounds at {len(reqs)} slots: {hist}")
    return [dict(bench="fused_march", mode="engine", frames=len(reqs),
                 wall_s=wall, march_ms_p50=st["march_ms_p50"],
                 march_ms_p99=st["march_ms_p99"],
                 batches_per_round={str(k): v for k, v in hist.items()},
                 gate_ok=True)]


def write_canonical(rows):
    """BENCH_fused_march.json at the repo root: the one-file perf record
    (latest full run wins; the append-only history stays in out/bench/)."""
    import json
    by_mode = {}
    for r in rows:
        by_mode.setdefault(r["mode"], r)
    summary = {
        "bench": "fused_march",
        "backend": "fused (streamed at full config, resident when fits)",
        "replay": {k: by_mode["replay_summary"][k]
                   for k in ("speedup", "worst_psnr_delta_db", "gate_ok")},
        "full_config": {k: by_mode["full_config"][k]
                        for k in ("config", "table_mb", "speedup",
                                  "psnr_delta_db", "chunks_parity",
                                  "ray_chunks_parity", "resident_refused",
                                  "fused_march_vmem_bytes", "gate_ok")},
        "per_ray_exit": {k: by_mode["per_ray_exit"][k]
                         for k in ("samples_skipped", "skipped_fraction",
                                   "chunks_parity", "gate_ok")},
        "engine": {k: by_mode["engine"][k]
                   for k in ("frames", "march_ms_p50", "batches_per_round",
                             "gate_ok")},
        "chunks_parity": True,
    }
    BENCH_PATH.write_text(json.dumps(summary, indent=1) + "\n")
    print(f"  [json] canonical summary -> {BENCH_PATH}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="nightly CI: replay gates only, one small frame")
    ap.add_argument("--frames", type=int, default=3)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--inflight", type=int, default=2)
    args = ap.parse_args()
    if args.smoke:
        args.quick, args.frames, args.size, args.block = True, 1, 32, 64
    print("[fused-march] replay: reference vs fused backend")
    rows, fns = replay_section(args)
    if args.smoke:
        emit_rows("fused_march", rows)
        print("[fused-march] smoke gates OK (chunks parity + psnr delta)")
        return
    print("[fused-march] full config: streamed tables (64 MB stack)")
    rows += full_config_section(args)
    print("[fused-march] per-ray early exit: gated skip counter")
    rows += per_ray_exit_section(args)
    print("[fused-march] engine: streaming dispatch at "
          f">={max(args.slots, 8)} slots")
    rows += engine_section(args, fns)
    emit_rows("fused_march", rows)
    write_canonical(rows)
    print("[fused-march] all gates OK")


if __name__ == "__main__":
    main()
