"""Fused-march benchmark: single-kernel Phase II vs the chunked reference.

  PYTHONPATH=src python benchmarks/fused_march.py [--quick]

Two sections, both appending JSON rows to out/bench/fused_march.json:

  * replay — a short trained-NGP trajectory marches its Phase-II blocks
    through BOTH backends (the serving pool's jitted batched march, so
    this times exactly what the engine launches).  Gates:
      - per-frame |PSNR(ref) - PSNR(fused)| vs the fixed-96 baseline
        <= 0.1 dB (the backend-seam quality contract),
      - chunks_done identical on every frame (early-termination parity),
      - fused speedup >= 1.0x on the marched wall time.
  * engine — a >=8-slot serving run with the fused backend and
    inflight_batches >= 2.  Gate: some round launched > 1 batch
    (the streaming scheduler actually fills idle dispatch slots).

The trained model (not the analytic field) exercises the real kernel
path: hash tables + padded MLP stacks resident in the fused kernel.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from common import baseline_image, emit_rows, serve_bench_acfg, trained_model
from repro.core import pipeline, rendering, scene
from repro.kernels import ops
from repro.serve import pool as pool_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)

MAX_PSNR_DELTA_DB = 0.1


def _frame_blocks(fns, acfg, cam):
    """One pose's Phase-II block tensors (o_b, d_b, budgets, order, R)."""
    o, d = scene.camera_rays(cam)
    counts, _ = pipeline.probe_phase(fns, acfg, cam)
    o, d, counts, _, _ = pipeline.pad_rays_to_blocks(acfg, o, d, counts)
    order, budgets = pipeline.block_sort(acfg, counts)
    B = acfg.block_size
    return (o[order].reshape(-1, B, 3), d[order].reshape(-1, B, 3),
            budgets, order, cam.height * cam.width)


def _image(rgb_s, order, R, hw):
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype))
    return np.asarray(rgb_s.reshape(-1, 3)[inv][:R].reshape(*hw, 3))


def replay_section(args):
    params, cfg = trained_model("lego", quick=args.quick)
    fns = ops.field_fns(params, cfg)
    acfg_r = serve_bench_acfg(block=args.block)
    acfg_f = dataclasses.replace(acfg_r, march_backend="fused")
    cams = [scene.look_at_camera(args.size, args.size,
                                 theta=0.9 + 0.08 * i, phi=0.55)
            for i in range(args.frames)]

    march_r = pool_lib.batched_march(fns, acfg_r)
    march_f = pool_lib.batched_march(fns, acfg_f)
    rows, t_ref = [], {"reference": 0.0, "fused": 0.0}
    worst = 0.0
    for fi, cam in enumerate(cams):
        o_b, d_b, budgets, order, R = _frame_blocks(fns, acfg_r, cam)
        outs, times = {}, {}
        for name, march in [("reference", march_r), ("fused", march_f)]:
            jax.block_until_ready(march(o_b, d_b, budgets))  # compile warm
            t0 = time.time()
            outs[name] = jax.block_until_ready(march(o_b, d_b, budgets))
            times[name] = (time.time() - t0) * 1e3
            t_ref[name] += times[name]
        assert np.array_equal(np.asarray(outs["reference"][3]),
                              np.asarray(outs["fused"][3])), (
            f"frame {fi}: chunks_done diverged")
        hw = (cam.height, cam.width)
        base = jnp.asarray(baseline_image(fns, cam))
        img_r = _image(outs["reference"][0], order, R, hw)
        img_f = _image(outs["fused"][0], order, R, hw)
        p_r = float(rendering.psnr(jnp.asarray(img_r), base))
        p_f = float(rendering.psnr(jnp.asarray(img_f), base))
        worst = max(worst, abs(p_r - p_f))
        print(f"  frame {fi}: ref {times['reference']:7.1f}ms "
              f"fused {times['fused']:7.1f}ms  "
              f"psnr {p_r:.2f}/{p_f:.2f} dB (|d|={abs(p_r - p_f):.4f})")
        rows.append(dict(bench="fused_march", mode="replay", frame=fi,
                         ref_ms=times["reference"], fused_ms=times["fused"],
                         psnr_ref_db=p_r, psnr_fused_db=p_f,
                         n_blocks=int(o_b.shape[0])))
    speedup = t_ref["reference"] / max(t_ref["fused"], 1e-9)
    print(f"  total: ref {t_ref['reference']:.0f}ms fused "
          f"{t_ref['fused']:.0f}ms -> {speedup:.2f}x, "
          f"worst |psnr delta| {worst:.4f} dB")
    assert worst <= MAX_PSNR_DELTA_DB, (
        f"GATE: fused psnr delta {worst:.4f} dB > {MAX_PSNR_DELTA_DB}")
    assert speedup >= 1.0, f"GATE: fused speedup {speedup:.2f}x < 1.0x"
    rows.append(dict(bench="fused_march", mode="replay_summary",
                     speedup=speedup, worst_psnr_delta_db=worst,
                     gate_ok=True))
    return rows, fns


def engine_section(args, fns):
    acfg = dataclasses.replace(serve_bench_acfg(block=64),
                               march_backend="fused")
    eng = RenderServingEngine({"lego": fns}, acfg, RenderServeConfig(
        slots=max(args.slots, 8), blocks_per_batch=4, reuse=None,
        inflight_batches=max(args.inflight, 2)))
    reqs = [RenderRequest(rid=i, scene="lego",
                          cam=scene.look_at_camera(
                              32, 32, theta=0.9 + 0.05 * i, phi=0.55))
            for i in range(max(args.slots, 8))]
    t0 = time.time()
    eng.render(reqs)
    wall = time.time() - t0
    st = eng.engine_stats()
    hist = st["batches_per_round"]
    print(f"  engine: {len(reqs)} frames in {wall:.2f}s, "
          f"march p50 {st['march_ms_p50']:.1f}ms, "
          f"batches/round {hist}")
    assert hist and max(hist) > 1, (
        f"GATE: no multi-batch rounds at {len(reqs)} slots: {hist}")
    return [dict(bench="fused_march", mode="engine", frames=len(reqs),
                 wall_s=wall, march_ms_p50=st["march_ms_p50"],
                 march_ms_p99=st["march_ms_p99"],
                 batches_per_round={str(k): v for k, v in hist.items()},
                 gate_ok=True)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--frames", type=int, default=3)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--inflight", type=int, default=2)
    args = ap.parse_args()
    print("[fused-march] replay: reference vs fused backend")
    rows, fns = replay_section(args)
    print("[fused-march] engine: streaming dispatch at "
          f">={max(args.slots, 8)} slots")
    rows += engine_section(args, fns)
    emit_rows("fused_march", rows)
    print("[fused-march] all gates OK")


if __name__ == "__main__":
    main()
