"""Figs. 4/8/15 — locality profiles that motivate the architecture."""
from __future__ import annotations

import numpy as np

from repro.core import pipeline, reuse, scene

from . import common


def run(quick: bool = False):
    fns, cfg, cam, _ = common.eval_setup("lego", quick)
    o, d = scene.camera_rays(cam)

    pts_a, _, _ = scene.sample_points(o[100:101], d[100:101], common.NS_FULL)
    pts_b, _, _ = scene.sample_points(o[101:102], d[101:102], common.NS_FULL)
    inter = reuse.inter_ray_repetition(pts_a[0], pts_b[0], cfg.grid)
    intra = reuse.intra_ray_max_voxel_count(pts_a[0], cfg.grid)

    _, aux = pipeline.render_fixed_fns(fns, o[:128], d[:128], common.NS_FULL)
    cos = reuse.adjacent_color_cosine(aux["colors"])

    tr_d = reuse.hash_address_trace(pts_a[0], cfg.grid, 0)
    tr_h = reuse.hash_address_trace(pts_a[0], cfg.grid, cfg.grid.n_levels - 1)
    return {
        "inter_ray_repetition_per_level": inter.tolist(),
        "intra_ray_max_count_per_level": intra.tolist(),
        "cosine_frac_above_0.95": float((cos > 0.95).mean()),
        "dense_addr_mean_jump": float(np.abs(np.diff(tr_d[:, 0])).mean()),
        "hash_addr_mean_jump": float(np.abs(np.diff(tr_h[:, 0])).mean()),
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value  # paper: Fig15a >90% low-res, Fig8 >95% cos~1")
    print(f"inter_ray_rep_L0,{r['inter_ray_repetition_per_level'][0]:.3f}")
    print(f"inter_ray_rep_Lmax,{r['inter_ray_repetition_per_level'][-1]:.3f}")
    print(f"intra_ray_max_L0,{r['intra_ray_max_count_per_level'][0]}")
    print(f"intra_ray_max_Lmax,{r['intra_ray_max_count_per_level'][-1]}")
    print(f"cos_frac_gt_0.95,{r['cosine_frac_above_0.95']:.3f}")
    print(f"dense_addr_jump,{r['dense_addr_mean_jump']:.1f}")
    print(f"hash_addr_jump,{r['hash_addr_mean_jump']:.1f}")
    return r
