"""Render-fleet benchmark: N engine replicas x one shared sharded cache.

  PYTHONPATH=src python benchmarks/render_fleet.py          # via make bench-fleet

The distributed-fleet workload (ROADMAP item): several RenderServingEngine
replicas — each with Stage-A speculation placed on secondary devices via
the DeviceExecutor — serve the SAME pose orbit concurrently against one
shared ``ShardedSceneCache``.  The pose overlap is the point: replicas
beyond the first should pull Phase-II block outputs from the shared store
instead of re-marching them, exactly the multi-client scene-space reuse
the cache exists for.

Gates (per replica count in --replicas, all must hold for ok):

  * every frame from every replica is BIT-IDENTICAL to a plain
    single-engine synchronous run of the same pose (so the PSNR delta vs
    that baseline is exactly 0.0 dB) — placement and sharding move where
    work runs and where blocks live, never what commits;
  * cross-replica reuse: at >= 2 replicas, replicas beyond the first
    record scene_block_hits > 0 (their blocks came from the shared
    store; laps=1 keeps within-replica hits out of the signal);
  * every shard stays within its per-shard byte budget;
  * aggregate fps (total frames / wall clock) >= 0.75x the single-sync
    baseline fps.  On this 1-core container replicas CONTEND for the
    same ALUs rather than overlapping, so aggregate throughput can only
    reach parity via shared-store hits, not exceed it — the 0.75 floor
    checks sharding/locking overhead stays small, not that a fleet
    scales on hardware that cannot.

The script forces 4 host devices itself (before the first jax import)
when XLA_FLAGS does not already pin a count, mirroring the launcher's
dry-run mode.  Rows append to out/bench/render_fleet.json.
"""
from __future__ import annotations

import os
import sys

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    # must precede the first jax import (jax locks device count on init)
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import argparse
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from common import emit_rows as _emit_rows, serve_bench_acfg
from repro.core import fields, scene
from repro.scenecache import SceneCacheConfig, ShardedSceneCache
from repro.serve import executor as executor_lib
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)


def trajectory_requests(scene_name, poses, size, dtheta, offset):
    return [RenderRequest(
        rid=offset + i, scene=scene_name,
        cam=scene.look_at_camera(size, size, theta=0.55 + dtheta * i,
                                 phi=0.5))
        for i in range(poses)]


def run_fleet(flds, acfg, args, n_replicas):
    """n_replicas engines over one shared sharded cache; returns
    (frames per replica, wall seconds, engines, shared cache)."""
    shared = ShardedSceneCache(
        SceneCacheConfig(byte_budget=args.scenecache_mb << 20),
        shards=args.shards)
    cfg = RenderServeConfig(slots=2, blocks_per_batch=8,
                            reuse=None, radiance=None,
                            prefetch=2, devices=2)
    engines = [RenderServingEngine(flds, acfg, cfg, scenecache=shared)
               for _ in range(n_replicas)]
    results = [None] * n_replicas

    def worker(i):
        # staggered start: replica i replays the orbit after replica
        # i-1 has begun populating the shared store
        time.sleep(0.25 * i)
        reqs = []
        for t in range(args.trajectories):
            offset = (i * args.trajectories + t) * args.poses
            reqs.extend(trajectory_requests(
                args.scene, args.poses, args.size, args.dtheta, offset))
        results[i] = engines[i].render(reqs)

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n_replicas)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0
    return results, wall, engines, shared


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="mic")
    ap.add_argument("--poses", type=int, default=6,
                    help="orbit length each trajectory replays")
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--dtheta", type=float, default=0.04)
    ap.add_argument("--trajectories", type=int, default=1,
                    help="trajectories per replica (same orbit, fresh rids)")
    ap.add_argument("--replicas", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--scenecache-mb", type=int, default=8)
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()

    import jax
    print(f"== render_fleet: {len(jax.devices())} devices, "
          f"orbit {args.poses} poses x {args.trajectories} traj, "
          f"{args.size}x{args.size}, scene={args.scene}, "
          f"sharded cache {args.scenecache_mb} MB / {args.shards} shards ==")
    field = scene.make_scene(args.scene)
    flds = {args.scene: fields.analytic_field_fns(field)}
    acfg = serve_bench_acfg()

    # single synchronous no-cache engine: the bit-identity baseline AND
    # the fps comparator (run_engine-style warm pass compiles the march
    # into the shared module cache first, keeping clocks compile-free)
    base_cfg = RenderServeConfig(slots=2, blocks_per_batch=8,
                                 reuse=None, radiance=None)
    base_reqs = trajectory_requests(args.scene, args.poses, args.size,
                                    args.dtheta, 0)
    warm = RenderServingEngine(flds, acfg, base_cfg)
    warm.render([base_reqs[0]])
    warm.close()
    eng0 = RenderServingEngine(flds, acfg, base_cfg)
    t0 = time.time()
    ref_frames = eng0.render(list(base_reqs))
    base_dt = time.time() - t0
    eng0.close()
    base_fps = len(ref_frames) / base_dt
    ref = {r.rid % args.poses: r.image for r in ref_frames}
    print(f"  baseline single sync engine : {base_fps:5.2f} fps "
          f"({base_dt:.2f}s for {len(ref_frames)} frames)")

    # warm the fleet path too: Stage-A jits compile per DEVICE, and the
    # baseline warm pass only touched device 0 — one untimed fleet pass
    # compiles probe/warp on both secondary devices (round-robin visits
    # each) so the replicas=1 clock stays compile-free
    _res, _w, wengs, wcache = run_fleet(flds, acfg, args, 1)
    for e in wengs:
        e.close()
    wcache.close()

    rows, all_ok = [], True
    for n in args.replicas:
        results, wall, engines, shared = run_fleet(flds, acfg, args, n)
        frames = [r for res in results for r in res]
        fps = len(frames) / wall

        identical = all(
            np.array_equal(r.image, ref[r.rid % args.poses])
            for r in frames)
        # 20*log10 of a zero max-abs-diff is exactly a 0.0 dB delta
        max_abs = max(
            float(np.max(np.abs(
                np.asarray(r.image, np.float64)
                - np.asarray(ref[r.rid % args.poses], np.float64))))
            for r in frames)
        cross_hits = sum(e.engine_stats()["scene_block_hits"]
                         for e in engines[1:])
        st = shared.stats()
        budget_ok = all(b <= st["per_shard_budget"]
                        for b in st["per_shard_resident_bytes"])
        device_ok = all(
            isinstance(e.executor, executor_lib.DeviceExecutor)
            for e in engines)
        for e in engines:
            e.close()
        shared.close()

        fps_ok = fps >= 0.75 * base_fps
        reuse_ok = (n < 2) or cross_hits > 0
        ok = identical and budget_ok and device_ok and fps_ok and reuse_ok
        all_ok &= ok
        print(f"  replicas {n}: {fps:5.2f} fps aggregate "
              f"({len(frames)} frames / {wall:.2f}s)  "
              f"bit-identical {'yes' if identical else 'NO'} "
              f"(max|diff| {max_abs:.1e} -> delta "
              f"{'0.0' if identical else '>0'} dB)  "
              f"cross-replica hits {cross_hits}  "
              f"hit_rate {st['hit_rate']:.3f}  "
              f"{'OK' if ok else 'FAIL'}")
        rows.append({
            "bench": "fleet", "scene": args.scene, "size": args.size,
            "poses": args.poses, "trajectories": args.trajectories,
            "replicas": n, "devices_per_replica": 2,
            "shards": args.shards,
            "scenecache_mb": args.scenecache_mb,
            "fps_aggregate": fps, "fps_single_sync": base_fps,
            "frames": len(frames),
            "frames_identical": identical,
            "psnr_delta_db": 0.0 if identical else float("inf"),
            "cross_replica_hits": cross_hits,
            "shared_hit_rate": st["hit_rate"],
            "per_shard_resident_bytes": st["per_shard_resident_bytes"],
            "per_shard_budget": st["per_shard_budget"],
            "budget_ok": budget_ok,
            "fps_floor_note": "0.75x single-sync floor: 1-core container "
                              "— replicas contend, shared-store hits buy "
                              "back the contention; the floor gates "
                              "sharding overhead, not hardware scaling",
            "ok": ok,
        })
    print(f"  acceptance (bit-identical frames -> 0.0 dB, cross-replica "
          f"hits > 0 at >= 2 replicas, per-shard budgets hold, aggregate "
          f"fps >= 0.75x single sync): {'OK' if all_ok else 'FAIL'}")
    _emit_rows("render_fleet", rows)
    return all_ok


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
