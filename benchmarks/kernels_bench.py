"""Per-kernel microbenchmarks (interpret-mode CPU — correctness path cost,
NOT TPU perf; the TPU story is the roofline report)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashgrid, model as model_lib
from repro.kernels import ops

from . import common


def run(quick: bool = False):
    cfg = model_lib.NGPConfig.small()
    params = model_lib.init_ngp(jax.random.PRNGKey(0), cfg)
    n = 2048 if quick else 8192
    pts = jax.random.uniform(jax.random.PRNGKey(1), (n, 3))
    dirs = pts / jnp.linalg.norm(pts, axis=-1, keepdims=True)
    enc = hashgrid.encode(pts, params["grid"], cfg.grid)

    rows = {}
    rows["hash_encode_kernel_us"] = 1e6 * common.timer(
        lambda: ops.hash_encode(pts, params["grid"], cfg.grid))
    rows["hash_encode_ref_us"] = 1e6 * common.timer(
        jax.jit(lambda p: hashgrid.encode(p, params["grid"], cfg.grid)), pts)
    rows["fused_mlp_kernel_us"] = 1e6 * common.timer(
        lambda: ops.fused_field(enc, dirs, params["mlps"], cfg.net))
    R, S, g = 256, 96, 2
    sig = jax.random.uniform(jax.random.PRNGKey(2), (R, S)) * 5
    anch = jax.random.uniform(jax.random.PRNGKey(3), (R, -(-S // g), 3))
    dl = jnp.full((R, S), 0.02)
    rows["volume_render_kernel_us"] = 1e6 * common.timer(
        lambda: ops.volume_render(sig, anch, dl, g))
    return rows


def main(quick: bool = False):
    r = run(quick)
    print("name,us_per_call")
    for k, v in r.items():
        print(f"{k},{v:.0f}")
    return r
