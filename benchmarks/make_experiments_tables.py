"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from
results/dryrun/*.json (single source of truth)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def fmt_cell(r):
    t = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| {t['bottleneck']} | {t['roofline_fraction_compute']:.3f} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r['memory']['temp_bytes']/1e9:.2f} |")


def main():
    for mesh in ("single", "multi"):
        print(f"\n### Mesh: {'(16,16) = 256 chips' if mesh=='single' else '(2,16,16) = 512 chips'}\n")
        print("| arch | shape | compute (s) | memory (s) | collective (s) "
              "| bottleneck | frac-of-roofline | useful/executed | peak GB/chip |")
        print("|---|---|---|---|---|---|---|---|---|")
        for p in sorted(RESULTS.glob(f"*_{mesh}.json")):
            r = json.loads(p.read_text())
            if r.get("skipped"):
                print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"SKIP (full attention; DESIGN.md §4) | — | — | — |")
                continue
            print(fmt_cell(r))
    # opt variants
    print("\n### §Perf optimized variants (hillclimbed cells)\n")
    print("| arch | shape | variant | compute (s) | memory (s) "
          "| collective (s) | bottleneck | peak GB/chip |")
    print("|---|---|---|---|---|---|---|---|")
    for p in sorted(RESULTS.glob("*_opt.json")):
        r = json.loads(p.read_text())
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | opt | {t['compute_s']:.4f} "
              f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} "
              f"| {t['bottleneck']} | {r['memory']['temp_bytes']/1e9:.2f} |")


if __name__ == "__main__":
    main()
