"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only quality,sweeps]
"""
from __future__ import annotations

import argparse
import time
import traceback

MODULES = [
    "quality",        # Fig 16 / Table 3
    "speedup",        # Fig 17 / Fig 24 (software-only analogue)
    "phase_split",    # Fig 18
    "ablation",       # Fig 20
    "sweeps",         # Fig 21
    "reuse_cache",    # Fig 22 (+ Fig 13 utilization)
    "early_term",     # Fig 23
    "locality",       # Figs 4 / 8 / 15
    "kernels_bench",  # per-kernel timings
    "roofline_report",  # EXPERIMENTS.md §Roofline source
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    ok, failed = [], []
    for name in mods:
        print(f"\n{'='*70}\n# benchmark: {name}\n{'='*70}", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
            ok.append(name)
            print(f"# [{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print(f"\n# benchmarks complete: {len(ok)} ok, {len(failed)} failed "
          f"({','.join(failed) if failed else '-'})")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
