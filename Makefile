# Tier-1 verification targets.  `make test` is the CI entry point: the
# fast subset (slow train/e2e tests excluded via pytest.ini addopts),
# bounded well under 120 s on this container.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-full lint bench-serve bench-serve-sweep \
        bench-serve-latency bench-serve-workers bench-scenecache \
        bench-scenecache-budgets dryrun-serve

test:
	$(PY) -m pytest -x -q

test-full:
	$(PY) -m pytest -m "" -q

# ruff > pyflakes > the ast-based fallback in tools/lint.py (this
# container bakes in neither linter; CI installs ruff), plus the
# file-size budget check (the serve facade must stay a thin loop)
lint:
	$(PY) tools/lint.py src tests benchmarks examples tools
	$(PY) tools/check_sizes.py

bench-serve:
	$(PY) benchmarks/render_serve.py

bench-serve-sweep:
	$(PY) benchmarks/render_serve.py --sweep

bench-serve-latency:
	$(PY) benchmarks/render_serve.py --latency

bench-serve-workers:
	$(PY) benchmarks/render_serve.py --workers

bench-scenecache:
	$(PY) benchmarks/scene_cache.py

bench-scenecache-budgets:
	$(PY) benchmarks/scene_cache.py --budgets

dryrun-serve:
	$(PY) -m repro.launch.render_serve --dryrun
