# Tier-1 verification targets.  `make test` is the CI entry point: the
# fast subset (slow train/e2e tests excluded via pytest.ini addopts),
# bounded well under 120 s on this container.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fleet test-full lint bench-serve bench-serve-sweep \
        bench-serve-latency bench-serve-workers bench-obs \
        bench-scenecache bench-scenecache-budgets bench-fleet \
        bench-march bench-march-smoke bench-slo dryrun-serve

test:
	$(PY) -m pytest -x -q

# multi-device fleet lane: jax locks the device count at init, so these
# tests need their own interpreter with forced host devices (cheap CPU
# stand-in for a multi-chip host; see tests/test_fleet.py)
test-fleet:
	XLA_FLAGS="--xla_force_host_platform_device_count=4$(if $(XLA_FLAGS), $(XLA_FLAGS))" \
	$(PY) -m pytest -x -q -m fleet

test-full:
	$(PY) -m pytest -m "" -q

# ruff > pyflakes > the ast-based fallback in tools/lint.py (this
# container bakes in neither linter; CI installs ruff), plus the
# file-size budget check (the serve facade must stay a thin loop) and
# the trace-format self-test (exporter -> validator round trip)
lint:
	$(PY) tools/lint.py src tests benchmarks examples tools
	$(PY) tools/check_sizes.py
	$(PY) tools/check_trace.py

bench-serve:
	$(PY) benchmarks/render_serve.py

bench-serve-sweep:
	$(PY) benchmarks/render_serve.py --sweep

bench-serve-latency:
	$(PY) benchmarks/render_serve.py --latency

bench-serve-workers:
	$(PY) benchmarks/render_serve.py --workers

# tracing-overhead gate: tracer on must cost <= 5% fps at 0.0 dB delta
bench-obs:
	$(PY) benchmarks/render_serve.py --obs

bench-scenecache:
	$(PY) benchmarks/scene_cache.py

bench-scenecache-budgets:
	$(PY) benchmarks/scene_cache.py --budgets

# fused single-kernel march vs chunked reference: <=0.1 dB + speedup
# >=1.0 gates on a trained NGP, the FULL-config (64 MB tables) streamed
# section at >=2x with the resident pin refused, the per-ray-exit skip
# counter, and the streaming-dispatch round gate; writes the canonical
# BENCH_fused_march.json at the repo root
bench-march:
	$(PY) benchmarks/fused_march.py --quick

# nightly regression smoke: one small replay frame asserting chunks
# parity + the 0.1 dB ceiling (no root summary rewrite)
bench-march-smoke:
	$(PY) benchmarks/fused_march.py --smoke

# SLO gate: open-loop Poisson overload — at the deepest factor
# ShedPolicy must hold rt-class p99 under the FIFO baseline with
# sheds > 0; lighter factors gate non-regression only (smoke = one
# factor, best-of-2; drop --smoke for the full 0.7/1.5/2.5x sweep)
bench-slo:
	$(PY) benchmarks/render_serve.py --slo --smoke

# N engine replicas x one shared sharded scenecache (the script forces
# 4 host devices itself when XLA_FLAGS doesn't already pin a count)
bench-fleet:
	$(PY) benchmarks/render_fleet.py

dryrun-serve:
	$(PY) -m repro.launch.render_serve --dryrun
