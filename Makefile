# Tier-1 verification targets.  `make test` is the CI entry point: the
# fast subset (slow train/e2e tests excluded via pytest.ini addopts),
# bounded well under 120 s on this container.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-full bench-serve dryrun-serve

test:
	$(PY) -m pytest -x -q

test-full:
	$(PY) -m pytest -m "" -q

bench-serve:
	$(PY) benchmarks/render_serve.py

dryrun-serve:
	$(PY) -m repro.launch.render_serve --dryrun
