"""Batched LM serving with the slot engine (prefill + decode).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b --requests 6

Loads a reduced config of the chosen architecture (random weights — the
point is the serving machinery: batched prefill, KV caches with ring
buffers for local-attention layers, greedy/temperature sampling, slot
waves) and reports per-request latency + aggregate decode throughput.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

import jax
import numpy as np

import repro.configs as configs
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(configs.get_smoke(args.arch), dtype="float32")
    api = lm.build(cfg, remat_policy=None)
    values = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(api, values, ServeConfig(
        max_seq=args.prompt_len + args.max_new + 8, slots=4,
        temperature=args.temperature,
    ))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    print(f"== serving {args.requests} requests on {cfg.name} "
          f"(slots=4, greedy={args.temperature == 0.0}) ==")
    t0 = time.time()
    done = eng.generate(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:6]={r.prompt[:6].tolist()} -> "
              f"out[:8]={r.out[:8].tolist()} ({r.latency_s:.2f}s)")
    print(f"\n{tok} tokens in {dt:.2f}s = {tok/dt:.1f} tok/s "
          f"(CPU, reduced config)")


if __name__ == "__main__":
    main()
