"""End-to-end LM training driver (few hundred steps, CPU-sized).

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b --steps 200

Uses the production stack end to end: config registry -> model zoo ->
train-step factory (microbatching, clipping, schedule, AdamW) -> data
pipeline -> checkpoint manager with restart.  ``--arch`` picks any of the
10 assigned architectures (reduced same-family config on CPU; the FULL
config runs through the identical path on the production mesh — see
launch/dryrun.py).  A ~100M-parameter variant is selected with
--width 512 --layers 8 --vocab 32000 (expect minutes/step on 1 CPU core;
the default is sized for this container).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

import repro.configs as configs
from repro.launch.train import train_loop
from repro.models import lm
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--width", type=int, default=0, help="override d_model")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    over = {}
    if args.width:
        over.update(d_model=args.width, head_dim=args.width // cfg.n_heads)
    if args.layers:
        over["n_layers"] = args.layers
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    api = lm.build(cfg, remat_policy=None)
    n_params = cfg.param_count()
    print(f"== training {cfg.name} ({n_params/1e6:.1f}M params) "
          f"for {args.steps} steps ==")

    tcfg = TrainConfig(
        microbatches=args.microbatches, lr=1e-3,
        warmup_steps=max(2, args.steps // 20), total_steps=args.steps,
    )
    _, _, losses = train_loop(
        api, tcfg, args.steps, args.batch, args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=50,
    )
    print(f"\nloss: {losses[0][1]:.3f} -> {losses[-1][1]:.3f} "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()
