"""Full ASDR two-phase rendering walkthrough with per-stage statistics.

  PYTHONPATH=src python examples/asdr_render.py [--kernels]

Renders through the composable pipeline on the EXACT analytic field (no
training error in the way), showing Phase I probe -> per-pixel counts ->
Phase II sorted-block marching with early termination, and optionally the
Pallas-kernel-backed field path (--kernels, interpret mode on CPU).
Writes side-by-side PPM images into ./out/.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import fields, pipeline, rendering, scene


def write_ppm(path, img):
    img8 = np.asarray(np.clip(np.asarray(img) * 255, 0, 255), np.uint8)
    h, w, _ = img8.shape
    with open(path, "wb") as f:
        f.write(f"P6 {w} {h} 255\n".encode())
        f.write(img8.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="hotdog")
    ap.add_argument("--size", type=int, default=96)
    ap.add_argument("--kernels", action="store_true",
                    help="drive the pipeline through the Pallas kernels")
    args = ap.parse_args()

    field = scene.make_scene(args.scene)
    fns = fields.analytic_field_fns(field)
    if args.kernels:
        # kernel path needs a trained model (it renders the network);
        # quickest: tiny train then wrap kernels ops
        from repro.core import train as T
        from repro.kernels import ops
        params, cfg, field, _ = T.train_ngp(T.NGPTrainConfig(
            scene=args.scene, steps=120, batch_rays=1024, n_samples=48,
            n_views=6, view_hw=(64, 64)))
        fns = ops.field_fns(params, cfg)
        print("[kernel path] pipeline driven by Pallas interpret-mode kernels")

    cam = scene.look_at_camera(args.size, args.size, theta=0.7, phi=0.5)
    o, d = scene.camera_rays(cam)

    acfg = pipeline.ASDRConfig(ns_full=128, probe_stride=5,
                               candidates=(16, 32, 64),
                               block_size=256, chunk=16)

    print("== Phase I: probe ==")
    t0 = time.time()
    counts, probe_cost = pipeline.probe_phase(fns, acfg, cam)
    hist = {int(v): int((counts == v).sum()) for v in np.unique(counts)}
    print(f"  probe cost {probe_cost} samples; count histogram: {hist}")

    print("== Phase II: sorted-block adaptive march ==")
    img, stats = pipeline.render_asdr_image(fns, acfg, cam)
    print(f"  avg samples/ray  : {stats['avg_samples_per_ray']:.1f} "
          f"(baseline {acfg.ns_full})")
    print(f"  phase-II samples : {float(stats['samples_processed']):.0f} "
          f"({100*float(stats['phase2_fraction_of_baseline']):.1f}% of baseline)")
    print(f"  wall time        : {time.time()-t0:.2f}s")

    base, _ = pipeline.render_fixed_fns(fns, o, d, acfg.ns_full)
    base = base.reshape(args.size, args.size, 3)
    print(f"  PSNR ASDR vs fixed-{acfg.ns_full}: "
          f"{float(rendering.psnr(img, base)):.2f} dB")

    out = Path("out")
    out.mkdir(exist_ok=True)
    write_ppm(out / "asdr.ppm", img)
    write_ppm(out / "baseline.ppm", base)
    heat = np.asarray(counts, np.float32).reshape(args.size, args.size)
    heat = (heat - heat.min()) / max(np.ptp(heat), 1)
    write_ppm(out / "difficulty.ppm",
              np.stack([heat, 0.2 + 0 * heat, 1.0 - heat], -1))
    print(f"  wrote {out}/asdr.ppm, baseline.ppm, difficulty.ppm "
          "(red = hard pixels, blue = easy — paper Fig. 7)")


if __name__ == "__main__":
    main()
