"""Multi-user render serving demo: pooled blocks + cross-frame reuse.

  PYTHONPATH=src python examples/render_serve.py [--frames 12] [--size 64]

Simulates two users orbiting two different scenes at once.  Their render
requests interleave in the engine's slots; every scheduling round pools
the Phase-II blocks of all live frames into budget-sorted batches.  Each
user's smooth trajectory reuses its own cached maps through both
framecache tiers: Phase-I probe maps warp to nearby poses instead of
re-probing, and finished Phase-II frames warp forward so later frames
march only their disoccluded rays.

Writes out/serve_<scene>_<frame>.ppm plus a per-frame stats table.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import fields, pipeline, scene
from repro.framecache import ProbeReuseConfig, RadianceReuseConfig
from repro.scenecache import SceneCacheConfig
from repro.serve.render_engine import (RenderRequest, RenderServeConfig,
                                       RenderServingEngine)


def write_ppm(path, img):
    img8 = np.asarray(np.clip(np.asarray(img) * 255, 0, 255), np.uint8)
    h, w, _ = img8.shape
    with open(path, "wb") as f:
        f.write(f"P6 {w} {h} 255\n".encode())
        f.write(img8.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=12,
                    help="frames per user trajectory")
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--scenes", nargs=2, default=("hotdog", "mic"))
    ap.add_argument("--spectators", type=int, default=0,
                    help="extra users replaying user 0's exact poses — "
                         "their blocks hit the shared scene-space store")
    args = ap.parse_args()

    acfg = pipeline.ASDRConfig(
        ns_full=96, probe_stride=4, candidates=(12, 24, 48),
        block_size=256, chunk=16, sort_by_opacity=False)
    flds = {s: fields.analytic_field_fns(scene.make_scene(s))
            for s in args.scenes}
    eng = RenderServingEngine(flds, acfg, RenderServeConfig(
        slots=4, blocks_per_batch=16,
        reuse=ProbeReuseConfig(max_angle_deg=3.0, max_translation=0.05,
                               refresh_every=6),
        radiance=RadianceReuseConfig(max_angle_deg=1.5, max_translation=0.03,
                                     refresh_every=6),
        scenecache=(SceneCacheConfig(byte_budget=16 << 20)
                    if args.spectators else None)))

    # two users, interleaved frame requests along their own orbits; any
    # --spectators ride user 0's poses and share its blocks scene-side
    reqs = []
    for f in range(args.frames):
        for u, sc in enumerate(args.scenes):
            reqs.append(RenderRequest(
                rid=len(reqs), scene=sc,
                cam=scene.look_at_camera(
                    args.size, args.size,
                    theta=0.6 + 0.008 * f + 0.3 * u, phi=0.5)))
        for s in range(args.spectators):
            reqs.append(RenderRequest(
                rid=len(reqs), scene=args.scenes[0],
                cam=scene.look_at_camera(
                    args.size, args.size, theta=0.6 + 0.008 * f, phi=0.5)))

    t0 = time.time()
    done = eng.render(reqs)
    dt = time.time() - t0

    out = Path("out")
    out.mkdir(exist_ok=True)
    print(f"{'frame':>5} {'scene':>8} {'probe':>7} {'phase2':>7} "
          f"{'rays':>11} {'samples':>9}")
    per_scene = {s: 0 for s in args.scenes}
    for r in sorted(done, key=lambda r: r.rid):
        tag = ("skipped" if r.stats["probe_skipped"]
               else "reused" if r.stats["probe_reused"] else "probed")
        rtag = "warped" if r.stats["radiance_reused"] else "marched"
        rays = f"{r.stats['rays_marched']}/{r.stats['rays_total']}"
        print(f"{r.rid:>5} {r.scene:>8} {tag:>7} {rtag:>7} {rays:>11} "
              f"{r.stats['samples_processed']:>9}")
        write_ppm(out / f"serve_{r.scene}_{per_scene[r.scene]:03d}.ppm",
                  r.image)
        per_scene[r.scene] += 1

    st = eng.engine_stats()
    print(f"\n[engine] {st['frames']} frames in {dt:.2f}s = "
          f"{st['frames']/dt:.2f} fps aggregate")
    print(f"  reused-probe fraction {st['reused_probe_fraction']:.2f} "
          f"({st['probe_hits']} hits, {st['probe_skips']} skips, "
          f"{st['probe_misses']} probes, "
          f"{st['probe_refreshes']} refreshes)")
    print(f"  reused-radiance fraction {st['reused_radiance_fraction']:.2f}, "
          f"rays marched {100 * st['rays_marched_fraction']:.1f}% of total")
    print(f"  {st['batches']} pooled batches, pad fraction "
          f"{st['pad_block_fraction']:.2f}")
    if eng.scenecache is not None:
        sc = st["scenecache"]
        print(f"  scene-block hit rate {st['scene_block_hit_rate']:.2f} "
              f"({st['scene_block_hits']} hits), resident "
              f"{sc['resident_bytes'] / (1 << 20):.2f} MB, "
              f"{sc['evictions']} evictions")
    print(f"  wrote {sum(per_scene.values())} frames to {out}/")


if __name__ == "__main__":
    main()
