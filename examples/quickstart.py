"""Quickstart: train Instant-NGP on an analytic scene, render with ASDR.

  PYTHONPATH=src python examples/quickstart.py [--steps 150]

Trains a small hash-grid NeRF on the procedural "lego" scene, then renders
one view three ways — fixed-count baseline, ASDR two-phase adaptive, naive
half-sampling — and prints the paper's headline comparison (ASDR ~=
baseline quality with ~2x fewer samples; naive halving visibly worse).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


from repro.core import model as model_lib, pipeline, rendering, scene
from repro.core import train as train_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--size", type=int, default=64)
    args = ap.parse_args()

    print(f"== training Instant-NGP on analytic '{args.scene}' "
          f"({args.steps} steps) ==")
    tcfg = train_lib.NGPTrainConfig(
        scene=args.scene, steps=args.steps, batch_rays=1024, n_samples=48,
        n_views=6, view_hw=(64, 64), log_every=50,
    )
    params, cfg, field, hist = train_lib.train_ngp(tcfg)

    fns = model_lib.field_fns(params, cfg)
    cam = scene.look_at_camera(args.size, args.size, theta=0.9, phi=0.55)
    o, d = scene.camera_rays(cam)
    ref, _ = scene.render_reference(field, o, d)
    ref = ref.reshape(args.size, args.size, 3)

    print("== rendering ==")
    base, _ = pipeline.render_fixed_fns(fns, o, d, 96)
    base = base.reshape(args.size, args.size, 3)

    acfg = pipeline.ASDRConfig(ns_full=96, probe_stride=4,
                               candidates=(12, 24, 48),
                               block_size=256, chunk=16)
    asdr_img, stats = pipeline.render_asdr_image(fns, acfg, cam)

    naive, _ = pipeline.render_fixed_fns(fns, o, d, 48)
    naive = naive.reshape(args.size, args.size, 3)

    p = rendering.psnr
    print(f"\nPSNR vs analytic reference:")
    print(f"  fixed-96 baseline : {float(p(base, ref)):6.2f} dB")
    print(f"  ASDR (two-phase)  : {float(p(asdr_img, ref)):6.2f} dB   "
          f"avg {stats['avg_samples_per_ray']:.0f} samples/ray "
          f"({stats['sample_reduction']:.2f}x fewer)")
    print(f"  naive half (48)   : {float(p(naive, ref)):6.2f} dB")
    print(f"\nASDR vs baseline drop: "
          f"{float(p(base, ref)) - float(p(asdr_img, ref)):.2f} dB "
          f"(paper: ~0.07)")


if __name__ == "__main__":
    main()
