#!/usr/bin/env python
"""Repo lint driver: ruff > pyflakes > built-in fallback.

  python tools/lint.py [paths...]      (default: src tests benchmarks examples)

The container this repo grows in has no lint package baked in, so when
neither ruff nor pyflakes is importable we fall back to a minimal
checker that catches the two highest-value classes cheaply:

  * syntax errors (ast.parse), and
  * module-level unused imports (a name imported but never referenced
    anywhere in the module — comparisons are on the AST, so names used
    in annotations, decorators, f-strings or nested scopes all count).

An import line carrying ``# noqa`` is exempt, matching ruff/pyflakes
convention (re-export modules like package __init__ use it).
Exit code 1 on any finding; used by ``make lint`` and CI.
"""
from __future__ import annotations

import ast
import subprocess
import sys
from pathlib import Path

DEFAULT_PATHS = ["src", "tests", "benchmarks", "examples", "tools"]


def _try_external(paths):
    """Run ruff or pyflakes if available; return exit code or None."""
    probes = (
        (["ruff", "check"], ["ruff", "--version"]),
        ([sys.executable, "-m", "ruff", "check"],
         [sys.executable, "-m", "ruff", "--version"]),
        ([sys.executable, "-m", "pyflakes"],
         [sys.executable, "-c", "import pyflakes"]),
    )
    for cmd, probe in probes:
        try:
            if subprocess.run(probe, capture_output=True).returncode != 0:
                continue
        except FileNotFoundError:
            continue
        proc = subprocess.run(cmd + paths, capture_output=True, text=True)
        sys.stdout.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        tool = "ruff" if "ruff" in " ".join(cmd) else "pyflakes"
        print(f"[lint] checked with {tool}: "
              f"{'clean' if proc.returncode == 0 else 'FINDINGS'}")
        return proc.returncode
    return None


def _imported_names(node):
    """(alias, lineno) pairs bound by an import statement."""
    out = []
    for alias in node.names:
        if alias.name == "*":
            continue
        bound = alias.asname or alias.name.split(".")[0]
        out.append((bound, node.lineno))
    return out


def check_file(path: Path):
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    if path.name == "__init__.py":
        return []   # package surface: imports ARE the point (ruff F401 rule)
    lines = src.splitlines()
    imports = []   # (name, lineno)
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if isinstance(node, ast.ImportFrom) and node.module == "__future__":
                continue
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if "# noqa" in line:
                continue
            imports.append(_imported_names(node))
    if not imports:
        return []
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            pass  # the root Name of a dotted use is a Name node anyway
    # __all__ strings count as uses (explicit re-export)
    for node in tree.body:
        if (isinstance(node, ast.Assign) and
                any(isinstance(t, ast.Name) and t.id == "__all__"
                    for t in node.targets)):
            for c in ast.walk(node.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    used.add(c.value)
    problems = []
    for group in imports:
        for name, lineno in group:
            if name not in used:
                problems.append(
                    f"{path}:{lineno}: '{name}' imported but unused")
    return problems


def main(argv):
    paths = argv or DEFAULT_PATHS
    code = _try_external(paths)
    if code is not None:
        return code
    files = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    problems = []
    for f in files:
        problems.extend(check_file(f))
    for line in problems:
        print(line)
    print(f"[lint] fallback checker: {len(files)} files, "
          f"{len(problems)} findings")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
