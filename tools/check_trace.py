#!/usr/bin/env python
"""Validate exported Chrome/Perfetto trace files (the obs contract).

  python tools/check_trace.py out/trace.json [...]   validate files
  python tools/check_trace.py                        self-test (make lint)

Checks, per file (see src/repro/obs/README.md for the format contract):

  * structure — ``traceEvents`` list present; every non-metadata event
    is a complete span (``ph: "X"``) with name / pid / tid / ts / dur
    and an ``args`` dict carrying its ``sid`` and ``parent``;
  * balanced spans — sids unique; every nonzero parent refers to a span
    in the file whose [ts, ts+dur] interval CONTAINS the child's (same
    lane — parents are the innermost open span on the recording
    thread), up to a float-rounding epsilon;
  * monotonic timestamps — ts >= 0 and dur >= 0 everywhere;
  * known lanes — every tid is declared by a ``thread_name`` metadata
    event, and every lane name matches the taxonomy (engine main
    thread, serve-stage-a workers, serve-dev device queues,
    scenecache-fetch pool, or a pytest/driver thread);
  * replica namespaces — spans, lanes, and parent links are validated
    PER ``pid``: a merged fleet timeline (export.merge_chrome_traces)
    carries one process group per replica, and sids are only unique
    within their replica's tracer.

With no arguments the script self-tests: it records a tiny two-thread
span tree through ``repro.obs`` itself, exports it, and validates the
result — so ``make lint`` exercises the exporter + this checker without
needing a rendered trace on disk.  Exit code 1 on any finding.
"""
from __future__ import annotations

import json
import re
import sys
import tempfile
from pathlib import Path

# lane taxonomy: the thread names the serving stack records under
# (obs/trace.py lane = thread name) plus generic driver threads
LANE_PATTERNS = (
    r"MainThread",
    r"engine.*",
    r"serve-stage-a.*",          # ThreadedExecutor workers
    r"serve-dev\d+.*",           # DeviceExecutor per-device queues
    r"scenecache-fetch.*",       # ShardedSceneCache fetch pool
    r"shard-.*",
    r"Thread-\d+.*",             # bare threading.Thread (tests/drivers)
    r"Dummy-\d+.*",
    r"(pytest|asyncio).*",
)
_LANE_RE = re.compile("^(%s)$" % "|".join(LANE_PATTERNS))
_EPS_US = 50.0      # parent/child containment slack (clock rounding)


def validate(data: dict) -> list:
    """All contract violations in one parsed trace dict (empty = ok)."""
    errs = []
    events = data.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    lanes = {}              # (pid, tid) -> lane name
    spans = {}              # (pid, sid) -> event: sids are per-replica
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                lanes[(ev.get("pid"), ev.get("tid"))] = \
                    ev.get("args", {}).get("name", "")
            continue
        if ph != "X":
            errs.append(f"event {i}: unexpected phase {ph!r}")
            continue
        for field in ("name", "pid", "tid", "ts", "dur"):
            if field not in ev:
                errs.append(f"event {i}: missing {field!r}")
        ts, dur = ev.get("ts", 0), ev.get("dur", 0)
        if ts < 0:
            errs.append(f"event {i} ({ev.get('name')}): negative ts {ts}")
        if dur < 0:
            errs.append(f"event {i} ({ev.get('name')}): negative dur {dur}")
        args = ev.get("args")
        if not isinstance(args, dict) or "sid" not in args \
                or "parent" not in args:
            errs.append(f"event {i} ({ev.get('name')}): args must carry "
                        f"sid + parent")
            continue
        key = (ev.get("pid"), args["sid"])
        if key in spans:
            errs.append(f"event {i}: duplicate sid {key[1]} in "
                        f"pid {key[0]}")
        spans[key] = ev
    # balanced spans: parent exists (same replica) and contains the
    # child (same lane)
    for (pid, sid), ev in spans.items():
        parent = ev["args"]["parent"]
        if parent == 0:
            continue
        pev = spans.get((pid, parent))
        if pev is None:
            errs.append(f"span {sid} ({ev['name']}): parent {parent} "
                        f"not in trace")
            continue
        if pev["tid"] != ev["tid"]:
            errs.append(f"span {sid} ({ev['name']}): parent on a "
                        f"different lane")
        if ev["ts"] < pev["ts"] - _EPS_US or \
                ev["ts"] + ev["dur"] > pev["ts"] + pev["dur"] + _EPS_US:
            errs.append(f"span {sid} ({ev['name']}): not contained in "
                        f"parent {parent} ({pev['name']})")
    # known lanes: every span's tid declared, every lane name known
    for (pid, sid), ev in spans.items():
        if (pid, ev["tid"]) not in lanes:
            errs.append(f"span {sid} ({ev['name']}): tid {ev['tid']} has "
                        f"no thread_name metadata")
    for (pid, tid), name in lanes.items():
        if not _LANE_RE.match(name):
            errs.append(f"lane tid={tid}: unknown lane name {name!r}")
    return errs


def check_file(path) -> list:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace: {e}"]
    return validate(data)


def self_test() -> list:
    """Record a tiny two-thread span tree and validate its export."""
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    import threading

    from repro.obs import TraceConfig, Tracer, install, uninstall

    tracer = Tracer(TraceConfig())
    install(tracer)
    try:
        with tracer.span("admission.wait", req=0, scene="mic"):
            with tracer.span("stage_a.prepare", req=0):
                pass
        t = threading.Thread(
            target=lambda: tracer.span("executor.run",
                                       backend="threaded").__enter__()
            .__exit__(None, None, None),
            name="serve-stage-a_0")
        t.start()
        t.join()
        tracer.drain()
        with tempfile.TemporaryDirectory() as d:
            path = Path(d) / "trace.json"
            tracer.cfg = TraceConfig(path=str(path))
            tracer.finish()
            errs = check_file(path)
        n = len(tracer.spans)
        if n != 3:
            errs.append(f"self-test recorded {n} spans, expected 3")
        return errs
    finally:
        uninstall(tracer)


def main(argv) -> int:
    if argv:
        bad = 0
        for path in argv:
            errs = check_file(path)
            for e in errs:
                print(f"{path}: {e}")
            bad += bool(errs)
            if not errs:
                print(f"[check_trace] {path}: ok")
        return 1 if bad else 0
    errs = self_test()
    for e in errs:
        print(f"self-test: {e}")
    print(f"[check_trace] self-test: {'FINDINGS' if errs else 'ok'}")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
